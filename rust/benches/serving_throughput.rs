//! Serving-throughput benchmark (the perf-trajectory instrument for the
//! zero-copy serving path): queries/sec of **gathered** batch scoring —
//! copy every candidate reference row out of the library panel, then run
//! a dense MVM job with a fresh output allocation, exactly what
//! `SearchEngine::score_packed` did before the bucket-contiguous layout —
//! versus **segmented** scoring (borrowed panel ranges through
//! `mvm_scores_into` with output/query buffers reused across batches), at
//! 1/2/4 worker threads. Both paths produce bit-identical scores
//! (asserted every run), so the only thing compared is host wall time.
//!
//! Also measures **single-query** (`nq = 1`) segmented serving across
//! thread counts — the dominant front-door shape, which PR 6's
//! reference-row striping fans out across workers (before PR 6 it ran
//! single-threaded at every thread count) — plus end-to-end
//! `SearchEngine::search_batch` throughput on a synthetic library, and
//! writes the machine-readable `BENCH_serving.json` next to the text
//! table so future PRs have a baseline to diff against
//! (`python/tools/bench_compare.py` diffs two such files).
//!
//! `--tiny` runs a seconds-scale smoke configuration (CI's default step);
//! the >=1.5x speedup asserts (segmented-vs-gathered at 4 threads, and
//! single-query 4-thread-vs-1-thread) are opt-in via
//! `SPECPCM_ASSERT_SPEEDUP=1` and guarded on >=4 real cores, mirroring
//! `hotpath_microbench`.

use std::ops::Range;
use std::time::Instant;

use specpcm::array::AdcConfig;
use specpcm::backend::{BackendDispatcher, MvmBackend, MvmJob, ParallelBackend};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::SearchEngine;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::{render_json_records, render_table, JsonField};
use specpcm::util::Rng;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

/// Ragged "bucket" segments over a panel: contiguous runs of 1..=max_run
/// rows separated by skipped runs, the serving shape candidate sets take
/// after bucket coalescing. Deterministic per seed.
fn ragged_segments(rng: &mut Rng, panel_rows: usize, max_run: usize) -> Vec<Range<usize>> {
    let mut segs = Vec::new();
    let mut row = 0usize;
    while row < panel_rows {
        let take = (1 + rng.below(max_run)).min(panel_rows - row);
        segs.push(row..row + take);
        row += take;
        row += 1 + rng.below(max_run); // gap
    }
    segs
}

struct Scale {
    panel_rows: usize,
    cp: usize,
    nq: usize,
    max_run: usize,
    reps: usize,
    engine_targets: usize,
    engine_queries: usize,
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let scale = if tiny {
        Scale {
            panel_rows: 512,
            cp: 256,
            nq: 4,
            max_run: 64,
            reps: 3,
            engine_targets: 40,
            engine_queries: 8,
        }
    } else {
        // nq = 4 queries/batch: small groups are the serving reality (the
        // gather the old path paid is per *batch*, not per query), and 4
        // query rows let the x4 sweep actually use 4 workers (the
        // parallel backend shards by query row).
        Scale {
            panel_rows: 6144,
            cp: 768,
            nq: 4,
            max_run: 384,
            reps: 5,
            engine_targets: 300,
            engine_queries: 64,
        }
    };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "host parallelism: {cores} logical cores{}\n",
        if tiny { " (tiny smoke scale)" } else { "" }
    );

    let mut rng = Rng::new(0x5e71);
    let panel = rand_packed(&mut rng, scale.panel_rows * scale.cp, 3);
    let segs = ragged_segments(&mut rng, scale.panel_rows, scale.max_run);
    let n_cand: usize = segs.iter().map(|s| s.len()).sum();
    let queries = rand_packed(&mut rng, scale.nq * scale.cp, 3);
    let adc = AdcConfig::new(6, 512.0);
    let (nq, cp) = (scale.nq, scale.cp);

    println!(
        "workload: {} candidate rows in {} segments of a {}-row panel, \
         cp={cp}, {} queries/batch",
        n_cand,
        segs.len(),
        scale.panel_rows,
        nq
    );

    let seg_job = MvmJob::segmented(&queries, nq, &panel, &segs, cp, adc);

    let mut rows = Vec::new();
    let mut records = Vec::new();
    let mut speedup_4t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let backend = ParallelBackend::new(threads);

        // Gathered baseline: per batch, copy candidate rows + query rows
        // into fresh buffers and run a dense job with a fresh output —
        // the pre-layout engine's per-batch behavior.
        let gathered_t = median_time(
            || {
                let mut cand_rows = Vec::with_capacity(n_cand * cp);
                for s in &segs {
                    cand_rows.extend_from_slice(&panel[s.start * cp..s.end * cp]);
                }
                let mut q_rows = Vec::with_capacity(nq * cp);
                q_rows.extend_from_slice(&queries);
                let job = MvmJob::new(&q_rows, nq, &cand_rows, n_cand, cp, adc);
                std::hint::black_box(backend.mvm_scores(&job).unwrap());
            },
            scale.reps,
        );

        // Segmented path: zero reference copies, output buffer reused
        // across batches.
        let mut out = vec![0f32; nq * n_cand];
        let segmented_t = median_time(
            || {
                backend.mvm_scores_into(&seg_job, &mut out).unwrap();
                std::hint::black_box(&out);
            },
            scale.reps,
        );

        // Both paths must agree bit-for-bit before their times mean
        // anything.
        let mut gathered_rows = Vec::new();
        for s in &segs {
            gathered_rows.extend_from_slice(&panel[s.start * cp..s.end * cp]);
        }
        let dense = MvmJob::new(&queries, nq, &gathered_rows, n_cand, cp, adc);
        assert_eq!(
            backend.mvm_scores(&dense).unwrap(),
            out,
            "segmented scoring diverged from the gathered oracle"
        );

        let qps_gathered = nq as f64 / gathered_t;
        let qps_segmented = nq as f64 / segmented_t;
        let speedup = gathered_t / segmented_t;
        if threads == 4 {
            speedup_4t = speedup;
        }
        rows.push(vec![
            format!("batch scoring x{threads}"),
            format!("{:.1}", qps_gathered),
            format!("{:.1}", qps_segmented),
            format!("{speedup:.2}x"),
        ]);
        records.push(vec![
            ("section", JsonField::S("batch_scoring".into())),
            ("threads", JsonField::U(threads as u64)),
            ("cand_rows", JsonField::U(n_cand as u64)),
            ("cp", JsonField::U(cp as u64)),
            ("queries_per_batch", JsonField::U(nq as u64)),
            ("qps_gathered", JsonField::F(qps_gathered)),
            ("qps_segmented", JsonField::F(qps_segmented)),
            ("speedup", JsonField::F(speedup)),
            ("tiny", JsonField::B(tiny)),
        ]);
    }

    // ---- Single-query serving (nq = 1, the front-door latency shape) --------
    // Before PR 6 the parallel backend could only shard query rows, so
    // this section was flat across thread counts; reference-row striping
    // splits the candidate span instead.
    let q1 = &queries[..cp];
    let q1_job = MvmJob::segmented(q1, 1, &panel, &segs, cp, adc);
    let want1 = ParallelBackend::new(1).mvm_scores(&q1_job).unwrap();
    let mut out1 = vec![0f32; n_cand];
    let mut single_qps_1t = 0.0f64;
    let mut single_speedup_4t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let backend = ParallelBackend::new(threads);
        let t = median_time(
            || {
                backend.mvm_scores_into(&q1_job, &mut out1).unwrap();
                std::hint::black_box(&out1);
            },
            scale.reps,
        );
        assert_eq!(out1, want1, "striped single-query scoring diverged");
        let qps = 1.0 / t;
        if threads == 1 {
            single_qps_1t = qps;
        }
        let speedup = qps / single_qps_1t;
        if threads == 4 {
            single_speedup_4t = speedup;
        }
        rows.push(vec![
            format!("single query x{threads}"),
            "-".into(),
            format!("{qps:.1}"),
            format!("{speedup:.2}x"),
        ]);
        records.push(vec![
            ("section", JsonField::S("single_query".into())),
            ("threads", JsonField::U(threads as u64)),
            ("cand_rows", JsonField::U(n_cand as u64)),
            ("cp", JsonField::U(cp as u64)),
            ("queries_per_batch", JsonField::U(1)),
            ("qps_segmented", JsonField::F(qps)),
            ("speedup", JsonField::F(speedup)),
            ("tiny", JsonField::B(tiny)),
        ]);
    }

    // ---- End-to-end engine serving (segmented path, informational) ----------
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate(
        "serving",
        77,
        scale.engine_targets,
        scale.engine_queries,
        0.8,
        0.2,
        0,
        0,
    );
    for threads in [1usize, 4] {
        let be = BackendDispatcher::parallel(threads);
        let engine = SearchEngine::program(cfg.clone(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let t = median_time(
            || {
                engine.clear_query_cache();
                std::hint::black_box(engine.search_batch(&queries, &be).unwrap());
            },
            scale.reps,
        );
        let qps = queries.len() as f64 / t;
        rows.push(vec![
            format!("engine search_batch x{threads}"),
            "-".into(),
            format!("{qps:.1}"),
            "-".into(),
        ]);
        records.push(vec![
            ("section", JsonField::S("engine_search_batch".into())),
            ("threads", JsonField::U(threads as u64)),
            ("n_refs", JsonField::U(engine.n_refs() as u64)),
            ("queries_per_batch", JsonField::U(queries.len() as u64)),
            ("qps_segmented", JsonField::F(qps)),
            ("tiny", JsonField::B(tiny)),
        ]);
    }

    println!(
        "{}",
        render_table(
            "serving throughput (host wall clock)",
            &["path", "gathered q/s", "segmented q/s", "speedup"],
            &rows
        )
    );

    let json = render_json_records(&records);
    let json_path = "BENCH_serving.json";
    std::fs::write(json_path, &json).expect("write BENCH_serving.json");
    println!("wrote {json_path} ({} records)", records.len());

    // Reproduction contract: with >=4 real cores, zero-copy segmented
    // serving should beat the gather-per-batch baseline by >=1.5x at 4
    // threads (the gather is serial and its memory traffic grows with the
    // candidate panel, while the segmented kernel's tiles stay hot). The
    // hard assert is opt-in (wall-clock ratios are noisy on shared
    // runners) and meaningless at tiny scale.
    let enforce = std::env::var("SPECPCM_ASSERT_SPEEDUP").as_deref() == Ok("1");
    if tiny {
        println!("tiny smoke scale: speedup assert skipped by design.");
    } else if cores >= 4 && enforce {
        assert!(
            speedup_4t > 1.5,
            "segmented serving should be >=1.5x the gathered path at 4 threads \
             (got {speedup_4t:.2}x)"
        );
        // PR 6 acceptance: striping must make single-query latency scale
        // (it was ~1.0x by construction before reference-row striping).
        assert!(
            single_speedup_4t > 1.5,
            "single-query serving should be >=1.5x at 4 threads vs 1 \
             (got {single_speedup_4t:.2}x)"
        );
        println!(
            "shape check OK: segmented = {speedup_4t:.2}x gathered at 4 threads; \
             single query = {single_speedup_4t:.2}x its 1-thread time."
        );
    } else if cores >= 4 {
        println!(
            "shape check (informational; SPECPCM_ASSERT_SPEEDUP=1 to enforce): \
             segmented = {speedup_4t:.2}x gathered at 4 threads; \
             single query = {single_speedup_4t:.2}x its 1-thread time."
        );
    } else {
        println!("shape check skipped: only {cores} cores available.");
    }
}
