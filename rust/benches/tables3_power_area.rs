//! Table S3: per-component power and area at 40 nm / 500 MHz, plus the
//! derived per-operation energies the accelerator model charges.

use specpcm::device::Material;
use specpcm::energy::{components::COMPONENTS, EnergyLatencyModel};
use specpcm::telemetry::render_table;

fn main() {
    let rows: Vec<Vec<String>> = COMPONENTS
        .iter()
        .map(|c| {
            vec![
                c.name.to_string(),
                c.unit_power_uw.map_or("-".into(), |v| format!("{v}")),
                c.unit_area_um2.map_or("-".into(), |v| format!("{v}")),
                format!("{}", c.units_per_bank),
                format!("{:.2}", c.total_power_mw),
                format!("{:.4}", c.total_area_mm2),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            "Table S3 — component power/area per bank (40 nm, 500 MHz)",
            &["component", "unit uW", "unit um2", "units", "total mW", "total mm2"],
            &rows
        )
    );

    let p: f64 = COMPONENTS.iter().map(|c| c.total_power_mw).sum();
    let a: f64 = COMPONENTS.iter().map(|c| c.total_area_mm2).sum();
    println!("totals: {p:.2} mW, {a:.4} mm2 (paper: 15.59 mW, 0.0402 mm2)");
    assert!((p - 15.59).abs() < 1e-9 && (a - 0.0402).abs() < 1e-9);

    // Derived per-op energies used by every pipeline run.
    let mut rows = Vec::new();
    for material in Material::ALL {
        for adc_bits in [6u32, 4] {
            let m = EnergyLatencyModel::new(material, adc_bits, 1);
            rows.push(vec![
                material.name().to_string(),
                format!("{adc_bits}"),
                format!("{:.3}", m.mvm_op_j() * 1e9),
                format!("{:.3}", m.program_round_j() * 1e9),
                format!("{:.3}", m.row_read_j() * 1e12),
            ]);
        }
    }
    println!(
        "{}",
        render_table(
            "derived per-operation energies",
            &["material", "ADC bits", "MVM nJ", "program-round nJ", "row-read pJ"],
            &rows
        )
    );
}
