//! Fig. 3: latency breakdown of the software HD tools — the measurement
//! that motivates SpecPCM. (a) HyperSpec-like clustering: distance
//! calculation dominates; (b) HyperOMS-like DB search: Hamming similarity
//! search dominates. Both are measured here by instrumenting the actual
//! software baselines on this host.
//!
//! Expected shape: the matrix stage (distance calc / similarity search)
//! takes the majority of the runtime — the paper reports >60%.

use std::time::Instant;

use specpcm::baselines::hd_soft;
use specpcm::cluster::complete_linkage;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::HdFrontend;
use specpcm::hd;
use specpcm::ms::{bucket_by_precursor, ClusteringDataset, SearchDataset, Spectrum};
use specpcm::telemetry::render_table;

fn main() {
    // ---- (a) clustering breakdown ------------------------------------------
    // Real MassIVE-scale buckets hold thousands of co-eluting spectra; at
    // bench scale we widen the precursor window so bucket sizes (and hence
    // the pairwise distance work) are representative of the regime the
    // paper profiles.
    let cfg = SpecPcmConfig {
        bucket_width: 400.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let ds = ClusteringDataset::pxd000561_like(cfg.seed, 0.35);
    let fe = HdFrontend::new(&cfg);

    let t0 = Instant::now();
    let all: Vec<&Spectrum> = ds.spectra.iter().collect();
    let levels = fe.levels_of(&all);
    let preprocess_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let hvs: Vec<hd::Hv> = levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let encode_s = t0.elapsed().as_secs_f64();

    let buckets = bucket_by_precursor(&ds.spectra, cfg.bucket_width);
    let (mut dist_s, mut merge_s) = (0.0f64, 0.0f64);
    for members in buckets.values() {
        if members.len() < 2 {
            continue;
        }
        let local: Vec<hd::Hv> = members.iter().map(|&i| hvs[i].clone()).collect();
        let t0 = Instant::now();
        let m = hd_soft::distance_matrix(&local);
        dist_s += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let _ = complete_linkage(&m, local.len(), 0.8);
        merge_s += t0.elapsed().as_secs_f64();
    }

    let total = preprocess_s + encode_s + dist_s + merge_s;
    let rows = vec![
        vec!["preprocess".into(), format!("{preprocess_s:.3}s"), format!("{:.1}%", 100.0 * preprocess_s / total)],
        vec!["HD encode".into(), format!("{encode_s:.3}s"), format!("{:.1}%", 100.0 * encode_s / total)],
        vec!["distance calculation".into(), format!("{dist_s:.3}s"), format!("{:.1}%", 100.0 * dist_s / total)],
        vec!["cluster merge".into(), format!("{merge_s:.3}s"), format!("{:.1}%", 100.0 * merge_s / total)],
    ];
    println!(
        "{}",
        render_table(
            "Fig. 3(a) — HyperSpec-like clustering latency breakdown (this host)",
            &["stage", "time", "fraction"],
            &rows
        )
    );
    let dist_frac = dist_s / total;

    // ---- (b) DB-search breakdown -------------------------------------------
    let cfg = SpecPcmConfig {
        hd_dim: 4096,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::hek293_like(cfg.seed, 0.35);
    let fe = HdFrontend::new(&cfg);

    let all_refs: Vec<&Spectrum> = ds.library.iter().chain(ds.decoys.iter()).collect();
    let t0 = Instant::now();
    let ref_levels = fe.levels_of(&all_refs);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let q_levels = fe.levels_of(&queries);
    let preprocess_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let ref_hvs: Vec<hd::Hv> = ref_levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let q_hvs: Vec<hd::Hv> = q_levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let encode_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let ref_bits = hd_soft::pack_refs(&ref_hvs);
    let mut best = Vec::with_capacity(q_hvs.len());
    for q in &q_hvs {
        let scores = hd_soft::search_scores(q, &ref_bits);
        let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        best.push(m);
    }
    let sim_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let pairs: Vec<(f32, f32)> = best.iter().map(|&b| (b, b * 0.5)).collect();
    let _ = specpcm::search::fdr_filter(&pairs, cfg.fdr);
    let filter_s = t0.elapsed().as_secs_f64().max(1e-6);

    let total = preprocess_s + encode_s + sim_s + filter_s;
    let rows = vec![
        vec!["preprocess".into(), format!("{preprocess_s:.3}s"), format!("{:.1}%", 100.0 * preprocess_s / total)],
        vec!["HD encode".into(), format!("{encode_s:.3}s"), format!("{:.1}%", 100.0 * encode_s / total)],
        vec!["Hamming similarity search".into(), format!("{sim_s:.3}s"), format!("{:.1}%", 100.0 * sim_s / total)],
        vec!["FDR filter".into(), format!("{filter_s:.3}s"), format!("{:.1}%", 100.0 * filter_s / total)],
    ];
    println!(
        "{}",
        render_table(
            "Fig. 3(b) — HyperOMS-like DB-search latency breakdown (this host)",
            &["stage", "time", "fraction"],
            &rows
        )
    );

    let sim_frac = sim_s / total;
    assert!(
        dist_frac > 0.4,
        "distance calc dominates clustering: {:.1}%",
        dist_frac * 100.0
    );
    assert!(
        sim_frac > 0.4,
        "similarity search dominates DB search: {:.1}%",
        sim_frac * 100.0
    );
    println!(
        "shape check OK: matrix stages dominate ({:.0}% / {:.0}%) — the operations\n\
         SpecPCM offloads to the PCM arrays (paper: >60%).",
        dist_frac * 100.0,
        sim_frac * 100.0
    );
}
