//! Remote-worker serving benchmark (the closed-loop instrument for
//! `coordinator::remote`): queries/sec of serving chunked query batches
//! through supervised per-shard **worker processes**, against the
//! in-process sharded engine as the zero-overhead baseline
//! (`workers = 0` records), sweeping worker count and injected chaos —
//! **none** (the pure wire/process-boundary overhead), **kill** (a
//! seeded worker kill that the retry/respawn machinery must absorb;
//! results are asserted bit-identical to in-process serving before the
//! time means anything), and **degrade** (retry budget zero, so the
//! killed shard degrades coverage instead of recovering — the reported
//! worst-batch coverage must drop below 1.0, proving degradation is
//! visible, never silent).
//!
//! Writes the machine-readable `BENCH_remote.json` next to the text
//! table (`python/tools/bench_compare.py` diffs two such files, keyed by
//! section/workers/chaos).
//!
//! `--tiny` runs a seconds-scale smoke configuration (CI's default
//! step; CI greps the DEGRADED line as its chaos smoke check).

use std::time::Instant;

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{
    BatchOutcome, ChaosEvent, ChaosKind, ChaosPlan, RemoteEngine, ShardedSearchEngine,
};
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::{render_json_records, render_table, JsonField};

/// The serving binary whose hidden `worker` subcommand the supervisor
/// spawns (cargo sets this for bench builds exactly like test builds).
const EXE: &str = env!("CARGO_BIN_EXE_specpcm");

struct Scale {
    targets: usize,
    queries: usize,
    reps: usize,
    worker_counts: &'static [usize],
}

/// One chaos mode of the sweep.
struct Mode {
    name: &'static str,
    /// Retry budget override (None = config default of 3).
    retries: Option<u32>,
    kill_shard: Option<usize>,
}

fn modes() -> Vec<Mode> {
    vec![
        Mode {
            name: "none",
            retries: None,
            kill_shard: None,
        },
        Mode {
            name: "kill",
            retries: None,
            kill_shard: Some(0),
        },
        Mode {
            name: "degrade",
            retries: Some(0),
            kill_shard: Some(1),
        },
    ]
}

fn chaos_for(mode: &Mode) -> ChaosPlan {
    match mode.kill_shard {
        Some(shard) => ChaosPlan::new(vec![ChaosEvent {
            // Fires at the victim's first score attempt.
            tick: 1,
            shard,
            kind: ChaosKind::Kill,
        }]),
        None => ChaosPlan::none(),
    }
}

fn worst_coverage(batches: &[BatchOutcome]) -> f64 {
    batches
        .iter()
        .map(|b| b.coverage.fraction())
        .fold(1.0f64, f64::min)
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let scale = if tiny {
        // Same worker-count cells as the full run (the compare tool
        // hard-fails on baseline keys missing from the current file, and
        // a small CI runner only ever produces the tiny file) — only the
        // workload shrinks.
        Scale {
            targets: 40,
            queries: 24,
            reps: 2,
            worker_counts: &[2, 4],
        }
    } else {
        Scale {
            targets: 300,
            queries: 96,
            reps: 3,
            worker_counts: &[2, 4],
        }
    };
    let n_batches = 4usize;
    println!(
        "remote-worker serving bench{}\n",
        if tiny { " (tiny smoke scale)" } else { "" }
    );

    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate(
        "remote",
        91,
        scale.targets,
        scale.queries,
        0.8,
        0.2,
        0,
        0,
    );
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::from_config(&cfg);

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for &workers in scale.worker_counts {
        // In-process sharded serving at the same shard count: the
        // zero-process-boundary baseline (workers = 0 in the JSON key)
        // and the bit-identity oracle for the recovered chaos modes.
        let sharded = ShardedSearchEngine::program(cfg.clone(), &ds, &be, workers).unwrap();
        let oracle = sharded.serve_chunked(&queries, n_batches, &be).unwrap();
        let mut in_process_times: Vec<f64> = (0..=scale.reps)
            .map(|_| {
                let t0 = Instant::now();
                std::hint::black_box(sharded.serve_chunked(&queries, n_batches, &be).unwrap());
                t0.elapsed().as_secs_f64()
            })
            .collect();
        in_process_times.remove(0); // warmup
        in_process_times.sort_by(f64::total_cmp);
        let qps_in_process =
            queries.len() as f64 / in_process_times[in_process_times.len() / 2];
        rows.push(vec![
            format!("in-process x{workers}"),
            format!("{qps_in_process:.1}"),
            "0".into(),
            "0".into(),
            "100%".into(),
        ]);
        records.push(vec![
            ("section", JsonField::S("serving_remote".into())),
            ("workers", JsonField::U(0)),
            ("chaos", JsonField::S(format!("in-process-x{workers}"))),
            ("requests", JsonField::U(queries.len() as u64)),
            ("qps_served", JsonField::F(qps_in_process)),
            ("retries", JsonField::U(0)),
            ("respawns", JsonField::U(0)),
            ("worst_coverage", JsonField::F(1.0)),
            ("tiny", JsonField::B(tiny)),
        ]);

        for mode in modes() {
            let mut c = cfg.clone();
            if let Some(r) = mode.retries {
                c.remote.retries = r;
                c.remote.breaker_threshold = 1;
            }
            // Chaos plans are consumed as their events fire, so every rep
            // programs a fresh supervisor (spawn/program cost is outside
            // the timed serving window).
            let mut times = Vec::with_capacity(scale.reps);
            let mut last = None;
            for rep in 0..=scale.reps {
                let engine =
                    RemoteEngine::program(c.clone(), &ds, workers, EXE, chaos_for(&mode))
                        .unwrap();
                let t0 = Instant::now();
                let out = engine.serve_chunked(&queries, n_batches, &be).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                if rep > 0 {
                    times.push(dt); // rep 0 is warmup
                }
                last = Some((engine.worker_stats(), out));
            }
            let (stats, out) = last.unwrap();
            times.sort_by(f64::total_cmp);
            let qps = queries.len() as f64 / times[times.len() / 2];
            let coverage = worst_coverage(&out);

            if mode.name == "degrade" {
                // The whole point of the mode: degradation must be
                // *reported*, not silently absorbed into full results.
                assert!(
                    coverage < 1.0,
                    "degrade mode served full coverage — chaos never fired?"
                );
                assert!(stats.degraded_batches > 0);
                println!(
                    "chaos smoke: DEGRADED coverage reported, worst batch {:.1}% \
                     ({} degraded batches, breaker open on the dead shard)",
                    coverage * 100.0,
                    stats.degraded_batches
                );
            } else {
                // Recovered modes are bit-identical to in-process serving.
                assert_eq!(out.len(), oracle.len());
                for (r, s) in out.iter().zip(&oracle) {
                    assert_eq!(r.pairs, s.pairs, "{}: pairs diverged", mode.name);
                    assert_eq!(r.matched, s.matched, "{}: matches diverged", mode.name);
                    assert_eq!(r.ops, s.ops, "{}: marginal ops diverged", mode.name);
                    assert!(r.coverage.is_full(), "{}: coverage dropped", mode.name);
                }
            }

            rows.push(vec![
                format!("{} x{workers}", mode.name),
                format!("{qps:.1}"),
                format!("{}", stats.retries),
                format!("{}", stats.respawns),
                format!("{:.0}%", coverage * 100.0),
            ]);
            records.push(vec![
                ("section", JsonField::S("serving_remote".into())),
                ("workers", JsonField::U(workers as u64)),
                ("chaos", JsonField::S(mode.name.into())),
                ("requests", JsonField::U(queries.len() as u64)),
                ("qps_served", JsonField::F(qps)),
                ("retries", JsonField::U(stats.retries)),
                ("respawns", JsonField::U(stats.respawns)),
                ("worst_coverage", JsonField::F(coverage)),
                ("tiny", JsonField::B(tiny)),
            ]);
        }
    }

    println!(
        "{}",
        render_table(
            "remote-worker serving throughput (host wall clock)",
            &["mode", "served q/s", "retries", "respawns", "worst coverage"],
            &rows
        )
    );

    let json = render_json_records(&records);
    let json_path = "BENCH_remote.json";
    std::fs::write(json_path, &json).expect("write BENCH_remote.json");
    println!("wrote {json_path} ({} records)", records.len());
}
