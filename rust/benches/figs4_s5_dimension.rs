//! Figs. S4/S5: quality vs HD dimension. S4 — DB-search identifications;
//! S5 — clustering quality. Expected shape: monotone-ish improvement with
//! D, saturating near the paper defaults (8192 search / 2048 clustering);
//! storage, energy and latency grow ~linearly with D.

use specpcm::backend::BackendDispatcher;
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchPipeline};
use specpcm::ms::{ClusteringDataset, SearchDataset};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    // ---- Fig. S4: search quality vs dimension ------------------------------
    let sbase = SpecPcmConfig::paper_search();
    let backend = BackendDispatcher::from_config(&sbase);
    let sds = SearchDataset::iprg2012_like(sbase.seed, 0.3);
    let mut rows = Vec::new();
    let mut ids = Vec::new();
    let mut margins = Vec::new();
    for d in [512usize, 1024, 2048, 4096, 8192] {
        let cfg = SpecPcmConfig { hd_dim: d, ..sbase.clone() };
        let out = SearchPipeline::new(cfg).run(&sds, &backend)?;
        ids.push(out.correct);
        margins.push(out.mean_margin());
        rows.push(vec![
            format!("{d}"),
            format!("{}", out.correct),
            format!("{:.4}", out.mean_margin()),
            format!("{}", out.ops.mvm_ops),
            format!("{:.4}", out.report.total_j() * 1e3),
            format!("{:.4}", out.report.overlapped_latency_s() * 1e3),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Fig. S4 — DB-search quality vs HD dimension",
            &["D", "identified", "margin", "MVM ops", "energy mJ", "latency ms"],
            &rows
        )
    );

    // ---- Fig. S5: clustering quality vs dimension --------------------------
    let cbase = SpecPcmConfig {
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let cds = ClusteringDataset::pxd001468_like(cbase.seed, 0.3);
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for d in [512usize, 1024, 2048, 4096] {
        let cfg = SpecPcmConfig { hd_dim: d, ..cbase.clone() };
        let out = ClusteringPipeline::new(cfg).run(&cds, &backend)?;
        let q = clustered_at_incorrect(&out.curve, 0.015);
        ratios.push(q);
        rows.push(vec![
            format!("{d}"),
            format!("{q:.4}"),
            format!("{}", out.ops.mvm_ops),
            format!("{:.4}", out.report.total_j() * 1e3),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Fig. S5 — clustering quality vs HD dimension",
            &["D", "clustered ratio @1.5%", "MVM ops", "energy mJ"],
            &rows
        )
    );

    // Shape checks: the identification count is noisy at bench scale, so
    // the monotone signal is the target/decoy score margin — it must grow
    // with D (paper Fig. S4's mechanism); small D must also identify less
    // than the best D.
    assert!(
        margins.windows(2).all(|w| w[1] >= w[0] - 0.02),
        "margin grows with D: {margins:?}"
    );
    assert!(
        *margins.last().unwrap() > margins[0] + 0.1,
        "margin clearly better at large D: {margins:?}"
    );
    assert!(
        ids[0] < *ids.iter().max().unwrap(),
        "tiny D is not the best: {ids:?}"
    );
    assert!(
        ratios.last().unwrap() + 0.05 >= ratios[0],
        "clustering quality non-degrading in D: {ratios:?}"
    );
    println!("shape check OK: quality saturates with D; cost grows ~linearly.");
    Ok(())
}
