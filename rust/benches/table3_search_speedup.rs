//! Table 3: DB-search latency/speedup vs prior tools (ANN-SoLo, HyperOMS,
//! RRAM- and 3D-NAND-based IMC). Baselines are the paper's published
//! measurements (DESIGN.md §5); SpecPCM latency/energy are simulated here
//! on a scaled synthetic workload and extrapolated linearly in query count.
//!
//! Reproduction targets: SpecPCM fastest (beating the prior IMC designs),
//! speedups in the ~1e2 range vs the CPU-GPU baseline, and the §IV-B
//! energy claim (0.149 J per HEK293 subset scale, 4 orders vs GPU).

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::latency_model::{paper_speedup, search_for};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{SearchEngine, SearchPipeline, ShardedSearchEngine};
use specpcm::energy::GpuEnvelope;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    // Paper hardware config (128 banks). The engine enforces bank capacity:
    // D=8192 n=3 packs to 22 segments -> 5 groups x 128 = 640 reference
    // slots per engine, so the monolithic Table 3 rows below run the
    // HEK293-like synthetic subset at scale 0.2 (320 targets + 320 decoys
    // = 640 rows) — the latency extrapolation normalizes per query, so the
    // reproduced numbers keep modeling the paper's 128-bank accelerator.
    // The sharded section at the end serves the bigger 0.3-scale subset by
    // splitting it across two 128-bank engines instead of shrinking it.
    let cfg = SpecPcmConfig::paper_search();
    let backend = BackendDispatcher::from_config(&cfg);

    for (preset, dataset) in [
        (SearchDataset::iprg2012_like(cfg.seed, 0.3), "iPRG2012"),
        (SearchDataset::hek293_like(cfg.seed, 0.2), "HEK293"),
    ] {
        let out = SearchPipeline::new(cfg.clone()).run(&preset, &backend)?;
        // Extrapolate to paper scale. Per-query IMC work is proportional to
        // the *candidate rows per query* (precursor bucketing, Fig. 2), not
        // the whole library: at paper scale a query touches its standard
        // window plus one window per PTM shift — 3 + 4*3 = 15 one-Da
        // windows — over a library spread across ~1000 Da of precursor m/z.
        // We measure our candidate rows/query from the op counts and scale
        // to that. (Cross-check: this predicts ~0.1 J for a HEK293 subset —
        // the paper reports 0.149 J.)
        let segments = (specpcm::hd::padded_packed_len(cfg.hd_dim, cfg.packing()) / 128) as f64;
        let our_cand_per_query =
            out.ops.mvm_ops as f64 * 128.0 / (segments * preset.queries.len() as f64);
        let paper_windows = 15.0; // 3 standard + 3 per PTM shift (4 shifts)
        let paper_mass_range_da = 1000.0;
        let paper_cand_per_query =
            paper_windows * preset.paper_library as f64 / paper_mass_range_da;
        let scale = (preset.paper_queries as f64 / preset.queries.len() as f64)
            * (paper_cand_per_query / our_cand_per_query);
        let sim_latency = out.report.imc_latency_s * scale + out.report.program_latency_s;
        let sim_energy = out.report.total_j() * scale;

        let baselines = search_for(dataset);
        let base = baselines[0].latency_s;
        let mut rows: Vec<Vec<String>> = baselines
            .iter()
            .map(|b| {
                vec![
                    b.tool.to_string(),
                    b.hardware.to_string(),
                    format!("{:.3}s", b.latency_s),
                    format!("{:.1}x", base / b.latency_s),
                ]
            })
            .collect();
        rows.push(vec![
            "SpecPCM (this repo, simulated)".into(),
            "sim 40nm".into(),
            format!("{sim_latency:.3}s"),
            format!("{:.1}x", base / sim_latency),
        ]);

        println!(
            "{}",
            render_table(
                &format!(
                    "Table 3 — DB-search speedup ({dataset}, {} synth queries x{scale:.0})",
                    preset.queries.len()
                ),
                &["tool", "hardware", "latency", "speedup"],
                &rows
            )
        );

        let gpu = GpuEnvelope::default();
        let hyperoms = baselines
            .iter()
            .find(|b| b.tool == "HyperOMS")
            .unwrap()
            .latency_s;
        println!(
            "energy: simulated SpecPCM {:.4} J vs GPU envelope {:.0} J -> {:.0e}x \
             (paper: 0.149 J per HEK293 subset, four orders of magnitude)\n",
            sim_energy,
            gpu.energy_j(hyperoms),
            gpu.energy_j(hyperoms) / sim_energy.max(1e-12),
        );

        let paper_x = paper_speedup(dataset, "SpecPCM(paper)").unwrap();
        let ours_x = base / sim_latency;
        assert!(
            ours_x > 10.0,
            "{dataset}: simulated SpecPCM >10x the slowest baseline (got {ours_x:.1})"
        );
        if dataset == "iPRG2012" {
            // Prior IMC comparison: SpecPCM must beat RRAM and 3D NAND.
            let rram = baselines.iter().find(|b| b.tool == "RRAM").unwrap().latency_s;
            let nand = baselines.iter().find(|b| b.tool == "3D NAND").unwrap().latency_s;
            assert!(
                sim_latency < rram && sim_latency < nand,
                "SpecPCM beats prior IMC: {sim_latency:.3}s vs RRAM {rram}s / NAND {nand}s"
            );
        }
        assert!(gpu.energy_j(hyperoms) / sim_energy > 1e3);
        println!(
            "shape check OK: ours {ours_x:.0}x vs paper {paper_x:.0}x (same order; \
             absolute differs — simulator + synthetic data)\n"
        );
    }

    // ---- program-once serving (the Table 3 deployment shape) ---------------
    // The persistent engine charges library encode+program exactly once;
    // only the marginal per-batch query cost repeats. A pipeline re-run
    // would pay the one-time column again on every sweep iteration.
    let ds = SearchDataset::iprg2012_like(cfg.seed, 0.3);
    let engine = SearchEngine::program(cfg.clone(), &ds, &backend)?;
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let outcomes = engine.serve_chunked(&queries, 4, &backend)?;
    let cost = engine.serving_cost(&outcomes);
    let one_shot = SearchPipeline::new(cfg.clone()).run(&ds, &backend)?;
    let served = engine.finalize(&queries, &outcomes)?;
    assert_eq!(served.pairs, one_shot.pairs, "serving is bit-identical");
    assert!(
        outcomes.iter().all(|b| b.ops.program_rounds == 0),
        "marginal batches must not re-pay programming"
    );
    assert_eq!(
        engine.program_ops().program_rounds,
        one_shot.ops.program_rounds,
        "programming charged exactly once"
    );
    println!(
        "serving check OK (iPRG2012, {} batches): one-time program {:.4} mJ, \
         marginal queries {:.4} mJ ({:.4} mJ amortized/batch) — pipeline \
         re-runs would pay the one-time column again every sweep",
        cost.n_batches,
        cost.one_time_j * 1e3,
        cost.marginal_j * 1e3,
        cost.amortized_j_per_batch() * 1e3
    );

    // ---- sharded serving: HEK293 beyond one engine's capacity --------------
    // 0.3-scale HEK293 needs 480 targets + 480 decoys = 960 reference rows
    // vs 640 slots per 128-bank engine: the shard layer auto-splits it
    // across two engines and fans each batch out concurrently. The
    // contract — also locked in by rust/tests/engine_equivalence.rs — is
    // bit-identical results *and* identical total simulated ASIC work vs
    // one monolithic engine owning the union pool (256 banks).
    let big = SearchDataset::hek293_like(cfg.seed, 0.3);
    let sharded = ShardedSearchEngine::program(cfg.clone(), &big, &backend, 0)?;
    assert_eq!(sharded.n_shards(), 2, "960 rows over 640-slot engines");
    let big_queries: Vec<&Spectrum> = big.queries.iter().collect();
    let big_outcomes = sharded.serve_chunked(&big_queries, 4, &backend)?;
    let big_cost = sharded.serving_cost(&big_outcomes);
    let served_big = sharded.finalize(&big_queries, &big_outcomes)?;

    let union_cfg = SpecPcmConfig {
        num_banks: cfg.num_banks * sharded.n_shards(),
        ..cfg
    };
    let mono_big = SearchPipeline::new(union_cfg).run(&big, &backend)?;
    assert_eq!(served_big.pairs, mono_big.pairs, "sharded == monolithic");
    assert_eq!(
        served_big.ops, mono_big.ops,
        "sharding must not change total simulated ASIC work"
    );
    println!(
        "shard check OK (HEK293 x0.3, {} shards x {} banks): {} rows served \
         bit-identically to one {}-bank engine; one-time program {:.4} mJ, \
         marginal {:.4} mJ over {} fan-out batches",
        sharded.n_shards(),
        sharded.total_banks() / sharded.n_shards(),
        sharded.n_refs(),
        sharded.total_banks(),
        big_cost.one_time_j * 1e3,
        big_cost.marginal_j * 1e3,
        big_cost.n_batches
    );
    Ok(())
}
