//! Fig. 10: DB-search quality on HEK293-like subsets — identified peptides
//! per subset for SpectraST-like (standard search only: misses modified
//! peptides), HyperOMS-like (exact binary HD, open search), ANN-SoLo-like
//! (exact cosine, open search) and SpecPCM (MLC3 + PCM noise, open search).
//!
//! Expected shape: ANN-SoLo highest, SpecPCM comparable to HyperOMS,
//! SpectraST lowest (no open-modification hits).

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::{exact, hd_soft, levels_to_f32};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{HdFrontend, SearchPipeline};
use specpcm::hd;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::search::fdr_filter;
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

/// Baseline identification with optional open-modification candidate
/// windows (SpectraST-like turns them off).
fn identify(
    scores: &dyn Fn(usize) -> Vec<f32>,
    ds: &SearchDataset,
    open_search: bool,
    fdr: f64,
) -> usize {
    let nt = ds.library.len();
    let mut pairs = Vec::new();
    let mut matched = Vec::new();
    for (qi, q) in ds.queries.iter().enumerate() {
        // SpectraST-like: only consider candidates in the standard
        // precursor window; a modified query's precursor is shifted, so its
        // true peptide is out of window.
        let allowed = |r: &Spectrum| {
            open_search || (r.precursor_mz - q.precursor_mz).abs() < 2.5
        };
        let row = scores(qi);
        let (mut ts, mut ti, mut dsc) = (f32::NEG_INFINITY, None, f32::NEG_INFINITY);
        for (ri, &s) in row.iter().enumerate() {
            let spec = if ri < nt { &ds.library[ri] } else { &ds.decoys[ri - nt] };
            if !allowed(spec) {
                continue;
            }
            if ri < nt {
                if s > ts {
                    ts = s;
                    ti = spec.peptide_id;
                }
            } else if s > dsc {
                dsc = s;
            }
        }
        pairs.push((ts, dsc));
        matched.push(ti);
    }
    let r = fdr_filter(&pairs, fdr);
    r.accepted
        .iter()
        .filter(|&&qi| matched[qi].is_some() && matched[qi] == ds.queries[qi].peptide_id)
        .count()
}

fn main() -> Result<()> {
    let cfg = SpecPcmConfig {
        hd_dim: 2048, // bench-speed dimension; shape matches D=8192
        ..SpecPcmConfig::paper_search()
    };
    let backend = BackendDispatcher::from_config(&cfg);

    // Four HEK293-like subsets (the paper uses b1906..b1931).
    let mut rows = Vec::new();
    let mut sums = [0usize; 4];
    for (_si, seed) in [1906u64, 1915, 1924, 1931].iter().enumerate() {
        let ds = SearchDataset::hek293_like(*seed, 0.18);
        let fe = HdFrontend::new(&cfg);
        let all_refs: Vec<&Spectrum> = ds.library.iter().chain(ds.decoys.iter()).collect();
        let ref_levels = fe.levels_of(&all_refs);
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let q_levels = fe.levels_of(&queries);

        let ref_floats: Vec<Vec<f32>> = ref_levels.iter().map(|l| levels_to_f32(l)).collect();
        let cosine_scores =
            |qi: usize| exact::search_scores(&levels_to_f32(&q_levels[qi]), &ref_floats);
        // ANN-SoLo's open-mod scoring aligns by candidate PTM deltas
        // (shifted dot product); deltas in bins of the 512-bin vector.
        let bin_w = (1900.0 - 100.0) / 512.0;
        let shifts: Vec<i64> = specpcm::ms::synth::PTM_SHIFTS
            .iter()
            .map(|&d| (d / bin_w).round() as i64)
            .collect();
        let annsolo_scores = |qi: usize| {
            exact::search_scores_shifted(&levels_to_f32(&q_levels[qi]), &ref_floats, &shifts)
        };
        let ref_hvs: Vec<hd::Hv> = ref_levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
        let ref_bits = hd_soft::pack_refs(&ref_hvs);
        let hd_scores =
            |qi: usize| hd_soft::search_scores(&hd::encode(&q_levels[qi], &fe.im), &ref_bits);

        let spectrast = identify(&cosine_scores, &ds, false, cfg.fdr);
        let annsolo = identify(&annsolo_scores, &ds, true, cfg.fdr);
        let hyperoms = identify(&hd_scores, &ds, true, cfg.fdr);
        let spec = SearchPipeline::new(cfg.clone()).run(&ds, &backend)?;

        sums[0] += spectrast;
        sums[1] += hyperoms;
        sums[2] += annsolo;
        sums[3] += spec.correct;
        rows.push(vec![
            format!("b{seed}-like"),
            format!("{spectrast}"),
            format!("{hyperoms}"),
            format!("{annsolo}"),
            format!("{}", spec.correct),
        ]);
    }
    rows.push(vec![
        "TOTAL".into(),
        format!("{}", sums[0]),
        format!("{}", sums[1]),
        format!("{}", sums[2]),
        format!("{}", sums[3]),
    ]);

    println!(
        "{}",
        render_table(
            "Fig. 10 — identified peptides per HEK293-like subset (1% FDR)",
            &["subset", "SpectraST-like", "HyperOMS-like", "ANN-SoLo-like", "SpecPCM"],
            &rows
        )
    );

    assert!(sums[2] >= sums[1], "ANN-SoLo >= HyperOMS");
    assert!(sums[1] > sums[0], "open search beats standard-only SpectraST");
    assert!(
        sums[3] as f64 > 0.7 * sums[1] as f64,
        "SpecPCM comparable to HyperOMS: {} vs {}",
        sums[3],
        sums[1]
    );
    println!(
        "shape check OK: ANN-SoLo highest, SpecPCM ~ HyperOMS, SpectraST lowest\n\
         (paper Fig. 10 ordering)."
    );
    Ok(())
}
