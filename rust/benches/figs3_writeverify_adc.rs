//! Fig. S3: accuracy/efficiency trade-offs. (a) quality vs write-verify
//! cycles for both pipelines; (b) quality vs ADC bit precision.
//!
//! Expected shapes: clustering quality is flat in write-verify (why the
//! default uses none); search quality improves then saturates; quality
//! degrades gracefully as ADC precision drops, with 4-bit close to 6-bit.

use specpcm::backend::BackendDispatcher;
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchPipeline};
use specpcm::energy::EnergyLatencyModel;
use specpcm::ms::{ClusteringDataset, SearchDataset};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    let cbase = SpecPcmConfig {
        hd_dim: 1024, // bench-speed dimensions; shapes carry
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let sbase = SpecPcmConfig {
        hd_dim: 2048,
        ..SpecPcmConfig::paper_search()
    };
    let cds = ClusteringDataset::pxd001468_like(cbase.seed, 0.3);
    let sds = SearchDataset::iprg2012_like(sbase.seed, 0.3);
    let backend = BackendDispatcher::from_config(&cbase);

    // ---- (a) write-verify sweep -------------------------------------------
    let mut rows = Vec::new();
    let mut cluster_q = Vec::new();
    let mut search_q = Vec::new();
    let mut margins = Vec::new();
    for wv in [0u32, 1, 2, 3, 4, 6] {
        let c = ClusteringPipeline::new(SpecPcmConfig { write_verify: wv, ..cbase.clone() })
            .run(&cds, &backend)?;
        let s = SearchPipeline::new(SpecPcmConfig { write_verify: wv, ..sbase.clone() })
            .run(&sds, &backend)?;
        let cq = clustered_at_incorrect(&c.curve, 0.015);
        cluster_q.push(cq);
        search_q.push(s.correct);
        margins.push(s.mean_margin());
        rows.push(vec![
            format!("{wv}"),
            format!("{cq:.4}"),
            format!("{}", s.correct),
            format!("{:.4}", s.mean_margin()),
            format!("{:.4}", c.report.program_latency_s * 1e3),
            format!("{:.4}", s.report.program_latency_s * 1e3),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Fig. S3(a) — quality vs write-verify cycles",
            &["write-verify", "cluster ratio @1.5%", "search IDs", "score margin", "cluster prog ms", "search prog ms"],
            &rows
        )
    );

    // Shape: clustering flat (max-min small); identification counts have
    // headroom on this workload, so the fine-grained noise signal is the
    // target/decoy score margin — it must improve with write-verify, and
    // programming latency must grow.
    let cmin = cluster_q.iter().copied().fold(f64::INFINITY, f64::min);
    let cmax = cluster_q.iter().copied().fold(0.0f64, f64::max);
    assert!(
        cmax - cmin < 0.1,
        "clustering insensitive to write-verify: {cluster_q:?}"
    );
    assert!(
        *search_q.last().unwrap() as f64 >= 0.9 * search_q[0] as f64,
        "search quality never degrades with write-verify: {search_q:?}"
    );
    // At this synthetic scale HD absorbs the residual PCM error entirely, so
    // both the identification count and the margin sit at their noise floor
    // (the paper's Fig. S3(a) search curve also saturates after ~3 cycles);
    // the underlying BER-vs-write-verify improvement is asserted device-
    // level by the fig7_ber_writeverify bench. Here: no degradation.
    assert!(
        *margins.last().unwrap() > margins[0] - 0.01,
        "score margin never degrades with write-verify: {margins:?}"
    );

    // ---- (b) ADC precision sweep -------------------------------------------
    let mut rows = Vec::new();
    let mut adc_q = Vec::new();
    for adc in [6u32, 5, 4, 3, 2, 1] {
        let c = ClusteringPipeline::new(SpecPcmConfig { adc_bits: adc, ..cbase.clone() })
            .run(&cds, &backend)?;
        let s = SearchPipeline::new(SpecPcmConfig { adc_bits: adc, ..sbase.clone() })
            .run(&sds, &backend)?;
        let cq = clustered_at_incorrect(&c.curve, 0.015);
        adc_q.push((adc, cq, s.correct));
        let m = EnergyLatencyModel::new(sbase.material, adc, sbase.num_banks);
        rows.push(vec![
            format!("{adc}"),
            format!("{cq:.4}"),
            format!("{}", s.correct),
            format!("{:.3}", m.adc_energy_scale()),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Fig. S3(b) — quality vs ADC precision",
            &["ADC bits", "cluster ratio @1.5%", "search IDs", "ADC energy scale"],
            &rows
        )
    );

    // Shape: 4-bit within a modest margin of 6-bit; 1-bit clearly worse.
    let q6 = adc_q.iter().find(|x| x.0 == 6).unwrap();
    let q4 = adc_q.iter().find(|x| x.0 == 4).unwrap();
    let q1 = adc_q.iter().find(|x| x.0 == 1).unwrap();
    assert!(
        q4.2 as f64 >= 0.8 * q6.2 as f64,
        "4-bit close to 6-bit: {} vs {}",
        q4.2,
        q6.2
    );
    assert!(q1.2 <= q6.2, "1-bit no better than 6-bit");
    println!(
        "shape check OK: clustering flat in write-verify; graceful ADC degradation\n\
         (4-bit ~= 6-bit at ~4x lower ADC energy)."
    );
    Ok(())
}
