//! Front-door coalescing benchmark (the closed-loop instrument for
//! `coordinator::scheduler`): queries/sec of serving one seeded
//! Poisson-like arrival trace through the dynamic-batching front door,
//! sweeping coalescing policy — **batch-size-1 naive** (`off`, every
//! request flushes alone, the pre-front-door behavior), **size-triggered**
//! (`size`, flush at the tile-fill target derived from the config-default
//! `min_utilization = 0.3`, i.e. 39 queries/tile), and **size+deadline**
//! (`deadline`, same fill target plus a logical-tick latency bound) — at
//! 1 and 4 worker threads. Every policy run is asserted bit-identical to
//! a single arrival-order `search_batch` oracle before its time means
//! anything, so the only thing compared is host wall time; the
//! queue-latency price of each policy is reported alongside in logical
//! ticks (p50/p99), which are deterministic per trace.
//!
//! Writes the machine-readable `BENCH_frontdoor.json` next to the text
//! table (`python/tools/bench_compare.py` diffs two such files, keyed by
//! section/policy/threads, with inverted tolerance for the latency
//! percentiles).
//!
//! `--tiny` runs a seconds-scale smoke configuration (CI's default
//! step); the >=2x coalesced-vs-naive throughput assert at 4 threads is
//! opt-in via `SPECPCM_ASSERT_SPEEDUP=1` and guarded on >=4 real cores,
//! mirroring `serving_throughput`.

use std::time::Instant;

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{
    tile_fill_target, ArrivalTrace, CoalescePolicy, FrontDoor, SearchEngine,
};
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::{render_json_records, render_table, JsonField};
use specpcm::util::Rng;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

struct Scale {
    targets: usize,
    queries: usize,
    reps: usize,
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let scale = if tiny {
        Scale {
            targets: 40,
            queries: 24,
            reps: 3,
        }
    } else {
        // ~5 full 39-query tiles per trace for the coalescing policies
        // vs. 192 singleton flushes for the naive baseline.
        Scale {
            targets: 300,
            queries: 192,
            reps: 5,
        }
    };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "host parallelism: {cores} logical cores{}\n",
        if tiny { " (tiny smoke scale)" } else { "" }
    );

    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate(
        "frontdoor",
        77,
        scale.targets,
        scale.queries,
        0.8,
        0.2,
        0,
        0,
    );
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    // One seeded trace shared by every policy and thread count, so each
    // cell serves the exact same request schedule (C4-RNG: the RNG is
    // constructed here, outside engine code, and threaded in).
    let mut trace_rng = Rng::new(0xf00d);
    let trace = ArrivalTrace::poisson_from_rng(&mut trace_rng, queries.len(), 1.0);
    let fill = tile_fill_target(cfg.backend.min_utilization);
    let policies = [
        CoalescePolicy::Off,
        CoalescePolicy::Size { max_batch: fill },
        CoalescePolicy::SizeDeadline {
            max_batch: fill,
            deadline_ticks: 64,
        },
    ];
    println!(
        "workload: {} requests over {} logical ticks, fill target {fill} \
         (min_utilization {:.2})",
        queries.len(),
        trace.ticks.last().copied().unwrap_or(0),
        cfg.backend.min_utilization
    );

    let mut rows = Vec::new();
    let mut records = Vec::new();
    let mut qps_naive_4t = 0.0f64;
    let mut qps_size_4t = 0.0f64;
    for threads in [1usize, 4] {
        let be = BackendDispatcher::parallel(threads);
        let mut engine = SearchEngine::program(cfg.clone(), &ds, &be).unwrap();
        let oracle = engine.search_batch(&queries, &be).unwrap();
        for policy in policies {
            let fd = FrontDoor::new(policy);
            let t = median_time(
                || {
                    engine.clear_query_cache();
                    std::hint::black_box(
                        fd.serve_trace(&mut engine, &queries, &trace, &be).unwrap(),
                    );
                },
                scale.reps,
            );
            // Results must match the arrival-order oracle bit for bit
            // before the time means anything (the telemetry is
            // deterministic per trace, so this run's stats are the
            // timed runs' stats).
            let served = fd.serve_trace(&mut engine, &queries, &trace, &be).unwrap();
            assert_eq!(served.pairs, oracle.pairs, "fan-back diverged from oracle");
            assert_eq!(served.matched, oracle.matched, "matches diverged");
            assert_eq!(served.ops, oracle.ops, "marginal ops diverged");

            let qps = queries.len() as f64 / t;
            if threads == 4 {
                match policy {
                    CoalescePolicy::Off => qps_naive_4t = qps,
                    CoalescePolicy::Size { .. } => qps_size_4t = qps,
                    CoalescePolicy::SizeDeadline { .. } => {}
                }
            }
            let st = &served.stats;
            rows.push(vec![
                format!("{} x{threads}", policy.name()),
                format!("{qps:.1}"),
                format!("{}", st.batches),
                format!("{:.0}%", st.mean_fill_fraction * 100.0),
                format!("{}/{}", st.p50_wait_ticks, st.p99_wait_ticks),
            ]);
            records.push(vec![
                ("section", JsonField::S("serving_frontdoor".into())),
                ("policy", JsonField::S(policy.name().into())),
                ("threads", JsonField::U(threads as u64)),
                ("requests", JsonField::U(st.requests)),
                ("batches", JsonField::U(st.batches)),
                ("fill_target", JsonField::U(st.fill_target)),
                ("mean_fill_fraction", JsonField::F(st.mean_fill_fraction)),
                ("qps_served", JsonField::F(qps)),
                ("p50_wait_ticks", JsonField::F(st.p50_wait_ticks as f64)),
                ("p99_wait_ticks", JsonField::F(st.p99_wait_ticks as f64)),
                ("tiny", JsonField::B(tiny)),
            ]);
        }
    }

    println!(
        "{}",
        render_table(
            "front-door serving throughput (host wall clock)",
            &["policy", "served q/s", "batches", "fill", "wait p50/p99 ticks"],
            &rows
        )
    );

    let json = render_json_records(&records);
    let json_path = "BENCH_frontdoor.json";
    std::fs::write(json_path, &json).expect("write BENCH_frontdoor.json");
    println!("wrote {json_path} ({} records)", records.len());

    // Reproduction contract: with >=4 real cores, size-triggered
    // coalescing should serve >=2x the naive batch-size-1 rate at 4
    // threads — full tiles amortize per-call overhead and give the
    // parallel backend whole query tiles to shard, while naive serving
    // pays both on every request. The hard assert is opt-in (wall-clock
    // ratios are noisy on shared runners) and meaningless at tiny scale.
    let speedup = if qps_naive_4t > 0.0 {
        qps_size_4t / qps_naive_4t
    } else {
        0.0
    };
    let enforce = std::env::var("SPECPCM_ASSERT_SPEEDUP").as_deref() == Ok("1");
    if tiny {
        println!("tiny smoke scale: speedup assert skipped by design.");
    } else if cores >= 4 && enforce {
        assert!(
            speedup > 2.0,
            "size-triggered coalescing should be >=2x naive serving at 4 threads \
             (got {speedup:.2}x)"
        );
        println!("shape check OK: size coalescing = {speedup:.2}x naive at 4 threads.");
    } else if cores >= 4 {
        println!(
            "shape check (informational; SPECPCM_ASSERT_SPEEDUP=1 to enforce): \
             size coalescing = {speedup:.2}x naive at 4 threads."
        );
    } else {
        println!("shape check skipped: only {cores} cores available.");
    }
}
