//! Drift-aware serving benchmark (the robustness instrument for the
//! refresh-epoch machinery): identification accuracy versus served age
//! for one library programmed once and then aged through a schedule of
//! drift horizons, with the background [`RefreshPolicy`] either **off**
//! (the panel keeps decaying) or **on** (a full re-programming epoch runs
//! before each horizon is served). Both curves come from the *same*
//! deterministic device state — same seed, same injected faults, same
//! logical clock — so the gap between them is exactly what refresh buys.
//!
//! The accuracy lever is quantization, not noise: conductance drift
//! scales every stored row by the same `t^-nu` factor, and with a fixed
//! ADC full scale the shrunken scores collapse into fewer output codes
//! (ties break toward the lowest logical row), so this config runs the
//! drift-prone Sb2Te3 stack at 4 ADC bits where the effect bites hardest.
//! At the largest horizon the refresh-on curve must be at least as
//! accurate as refresh-off (hard assert, deterministic at every scale).
//!
//! Writes `BENCH_drift.json` (one record per (age, refresh) point, with
//! serving qps and health telemetry) next to the text table;
//! `python/tools/bench_compare.py` diffs the accuracy fields against the
//! committed baseline. `--tiny` is the seconds-scale CI smoke
//! configuration.

use std::time::Instant;

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{RefreshPolicy, SearchEngine};
use specpcm::device::{FaultModel, Material};
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::{render_json_records, render_table, JsonField};

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

fn main() {
    let tiny = std::env::args().any(|a| a == "--tiny");
    let (targets, n_queries, reps) = if tiny { (40, 8, 3) } else { (300, 64, 5) };

    // Sb2Te3 (nu = 0.02, the drift-prone stack) at 4 ADC bits: by 1e12 s
    // the stored panel sits at ~0.57x its programmed conductance, deep
    // into code-collapse territory for a 16-code ADC. Mild fault rates
    // keep the health telemetry exercised without drowning the drift
    // signal.
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        adc_bits: 4,
        material: Material::Sb2Te3Gst467,
        fault: FaultModel::new(0.001, 0.0005, 2.0),
        ..SpecPcmConfig::paper_search()
    };
    let horizons = [0.0, 1.0e6, 1.0e9, 1.0e11, 1.0e12];
    let full_refresh = RefreshPolicy {
        max_age_seconds: 0.0,
        budget: 0,
    };

    let ds = SearchDataset::generate("drift", 77, targets, n_queries, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    // Two engines, one programmed device state: identical config and seed
    // mean identical conductances, identical injected faults, identical
    // logical clocks — the refresh policy is the only divergence.
    let mut engines = [
        (false, SearchEngine::program(cfg.clone(), &ds, &be).unwrap()),
        (true, SearchEngine::program(cfg.clone(), &ds, &be).unwrap()),
    ];

    println!(
        "workload: {} reference rows, {} queries, Sb2Te3 @ {} ADC bits{}\n",
        engines[0].1.n_refs(),
        queries.len(),
        cfg.adc_bits,
        if tiny { " (tiny smoke scale)" } else { "" }
    );

    let mut rows = Vec::new();
    let mut records = Vec::new();
    let mut last_acc = [0.0f64; 2]; // [off, on] at the latest horizon
    let mut prev_age = 0.0f64;
    for &age in &horizons {
        for (refresh, engine) in engines.iter_mut() {
            engine.advance_age(age - prev_age);
            let mut refreshed_rows = 0usize;
            if *refresh {
                refreshed_rows = engine.maintain(&full_refresh).rows;
            }

            let t = median_time(
                || {
                    engine.clear_query_cache();
                    std::hint::black_box(engine.search_batch(&queries, &be).unwrap());
                },
                reps,
            );
            let batch = engine.search_batch(&queries, &be).unwrap();
            let health = batch.health;
            let out = engine
                .finalize(&queries, std::slice::from_ref(&batch))
                .unwrap();
            let accuracy = out.correct as f64 / queries.len() as f64;
            let qps = queries.len() as f64 / t;
            last_acc[*refresh as usize] = accuracy;

            rows.push(vec![
                format!("{age:.0e}"),
                if *refresh { "on".into() } else { "off".into() },
                format!("{accuracy:.3}"),
                format!("{}", out.identified),
                format!("{qps:.1}"),
                format!("{:.3}", health.est_conductance_loss),
                format!("{}", health.refreshes),
                format!("{}", health.injected_faults),
            ]);
            records.push(vec![
                ("section", JsonField::S("drift_serving".into())),
                ("threads", JsonField::U(1)),
                ("age_seconds", JsonField::F(age)),
                ("refresh", JsonField::B(*refresh)),
                ("accuracy", JsonField::F(accuracy)),
                ("identified", JsonField::U(out.identified as u64)),
                ("correct", JsonField::U(out.correct as u64)),
                ("qps_segmented", JsonField::F(qps)),
                ("refreshed_rows", JsonField::U(refreshed_rows as u64)),
                ("refreshes", JsonField::U(health.refreshes)),
                ("injected_faults", JsonField::U(health.injected_faults)),
                ("max_age_seconds", JsonField::F(health.max_age_seconds)),
                (
                    "est_conductance_loss",
                    JsonField::F(health.est_conductance_loss),
                ),
                ("tiny", JsonField::B(tiny)),
            ]);
        }
        prev_age = age;
    }

    println!(
        "{}",
        render_table(
            "drift-aware serving (accuracy vs age, refresh off/on)",
            &[
                "age s",
                "refresh",
                "accuracy",
                "identified",
                "q/s",
                "est loss",
                "refreshes",
                "faults",
            ],
            &rows
        )
    );

    let json = render_json_records(&records);
    let json_path = "BENCH_drift.json";
    std::fs::write(json_path, &json).expect("write BENCH_drift.json");
    println!("wrote {json_path} ({} records)", records.len());

    // Reproduction contract (deterministic — no core-count or wall-clock
    // guard needed): after the refresh epoch the on-curve serves an age-0
    // panel, so at the deepest horizon it can never identify fewer
    // queries correctly than the decayed off-curve.
    let (acc_off, acc_on) = (last_acc[0], last_acc[1]);
    assert!(
        acc_on + 1e-9 >= acc_off,
        "refresh-on accuracy ({acc_on:.3}) fell below refresh-off ({acc_off:.3}) \
         at the {:.0e}-second horizon",
        horizons[horizons.len() - 1]
    );
    println!(
        "shape check OK: at {:.0e} s, refresh-on accuracy {acc_on:.3} >= \
         refresh-off {acc_off:.3}.",
        horizons[horizons.len() - 1]
    );
}
