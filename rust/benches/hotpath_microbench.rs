//! Hot-path microbenchmarks (the §Perf instrument): wall-clock timing of
//! the PJRT artifact MVM vs the rust reference MVM across packed widths,
//! the encoder artifact vs rust encode+pack, and per-call marshalling
//! overhead. No criterion offline — median-of-N timing with warmup.

use std::time::Instant;

use specpcm::array::{imc_mvm_ref, AdcConfig};
use specpcm::hd::{self, ItemMemory};
use specpcm::runtime::Runtime;
use specpcm::telemetry::render_table;
use specpcm::util::Rng;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

fn main() {
    let mut rt = Runtime::load("artifacts").ok();
    let mut rng = Rng::new(0xbe7c);
    let mut rows = Vec::new();

    // ---- MVM: artifact vs rust reference across widths ----------------------
    let (b, r) = (64usize, 1024usize);
    for c in [256usize, 768, 2816] {
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let adc = AdcConfig::new(6, 512.0);

        let rust_t = median_time(
            || {
                std::hint::black_box(imc_mvm_ref(&q, &g, b, r, c, adc));
            },
            5,
        );
        let scores = (b * r) as f64;
        rows.push(vec![
            format!("mvm c={c} rust-ref"),
            format!("{:.2} ms", rust_t * 1e3),
            format!("{:.1}", scores / rust_t / 1e6),
        ]);

        if let Some(rt) = rt.as_mut() {
            let pjrt_t = median_time(
                || {
                    std::hint::black_box(rt.mvm(c, &q, &g, adc.lsb(), adc.qmax()).unwrap());
                },
                5,
            );
            rows.push(vec![
                format!("mvm c={c} pjrt"),
                format!("{:.2} ms", pjrt_t * 1e3),
                format!("{:.1}", scores / pjrt_t / 1e6),
            ]);
        }
    }

    // ---- Encoder: artifact vs rust ------------------------------------------
    let (f, m, d, n) = (512usize, 64usize, 2048usize, 3usize);
    let im = ItemMemory::generate(1, f, m, d);
    let mut levels_u16 = vec![vec![0u16; f]; b];
    let mut levels_i32 = vec![0i32; b * f];
    for bi in 0..b {
        for _ in 0..100 {
            let pos = rng.below(f);
            let lvl = 1 + rng.below(m - 1);
            levels_u16[bi][pos] = lvl as u16;
            levels_i32[bi * f + pos] = lvl as i32;
        }
    }

    let rust_t = median_time(
        || {
            for lv in &levels_u16 {
                std::hint::black_box(hd::pack(&hd::encode(lv, &im), n));
            }
        },
        5,
    );
    rows.push(vec![
        format!("encode+pack d={d} rust-ref (batch {b})"),
        format!("{:.2} ms", rust_t * 1e3),
        format!("{:.1}", b as f64 / rust_t / 1e3),
    ]);

    if let Some(rt) = rt.as_mut() {
        let idv = im.id_hvs_f32();
        let lvv = im.level_hvs_f32();
        let pjrt_t = median_time(
            || {
                std::hint::black_box(rt.encode_pack(d, n, &levels_i32, &idv, &lvv).unwrap());
            },
            5,
        );
        rows.push(vec![
            format!("encode+pack d={d} pjrt (batch {b})"),
            format!("{:.2} ms", pjrt_t * 1e3),
            format!("{:.1}", b as f64 / pjrt_t / 1e3),
        ]);

        // Marshalling floor: smallest artifact, repeated.
        let c = 256;
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let t = median_time(
            || {
                std::hint::black_box(rt.mvm(c, &q, &g, 16.0, 31.0).unwrap());
            },
            10,
        );
        rows.push(vec![
            "pjrt per-call floor (c=256)".into(),
            format!("{:.3} ms", t * 1e3),
            "-".into(),
        ]);
    }

    println!(
        "{}",
        render_table(
            "hot-path microbenchmarks (host wall clock)",
            &["kernel", "median time", "Mscores/s or Kspectra/s"],
            &rows
        )
    );
    println!(
        "note: these measure the *simulator host*; accelerator latency comes from\n\
         the cycle model (array MVM = 20 ns). Used for the EXPERIMENTS.md §Perf log."
    );
}
