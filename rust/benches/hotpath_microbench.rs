//! Hot-path microbenchmarks (the §Perf instrument): wall-clock timing of
//! the MVM execution backends against each other across packed widths —
//! the rust reference path vs the bank-sharded parallel backend at 2/4/8
//! threads (and the PJRT artifact when built with `--features pjrt`) —
//! the PR 6 lane-ordered blocked kernel vs a bench-local copy of the PR 5
//! ascending-k kernel (the SIMD-enablement before/after), plus the
//! encoder artifact vs rust encode+pack. No criterion offline —
//! median-of-N timing with warmup.

use std::time::Instant;

use specpcm::array::{imc_mvm_blocked_into, AdcConfig, ARRAY_DIM};
use specpcm::backend::{MvmBackend, MvmJob, ParallelBackend, RefBackend};
use specpcm::encode::{
    BitpackedEncodeBackend, EncodeBackend, EncodeJob, ParallelEncodeBackend, ScalarEncodeBackend,
};
use specpcm::hd::{self, BitItemMemory, ItemMemory};
use specpcm::telemetry::render_table;
use specpcm::util::Rng;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

/// Bench-local copy of the PR 5 blocked kernel: identical cache blocking,
/// but the tile dot accumulates in ascending `k` — the serialized
/// dependence chain the PR 6 lane-ordered contract removed. Kept here (not
/// in the library) purely as the before/after comparison point. On integer
/// packed data every partial sum is exact, so its scores still equal the
/// lane-ordered kernel's bit-for-bit (asserted below) — only the wall
/// clock differs.
#[allow(clippy::too_many_arguments)]
fn blocked_ascending_k(
    q: &[f32],
    g: &[f32],
    b: usize,
    r: usize,
    c: usize,
    adc: AdcConfig,
    out: &mut [f32],
) {
    const QUERY_BLOCK: usize = 16;
    let tiles = c / ARRAY_DIM;
    let mut acc = [0f32; QUERY_BLOCK * ARRAY_DIM];
    let mut q0 = 0;
    while q0 < b {
        let qn = QUERY_BLOCK.min(b - q0);
        let mut p0 = 0;
        while p0 < r {
            let pn = ARRAY_DIM.min(r - p0);
            let sub = &mut acc[..qn * pn];
            sub.fill(0.0);
            for t in 0..tiles {
                let lo = t * ARRAY_DIM;
                for qi in 0..qn {
                    let qoff = (q0 + qi) * c + lo;
                    let qrow = &q[qoff..qoff + ARRAY_DIM];
                    for pi in 0..pn {
                        let goff = (p0 + pi) * c + lo;
                        let grow = &g[goff..goff + ARRAY_DIM];
                        let mut part = 0f32;
                        for k in 0..ARRAY_DIM {
                            part += qrow[k] * grow[k];
                        }
                        sub[qi * pn + pi] += adc.quantize(part);
                    }
                }
            }
            for qi in 0..qn {
                let ooff = (q0 + qi) * r + p0;
                out[ooff..ooff + pn].copy_from_slice(&sub[qi * pn..(qi + 1) * pn]);
            }
            p0 += pn;
        }
        q0 += qn;
    }
}

fn main() {
    let mut rng = Rng::new(0xbe7c);
    let mut rows = Vec::new();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("host parallelism: {cores} logical cores\n");
    // One runtime (and one executable cache) for every pjrt section below.
    #[cfg(feature = "pjrt")]
    let mut pjrt_rt = specpcm::runtime::Runtime::load("artifacts").ok();

    // ---- MVM: reference vs bank-sharded parallel across widths --------------
    let (b, r) = (64usize, 1024usize);
    let mut speedup_4t_widest = 0.0f64;
    for c in [256usize, 768, 2816] {
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::new(&q, b, &g, r, c, adc);
        let scores = (b * r) as f64;

        let rust_t = median_time(
            || {
                std::hint::black_box(RefBackend.mvm_scores(&job).unwrap());
            },
            5,
        );
        rows.push(vec![
            format!("mvm c={c} rust-ref"),
            format!("{:.2} ms", rust_t * 1e3),
            format!("{:.1}", scores / rust_t / 1e6),
            "1.00x".into(),
        ]);

        for threads in [2usize, 4, 8] {
            let backend = ParallelBackend::new(threads);
            let par_t = median_time(
                || {
                    std::hint::black_box(backend.mvm_scores(&job).unwrap());
                },
                5,
            );
            let speedup = rust_t / par_t;
            if threads == 4 && c == 2816 {
                speedup_4t_widest = speedup;
            }
            rows.push(vec![
                format!("mvm c={c} parallel x{threads}"),
                format!("{:.2} ms", par_t * 1e3),
                format!("{:.1}", scores / par_t / 1e6),
                format!("{speedup:.2}x"),
            ]);
        }

        #[cfg(feature = "pjrt")]
        if let Some(rt) = pjrt_rt.as_mut() {
            let pjrt_t = median_time(
                || {
                    std::hint::black_box(rt.mvm(c, &q, &g, adc.lsb(), adc.qmax()).unwrap());
                },
                5,
            );
            rows.push(vec![
                format!("mvm c={c} pjrt"),
                format!("{:.2} ms", pjrt_t * 1e3),
                format!("{:.1}", scores / pjrt_t / 1e6),
                format!("{:.2}x", rust_t / pjrt_t),
            ]);
        }
    }

    // ---- Tile dot: PR 6 lane-ordered kernel vs PR 5 ascending-k -------------
    // Same cache blocking, same single thread; the only difference is the
    // in-tile accumulation order (8 independent lanes + tree reduce vs one
    // serialized dependence chain), i.e. whether the autovectorizer can
    // emit SIMD. Integer data keeps the two bit-identical.
    let lane_speedup;
    {
        let c = 768usize;
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        let scores = (b * r) as f64;
        let mut out_old = vec![0f32; b * r];
        let mut out_new = vec![0f32; b * r];

        let old_t = median_time(
            || {
                blocked_ascending_k(&q, &g, b, r, c, adc, &mut out_old);
                std::hint::black_box(&out_old);
            },
            5,
        );
        let new_t = median_time(
            || {
                imc_mvm_blocked_into(&q, &g, &[0..r], b, c, adc, &mut out_new);
                std::hint::black_box(&out_new);
            },
            5,
        );
        assert_eq!(out_new, out_old, "integer data must be order-insensitive");
        lane_speedup = old_t / new_t;
        rows.push(vec![
            format!("mvm c={c} blocked ascending-k (PR 5)"),
            format!("{:.2} ms", old_t * 1e3),
            format!("{:.1}", scores / old_t / 1e6),
            "1.00x".into(),
        ]);
        rows.push(vec![
            format!("mvm c={c} blocked lane-ordered (PR 6)"),
            format!("{:.2} ms", new_t * 1e3),
            format!("{:.1}", scores / new_t / 1e6),
            format!("{lane_speedup:.2}x"),
        ]);
    }

    // ---- Encoder: rust reference (artifact path needs `pjrt`) ---------------
    let (f, m, d, n) = (512usize, 64usize, 2048usize, 3usize);
    let im = ItemMemory::generate(1, f, m, d);
    let mut levels_u16 = vec![vec![0u16; f]; b];
    for lv in levels_u16.iter_mut() {
        for _ in 0..100 {
            let pos = rng.below(f);
            lv[pos] = (1 + rng.below(m - 1)) as u16;
        }
    }

    let rust_t = median_time(
        || {
            for lv in &levels_u16 {
                std::hint::black_box(hd::pack(&hd::encode(lv, &im), n));
            }
        },
        5,
    );
    rows.push(vec![
        format!("encode+pack d={d} rust-ref (batch {b})"),
        format!("{:.2} ms", rust_t * 1e3),
        format!("{:.1}", b as f64 / rust_t / 1e3),
        "-".into(),
    ]);

    // ---- Encode backends: scalar vs bitpacked vs spectra-parallel -----------
    // Same batch through the pluggable encode seam; all bit-identical, so
    // the only thing compared is host rows/sec.
    let bim = BitItemMemory::from_item_memory(&im);
    let enc_job = EncodeJob::new(&levels_u16, &im, &bim, n);
    let mut enc_out = vec![0f32; enc_job.out_len()];

    let scalar_t = median_time(
        || {
            ScalarEncodeBackend.encode_pack(&enc_job, &mut enc_out).unwrap();
            std::hint::black_box(&enc_out);
        },
        5,
    );
    rows.push(vec![
        format!("encode d={d} scalar (batch {b})"),
        format!("{:.2} ms", scalar_t * 1e3),
        format!("{:.1}", b as f64 / scalar_t / 1e3),
        "1.00x".into(),
    ]);

    let bitpacked_t = median_time(
        || {
            BitpackedEncodeBackend.encode_pack(&enc_job, &mut enc_out).unwrap();
            std::hint::black_box(&enc_out);
        },
        5,
    );
    let encode_speedup_bitpacked = scalar_t / bitpacked_t;
    rows.push(vec![
        format!("encode d={d} bitpacked (batch {b})"),
        format!("{:.2} ms", bitpacked_t * 1e3),
        format!("{:.1}", b as f64 / bitpacked_t / 1e3),
        format!("{encode_speedup_bitpacked:.2}x"),
    ]);

    for threads in [2usize, 4, 8] {
        let backend = ParallelEncodeBackend::new(threads);
        let par_t = median_time(
            || {
                backend.encode_pack(&enc_job, &mut enc_out).unwrap();
                std::hint::black_box(&enc_out);
            },
            5,
        );
        rows.push(vec![
            format!("encode d={d} parallel x{threads} (batch {b})"),
            format!("{:.2} ms", par_t * 1e3),
            format!("{:.1}", b as f64 / par_t / 1e3),
            format!("{:.2}x", scalar_t / par_t),
        ]);
    }

    #[cfg(feature = "pjrt")]
    if let Some(rt) = pjrt_rt.as_mut() {
        let mut levels_i32 = vec![0i32; b * f];
        for (bi, lv) in levels_u16.iter().enumerate() {
            for (j, &v) in lv.iter().enumerate() {
                levels_i32[bi * f + j] = v as i32;
            }
        }
        let idv = im.id_hvs_f32();
        let lvv = im.level_hvs_f32();
        let pjrt_t = median_time(
            || {
                std::hint::black_box(rt.encode_pack(d, n, &levels_i32, &idv, &lvv).unwrap());
            },
            5,
        );
        rows.push(vec![
            format!("encode+pack d={d} pjrt (batch {b})"),
            format!("{:.2} ms", pjrt_t * 1e3),
            format!("{:.1}", b as f64 / pjrt_t / 1e3),
            format!("{:.2}x", rust_t / pjrt_t),
        ]);

        // Marshalling floor: smallest artifact, repeated.
        let c = 256;
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let t = median_time(
            || {
                std::hint::black_box(rt.mvm(c, &q, &g, 16.0, 31.0).unwrap());
            },
            10,
        );
        rows.push(vec![
            "pjrt per-call floor (c=256)".into(),
            format!("{:.3} ms", t * 1e3),
            "-".into(),
            "-".into(),
        ]);
    }

    println!(
        "{}",
        render_table(
            "hot-path microbenchmarks (host wall clock)",
            &["kernel", "median time", "Mscores/s or Kspectra/s", "vs rust-ref"],
            &rows
        )
    );
    println!(
        "note: these measure the *simulator host*; accelerator latency comes from\n\
         the cycle model (array MVM = 20 ns). Used for the EXPERIMENTS.md §Perf log."
    );

    // Reproduction contract: with >=4 real cores, sharding the widest score
    // tile across 4 workers should beat the scalar path. On shared/contended
    // CI runners the wall-clock ratio is noisy, so the hard assert only
    // fires when SPECPCM_ASSERT_SPEEDUP=1 (set in the dedicated CI step,
    // which also guards on `nproc`); every other run just reports.
    let enforce = std::env::var("SPECPCM_ASSERT_SPEEDUP").as_deref() == Ok("1");
    if cores >= 4 && enforce {
        assert!(
            speedup_4t_widest > 1.2,
            "parallel x4 should outrun rust-ref on c=2816 (got {speedup_4t_widest:.2}x)"
        );
        println!(
            "shape check OK: parallel x4 = {speedup_4t_widest:.2}x rust-ref on the widest tile."
        );
    } else if cores >= 4 {
        println!(
            "shape check (informational; SPECPCM_ASSERT_SPEEDUP=1 to enforce): \
             parallel x4 = {speedup_4t_widest:.2}x rust-ref on the widest tile."
        );
    } else {
        println!("shape check skipped: only {cores} cores available.");
    }

    // Lane-order reproduction contract: the vectorized tile dot is a
    // single-thread property (no core-count guard), same opt-in as above.
    // >=1.2x is deliberately conservative — 8 independent f32 lanes
    // usually buy 2x+ over the serialized chain on any SSE-or-wider host.
    if enforce {
        assert!(
            lane_speedup > 1.2,
            "lane-ordered blocked kernel should outrun the PR 5 ascending-k \
             kernel (got {lane_speedup:.2}x)"
        );
        println!("lane shape check OK: lane-ordered = {lane_speedup:.2}x ascending-k.");
    } else {
        println!(
            "lane shape check (informational; SPECPCM_ASSERT_SPEEDUP=1 to enforce): \
             lane-ordered = {lane_speedup:.2}x ascending-k."
        );
    }

    // Encode reproduction contract: the word-packed kernel replaces 64
    // scalar multiply-adds with ~4 word ops per codebook word, so >=4x
    // over the scalar path at D=2048 is a *single-thread* property — no
    // core-count guard, same SPECPCM_ASSERT_SPEEDUP=1 opt-in as above.
    if enforce {
        assert!(
            encode_speedup_bitpacked > 4.0,
            "bitpacked encode should be >=4x the scalar path at d={d} \
             (got {encode_speedup_bitpacked:.2}x)"
        );
        println!(
            "encode shape check OK: bitpacked = {encode_speedup_bitpacked:.2}x scalar at d={d}."
        );
    } else {
        println!(
            "encode shape check (informational; SPECPCM_ASSERT_SPEEDUP=1 to enforce): \
             bitpacked = {encode_speedup_bitpacked:.2}x scalar at d={d}."
        );
    }
}
