//! Table 2: clustering latency/speedup vs prior tools.
//!
//! Baseline latencies are the paper's published measurements on its own
//! testbeds (i7-11700K / RTX 4090 / SpecHD FPGA) — we cannot re-measure
//! them here (DESIGN.md §5). SpecPCM's latency is *simulated* by this
//! repo's cycle/energy model on a scaled synthetic workload and
//! extrapolated linearly in spectrum count to the real dataset size. The
//! reproduction target is the *shape*: SpecPCM fastest, speedup vs the
//! CPU baseline in the ~1e2 range, and ~4 orders of magnitude energy
//! advantage over a 450 W GPU envelope.

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::latency_model::{clustering_for, paper_speedup};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::ClusteringPipeline;
use specpcm::energy::GpuEnvelope;
use specpcm::ms::ClusteringDataset;
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    let cfg = SpecPcmConfig {
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let backend = BackendDispatcher::from_config(&cfg);

    for (preset, dataset) in [
        (ClusteringDataset::pxd001468_like(cfg.seed, 0.35), "PXD001468"),
        (ClusteringDataset::pxd000561_like(cfg.seed, 0.35), "PXD000561"),
    ] {
        let out = ClusteringPipeline::new(cfg.clone()).run(&preset, &backend)?;
        // Extrapolate the simulated accelerator latency/energy linearly in
        // spectrum count to the real dataset size.
        let scale = preset.paper_spectra as f64 / preset.len() as f64;
        let sim_latency = out.report.overlapped_latency_s() * scale;
        let sim_energy = out.report.total_j() * scale;

        let baselines = clustering_for(dataset);
        let base = baselines[0].latency_s;
        let mut rows: Vec<Vec<String>> = baselines
            .iter()
            .map(|b| {
                vec![
                    b.tool.to_string(),
                    b.hardware.to_string(),
                    format!("{:.2}s", b.latency_s),
                    format!("{:.1}x", base / b.latency_s),
                ]
            })
            .collect();
        rows.push(vec![
            "SpecPCM (this repo, simulated)".into(),
            "sim 40nm".into(),
            format!("{sim_latency:.2}s"),
            format!("{:.1}x", base / sim_latency),
        ]);

        println!(
            "{}",
            render_table(
                &format!("Table 2 — clustering speedup ({dataset}, {} synth spectra x{scale:.0})", preset.len()),
                &["tool", "hardware", "latency", "speedup"],
                &rows
            )
        );

        // Energy: paper reports 3.27 J for the full PXD000561 clustering; a
        // 450 W GPU at the HyperSpec latency burns ~5 orders more.
        let gpu = GpuEnvelope::default();
        let hyperspec = baselines
            .iter()
            .find(|b| b.tool == "HyperSpec")
            .unwrap()
            .latency_s;
        println!(
            "energy: simulated SpecPCM {:.3} J vs GPU envelope {:.0} J -> {:.0e}x \
             (paper: 3.27 J on PXD000561, four orders of magnitude)\n",
            sim_energy,
            gpu.energy_j(hyperspec),
            gpu.energy_j(hyperspec) / sim_energy.max(1e-12),
        );

        // Shape checks.
        let paper_x = paper_speedup(dataset, "SpecPCM(paper)").unwrap();
        let ours_x = base / sim_latency;
        assert!(
            ours_x > 10.0,
            "{dataset}: simulated SpecPCM must be >10x the CPU baseline (got {ours_x:.1})"
        );
        assert!(
            gpu.energy_j(hyperspec) / sim_energy > 1e3,
            "{dataset}: >=3 orders of magnitude energy advantage"
        );
        println!(
            "shape check OK: ours {ours_x:.0}x vs paper {paper_x:.0}x (same order; \
             absolute differs because the substrate is a simulator on synthetic data)\n"
        );
    }
    Ok(())
}
