//! Fig. 8: area breakdown of one SpecPCM bank (from the Table S3
//! post-layout constants). The headline: the flash ADC dominates — the
//! reason one ADC is shared across eight rows (Table 1).

use specpcm::energy::{area_breakdown, components};
use specpcm::telemetry::render_table;

fn main() {
    let rows: Vec<Vec<String>> = area_breakdown()
        .into_iter()
        .map(|(name, mm2, frac)| {
            let bar = "#".repeat((frac * 50.0).round() as usize);
            vec![
                name.to_string(),
                format!("{mm2:.4}"),
                format!("{:.1}%", frac * 100.0),
                bar,
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            "Fig. 8 — area breakdown per bank (40 nm)",
            &["component", "area mm2", "fraction", ""],
            &rows
        )
    );
    println!(
        "total bank area: {:.4} mm2 (Table S3 reports {:.4})",
        area_breakdown().iter().map(|r| r.1).sum::<f64>(),
        components::BANK_TOTAL_AREA_MM2
    );

    let top = &area_breakdown()[0];
    assert_eq!(top.0, "Flash ADC");
    assert!(top.2 > 0.3);
    println!("shape check OK: Flash ADC is the largest consumer ({:.1}%).", top.2 * 100.0);
}
