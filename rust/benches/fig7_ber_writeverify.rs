//! Fig. 7: bit error rate vs write-verify cycles (3-bit MLC).
//!
//! The paper measures 100 fabricated devices over 100 rounds; here the
//! calibrated noise model plays the devices: for each write-verify count we
//! program 100 simulated cells 100 times each and count level misreads,
//! against the analytic fit the model was built from.
//!
//! Expected shape: monotone decrease from >10% at 0 cycles toward the
//! material's error floor — and the empirical points must sit on the fit.

use specpcm::device::{Material, MlcConfig, NoiseModel, Programmer};
use specpcm::telemetry::render_table;
use specpcm::util::Rng;

fn main() {
    let mlc = MlcConfig::new(3);
    let mut rows = Vec::new();

    for wv in 0..=8u32 {
        let mut cells = Vec::new();
        for material in Material::ALL {
            let nm = NoiseModel::new(material, mlc);
            let programmer = Programmer::new(nm.clone(), wv);
            let mut rng = Rng::new(0xF16_7 + wv as u64);

            // 100 devices x 100 measurement rounds (paper protocol).
            let (mut errors, mut total) = (0u64, 0u64);
            let half = (mlc.level_spacing() / 2.0) as f32;
            for dev in 0..100 {
                let target = [-3.0f32, -1.0, 1.0, 3.0][dev % 4];
                for _ in 0..100 {
                    let out = programmer.program(target, &mut rng);
                    if (out.stored - target).abs() > half * (target.abs() / 3.0).max(0.3) {
                        errors += 1;
                    }
                    total += 1;
                }
            }
            let emp = errors as f64 / total as f64;
            let fit = nm.ber(wv);
            cells.push(format!("{:.4}", emp));
            cells.push(format!("{:.4}", fit));
        }
        rows.push({
            let mut r = vec![format!("{wv}")];
            r.extend(cells);
            r
        });
    }

    println!(
        "{}",
        render_table(
            "Fig. 7 — BER vs write-verify cycles (3-bit MLC, 100 devices x 100 rounds)",
            &[
                "write-verify",
                "Sb2Te3 measured",
                "Sb2Te3 fit",
                "TiTe2 measured",
                "TiTe2 fit",
            ],
            &rows
        )
    );

    // Shape assertions (the reproduction contract).
    for material in Material::ALL {
        let nm = NoiseModel::new(material, mlc);
        assert!(nm.ber(0) > 0.10, "starts above 10% ({material:?})");
        assert!(nm.ber(8) < nm.ber(0) / 3.0, "falls with cycles ({material:?})");
    }
    println!("shape check OK: BER > 10% at 0 cycles, monotone decrease to the floor.");
}
