//! Fig. S1: Venn-diagram overlap of peptides identified by SpecPCM,
//! HyperOMS-like and ANN-SoLo-like on one HEK293-like subset (the paper
//! uses b1931). The claim being reproduced: "the majority of peptides
//! detected by SpecPCM can also be found by other tools".

use std::collections::HashSet;

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::{exact, hd_soft, levels_to_f32};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{HdFrontend, SearchPipeline};
use specpcm::hd;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::search::fdr_filter;
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn identified_set(scores: &dyn Fn(usize) -> Vec<f32>, ds: &SearchDataset, fdr: f64) -> HashSet<u32> {
    let nt = ds.library.len();
    let mut pairs = Vec::new();
    let mut matched = Vec::new();
    for qi in 0..ds.queries.len() {
        let row = scores(qi);
        let (ti, ts) = row[..nt]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let dsc = row[nt..].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        pairs.push((*ts, dsc));
        matched.push(ds.library[ti].peptide_id);
    }
    let r = fdr_filter(&pairs, fdr);
    r.accepted
        .iter()
        .filter_map(|&qi| {
            (matched[qi] == ds.queries[qi].peptide_id).then(|| matched[qi]).flatten()
        })
        .collect()
}

fn main() -> Result<()> {
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::hek293_like(1931, 0.25);
    let backend = BackendDispatcher::from_config(&cfg);

    let fe = HdFrontend::new(&cfg);
    let all_refs: Vec<&Spectrum> = ds.library.iter().chain(ds.decoys.iter()).collect();
    let ref_levels = fe.levels_of(&all_refs);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let q_levels = fe.levels_of(&queries);

    let ref_floats: Vec<Vec<f32>> = ref_levels.iter().map(|l| levels_to_f32(l)).collect();
    let ann: HashSet<u32> = identified_set(
        &|qi| exact::search_scores(&levels_to_f32(&q_levels[qi]), &ref_floats),
        &ds,
        cfg.fdr,
    );
    let ref_hvs: Vec<hd::Hv> = ref_levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let ref_bits = hd_soft::pack_refs(&ref_hvs);
    let oms: HashSet<u32> = identified_set(
        &|qi| hd_soft::search_scores(&hd::encode(&q_levels[qi], &fe.im), &ref_bits),
        &ds,
        cfg.fdr,
    );
    let out = SearchPipeline::new(cfg).run(&ds, &backend)?;
    let spec: HashSet<u32> = out.identified_peptides.iter().copied().collect();

    let count = |s: &HashSet<u32>| s.len();
    let inter = |a: &HashSet<u32>, b: &HashSet<u32>| a.intersection(b).count();
    let all3 = spec
        .iter()
        .filter(|p| ann.contains(p) && oms.contains(p))
        .count();

    let rows = vec![
        vec!["SpecPCM only".into(), format!("{}", spec.iter().filter(|p| !ann.contains(p) && !oms.contains(p)).count())],
        vec!["ANN-SoLo only".into(), format!("{}", ann.iter().filter(|p| !spec.contains(p) && !oms.contains(p)).count())],
        vec!["HyperOMS only".into(), format!("{}", oms.iter().filter(|p| !spec.contains(p) && !ann.contains(p)).count())],
        vec!["SpecPCM & ANN-SoLo".into(), format!("{}", inter(&spec, &ann))],
        vec!["SpecPCM & HyperOMS".into(), format!("{}", inter(&spec, &oms))],
        vec!["ANN-SoLo & HyperOMS".into(), format!("{}", inter(&ann, &oms))],
        vec!["all three".into(), format!("{all3}")],
        vec!["|SpecPCM| / |ANN-SoLo| / |HyperOMS|".into(), format!("{} / {} / {}", count(&spec), count(&ann), count(&oms))],
    ];
    println!(
        "{}",
        render_table(
            "Fig. S1 — identified-peptide overlap (b1931-like subset, 1% FDR)",
            &["region", "peptides"],
            &rows
        )
    );

    // Reproduction contract: the majority of SpecPCM's peptides are also
    // found by at least one other tool.
    let shared = spec
        .iter()
        .filter(|p| ann.contains(p) || oms.contains(p))
        .count();
    assert!(
        shared * 2 >= spec.len(),
        "majority shared: {shared} of {}",
        spec.len()
    );
    println!(
        "shape check OK: {shared}/{} SpecPCM peptides also found by other tools.",
        spec.len()
    );
    Ok(())
}
