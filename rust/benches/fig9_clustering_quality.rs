//! Fig. 9: clustering quality on the (synthetic) PXD000561-like corpus —
//! clustered-spectra ratio as a function of incorrect-clustering ratio for
//! SpecPCM at SLC / MLC2 / MLC3 against falcon-like and msCRUSH-like
//! baselines (threshold sweeps trace each curve).
//!
//! Expected shape (the reproduction contract): SLC >= MLC2 >= MLC3 with a
//! small spread (dimension packing costs little), all well above msCRUSH;
//! ~60%-scale clustered ratio in the <=2% incorrect region.

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::{greedy_nn, levels_to_f32, lsh};
use specpcm::cluster::quality::{clustered_at_incorrect, evaluate, ClusterQuality};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, HdFrontend};
use specpcm::ms::{bucket_by_precursor, ClusteringDataset, Spectrum};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn curve_to_rows(name: &str, curve: &[ClusterQuality], rows: &mut Vec<Vec<String>>) {
    // Downsample the sweep to readable rows in the region of interest.
    for q in curve.iter().filter(|q| q.incorrect_ratio <= 0.05) {
        rows.push(vec![
            name.to_string(),
            format!("{:.2}", q.threshold),
            format!("{:.4}", q.incorrect_ratio),
            format!("{:.4}", q.clustered_ratio),
        ]);
    }
}

fn main() -> Result<()> {
    let base = SpecPcmConfig {
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let ds = ClusteringDataset::pxd000561_like(base.seed, 0.25);
    println!(
        "workload: {} spectra, {} ground-truth peptides (stand-in for PXD000561)\n",
        ds.len(),
        ds.n_peptides
    );
    let backend = BackendDispatcher::from_config(&base);

    let truth: Vec<u32> = ds
        .spectra
        .iter()
        .map(|s| s.peptide_id.unwrap_or(u32::MAX))
        .collect();

    let mut rows = Vec::new();
    let mut summary = Vec::new();

    // --- SpecPCM at SLC / MLC2 / MLC3 -------------------------------------
    for mlc in [1u8, 2, 3] {
        let cfg = SpecPcmConfig { mlc_bits: mlc, ..base.clone() };
        let out = ClusteringPipeline::new(cfg).run(&ds, &backend)?;
        let name = format!("SpecPCM MLC{mlc}");
        curve_to_rows(&name, &out.curve, &mut rows);
        summary.push((name, clustered_at_incorrect(&out.curve, 0.015)));
    }

    // --- Baselines (threshold sweeps on the same buckets) ------------------
    let fe = HdFrontend::new(&base);
    let all: Vec<&Spectrum> = ds.spectra.iter().collect();
    let levels = fe.levels_of(&all);
    let floats: Vec<Vec<f32>> = levels.iter().map(|l| levels_to_f32(l)).collect();
    let buckets = bucket_by_precursor(&ds.spectra, base.bucket_width);

    let mut run_partitioner =
        |name: &str, f: &mut dyn FnMut(&[Vec<f32>], f32) -> Vec<usize>, sweep: &[f32]| {
            let mut curve = Vec::new();
            for &t in sweep {
                let mut labels = vec![usize::MAX; ds.len()];
                let mut next = 0usize;
                for members in buckets.values() {
                    let vecs: Vec<Vec<f32>> =
                        members.iter().map(|&i| floats[i].clone()).collect();
                    let local = f(&vecs, t);
                    for (li, &gi) in members.iter().enumerate() {
                        labels[gi] = next + local[li];
                    }
                    next += members.len();
                }
                curve.push(evaluate(&labels, &truth, t));
            }
            curve_to_rows(name, &curve, &mut rows);
            summary.push((name.to_string(), clustered_at_incorrect(&curve, 0.015)));
        };

    let falcon_sweep: Vec<f32> = (0..12).map(|i| 0.95 - i as f32 * 0.03).collect();
    run_partitioner(
        "falcon-like",
        &mut |vecs, t| greedy_nn::cluster(vecs, t),
        &falcon_sweep,
    );
    run_partitioner(
        "msCRUSH-like",
        &mut |vecs, t| lsh::cluster(vecs, 6, 12, t, base.seed),
        &falcon_sweep,
    );

    println!(
        "{}",
        render_table(
            "Fig. 9 — clustering quality curves (region of interest: incorrect <= 5%)",
            &["series", "threshold", "incorrect ratio", "clustered ratio"],
            &rows
        )
    );

    let srows: Vec<Vec<String>> = summary
        .iter()
        .map(|(n, v)| vec![n.clone(), format!("{:.4}", v)])
        .collect();
    println!(
        "{}",
        render_table(
            "clustered ratio at <=1.5% incorrect (paper: SLC 60.57%, MLC2 59.80%, MLC3 59.54%)",
            &["series", "clustered ratio"],
            &srows
        )
    );

    // Shape checks.
    let get = |name: &str| summary.iter().find(|(n, _)| n == name).unwrap().1;
    let (slc, _mlc2, mlc3) = (get("SpecPCM MLC1"), get("SpecPCM MLC2"), get("SpecPCM MLC3"));
    assert!(slc >= mlc3 - 0.02, "SLC {slc} vs MLC3 {mlc3}");
    assert!(slc - mlc3 < 0.1, "packing cost stays small: {slc} vs {mlc3}");
    assert!(mlc3 > get("msCRUSH-like"), "SpecPCM beats msCRUSH-like");
    println!("shape check OK: SLC >= MLC2/MLC3 within a small spread; SpecPCM > msCRUSH.");
    Ok(())
}
