//! Best-match selection over similarity scores.

/// One query's best target and decoy matches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Match {
    /// Index of the best-scoring target reference (into the candidate set).
    pub target_idx: usize,
    pub target_score: f32,
    /// Best decoy score for the same query (drives the FDR estimate).
    pub decoy_score: f32,
}

/// Outcome of searching one query batch.
#[derive(Clone, Debug, Default)]
pub struct SearchOutcome {
    pub matches: Vec<Option<Match>>,
}

/// Select the best target and decoy per query from a row-major score
/// matrix (`n_queries x (n_targets + n_decoys)`); the first `n_targets`
/// columns are targets, the rest decoys. Queries with no candidates yield
/// `None`.
pub fn best_matches(
    scores: &[f32],
    n_queries: usize,
    n_targets: usize,
    n_decoys: usize,
) -> SearchOutcome {
    let cols = n_targets + n_decoys;
    assert_eq!(scores.len(), n_queries * cols, "score matrix shape");
    let mut matches = Vec::with_capacity(n_queries);
    for q in 0..n_queries {
        let row = &scores[q * cols..(q + 1) * cols];
        if n_targets == 0 {
            matches.push(None);
            continue;
        }
        let (ti, ts) = row[..n_targets]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let ds = row[n_targets..]
            .iter()
            .copied()
            .fold(f32::NEG_INFINITY, f32::max);
        matches.push(Some(Match {
            target_idx: ti,
            target_score: *ts,
            decoy_score: if n_decoys > 0 { ds } else { f32::NEG_INFINITY },
        }));
    }
    SearchOutcome { matches }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_best_target_and_decoy() {
        // 1 query, 3 targets, 2 decoys.
        let scores = vec![1.0, 5.0, 3.0, 2.0, 4.0];
        let out = best_matches(&scores, 1, 3, 2);
        let m = out.matches[0].unwrap();
        assert_eq!(m.target_idx, 1);
        assert_eq!(m.target_score, 5.0);
        assert_eq!(m.decoy_score, 4.0);
    }

    #[test]
    fn no_targets_yields_none() {
        let out = best_matches(&[], 1, 0, 0);
        assert!(out.matches[0].is_none());
    }

    #[test]
    fn no_decoys_neg_infinity() {
        let scores = vec![1.0, 2.0];
        let out = best_matches(&scores, 1, 2, 0);
        assert_eq!(out.matches[0].unwrap().decoy_score, f32::NEG_INFINITY);
    }

    #[test]
    fn multiple_queries_rows_independent() {
        let scores = vec![
            9.0, 1.0, 0.5, // q0
            1.0, 8.0, 7.5, // q1
        ];
        let out = best_matches(&scores, 2, 2, 1);
        assert_eq!(out.matches[0].unwrap().target_idx, 0);
        assert_eq!(out.matches[1].unwrap().target_idx, 1);
        assert_eq!(out.matches[1].unwrap().decoy_score, 7.5);
    }
}
