//! Database search (paper Fig. 2, §III-C "IMC for DB search").
//!
//! Query HVs are compared against all reference HVs via Hamming/dot
//! similarity; the near-memory ASIC picks the best-scoring candidate and
//! the result list is filtered at a fixed false-discovery rate using the
//! target-decoy method [17].

pub mod engine;
pub mod fdr;

pub use engine::{Match, SearchOutcome};
pub use fdr::{fdr_filter, FdrResult};
