//! Target-decoy false-discovery-rate filtering (Elias & Gygi [17]).
//!
//! Every query contributes its best target-vs-decoy match; sorting all
//! matches by score and walking down, the FDR at a score threshold is
//! (#decoy hits above) / (#target hits above). The paper fixes FDR = 1%
//! and reports the number of identified peptides (Fig. 10, Table 3).

/// Result of FDR filtering at a fixed rate.
#[derive(Clone, Debug, Default)]
pub struct FdrResult {
    /// Score threshold achieving the requested FDR.
    pub threshold: f32,
    /// Indices of accepted (identified) queries.
    pub accepted: Vec<usize>,
    /// Estimated FDR actually achieved at the threshold.
    pub achieved_fdr: f64,
}

/// Filter per-query (target_score, decoy_score) pairs at `fdr` (e.g. 0.01).
///
/// Implementation: pool target and decoy scores, sort descending, find the
/// lowest threshold where decoys/targets <= fdr, then accept target matches
/// whose score >= threshold *and* beats their own decoy.
pub fn fdr_filter(pairs: &[(f32, f32)], fdr: f64) -> FdrResult {
    if pairs.is_empty() {
        return FdrResult::default();
    }

    // (score, is_decoy) pooled competition.
    let mut pool: Vec<(f32, bool)> = Vec::with_capacity(pairs.len() * 2);
    for &(t, d) in pairs {
        if t.is_finite() {
            pool.push((t, false));
        }
        if d.is_finite() {
            pool.push((d, true));
        }
    }
    // Descending by score; at tied scores decoys sort *first* so the
    // running decoy count is included before any tied target can set the
    // threshold — the conservative convention of standard target-decoy
    // practice (counting tied targets first understates the FDR).
    pool.sort_by(|a, b| b.0.total_cmp(&a.0).then(b.1.cmp(&a.1)));

    let mut best_threshold = f32::INFINITY;
    let mut achieved = 0.0f64;
    let (mut targets, mut decoys) = (0u64, 0u64);
    for &(score, is_decoy) in &pool {
        if is_decoy {
            decoys += 1;
        } else {
            targets += 1;
        }
        if targets > 0 {
            let cur_fdr = decoys as f64 / targets as f64;
            if cur_fdr <= fdr {
                best_threshold = score;
                achieved = cur_fdr;
            }
        }
    }

    if best_threshold == f32::INFINITY {
        return FdrResult {
            threshold: f32::INFINITY,
            accepted: vec![],
            achieved_fdr: 0.0,
        };
    }

    let accepted = pairs
        .iter()
        .enumerate()
        .filter(|(_, &(t, d))| t >= best_threshold && t > d)
        .map(|(i, _)| i)
        .collect();

    FdrResult {
        threshold: best_threshold,
        accepted,
        achieved_fdr: achieved,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_separation_accepts_all_targets() {
        // Targets score ~10, decoys ~1: everything identifiable at 1%.
        let pairs: Vec<(f32, f32)> = (0..100)
            .map(|i| (10.0 + (i % 7) as f32 * 0.1, 1.0 + (i % 5) as f32 * 0.1))
            .collect();
        let r = fdr_filter(&pairs, 0.01);
        assert_eq!(r.accepted.len(), 100);
        assert!(r.achieved_fdr <= 0.01);
    }

    #[test]
    fn no_separation_rejects_most() {
        // Target and decoy scores identically distributed: at 1% FDR almost
        // nothing should pass.
        let pairs: Vec<(f32, f32)> = (0..200)
            .map(|i| {
                let x = (i * 2654435761u64 as usize % 1000) as f32 / 100.0;
                let y = ((i + 7) * 2654435761u64 as usize % 1000) as f32 / 100.0;
                (x, y)
            })
            .collect();
        let r = fdr_filter(&pairs, 0.01);
        assert!(
            r.accepted.len() < 20,
            "accepted {} of 200 with no separation",
            r.accepted.len()
        );
    }

    #[test]
    fn stricter_fdr_accepts_fewer() {
        let pairs: Vec<(f32, f32)> = (0..300)
            .map(|i| {
                let t = if i < 200 { 10.0 + (i % 10) as f32 } else { 3.0 + (i % 10) as f32 };
                let d = 2.5 + (i % 12) as f32;
                (t, d)
            })
            .collect();
        let strict = fdr_filter(&pairs, 0.001);
        let loose = fdr_filter(&pairs, 0.05);
        assert!(strict.accepted.len() <= loose.accepted.len());
    }

    #[test]
    fn empty_input() {
        let r = fdr_filter(&[], 0.01);
        assert!(r.accepted.is_empty());
    }

    #[test]
    fn tied_scores_count_decoys_first() {
        // 10 targets at score 5.0 and one decoy also at exactly 5.0 (its
        // own target is far below threshold). Counting the tied decoy
        // *before* the tied targets, the FDR at 5.0 is 1/10 = 10%.
        let mut pairs: Vec<(f32, f32)> = (0..10).map(|_| (5.0, 1.0)).collect();
        pairs.push((0.5, 5.0));

        // At 5% FDR the tied block is not acceptable: nothing passes. (The
        // pre-fix score-only sort counted the 10 targets first, set the
        // threshold at 5.0 with an "achieved" FDR of 0, and accepted all
        // ten.)
        let strict = fdr_filter(&pairs, 0.05);
        assert!(
            strict.accepted.is_empty(),
            "tied decoy ignored: accepted {:?}",
            strict.accepted
        );

        // At 20% FDR the same block is acceptable (1/10 = 10%), so the
        // conservative tie-break must not over-reject either.
        let loose = fdr_filter(&pairs, 0.2);
        assert_eq!(loose.accepted.len(), 10);
        assert!((loose.achieved_fdr - 0.1).abs() < 1e-12);
        assert!(!loose.accepted.contains(&10)); // the decoy-dominated query
    }

    #[test]
    fn accepted_beat_their_own_decoy() {
        let pairs = vec![(10.0, 12.0), (10.0, 1.0)];
        let r = fdr_filter(&pairs, 0.5);
        // Query 0's decoy outranks its target: never accepted.
        assert!(!r.accepted.contains(&0));
    }
}
