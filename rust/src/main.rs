//! SpecPCM command-line launcher.
//!
//! Subcommands drive the two end-to-end pipelines on synthetic datasets,
//! inspect the hardware model, and exercise the ISA. The MVM hot path runs
//! on a pluggable backend (`--backend ref|parallel|pjrt`, default
//! `parallel`); the PJRT artifact path additionally needs the `pjrt`
//! cargo feature and a built `artifacts/` tree. All backends produce
//! bit-identical scores. (Offline environment: argument parsing is
//! hand-rolled, no clap.)

#![forbid(unsafe_code)]

use specpcm::backend::{BackendDispatcher, BackendKind};
use specpcm::baselines::latency_model;
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::{SpecPcmConfig, Task};
use specpcm::coordinator::{
    tile_fill_target, ArrivalTrace, BatchOutcome, ChaosPlan, ClusteringPipeline, CoalescePolicy,
    FrontDoor, RefreshPolicy, RemoteEngine, SearchEngine, SearchPipeline, ServeEngine, ShardPlan,
    ShardedSearchEngine,
};
use specpcm::encode::EncodeKind;
use specpcm::energy::area_breakdown;
use specpcm::ms::{ClusteringDataset, SearchDataset, Spectrum};
use specpcm::telemetry::render_table;
use specpcm::util::error::{Error, Result};
use specpcm::util::Rng;

const USAGE: &str = "\
specpcm — PCM-based analog IMC accelerator for MS analysis

USAGE:
  specpcm cluster [--dataset pxd001468|pxd000561] [--scale F] [--config FILE]
                  [--backend ref|parallel|pjrt] [--threads N] [--num-banks N]
                  [--encode-backend scalar|bitpacked|parallel] [--no-artifacts]
  specpcm search  [--dataset iprg2012|hek293]     [--scale F] [--config FILE]
                  [--backend ref|parallel|pjrt] [--threads N] [--num-banks N]
                  [--encode-backend scalar|bitpacked|parallel]
                  [--serve-batches N] [--shards N|auto] [--workers N|auto]
                  [--no-artifacts]
                  [--age-seconds T] [--refresh-age A] [--refresh-budget N]
                  [--coalesce size|deadline|off] [--max-batch N]
                  [--deadline-ticks N] [--trace-seed N]
  specpcm info                  print the hardware model (Tables 1/S3, Fig. 8)
  specpcm config [clustering|search]   print a config preset
  specpcm isa <file>            assemble + run an ISA program

SERVING:
  --serve-batches N   program the reference library into the banks once,
                      then stream the queries in N batches through the
                      persistent SearchEngine; reports the one-time
                      programming cost vs the marginal per-batch cost and
                      the amortized total.

FRONT DOOR (serving mode):
  --coalesce P        serve the queries as a stream of single-spectrum
                      requests through the dynamic-batching front door
                      instead of fixed chunks: requests enter a bounded
                      FIFO queue and coalesce into batches. P = size
                      (flush at the tile-fill target), deadline (size
                      trigger plus a logical-tick latency bound), off
                      (batch-size-1 naive baseline). Implies serving
                      mode; mutually exclusive with --serve-batches.
                      Arrivals follow a seeded Poisson-like trace on the
                      engine's logical clock (~1 request/tick); results
                      are bit-identical to any other serving split. The
                      report prints queue depth, batch fill, and p50/p99
                      queue latency next to the device-health line; with
                      --refresh-age, idle gaps between flushes also run
                      maintain increments (refresh-in-the-gaps).
  --max-batch N       override the tile-fill target (default/0: derive
                      from the backend's min_utilization heuristic — 39
                      queries/tile at the config default 0.3).
  --deadline-ticks N  latency bound for --coalesce deadline (default 64
                      logical ticks; rejected with other policies).
  --trace-seed N      seed for the arrival trace (default: config seed).

DRIFT (serving mode):
  --age-seconds T     advance the engine's deterministic serving clock by
                      T seconds after programming, so the stored
                      conductances serve with t^-nu drift applied. Implies
                      serving mode (one batch) when --serve-batches is 0.
  --refresh-age A     run one background refresh epoch before serving:
                      every bucket whose stalest row exceeds A seconds is
                      re-programmed in place (charged to the one-time
                      ledger). Requires serving mode; reports the epoch
                      and the device-health telemetry.
  --refresh-budget N  cap a refresh epoch at the N stalest buckets
                      (0 = unbounded; needs --refresh-age).

SHARDING:
  --shards N|auto     split a library that overflows one engine's banks
                      across N engines (each with its own num_banks bank
                      pool), served concurrently with per-query bests
                      merged bit-identically to one big-enough engine.
                      'auto' (the default) computes the minimum shard
                      count from the capacity pre-flight, so the full
                      presets run at --scale 1.0 without shrinking.

REMOTE WORKERS:
  --workers N|auto    like --shards, but each shard lives in its own
                      supervised worker *process* (this binary re-exec'd,
                      stdin/stdout wire protocol): per-request deadlines,
                      bounded retries with exponential backoff, circuit
                      breakers, and bit-identical respawn — all on the
                      deterministic logical clock. A shard down past its
                      retry budget degrades the batch to partial coverage
                      instead of failing it. With no faults, results and
                      op counts are bit-identical to --shards. Tuned by
                      the [remote] config section (deadline_ticks,
                      retries, backoff_base_ticks, breaker_threshold).
                      Mutually exclusive with --shards (remote serving
                      plans its own shard-per-worker split).

CAPACITY:
  The engine places every reference HV on a physical bank row; at the
  paper-default D=8192 / 128 banks there are 640 reference slots per
  engine. A library that overflows them is auto-sharded (see SHARDING);
  forcing --shards N that still doesn't fit fails with a typed
  CapacityError rather than silently ignoring num_banks.

BACKENDS:
  MVM (--backend): how score tiles execute
    ref       single-threaded reference path (bit-exact oracle)
    parallel  bank-sharded across host threads (default; --threads 0 = auto);
              single-query jobs stripe the candidate span across workers
              (--stripe-rows N overrides the stripe height, 0 = auto)
    pjrt      AOT artifacts through PJRT (needs the `pjrt` cargo feature)
  Encode (--encode-backend): how HD encode+pack executes
    scalar     element-serial reference path (bit-exact oracle)
    bitpacked  u64 word-packed kernels (XOR bind + popcount)
    parallel   spectra sharded across threads, bitpacked per shard (default)
  All combinations produce bit-identical results; only host speed differs.
";

/// Tiny flag parser: `--key value`, `--key=value` and bare `--flag` forms.
/// Negative numbers are valid values (`--scale -0.5`): only tokens that
/// start with `--` are treated as flag names.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` separator: everything after is positional.
                    positional.extend(it.by_ref().cloned());
                    break;
                }
                if let Some((key, value)) = name.split_once('=') {
                    flags.insert(key.to_string(), value.to_string());
                    continue;
                }
                // A following token is this flag's value unless it is
                // itself a flag. `-0.5` does not start with `--`, so
                // negative numeric values parse as values, never as a
                // bare flag plus a stray positional.
                let value = match it.next_if(|v| !v.starts_with("--")) {
                    Some(v) => v.clone(),
                    None => "true".to_string(), // bare flag
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or(default.to_string())
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::msg(format!("--{key}: '{v}' is not a number"))),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::msg(format!("--{key}: '{v}' is not a non-negative integer"))),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Reject typo'd flags instead of silently ignoring them (a misspelled
    /// `--stripe-rows` used to fall back to the default without a word).
    fn check_known(&self, cmd: &str, known: &[&str]) -> Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(String::as_str)
            .filter(|k| !known.contains(k))
            .collect();
        unknown.sort_unstable();
        if let Some(k) = unknown.first() {
            if known.is_empty() {
                specpcm::bail!("--{k}: '{cmd}' takes no flags");
            }
            let list = known
                .iter()
                .map(|k| format!("--{k}"))
                .collect::<Vec<_>>()
                .join(" ");
            specpcm::bail!("unknown flag --{k} for '{cmd}' (known: {list})");
        }
        Ok(())
    }
}

/// The flags `cmd` accepts (every pipeline subcommand shares the
/// config/backend set that `load_cfg` applies).
fn known_flags(cmd: &str) -> Vec<&'static str> {
    let mut v = vec![
        "config",
        "backend",
        "encode-backend",
        "threads",
        "stripe-rows",
        "num-banks",
        "no-artifacts",
    ];
    match cmd {
        "cluster" => v.extend(["dataset", "scale"]),
        "search" => v.extend([
            "dataset",
            "scale",
            "serve-batches",
            "shards",
            "workers",
            "age-seconds",
            "refresh-age",
            "refresh-budget",
            "coalesce",
            "max-batch",
            "deadline-ticks",
            "trace-seed",
        ]),
        _ => v.clear(), // info/config/isa take positionals only
    }
    v
}

fn load_cfg(args: &Args, default: SpecPcmConfig) -> Result<SpecPcmConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(p) => SpecPcmConfig::load(p)?,
        None => default,
    };
    if args.has("no-artifacts") {
        cfg.use_artifacts = false;
    }
    if let Some(b) = args.flags.get("backend") {
        cfg.backend.kind = BackendKind::from_name(b)?;
    }
    if let Some(e) = args.flags.get("encode-backend") {
        cfg.backend.encode_kind = EncodeKind::from_name(e)?;
    }
    cfg.backend.threads = args.get_usize("threads", cfg.backend.threads)?;
    cfg.backend.stripe_rows = args.get_usize("stripe-rows", cfg.backend.stripe_rows)?;
    cfg.num_banks = args.get_usize("num-banks", cfg.num_banks)?;
    if let Some(s) = args.flags.get("shards") {
        cfg.backend.shards = if s == "auto" {
            0
        } else {
            s.parse().map_err(|_| {
                Error::msg(format!("--shards: '{s}' is not a shard count or 'auto'"))
            })?
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Drift-aware serving options (`--age-seconds` / `--refresh-age` /
/// `--refresh-budget`). `refresh` is `Some` only when `--refresh-age`
/// was given; a budget without a threshold is a usage error.
struct DriftOpts {
    age_seconds: f64,
    refresh: Option<RefreshPolicy>,
}

impl DriftOpts {
    fn parse(args: &Args) -> Result<Self> {
        let age_seconds = args.get_f64("age-seconds", 0.0)?;
        specpcm::ensure!(
            age_seconds.is_finite() && age_seconds >= 0.0,
            "--age-seconds: '{age_seconds}' is not a non-negative duration"
        );
        let refresh = if args.has("refresh-age") {
            let max_age_seconds = args.get_f64("refresh-age", 0.0)?;
            specpcm::ensure!(
                max_age_seconds.is_finite() && max_age_seconds >= 0.0,
                "--refresh-age: '{max_age_seconds}' is not a non-negative age threshold"
            );
            Some(RefreshPolicy {
                max_age_seconds,
                budget: args.get_usize("refresh-budget", 0)?,
            })
        } else {
            specpcm::ensure!(
                !args.has("refresh-budget"),
                "--refresh-budget needs --refresh-age (the refresh threshold)"
            );
            None
        };
        Ok(DriftOpts {
            age_seconds,
            refresh,
        })
    }

    fn active(&self) -> bool {
        self.age_seconds > 0.0 || self.refresh.is_some()
    }
}

/// Front-door serving options (`--coalesce` / `--max-batch` /
/// `--deadline-ticks` / `--trace-seed`). `policy` is `Some` only when
/// `--coalesce` was given; the dependent flags without it are usage
/// errors, as is `--deadline-ticks` under a policy with no deadline.
struct CoalesceOpts {
    policy: Option<CoalescePolicy>,
    trace_seed: Option<u64>,
}

impl CoalesceOpts {
    fn parse(args: &Args, min_utilization: f64) -> Result<Self> {
        if !args.has("coalesce") {
            for dep in ["max-batch", "deadline-ticks", "trace-seed"] {
                specpcm::ensure!(
                    !args.has(dep),
                    "--{dep} needs --coalesce (the front-door policy)"
                );
            }
            return Ok(CoalesceOpts {
                policy: None,
                trace_seed: None,
            });
        }
        let name = args.get("coalesce", "size");
        let max_batch = match args.get_usize("max-batch", 0)? {
            0 => tile_fill_target(min_utilization),
            n => n,
        };
        let policy = match name.as_str() {
            "off" => {
                specpcm::ensure!(
                    !args.has("max-batch"),
                    "--max-batch is meaningless with --coalesce off (batch size is 1)"
                );
                specpcm::ensure!(
                    !args.has("deadline-ticks"),
                    "--deadline-ticks needs --coalesce deadline"
                );
                CoalescePolicy::Off
            }
            "size" => {
                specpcm::ensure!(
                    !args.has("deadline-ticks"),
                    "--deadline-ticks needs --coalesce deadline"
                );
                CoalescePolicy::Size { max_batch }
            }
            "deadline" => CoalescePolicy::SizeDeadline {
                max_batch,
                deadline_ticks: args.get_usize("deadline-ticks", 64)? as u64,
            },
            other => {
                specpcm::bail!("--coalesce: unknown policy '{other}' (size|deadline|off)")
            }
        };
        let trace_seed = if args.has("trace-seed") {
            Some(args.get_usize("trace-seed", 0)? as u64)
        } else {
            None
        };
        Ok(CoalesceOpts { policy, trace_seed })
    }

    fn active(&self) -> bool {
        self.policy.is_some()
    }
}

/// Serve the queries as a request stream through the front door (the
/// `--coalesce` path, shared by the monolithic and sharded engines):
/// generate the seeded arrival trace, run it, and print the queue/fill/
/// latency telemetry next to the device-health line. Returns the flushed
/// batches for the usual cost/finalize reporting — bit-identical to any
/// other serving split of the same queries.
fn serve_front_door<E: ServeEngine>(
    engine: &mut E,
    policy: CoalescePolicy,
    trace_seed: u64,
    queries: &[&Spectrum],
    backend: &BackendDispatcher,
    refresh: Option<RefreshPolicy>,
) -> Result<Vec<BatchOutcome>> {
    let mut fd = FrontDoor::new(policy);
    if let Some(p) = refresh {
        fd = fd.with_refresh(p);
    }
    let mut rng = Rng::new(trace_seed);
    let trace = ArrivalTrace::poisson_from_rng(&mut rng, queries.len(), 1.0);
    println!(
        "front door: coalesce={} fill target {} (queue capacity {}), {} requests \
         over {} logical ticks (trace seed {trace_seed:#x})",
        policy.name(),
        policy.max_batch(),
        fd.capacity(),
        queries.len(),
        trace.ticks.last().copied().unwrap_or(0)
    );
    let served = fd.serve_trace(engine, queries, &trace, backend)?;
    println!("{}", served.stats.summary());
    print_health(&engine.device_health());
    Ok(served.outcomes)
}

fn print_health(h: &specpcm::telemetry::DeviceHealth) {
    println!(
        "device health: max age {:.3e} s, est conductance loss {:.2}%, \
         {} injected faults, {} refresh epochs",
        h.max_age_seconds,
        h.est_conductance_loss * 100.0,
        h.injected_faults,
        h.refreshes
    );
}

fn open_backend(cfg: &SpecPcmConfig) -> BackendDispatcher {
    let backend = BackendDispatcher::from_config(cfg);
    eprintln!(
        "backend: mvm={} encode={}",
        backend.primary_name(),
        backend.encode_name()
    );
    backend
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = load_cfg(args, SpecPcmConfig::paper_clustering())?;
    specpcm::ensure!(cfg.task == Task::Clustering, "config task must be clustering");
    let scale = args.get_f64("scale", 0.5)?;
    let ds = match args.get("dataset", "pxd001468").as_str() {
        "pxd001468" => ClusteringDataset::pxd001468_like(cfg.seed, scale),
        "pxd000561" => ClusteringDataset::pxd000561_like(cfg.seed, scale),
        other => specpcm::bail!("unknown dataset '{other}'"),
    };
    let backend = open_backend(&cfg);
    let out = ClusteringPipeline::new(cfg).run(&ds, &backend)?;
    println!("{}: {} spectra, {} buckets", ds.name, out.n_spectra, out.n_buckets);
    println!(
        "clustered ratio @1.5% incorrect: {:.4}",
        clustered_at_incorrect(&out.curve, 0.015)
    );
    println!(
        "IMC ops: {} MVMs, {} program rounds",
        out.ops.mvm_ops, out.ops.program_rounds
    );
    println!(
        "simulated: {:.3} mJ, {:.3} ms (overlapped {:.3} ms)",
        out.report.total_j() * 1e3,
        out.report.total_latency_s() * 1e3,
        out.report.overlapped_latency_s() * 1e3
    );
    let rows: Vec<Vec<String>> = out
        .wall
        .breakdown()
        .into_iter()
        .map(|(s, t, f)| vec![s, format!("{t:.3}s"), format!("{:.1}%", f * 100.0)])
        .collect();
    println!("{}", render_table("host wall time", &["stage", "time", "%"], &rows));
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = load_cfg(args, SpecPcmConfig::paper_search())?;
    specpcm::ensure!(cfg.task == Task::Search, "config task must be search");
    let dataset = args.get("dataset", "iprg2012");
    // Full presets by default: a library that overflows one engine's
    // banks is auto-sharded (`--shards auto`), so --scale no longer needs
    // shrunken per-dataset defaults to fit 640 slots.
    let scale = args.get_f64("scale", 1.0)?;
    // Serving-mode flags validate before the (much more expensive)
    // dataset generation so usage errors surface immediately.
    let drift = DriftOpts::parse(args)?;
    let coalesce = CoalesceOpts::parse(args, cfg.backend.min_utilization)?;
    specpcm::ensure!(
        !(coalesce.active() && args.has("serve-batches")),
        "--serve-batches and --coalesce are mutually exclusive serving modes"
    );
    let workers: Option<usize> = match args.flags.get("workers") {
        None => None,
        Some(w) if w == "auto" => Some(0),
        Some(w) => Some(w.parse().map_err(|_| {
            Error::msg(format!("--workers: '{w}' is not a worker count or 'auto'"))
        })?),
    };
    // Resolution order is explicit, not positional: remote serving plans
    // its own shard-per-worker split, so any --shards (even 'auto') next
    // to --workers is a conflict, never a silently ignored flag.
    specpcm::ensure!(
        workers.is_none() || !args.has("shards"),
        "--workers and --shards are mutually exclusive: remote serving plans its own \
         shard-per-worker split (drop --shards, including --shards auto)"
    );
    let ds = match dataset.as_str() {
        "iprg2012" => SearchDataset::iprg2012_like(cfg.seed, scale),
        "hek293" => SearchDataset::hek293_like(cfg.seed, scale),
        other => specpcm::bail!("unknown dataset '{other}'"),
    };
    let backend = open_backend(&cfg);
    // Drift, refresh, and coalescing are serving-mode concepts (they act
    // on a programmed, persistent engine), so those flags imply one
    // served batch when --serve-batches was not given.
    let n_batches = match args.get_usize("serve-batches", 0)? {
        0 if drift.active() || coalesce.active() => 1,
        n => n,
    };
    if let Some(n_workers) = workers {
        return cmd_search_remote(cfg, &ds, &backend, n_workers, n_batches, &drift, &coalesce);
    }
    let plan = ShardPlan::for_capacity(
        &cfg,
        ds.library.len(),
        ds.decoys.len(),
        cfg.backend.shards,
    )?;
    if plan.n_shards() > 1 {
        return cmd_search_sharded(cfg, &ds, &backend, plan, n_batches, &drift, &coalesce);
    }
    if n_batches > 0 {
        return cmd_serve(cfg, &ds, &backend, n_batches, &drift, &coalesce);
    }
    let fdr = cfg.fdr;
    let out = SearchPipeline::new(cfg).run(&ds, &backend)?;
    println!(
        "{}: identified {}/{} queries at {:.0}% FDR ({} correct)",
        ds.name,
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct
    );
    println!(
        "simulated: {:.3} mJ, {:.3} ms (overlapped {:.3} ms)",
        out.report.total_j() * 1e3,
        out.report.total_latency_s() * 1e3,
        out.report.overlapped_latency_s() * 1e3
    );
    let rows: Vec<Vec<String>> = out
        .wall
        .breakdown()
        .into_iter()
        .map(|(s, t, f)| vec![s, format!("{t:.3}s"), format!("{:.1}%", f * 100.0)])
        .collect();
    println!("{}", render_table("host wall time", &["stage", "time", "%"], &rows));
    Ok(())
}

/// A library that overflows one engine's banks: program it across
/// `n_shards` engines and serve concurrently (`--shards N|auto`). With
/// `--serve-batches 0` the queries go through in one batch; either way
/// the merged results are bit-identical to one big-enough engine.
fn cmd_search_sharded(
    cfg: SpecPcmConfig,
    ds: &SearchDataset,
    backend: &BackendDispatcher,
    plan: ShardPlan,
    n_batches: usize,
    drift: &DriftOpts,
    co: &CoalesceOpts,
) -> Result<()> {
    let fdr = cfg.fdr;
    let per_shard_banks = cfg.num_banks;
    let seed = cfg.seed;
    // The plan cmd_search validated (and routes on) is exactly the plan
    // the engine programs — one planning call site.
    let mut engine = ShardedSearchEngine::program_with_plan(cfg, ds, backend, plan)?;
    println!(
        "sharded library: {} reference rows across {} shards ({} banks each, {} total); \
         rows/shard: {:?}",
        engine.n_refs(),
        engine.n_shards(),
        per_shard_banks,
        engine.total_banks(),
        engine
            .plan()
            .ranges()
            .iter()
            .map(|r| r.len())
            .collect::<Vec<_>>()
    );
    let prog = *engine.program_report();
    println!(
        "programmed once: {:.4} mJ, {:.4} ms ({} program rounds)",
        prog.total_j() * 1e3,
        prog.total_latency_s() * 1e3,
        engine.program_ops().program_rounds
    );
    if drift.age_seconds > 0.0 {
        engine.advance_age(drift.age_seconds);
        println!("aged the library {:.3e} s before serving", drift.age_seconds);
    }
    if let Some(policy) = &drift.refresh {
        let r = engine.maintain(policy);
        println!(
            "refresh epoch (age > {:.3e} s, budget {}): {} rows in {} bucket \
             segments re-programmed ({} program rounds, one-time ledger)",
            policy.max_age_seconds, policy.budget, r.rows, r.buckets, r.ops.program_rounds
        );
    }
    if drift.active() {
        print_health(&engine.device_health());
    }

    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let outcomes = if let Some(policy) = co.policy {
        serve_front_door(
            &mut engine,
            policy,
            co.trace_seed.unwrap_or(seed),
            &queries,
            backend,
            drift.refresh,
        )?
    } else {
        engine.serve_chunked(&queries, n_batches.max(1), backend)?
    };
    // Per-flush tables are a --serve-batches report; under --coalesce off
    // they would print one row per request, and the front door already
    // summarizes its schedule in the telemetry line above.
    if !co.active() && outcomes.len() > 1 {
        let rows: Vec<Vec<String>> = outcomes
            .iter()
            .enumerate()
            .map(|(bi, out)| {
                vec![
                    format!("{bi}"),
                    format!("{}", out.pairs.len()),
                    format!("{:.4}", out.report.total_j() * 1e3),
                    format!("{:.4}", out.report.overlapped_latency_s() * 1e3),
                ]
            })
            .collect();
        println!(
            "{}",
            render_table(
                "marginal per-batch cost (fanned out across every shard)",
                &["batch", "queries", "energy mJ", "latency ms"],
                &rows
            )
        );
    }

    let cost = engine.serving_cost(&outcomes);
    println!(
        "energy:  one-time {:.4} mJ | marginal total {:.4} mJ | amortized/batch {:.4} mJ",
        cost.one_time_j * 1e3,
        cost.marginal_j * 1e3,
        cost.amortized_j_per_batch() * 1e3
    );

    let out = engine.finalize(&queries, &outcomes)?;
    println!(
        "identified {}/{} queries at {:.0}% FDR ({} correct) — bit-identical to one \
         monolithic engine with {} banks",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct,
        engine.total_banks()
    );
    Ok(())
}

/// `--workers N|auto`: serve the shard plan through supervised worker
/// processes (this binary re-exec'd under the hidden `worker`
/// subcommand) instead of in-process threads. Same report shape as the
/// sharded path, plus the supervision counters and the batch coverage —
/// a degraded batch prints its surviving row fraction instead of
/// failing.
fn cmd_search_remote(
    cfg: SpecPcmConfig,
    ds: &SearchDataset,
    backend: &BackendDispatcher,
    n_workers: usize,
    n_batches: usize,
    drift: &DriftOpts,
    co: &CoalesceOpts,
) -> Result<()> {
    let fdr = cfg.fdr;
    let seed = cfg.seed;
    let remote_cfg = cfg.remote;
    let exe = std::env::current_exe().map_err(|e| {
        Error::msg(format!("cannot locate the serving binary to spawn workers: {e}"))
    })?;
    let mut engine = RemoteEngine::program(cfg, ds, n_workers, exe, ChaosPlan::none())?;
    println!(
        "remote workers: {} reference rows across {} worker processes; rows/worker: {:?}",
        engine.n_refs(),
        engine.n_shards(),
        engine
            .plan()
            .ranges()
            .iter()
            .map(|r| r.len())
            .collect::<Vec<_>>()
    );
    println!(
        "supervision: deadline {} ticks, {} retries, backoff base {} ticks, \
         breaker at {} consecutive failures",
        remote_cfg.deadline_ticks,
        remote_cfg.retries,
        remote_cfg.backoff_base_ticks,
        remote_cfg.breaker_threshold
    );
    let prog = *engine.program_report();
    println!(
        "programmed once over the wire: {:.4} mJ, {:.4} ms ({} program rounds)",
        prog.total_j() * 1e3,
        prog.total_latency_s() * 1e3,
        engine.program_ops().program_rounds
    );
    if drift.age_seconds > 0.0 {
        engine.advance_age(drift.age_seconds);
        println!("aged the library {:.3e} s before serving", drift.age_seconds);
    }
    if let Some(policy) = &drift.refresh {
        let r = engine.maintain(policy);
        println!(
            "refresh epoch (age > {:.3e} s, budget {}): {} rows in {} bucket \
             segments re-programmed ({} program rounds, one-time ledger)",
            policy.max_age_seconds, policy.budget, r.rows, r.buckets, r.ops.program_rounds
        );
    }
    if drift.active() {
        print_health(&engine.device_health());
    }

    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let outcomes = if let Some(policy) = co.policy {
        serve_front_door(
            &mut engine,
            policy,
            co.trace_seed.unwrap_or(seed),
            &queries,
            backend,
            drift.refresh,
        )?
    } else {
        engine.serve_chunked(&queries, n_batches.max(1), backend)?
    };

    let stats = engine.worker_stats();
    println!(
        "workers: {}/{} up, {} respawns, {} retries, {} degraded batches, {} breakers open",
        stats.workers_up,
        stats.workers,
        stats.respawns,
        stats.retries,
        stats.degraded_batches,
        stats.breakers_open
    );
    // Partial answers must be visible, never silent (graceful
    // degradation contract): report the worst batch's coverage.
    match outcomes
        .iter()
        .map(|o| o.coverage)
        .min_by(|a, b| a.fraction().total_cmp(&b.fraction()))
    {
        Some(worst) if !worst.is_full() => println!(
            "coverage: DEGRADED — worst batch searched {}/{} rows ({:.1}%)",
            worst.rows_searched,
            worst.rows_total,
            worst.fraction() * 100.0
        ),
        Some(worst) => println!("coverage: full ({} rows) on every batch", worst.rows_total),
        None => {}
    }

    let cost = engine.serving_cost(&outcomes);
    println!(
        "energy:  one-time {:.4} mJ | marginal total {:.4} mJ | amortized/batch {:.4} mJ",
        cost.one_time_j * 1e3,
        cost.marginal_j * 1e3,
        cost.amortized_j_per_batch() * 1e3
    );

    let out = engine.finalize(&queries, &outcomes)?;
    println!(
        "identified {}/{} queries at {:.0}% FDR ({} correct) — bit-identical to \
         --shards {} when no worker faulted",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct,
        engine.n_shards()
    );
    Ok(())
}

/// `--serve-batches N`: the Table 3 serving shape — program the library
/// once, stream the queries in N batches through the persistent engine,
/// and split the report into one-time vs marginal vs amortized cost.
fn cmd_serve(
    cfg: SpecPcmConfig,
    ds: &SearchDataset,
    backend: &BackendDispatcher,
    n_batches: usize,
    drift: &DriftOpts,
    co: &CoalesceOpts,
) -> Result<()> {
    let fdr = cfg.fdr;
    let seed = cfg.seed;
    let mut engine = SearchEngine::program(cfg, ds, backend)?;
    let prog = *engine.program_report();
    println!(
        "programmed {} reference rows once: {:.4} mJ, {:.4} ms ({} program rounds)",
        engine.n_refs(),
        prog.total_j() * 1e3,
        prog.total_latency_s() * 1e3,
        engine.program_ops().program_rounds
    );
    if drift.age_seconds > 0.0 {
        engine.advance_age(drift.age_seconds);
        println!("aged the library {:.3e} s before serving", drift.age_seconds);
    }
    if let Some(policy) = &drift.refresh {
        let r = engine.maintain(policy);
        println!(
            "refresh epoch (age > {:.3e} s, budget {}): {} rows in {} bucket \
             segments re-programmed ({} program rounds, one-time ledger)",
            policy.max_age_seconds, policy.budget, r.rows, r.buckets, r.ops.program_rounds
        );
    }
    if drift.active() {
        print_health(&engine.device_health());
    }

    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let outcomes = if let Some(policy) = co.policy {
        serve_front_door(
            &mut engine,
            policy,
            co.trace_seed.unwrap_or(seed),
            &queries,
            backend,
            drift.refresh,
        )?
    } else {
        engine.serve_chunked(&queries, n_batches, backend)?
    };
    // Per-flush tables are a --serve-batches report; under --coalesce off
    // they would print one row per request, and the front door already
    // summarizes its schedule in the telemetry line above.
    if !co.active() {
        let rows: Vec<Vec<String>> = outcomes
            .iter()
            .enumerate()
            .map(|(bi, out)| {
                vec![
                    format!("{bi}"),
                    format!("{}", out.pairs.len()),
                    format!("{:.4}", out.report.total_j() * 1e3),
                    format!("{:.4}", out.report.overlapped_latency_s() * 1e3),
                ]
            })
            .collect();
        println!(
            "{}",
            render_table(
                "marginal per-batch cost (library programming excluded)",
                &["batch", "queries", "energy mJ", "latency ms"],
                &rows
            )
        );
    }

    let cost = engine.serving_cost(&outcomes);
    println!(
        "energy:  one-time {:.4} mJ | marginal total {:.4} mJ | amortized/batch {:.4} mJ",
        cost.one_time_j * 1e3,
        cost.marginal_j * 1e3,
        cost.amortized_j_per_batch() * 1e3
    );
    println!(
        "latency: one-time {:.4} ms | marginal total {:.4} ms | amortized/batch {:.4} ms",
        cost.one_time_s * 1e3,
        cost.marginal_s * 1e3,
        cost.amortized_s_per_batch() * 1e3
    );

    let out = engine.finalize(&queries, &outcomes)?;
    // At age 0 with no refresh epoch the drift machinery is a strict
    // no-op, so batched serving reproduces the one-shot pipeline byte for
    // byte; an aged/refreshed panel deliberately serves different scores.
    let note = if drift.active() {
        format!(" — served at age {:.3e} s", engine.age_seconds())
    } else {
        " — bit-identical to one-shot".to_string()
    };
    println!(
        "identified {}/{} queries at {:.0}% FDR ({} correct){note}",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct
    );
    Ok(())
}

fn cmd_info() {
    println!("SpecPCM hardware model (Table 1 / S3):");
    let rows: Vec<Vec<String>> = area_breakdown()
        .into_iter()
        .map(|(n, a, f)| vec![n.to_string(), format!("{a:.4} mm2"), format!("{:.1}%", f * 100.0)])
        .collect();
    println!(
        "{}",
        render_table("area breakdown (Fig. 8)", &["component", "area", "%"], &rows)
    );
    println!("paper baselines (Tables 2/3):");
    for b in latency_model::CLUSTERING_BASELINES {
        println!(
            "  [cluster] {:<16} {:<10} {:<10} {:>10.2}s",
            b.tool, b.hardware, b.dataset, b.latency_s
        );
    }
    for b in latency_model::SEARCH_BASELINES {
        println!(
            "  [search]  {:<16} {:<10} {:<10} {:>10.3}s",
            b.tool, b.hardware, b.dataset, b.latency_s
        );
    }
}

fn cmd_isa(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let prog = specpcm::isa::Program::assemble(&text)?;
    println!("assembled {} instructions:", prog.len());
    println!("{}", prog.disassemble());
    let mut ex = specpcm::isa::Executor::new(16, specpcm::device::Material::TiTe2Gst467, 1);
    for i in 0..4u8 {
        ex.set_buffer(i, (0..128).map(|k| ((k % 7) as i64 - 3) as f32).collect());
    }
    let res = ex.run(&prog)?;
    println!(
        "executed: {} MVMs, {} row reads, {} program rounds",
        res.ops.mvm_ops, res.ops.row_reads, res.ops.program_rounds
    );
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        // Typed errors surface as a one-line message (not a Debug dump or
        // a panic): `--stripe-rows banana` reports, it doesn't backtrace.
        eprintln!("error: {e}");
        eprintln!("run `specpcm help` for usage");
        std::process::exit(2);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "cluster" | "search" | "info" | "config" | "isa" | "worker" => {
            args.check_known(cmd, &known_flags(cmd))?
        }
        _ => {}
    }
    match cmd.as_str() {
        "cluster" => cmd_cluster(&args)?,
        "search" => cmd_search(&args)?,
        // Hidden: the remote supervisor re-execs this binary as `specpcm
        // worker` and owns both stdio pipes — stdout is the wire, so the
        // worker loop never prints. Not in USAGE on purpose.
        "worker" => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            // lint: sync-ok (StdinLock/StdoutLock are stdio handles, not poisonable Mutex guards)
            specpcm::coordinator::remote::run_worker(&mut stdin.lock(), &mut stdout.lock())?;
        }
        "info" => cmd_info(),
        "config" => {
            let cfg = match args.positional.first().map(String::as_str).unwrap_or("clustering") {
                "clustering" => SpecPcmConfig::paper_clustering(),
                "search" => SpecPcmConfig::paper_search(),
                other => specpcm::bail!("unknown task '{other}'"),
            };
            println!("{}", cfg.to_toml());
        }
        "isa" => {
            let path = args
                .positional
                .first()
                .ok_or(Error::msg("isa: missing <file>"))?;
            cmd_isa(path)?;
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn negative_numeric_flag_values_parse() {
        let a = Args::parse(&argv(&["--scale", "-0.5", "pos"])).unwrap();
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), -0.5);
        assert_eq!(a.positional, vec!["pos".to_string()]);
        // Equals form too.
        let a = Args::parse(&argv(&["--scale=-0.5"])).unwrap();
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), -0.5);
    }

    #[test]
    fn bare_flag_followed_by_flag() {
        let a = Args::parse(&argv(&["--no-artifacts", "--scale", "0.3"])).unwrap();
        assert!(a.has("no-artifacts"));
        assert_eq!(a.get("no-artifacts", ""), "true");
        assert_eq!(a.get_f64("scale", 0.0).unwrap(), 0.3);
    }

    #[test]
    fn double_dash_separator() {
        let a = Args::parse(&argv(&["--scale", "1.5", "--", "--not-a-flag"])).unwrap();
        assert_eq!(a.positional, vec!["--not-a-flag".to_string()]);
    }

    #[test]
    fn get_usize_rejects_garbage() {
        let a = Args::parse(&argv(&["--threads", "8"])).unwrap();
        assert_eq!(a.get_usize("threads", 0).unwrap(), 8);
        let a = Args::parse(&argv(&["--threads", "-4"])).unwrap();
        assert!(a.get_usize("threads", 0).is_err());
    }

    #[test]
    fn backend_flags_apply_to_config() {
        let a = Args::parse(&argv(&["--backend", "ref", "--threads", "2", "--stripe-rows", "384"]))
            .unwrap();
        let cfg = load_cfg(&a, SpecPcmConfig::paper_clustering()).unwrap();
        assert_eq!(cfg.backend.kind, BackendKind::Reference);
        assert_eq!(cfg.backend.threads, 2);
        assert_eq!(cfg.backend.stripe_rows, 384);
        let bad = Args::parse(&argv(&["--backend", "gpu"])).unwrap();
        assert!(load_cfg(&bad, SpecPcmConfig::paper_clustering()).is_err());
    }

    #[test]
    fn encode_backend_flag_applies_to_config() {
        let a = Args::parse(&argv(&["--encode-backend", "bitpacked"])).unwrap();
        let cfg = load_cfg(&a, SpecPcmConfig::paper_clustering()).unwrap();
        assert_eq!(cfg.backend.encode_kind, EncodeKind::Bitpacked);
        // Default stays the parallel encode path.
        let none = Args::parse(&argv(&[])).unwrap();
        let cfg = load_cfg(&none, SpecPcmConfig::paper_clustering()).unwrap();
        assert_eq!(cfg.backend.encode_kind, EncodeKind::Parallel);
        let bad = Args::parse(&argv(&["--encode-backend", "gpu"])).unwrap();
        assert!(load_cfg(&bad, SpecPcmConfig::paper_clustering()).is_err());
    }

    #[test]
    fn shards_flag_parses_count_and_auto() {
        let a = Args::parse(&argv(&["--shards", "4"])).unwrap();
        let cfg = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap();
        assert_eq!(cfg.backend.shards, 4);

        let a = Args::parse(&argv(&["--shards", "auto"])).unwrap();
        let cfg = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap();
        assert_eq!(cfg.backend.shards, 0);

        // Default is auto.
        let none = Args::parse(&argv(&[])).unwrap();
        let cfg = load_cfg(&none, SpecPcmConfig::paper_search()).unwrap();
        assert_eq!(cfg.backend.shards, 0);

        let bad = Args::parse(&argv(&["--shards", "many"])).unwrap();
        assert!(load_cfg(&bad, SpecPcmConfig::paper_search()).is_err());
    }

    #[test]
    fn drift_flags_parse_and_validate() {
        let a = Args::parse(&argv(&[
            "--age-seconds",
            "1e9",
            "--refresh-age",
            "0",
            "--refresh-budget",
            "5",
        ]))
        .unwrap();
        let d = DriftOpts::parse(&a).unwrap();
        assert_eq!(d.age_seconds, 1.0e9);
        assert!(d.active());
        let p = d.refresh.unwrap();
        assert_eq!(p.max_age_seconds, 0.0);
        assert_eq!(p.budget, 5);
        // The drift flags belong to search, not cluster.
        assert!(a.check_known("search", &known_flags("search")).is_ok());
        assert!(a.check_known("cluster", &known_flags("cluster")).is_err());

        // A budget without a threshold is a usage error, not a silent no-op.
        let a = Args::parse(&argv(&["--refresh-budget", "3"])).unwrap();
        let err = DriftOpts::parse(&a).unwrap_err();
        assert!(err.to_string().contains("--refresh-age"), "{err}");

        // Negative / malformed values report typed errors.
        let a = Args::parse(&argv(&["--age-seconds", "-5"])).unwrap();
        assert!(DriftOpts::parse(&a).is_err());
        let a = Args::parse(&argv(&["--refresh-age", "banana"])).unwrap();
        assert!(DriftOpts::parse(&a).is_err());

        // Absent flags leave serving untouched.
        let none = Args::parse(&argv(&[])).unwrap();
        let d = DriftOpts::parse(&none).unwrap();
        assert_eq!(d.age_seconds, 0.0);
        assert!(d.refresh.is_none() && !d.active());
    }

    #[test]
    fn coalesce_flags_parse_and_validate() {
        // Absent flags leave serving untouched.
        let none = Args::parse(&argv(&[])).unwrap();
        let c = CoalesceOpts::parse(&none, 0.3).unwrap();
        assert!(c.policy.is_none() && c.trace_seed.is_none() && !c.active());

        // The size policy defaults its batch to the tile-fill target the
        // backend routing heuristic implies (ceil(128 * 0.3) = 39).
        let a = Args::parse(&argv(&["--coalesce", "size"])).unwrap();
        let c = CoalesceOpts::parse(&a, 0.3).unwrap();
        assert_eq!(
            c.policy,
            Some(CoalescePolicy::Size {
                max_batch: tile_fill_target(0.3)
            })
        );
        assert_eq!(c.policy.unwrap().max_batch(), 39);
        assert!(c.trace_seed.is_none() && c.active());

        // An explicit batch size wins over the derived target.
        let a = Args::parse(&argv(&["--coalesce", "size", "--max-batch", "16"])).unwrap();
        let c = CoalesceOpts::parse(&a, 0.3).unwrap();
        assert_eq!(c.policy, Some(CoalescePolicy::Size { max_batch: 16 }));

        // Deadline policy: explicit tick budget + trace seed, and the
        // 64-tick default when --deadline-ticks is omitted.
        let a = Args::parse(&argv(&[
            "--coalesce",
            "deadline",
            "--deadline-ticks",
            "7",
            "--trace-seed",
            "7",
        ]))
        .unwrap();
        let c = CoalesceOpts::parse(&a, 0.3).unwrap();
        assert_eq!(
            c.policy,
            Some(CoalescePolicy::SizeDeadline {
                max_batch: 39,
                deadline_ticks: 7
            })
        );
        assert_eq!(c.trace_seed, Some(7));
        // The front-door flags belong to search, not cluster.
        assert!(a.check_known("search", &known_flags("search")).is_ok());
        assert!(a.check_known("cluster", &known_flags("cluster")).is_err());
        let a = Args::parse(&argv(&["--coalesce", "deadline"])).unwrap();
        let c = CoalesceOpts::parse(&a, 0.3).unwrap();
        assert_eq!(c.policy.unwrap().deadline_ticks(), Some(64));

        // --coalesce off is the naive batch-size-1 baseline; sizing flags
        // alongside it are usage errors, not silent no-ops.
        let a = Args::parse(&argv(&["--coalesce", "off"])).unwrap();
        assert_eq!(
            CoalesceOpts::parse(&a, 0.3).unwrap().policy,
            Some(CoalescePolicy::Off)
        );
        let a = Args::parse(&argv(&["--coalesce", "off", "--max-batch", "8"])).unwrap();
        assert!(CoalesceOpts::parse(&a, 0.3).is_err());
        let a = Args::parse(&argv(&["--coalesce", "size", "--deadline-ticks", "9"])).unwrap();
        let err = CoalesceOpts::parse(&a, 0.3).unwrap_err();
        assert!(err.to_string().contains("--coalesce deadline"), "{err}");

        // Unknown policy names report a typed error listing the options.
        let a = Args::parse(&argv(&["--coalesce", "banana"])).unwrap();
        let err = CoalesceOpts::parse(&a, 0.3).unwrap_err();
        assert!(err.to_string().contains("size|deadline|off"), "{err}");

        // Dependent flags without --coalesce are usage errors.
        for dep in [
            &["--max-batch", "8"][..],
            &["--deadline-ticks", "4"],
            &["--trace-seed", "1"],
        ] {
            let a = Args::parse(&argv(dep)).unwrap();
            let err = CoalesceOpts::parse(&a, 0.3).unwrap_err();
            assert!(err.to_string().contains("--coalesce"), "{err}");
        }
    }

    #[test]
    fn full_scale_presets_auto_shard() {
        // The satellite contract: `--scale 1.0 --dataset hek293` must
        // resolve to a runnable shard plan instead of a CapacityError.
        let cfg = SpecPcmConfig::paper_search();
        let ds = SearchDataset::hek293_like(cfg.seed, 1.0);
        let plan =
            ShardPlan::for_capacity(&cfg, ds.library.len(), ds.decoys.len(), 0).unwrap();
        assert!(plan.n_shards() > 1, "full HEK293 must shard");
        // 640 slots per engine at D=8192 n=3 / 128 banks.
        assert!(plan.ranges().iter().all(|r| r.len() <= 640));
    }

    #[test]
    fn num_banks_flag_applies_and_validates() {
        let a = Args::parse(&argv(&["--num-banks", "256"])).unwrap();
        let cfg = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap();
        assert_eq!(cfg.num_banks, 256);
        // num_banks = 0 is rejected by config validation.
        let bad = Args::parse(&argv(&["--num-banks", "0"])).unwrap();
        assert!(load_cfg(&bad, SpecPcmConfig::paper_search()).is_err());
    }

    #[test]
    fn malformed_numeric_flags_report_typed_errors() {
        // Each of these used to be a potential panic path; now they come
        // back as util::error values naming the offending flag.
        let a = Args::parse(&argv(&["--stripe-rows", "banana"])).unwrap();
        let err = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap_err();
        assert!(err.to_string().contains("--stripe-rows"), "{err}");

        let a = Args::parse(&argv(&["--threads", "1.5"])).unwrap();
        let err = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap_err();
        assert!(err.to_string().contains("--threads"), "{err}");

        let a = Args::parse(&argv(&["--shards", "-2"])).unwrap();
        let err = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
    }

    #[test]
    fn flag_missing_value_is_an_error_not_a_panic() {
        // `--stripe-rows` at end of line degrades to the bare-flag value
        // "true", which must surface as a parse error downstream.
        let a = Args::parse(&argv(&["--stripe-rows"])).unwrap();
        let err = load_cfg(&a, SpecPcmConfig::paper_search()).unwrap_err();
        assert!(err.to_string().contains("--stripe-rows"), "{err}");
    }

    #[test]
    fn unknown_flags_are_rejected_per_command() {
        let a = Args::parse(&argv(&["--striperows", "64"])).unwrap();
        let err = a.check_known("search", &known_flags("search")).unwrap_err();
        assert!(err.to_string().contains("--striperows"), "{err}");
        assert!(err.to_string().contains("--stripe-rows"), "{err}");

        // Near-miss front-door flags suggest the real spelling.
        let a = Args::parse(&argv(&["--maxbatch", "8"])).unwrap();
        let err = a.check_known("search", &known_flags("search")).unwrap_err();
        assert!(err.to_string().contains("--max-batch"), "{err}");

        // `--shards` belongs to search, not cluster.
        let a = Args::parse(&argv(&["--shards", "4"])).unwrap();
        assert!(a.check_known("cluster", &known_flags("cluster")).is_err());
        assert!(a.check_known("search", &known_flags("search")).is_ok());

        // info/config/isa take no flags at all.
        let a = Args::parse(&argv(&["--scale", "1.0"])).unwrap();
        let err = a.check_known("info", &known_flags("info")).unwrap_err();
        assert!(err.to_string().contains("takes no flags"), "{err}");
    }

    #[test]
    fn workers_flag_is_serve_scoped_and_excludes_shards() {
        // --workers belongs to search; a non-serving command rejects it
        // as unknown (exit 2 via main's error path).
        let a = Args::parse(&argv(&["--workers", "2"])).unwrap();
        let err = a.check_known("cluster", &known_flags("cluster")).unwrap_err();
        assert!(err.to_string().contains("--workers"), "{err}");
        assert!(a.check_known("search", &known_flags("search")).is_ok());
        let err = run(&argv(&["info", "--workers", "2"])).unwrap_err();
        assert!(err.to_string().contains("takes no flags"), "{err}");

        // Malformed counts report typed errors before any dataset work.
        let err = run(&argv(&["search", "--workers", "banana"])).unwrap_err();
        assert!(err.to_string().contains("--workers"), "{err}");
        let err = run(&argv(&["search", "--workers", "-1"])).unwrap_err();
        assert!(err.to_string().contains("--workers"), "{err}");

        // Resolution order is explicit: --shards next to --workers is a
        // conflict even in its 'auto' spelling, never silently ignored.
        for shards in ["auto", "4"] {
            let err =
                run(&argv(&["search", "--workers", "2", "--shards", shards])).unwrap_err();
            assert!(err.to_string().contains("mutually exclusive"), "{err}");
        }
        // The hidden worker subcommand takes no flags.
        let err = run(&argv(&["worker", "--workers", "2"])).unwrap_err();
        assert!(err.to_string().contains("takes no flags"), "{err}");
    }

    #[test]
    fn invalid_remote_config_values_are_typed_errors() {
        // A config file with a broken [remote] section fails in load_cfg
        // (typed error -> exit 2), long before any worker spawns.
        let dir = std::env::temp_dir();
        for (i, (key, val)) in [
            ("deadline_ticks", "0"),
            ("retries", "-1"),
            ("backoff_base_ticks", "0"),
            ("breaker_threshold", "0"),
            ("deadline_ticks", "1.5"),
        ]
        .iter()
        .enumerate()
        {
            let path = dir.join(format!(
                "specpcm_remote_cfg_{}_{i}.toml",
                std::process::id()
            ));
            let text = format!("task = \"search\"\n[remote]\n{key} = {val}\n");
            std::fs::write(&path, text).unwrap();
            let err = run(&argv(&[
                "search",
                "--config",
                path.to_str().unwrap(),
                "--workers",
                "2",
            ]))
            .unwrap_err();
            assert!(err.to_string().contains(key), "{key}={val}: {err}");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn run_reports_errors_for_malformed_argv() {
        // End-to-end through `run`: the dispatcher surfaces the typed
        // error instead of panicking (main() prints it and exits 2).
        let err = run(&argv(&["search", "--shards", "many"])).unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
        let err = run(&argv(&["cluster", "--bogus-flag", "1"])).unwrap_err();
        assert!(err.to_string().contains("--bogus-flag"), "{err}");
        // Front-door validation fires before any dataset is generated.
        let err = run(&argv(&["search", "--coalesce", "banana"])).unwrap_err();
        assert!(err.to_string().contains("--coalesce"), "{err}");
        let err = run(&argv(&[
            "search",
            "--coalesce",
            "size",
            "--serve-batches",
            "4",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
    }
}
