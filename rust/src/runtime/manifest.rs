//! `artifacts/manifest.json` parsing — the contract `python/compile/aot.py`
//! writes and the runtime consumes. Parsed with the in-tree JSON parser
//! (`util::json`; no serde in this offline environment).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub sha256: String,
    pub params: HashMap<String, usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub schema: u32,
    /// Encoder/MVM batch size B.
    pub batch: usize,
    /// MVM reference rows per call R.
    pub rows: usize,
    /// Encoder feature positions F.
    pub features: usize,
    /// Encoder intensity levels m.
    pub levels: usize,
    pub artifacts: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or(format!("manifest: missing numeric field '{key}'"))
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or(format!("manifest: missing string field '{key}'"))
}

fn parse_tensor(j: &Json) -> Result<TensorSpec, String> {
    Ok(TensorSpec {
        name: req_str(j, "name")?,
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("tensor: missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or("tensor: bad shape element"))
            .collect::<Result<_, _>>()?,
        dtype: req_str(j, "dtype")?,
    })
}

fn parse_entry(j: &Json) -> Result<ArtifactEntry, String> {
    let params = j
        .get("params")
        .and_then(Json::as_obj)
        .ok_or("artifact: missing params")?
        .iter()
        .filter_map(|(k, v)| v.as_usize().map(|u| (k.clone(), u)))
        .collect();
    let tensors = |key: &str| -> Result<Vec<TensorSpec>, String> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or(format!("artifact: missing {key}"))?
            .iter()
            .map(parse_tensor)
            .collect()
    };
    Ok(ArtifactEntry {
        name: req_str(j, "name")?,
        file: req_str(j, "file")?,
        kind: req_str(j, "kind")?,
        sha256: req_str(j, "sha256").unwrap_or_default(),
        params,
        inputs: tensors("inputs")?,
        outputs: tensors("outputs")?,
    })
}

impl Manifest {
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let j = Json::parse(text)?;
        let schema = req_usize(&j, "schema")? as u32;
        if schema != 1 {
            return Err(format!("unsupported manifest schema {schema}"));
        }
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest: missing artifacts")?
            .iter()
            .map(parse_entry)
            .collect::<Result<_, _>>()?;
        Ok(Manifest {
            schema,
            batch: req_usize(&j, "batch")?,
            rows: req_usize(&j, "rows")?,
            features: req_usize(&j, "features")?,
            levels: req_usize(&j, "levels")?,
            artifacts,
            dir,
        })
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Encoder artifact name for (d, n); exists iff aot.py emitted it.
    pub fn enc_pack_name(d: usize, n: usize) -> String {
        format!("enc_pack_d{d}_n{n}")
    }

    /// MVM artifact name for packed width c.
    pub fn mvm_name(c: usize) -> String {
        format!("mvm_c{c}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schema": 1, "batch": 64, "rows": 1024, "features": 512, "levels": 64,
      "artifacts": [
        {"name": "mvm_c768", "file": "mvm_c768.hlo.txt", "kind": "mvm",
         "sha256": "", "params": {"c": 768, "batch": 64, "rows": 1024},
         "inputs": [{"name": "queries", "shape": [64, 768], "dtype": "f32"}],
         "outputs": [{"name": "scores", "shape": [64, 1024], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("x")).unwrap();
        assert_eq!(m.batch, 64);
        let a = m.get("mvm_c768").unwrap();
        assert_eq!(a.params["c"], 768);
        assert_eq!(a.outputs[0].shape, vec![64, 1024]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_wrong_schema() {
        let bad = SAMPLE.replace("\"schema\": 1", "\"schema\": 9");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn names() {
        assert_eq!(Manifest::enc_pack_name(2048, 3), "enc_pack_d2048_n3");
        assert_eq!(Manifest::mvm_name(768), "mvm_c768");
    }

    #[test]
    fn loads_built_artifacts_if_present() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must parse and every referenced file must exist.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for a in &m.artifacts {
            assert!(m.artifact_path(a).exists(), "{}", a.name);
        }
    }
}
