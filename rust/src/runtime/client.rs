//! PJRT client wrapper: compile-once executable cache + typed execute
//! helpers for the two artifact kinds. (Feature `pjrt` — needs the
//! vendored `xla` crate; see rust/Cargo.toml.)

use std::collections::HashMap;

use crate::util::error::{Context, Error, Result};

use super::manifest::Manifest;

/// Owns the PJRT CPU client, the artifact manifest and the executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed per artifact (telemetry).
    pub exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir).map_err(Error::msg)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .get(name)
                .ok_or_else(|| Error::msg(format!("artifact '{name}' not in manifest")))?;
            let path = self.manifest.artifact_path(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile a set of artifacts (warm-up before the hot path).
    pub fn warm(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    fn run(&mut self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_borrowed(name, &refs)
    }

    fn run_borrowed(&mut self, name: &str, args: &[&xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        *self.exec_counts.entry(name.to_string()).or_default() += 1;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        result.to_tuple1().context("unwrapping result tuple")
    }

    /// Execute the encode+pack artifact for HD dimension `d`, packing `n`.
    ///
    /// * `levels`: B x F int32 quantized intensity levels (row-major).
    /// * `id_hvs`: F x D f32 +/-1; `level_hvs`: m x D f32 +/-1.
    ///
    /// Returns B x packed row-major packed HVs.
    pub fn encode_pack(
        &mut self,
        d: usize,
        n: usize,
        levels: &[i32],
        id_hvs: &[f32],
        level_hvs: &[f32],
    ) -> Result<Vec<f32>> {
        let name = Manifest::enc_pack_name(d, n);
        let (b, f, m) = (
            self.manifest.batch,
            self.manifest.features,
            self.manifest.levels,
        );
        crate::ensure!(
            levels.len() == b * f,
            "levels len {} != {}x{}",
            levels.len(),
            b,
            f
        );
        crate::ensure!(id_hvs.len() == f * d, "id_hvs len");
        crate::ensure!(level_hvs.len() == m * d, "level_hvs len");

        let args = [
            xla::Literal::vec1(levels)
                .reshape(&[b as i64, f as i64])
                .context("levels literal")?,
            xla::Literal::vec1(id_hvs)
                .reshape(&[f as i64, d as i64])
                .context("id_hvs literal")?,
            xla::Literal::vec1(level_hvs)
                .reshape(&[m as i64, d as i64])
                .context("level_hvs literal")?,
        ];
        let out = self.run(&name, &args)?;
        out.to_vec::<f32>().context("encode_pack output")
    }

    /// Build the R x C reference literal once; the hot path reuses it
    /// across every query batch scored against the same row block
    /// (marshalling an 11 MB refs buffer per call dominated the PJRT MVM
    /// cost before this — EXPERIMENTS.md §Perf L3).
    pub fn mvm_refs_literal(&self, c: usize, refs: &[f32]) -> Result<xla::Literal> {
        let r = self.manifest.rows;
        crate::ensure!(refs.len() == r * c, "refs len {} != {}x{}", refs.len(), r, c);
        xla::Literal::vec1(refs)
            .reshape(&[r as i64, c as i64])
            .context("refs literal")
    }

    /// Execute the IMC MVM artifact for packed width `c` against a
    /// pre-marshalled reference literal.
    pub fn mvm_with_refs(
        &mut self,
        c: usize,
        queries: &[f32],
        refs_lit: &xla::Literal,
        adc_lsb: f32,
        adc_qmax: f32,
    ) -> Result<Vec<f32>> {
        let name = Manifest::mvm_name(c);
        let b = self.manifest.batch;
        crate::ensure!(
            queries.len() == b * c,
            "queries len {} != {}x{}",
            queries.len(),
            b,
            c
        );
        let q_lit = xla::Literal::vec1(queries)
            .reshape(&[b as i64, c as i64])
            .context("queries literal")?;
        let lsb_lit = xla::Literal::vec1(&[adc_lsb])
            .reshape(&[1, 1])
            .context("lsb literal")?;
        let qmax_lit = xla::Literal::vec1(&[adc_qmax])
            .reshape(&[1, 1])
            .context("qmax literal")?;
        let args = [&q_lit, refs_lit, &lsb_lit, &qmax_lit];
        let out = self.run_borrowed(&name, &args)?;
        out.to_vec::<f32>().context("mvm output")
    }

    /// Execute the IMC MVM artifact for packed width `c`.
    ///
    /// * `queries`: B x C packed query HVs; `refs`: R x C stored (noisy)
    ///   conductances; `adc_lsb`/`adc_qmax` per `array::AdcConfig`.
    ///
    /// Returns B x R scores.
    pub fn mvm(
        &mut self,
        c: usize,
        queries: &[f32],
        refs: &[f32],
        adc_lsb: f32,
        adc_qmax: f32,
    ) -> Result<Vec<f32>> {
        let refs_lit = self.mvm_refs_literal(c, refs)?;
        self.mvm_with_refs(c, queries, &refs_lit, adc_lsb, adc_qmax)
    }

    /// Total artifact executions (all names).
    pub fn total_execs(&self) -> u64 {
        self.exec_counts.values().sum()
    }
}
