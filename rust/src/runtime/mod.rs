//! PJRT runtime (feature `pjrt`): loads the AOT HLO-text artifacts and
//! executes them on the CPU PJRT client — the only place the L3
//! coordinator touches XLA. The default build compiles without this
//! module; `backend::PjrtBackend` is the consumer.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, with HLO **text** as the interchange
//! format (see DESIGN.md §2). Executables are compiled once per artifact
//! and cached for the life of the runtime.

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactEntry, Manifest};
