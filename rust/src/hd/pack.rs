//! Dimension packing (paper §III-B) — rust mirror of
//! `python/compile/kernels/pack.py`.
//!
//! A binary HV of length D becomes ceil(D/n) packed values (sums of n
//! adjacent +/-1 elements), zero-padded up to a multiple of the 128-wide
//! array so every packed HV maps onto whole array segments.

use crate::array::ARRAY_DIM;
use crate::util::ceil_to;

use super::Hv;

/// Packed length before array padding: ceil(D / n).
#[inline]
pub fn packed_len(d: usize, n: usize) -> usize {
    d.div_ceil(n)
}

/// Packed length padded to a multiple of [`ARRAY_DIM`].
#[inline]
pub fn padded_packed_len(d: usize, n: usize) -> usize {
    ceil_to(packed_len(d, n), ARRAY_DIM)
}

/// Pack one +/-1 hypervector into a caller-provided row of exactly
/// `padded_packed_len` f32 entries (integer-valued, in [-n, n]) — the
/// allocation-free primitive batch packing and the encode backends build
/// on.
pub fn pack_into(hv: &Hv, n: usize, out: &mut [f32]) {
    assert!(n >= 1);
    let cp = padded_packed_len(hv.len(), n);
    assert_eq!(out.len(), cp, "packed row length");
    let groups = packed_len(hv.len(), n);
    for (slot, chunk) in out.iter_mut().zip(hv.chunks(n)) {
        *slot = chunk.iter().map(|&x| x as i32).sum::<i32>() as f32;
    }
    out[groups..].fill(0.0);
}

/// Pack one +/-1 hypervector; output has `padded_packed_len` f32 entries
/// (integer-valued, in [-n, n]).
pub fn pack(hv: &Hv, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; padded_packed_len(hv.len(), n)];
    pack_into(hv, n, &mut out);
    out
}

/// Pack a batch into one row-major buffer (B x padded_packed_len). One
/// allocation for the whole batch, not one per row.
pub fn pack_batch(hvs: &[Hv], n: usize) -> (Vec<f32>, usize) {
    assert!(!hvs.is_empty());
    let cp = padded_packed_len(hvs[0].len(), n);
    let mut out = vec![0f32; hvs.len() * cp];
    for (hv, row) in hvs.iter().zip(out.chunks_mut(cp)) {
        assert_eq!(hv.len(), hvs[0].len(), "ragged HV batch");
        pack_into(hv, n, row);
    }
    (out, cp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    #[test]
    fn lengths_match_python() {
        // Mirrors python/tests/test_pack.py::TestPackedLen.
        assert_eq!(packed_len(2048, 3), 683);
        assert_eq!(padded_packed_len(2048, 3), 768);
        assert_eq!(packed_len(8192, 3), 2731);
        assert_eq!(padded_packed_len(8192, 3), 2816);
        assert_eq!(padded_packed_len(512, 3), 256);
        assert_eq!(padded_packed_len(1024, 3), 384);
        assert_eq!(padded_packed_len(4096, 3), 1408);
        assert_eq!(padded_packed_len(2048, 1), 2048);
        assert_eq!(padded_packed_len(2048, 2), 1024);
    }

    #[test]
    fn values_bounded_and_adjacent_sums() {
        let mut rng = Rng::new(1);
        let hv = rand_hv(&mut rng, 2048);
        let p = pack(&hv, 3);
        assert_eq!(p.len(), 768);
        assert!(p.iter().all(|&v| v.abs() <= 3.0));
        // spot-check group 10: elements 30..33
        let manual: i32 = hv[30..33].iter().map(|&x| x as i32).sum();
        assert_eq!(p[10], manual as f32);
        // padding region is zero
        assert!(p[683..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn slc_is_identity_plus_padding() {
        let mut rng = Rng::new(2);
        let hv = rand_hv(&mut rng, 2048);
        let p = pack(&hv, 1);
        assert_eq!(p.len(), 2048);
        for (a, b) in hv.iter().zip(&p) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn packed_dot_estimates_binary_dot() {
        // <pack(a), pack(b)> is an unbiased estimator of <a, b> with
        // variance from cross terms — the mechanism behind the small
        // MLC2/MLC3 accuracy drop in Fig. 9.
        let mut rng = Rng::new(3);
        let trials = 200;
        let d = 2048;
        let mut err_sum = 0f64;
        for _ in 0..trials {
            let a = rand_hv(&mut rng, d);
            let b = rand_hv(&mut rng, d);
            let exact: i64 = crate::hd::dot(&a, &b);
            let (pa, pb) = (pack(&a, 3), pack(&b, 3));
            let packed: f64 = pa.iter().zip(&pb).map(|(x, y)| (x * y) as f64).sum();
            err_sum += packed - exact as f64;
        }
        let mean_err = err_sum / trials as f64;
        // Unbiased: mean error small relative to sqrt(D) noise scale.
        assert!(mean_err.abs() < 3.0 * (2.0 * d as f64).sqrt() / (trials as f64).sqrt());
    }

    #[test]
    fn pack_into_matches_pack_and_clears_padding() {
        let mut rng = Rng::new(5);
        let hv = rand_hv(&mut rng, 300);
        // A dirty output row must end up identical to a fresh pack().
        let mut row = vec![f32::NAN; padded_packed_len(300, 3)];
        pack_into(&hv, 3, &mut row);
        assert_eq!(row, pack(&hv, 3));
        assert!(row[packed_len(300, 3)..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pack_batch_layout() {
        let mut rng = Rng::new(4);
        let hvs: Vec<Hv> = (0..3).map(|_| rand_hv(&mut rng, 300)).collect();
        let (buf, cp) = pack_batch(&hvs, 3);
        assert_eq!(cp, 128);
        assert_eq!(buf.len(), 3 * 128);
        assert_eq!(&buf[0..128], &pack(&hvs[0], 3)[..]);
        assert_eq!(&buf[128..256], &pack(&hvs[1], 3)[..]);
    }
}
