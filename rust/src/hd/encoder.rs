//! ID-level HD encoding (paper Eq. 1) — rust reference implementation.
//!
//! `HV = sign( sum_{f: level_f > 0} LV[level_f] (*) ID_f )` with the tie
//! rule `sign(0) = +1`, matching `python/compile/kernels/ref.py::encode`
//! and the L2 scan encoder bit-for-bit.
//!
//! Level 0 means "no peak in this m/z bin" and contributes nothing: MS
//! spectra are sparse (~100 peaks over 512 bins), and summing empty bins
//! would give every pair of spectra a large shared baseline similarity,
//! destroying the score separation both pipelines rank by (this is how the
//! HyperSpec/HyperOMS encoders treat absent peaks as well).

use super::itemmem::ItemMemory;
use super::Hv;

/// Encode one quantized-level feature vector into a binary hypervector.
pub fn encode(levels: &[u16], im: &ItemMemory) -> Hv {
    assert_eq!(levels.len(), im.features(), "feature count");
    let d = im.dim;
    let mut acc = vec![0i32; d];
    for (f, &lvl) in levels.iter().enumerate() {
        if lvl == 0 {
            continue; // empty bin: no peak, no contribution
        }
        let lv = &im.level_hvs[lvl as usize];
        let id = &im.id_hvs[f];
        for j in 0..d {
            acc[j] += (lv[j] as i32) * (id[j] as i32);
        }
    }
    acc.iter().map(|&a| if a >= 0 { 1 } else { -1 }).collect()
}

/// Encode a batch (convenience over [`encode`]).
pub fn encode_batch(levels: &[Vec<u16>], im: &ItemMemory) -> Vec<Hv> {
    levels.iter().map(|l| encode(l, im)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::cosine_pm1;
    use crate::util::Rng;

    #[test]
    fn deterministic() {
        let im = ItemMemory::generate(1, 32, 8, 512);
        let levels: Vec<u16> = (0..32).map(|i| (i % 8) as u16).collect();
        assert_eq!(encode(&levels, &im), encode(&levels, &im));
    }

    #[test]
    fn output_is_bipolar() {
        let im = ItemMemory::generate(2, 16, 4, 256);
        let hv = encode(&vec![1; 16], &im);
        assert!(hv.iter().all(|&x| x == 1 || x == -1));
        assert_eq!(hv.len(), 256);
    }

    #[test]
    fn similar_inputs_similar_hvs() {
        let im = ItemMemory::generate(3, 128, 32, 2048);
        let mut rng = Rng::new(9);
        // Sparse spectra: ~30 peaks over 128 bins (levels >= 1).
        let sparse = |rng: &mut Rng| -> Vec<u16> {
            let mut v = vec![0u16; 128];
            for _ in 0..30 {
                v[rng.below(128)] = 1 + rng.below(31) as u16;
            }
            v
        };
        let base = sparse(&mut rng);
        let mut near = base.clone();
        for i in 0..5 {
            near[i * 20] = 1 + rng.below(31) as u16;
        }
        let far = sparse(&mut rng);
        let (hb, hn, hf) = (encode(&base, &im), encode(&near, &im), encode(&far, &im));
        let sim_near = cosine_pm1(&hb, &hn);
        let sim_far = cosine_pm1(&hb, &hf);
        assert!(sim_near > 0.5, "near: {sim_near}");
        assert!(sim_far < 0.3, "far: {sim_far}");
        assert!(sim_near > sim_far + 0.2);
    }

    #[test]
    fn tie_rule_is_plus_one() {
        // Two features with exactly cancelling contributions: LV row 1 all
        // +1, row 2 all -1, IDs all +1 -> acc == 0 -> +1 everywhere.
        let mut im = ItemMemory::generate(4, 2, 3, 64);
        im.id_hvs = vec![vec![1; 64], vec![1; 64]];
        im.level_hvs = vec![vec![1; 64], vec![1; 64], vec![-1; 64]];
        let hv = encode(&[1, 2], &im);
        assert!(hv.iter().all(|&x| x == 1));
    }

    #[test]
    fn level_zero_is_inert() {
        // A spectrum with every bin empty encodes to the all-ties vector,
        // and adding empty bins to a spectrum never changes its HV.
        let im = ItemMemory::generate(5, 8, 4, 256);
        let empty = encode(&[0; 8], &im);
        assert!(empty.iter().all(|&x| x == 1)); // sign(0) = +1 everywhere

        let mut some = vec![0u16; 8];
        some[2] = 3;
        some[5] = 1;
        let hv1 = encode(&some, &im);
        // Same peaks, levels of other bins remain 0 -> identical HV.
        let hv2 = encode(&some, &im);
        assert_eq!(hv1, hv2);
    }

    #[test]
    fn sparse_random_spectra_near_orthogonal() {
        // The property the level-0 rule exists for: two random sparse
        // spectra must not share a large baseline similarity.
        let im = ItemMemory::generate(6, 512, 64, 2048);
        let mut rng = Rng::new(3);
        let sparse = |rng: &mut Rng| -> Vec<u16> {
            let mut v = vec![0u16; 512];
            for _ in 0..60 {
                v[rng.below(512)] = 1 + rng.below(63) as u16;
            }
            v
        };
        let a = encode(&sparse(&mut rng), &im);
        let b = encode(&sparse(&mut rng), &im);
        assert!(
            cosine_pm1(&a, &b).abs() < 0.25,
            "baseline {}",
            cosine_pm1(&a, &b)
        );
    }
}
