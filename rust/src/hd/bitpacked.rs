//! Word-packed (u64 sign-bit) HD kernels — the bit-level fast path behind
//! the pluggable [`crate::encode`] backends.
//!
//! A +/-1 hypervector stores one element per bit (bit set = element is
//! **-1**), so the element-wise product of two HVs is a single XOR per 64
//! elements and similarity is a popcount: `dot = D - 2 * popcount(a ^ b)`.
//! This is exactly the observation SpecHD and HyperOMS build their
//! throughput on; here it turns the scalar O(peaks x D) `i32` multiply-add
//! encode loop (`super::encode`) into an O(peaks x D/64) word loop.
//!
//! # Encoding with bit-sliced counters
//!
//! `HV = sign(sum_f LV[level_f] (*) ID_f)` needs a per-element integer
//! accumulator across the P contributing peaks. Instead of 64 scalar
//! adds per word we keep a **vertical (bit-sliced) counter**: plane `k`
//! holds bit `k` of the running count of -1 products for each of the 64
//! lanes of a word. Adding one bound word is a ripple-carry add of a
//! 1-bit operand — amortized ~2 bitwise ops per word regardless of P.
//! After all peaks, `acc[j] = P - 2 * count[j]`, so the output sign bit is
//! a bit-sliced magnitude compare `count[j] > floor(P / 2)` — which also
//! reproduces the scalar path's `sign(0) = +1` tie rule exactly (acc == 0
//! means count == P/2, which is *not* greater than floor(P/2)).
//!
//! Every kernel here is **bit-identical** to `super::encode` +
//! `super::pack` by contract (same tie rule, same zero padding), enforced
//! by `rust/tests/encode_equivalence.rs` across dims that are not
//! multiples of 64 (tail-word masking), empty spectra and all-tie inputs.

use super::itemmem::ItemMemory;
use super::pack::{packed_len, padded_packed_len};
use super::Hv;

/// Elements per machine word.
pub const WORD_BITS: usize = 64;

/// Words needed for a D-element bit-packed HV.
#[inline]
pub fn words_len(d: usize) -> usize {
    d.div_ceil(WORD_BITS)
}

/// Mask of the valid bits in the last word (all-ones when D is a multiple
/// of 64).
#[inline]
pub fn tail_mask(d: usize) -> u64 {
    match d % WORD_BITS {
        0 => !0u64,
        r => (1u64 << r) - 1,
    }
}

/// Bit-packed +/-1 hypervector: bit set = element is -1. Bits past `d` in
/// the last word are always zero (the invariant `hamming`/`dot` rely on).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitHv {
    pub words: Vec<u64>,
    pub d: usize,
}

impl BitHv {
    /// Pack an i8 +/-1 hypervector.
    pub fn from_hv(hv: &[i8]) -> Self {
        let d = hv.len();
        let mut words = vec![0u64; words_len(d)];
        for (j, &x) in hv.iter().enumerate() {
            debug_assert!(x == 1 || x == -1, "element {j} is {x}, not +/-1");
            if x == -1 {
                words[j / WORD_BITS] |= 1u64 << (j % WORD_BITS);
            }
        }
        BitHv { words, d }
    }

    /// Unpack to the i8 representation.
    pub fn to_hv(&self) -> Hv {
        (0..self.d)
            .map(|j| {
                if (self.words[j / WORD_BITS] >> (j % WORD_BITS)) & 1 == 1 {
                    -1
                } else {
                    1
                }
            })
            .collect()
    }

    /// Hamming distance via XOR + popcount.
    pub fn hamming(&self, other: &BitHv) -> usize {
        assert_eq!(self.d, other.d);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(&a, &b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    /// Dot product of the underlying +/-1 vectors: `D - 2 * hamming`.
    pub fn dot(&self, other: &BitHv) -> i64 {
        self.d as i64 - 2 * self.hamming(other) as i64
    }

    /// Normalized similarity in [-1, 1] (popcount analogue of
    /// [`super::cosine_pm1`]).
    pub fn cosine_pm1(&self, other: &BitHv) -> f64 {
        self.dot(other) as f64 / self.d as f64
    }
}

/// Word-packed ID and level codebooks, derived once from an
/// [`ItemMemory`] (row-major `features x W` and `levels x W` u64 words).
#[derive(Clone, Debug)]
pub struct BitItemMemory {
    id_words: Vec<u64>,
    level_words: Vec<u64>,
    /// Words per hypervector.
    pub w: usize,
    pub d: usize,
    features: usize,
    levels: usize,
}

impl BitItemMemory {
    pub fn from_item_memory(im: &ItemMemory) -> Self {
        let d = im.dim;
        let pack_rows = |rows: &[Hv]| -> Vec<u64> {
            rows.iter()
                .flat_map(|hv| BitHv::from_hv(hv).words)
                .collect()
        };
        BitItemMemory {
            id_words: pack_rows(&im.id_hvs),
            level_words: pack_rows(&im.level_hvs),
            w: words_len(d),
            d,
            features: im.id_hvs.len(),
            levels: im.level_hvs.len(),
        }
    }

    pub fn features(&self) -> usize {
        self.features
    }

    pub fn levels(&self) -> usize {
        self.levels
    }

    #[inline]
    fn id_row(&self, f: usize) -> &[u64] {
        &self.id_words[f * self.w..(f + 1) * self.w]
    }

    #[inline]
    fn level_row(&self, l: usize) -> &[u64] {
        &self.level_words[l * self.w..(l + 1) * self.w]
    }
}

/// Reusable bit-sliced counter planes (one allocation per worker, not per
/// spectrum).
#[derive(Clone, Debug, Default)]
pub struct EncodeScratch {
    planes: Vec<u64>,
}

/// Encode one quantized-level feature vector into sign-bit words —
/// bit-identical to [`super::encode`] (level 0 is inert, `sign(0) = +1`).
/// `out` must be `words_len(d)` long.
pub fn encode_bits_into(
    levels: &[u16],
    bim: &BitItemMemory,
    scratch: &mut EncodeScratch,
    out: &mut [u64],
) {
    assert_eq!(levels.len(), bim.features(), "feature count");
    assert_eq!(out.len(), bim.w, "output word count");
    let w = bim.w;

    // P = contributing peaks; K = planes needed to count to P.
    let p = levels.iter().filter(|&&l| l > 0).count();
    let k_planes = (usize::BITS - p.leading_zeros()) as usize;
    scratch.planes.clear();
    scratch.planes.resize(k_planes * w, 0);
    let planes = &mut scratch.planes;

    for (f, &lvl) in levels.iter().enumerate() {
        if lvl == 0 {
            continue; // empty bin: no peak, no contribution
        }
        let id = bim.id_row(f);
        let lv = bim.level_row(lvl as usize);
        for wi in 0..w {
            // Bound word: bit set where lv * id == -1.
            let mut carry = id[wi] ^ lv[wi];
            let mut k = 0;
            while carry != 0 {
                debug_assert!(k < k_planes, "counter overflow past {k_planes} planes");
                let plane = &mut planes[k * w + wi];
                let t = *plane & carry;
                *plane ^= carry;
                carry = t;
                k += 1;
            }
        }
    }

    // Output is -1 exactly where count > floor(P/2): bit-sliced unsigned
    // compare, MSB plane first.
    let threshold = p / 2;
    for wi in 0..w {
        let mut gt = 0u64;
        let mut eq = !0u64;
        for k in (0..k_planes).rev() {
            let plane = planes[k * w + wi];
            let t = if (threshold >> k) & 1 == 1 { !0u64 } else { 0u64 };
            gt |= eq & plane & !t;
            eq &= !(plane ^ t);
        }
        out[wi] = gt;
    }
    if w > 0 {
        out[w - 1] &= tail_mask(bim.d);
    }
}

/// Encode into an owned [`BitHv`] (convenience over [`encode_bits_into`]).
pub fn encode_bits(levels: &[u16], bim: &BitItemMemory) -> BitHv {
    let mut scratch = EncodeScratch::default();
    let mut words = vec![0u64; bim.w];
    encode_bits_into(levels, bim, &mut scratch, &mut words);
    BitHv { words, d: bim.d }
}

/// Pack sign-bit words into the coordinator's f32 row layout —
/// bit-identical to [`super::pack`] on the unpacked HV: group `j` holds
/// the sum of elements `j*n .. min((j+1)*n, d)` and the padding region up
/// to `padded_packed_len(d, n)` is zero. `out` must be exactly that long.
pub fn pack_bits_into(words: &[u64], d: usize, n: usize, out: &mut [f32]) {
    assert!(n >= 1);
    assert_eq!(words.len(), words_len(d));
    assert_eq!(out.len(), padded_packed_len(d, n), "packed row length");
    let groups = packed_len(d, n);
    for (j, slot) in out.iter_mut().enumerate().take(groups) {
        let start = j * n;
        let end = (start + n).min(d);
        let mut neg = 0i32;
        for b in start..end {
            neg += ((words[b / WORD_BITS] >> (b % WORD_BITS)) & 1) as i32;
        }
        *slot = ((end - start) as i32 - 2 * neg) as f32;
    }
    out[groups..].fill(0.0);
}

/// Fused encode + pack: writes one packed f32 row directly, never
/// materializing the intermediate `Vec<i8>` hypervector. `out` must be
/// `padded_packed_len(bim.d, n)` long; `word_buf` must be `bim.w` long.
pub fn encode_pack_into(
    levels: &[u16],
    bim: &BitItemMemory,
    n: usize,
    scratch: &mut EncodeScratch,
    word_buf: &mut [u64],
    out: &mut [f32],
) {
    encode_bits_into(levels, bim, scratch, word_buf);
    pack_bits_into(word_buf, bim.d, n, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::{self, pack};
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    fn sparse_levels(rng: &mut Rng, f: usize, m: usize, peaks: usize) -> Vec<u16> {
        let mut v = vec![0u16; f];
        for _ in 0..peaks {
            v[rng.below(f)] = 1 + rng.below(m - 1) as u16;
        }
        v
    }

    #[test]
    fn bithv_roundtrip_and_tail_masking() {
        let mut rng = Rng::new(1);
        for d in [1usize, 63, 64, 65, 100, 128, 2048] {
            let hv = rand_hv(&mut rng, d);
            let b = BitHv::from_hv(&hv);
            assert_eq!(b.to_hv(), hv, "d={d}");
            // Tail bits past d stay zero.
            assert_eq!(b.words[b.words.len() - 1] & !tail_mask(d), 0);
        }
    }

    #[test]
    fn popcount_dot_hamming_match_scalar() {
        let mut rng = Rng::new(2);
        for d in [64usize, 100, 1024, 2048] {
            let a = rand_hv(&mut rng, d);
            let b = rand_hv(&mut rng, d);
            let (ba, bb) = (BitHv::from_hv(&a), BitHv::from_hv(&b));
            assert_eq!(ba.dot(&bb), hd::dot(&a, &b), "d={d}");
            assert_eq!(ba.hamming(&bb), hd::hamming(&a, &b), "d={d}");
            assert_eq!(ba.cosine_pm1(&bb), hd::cosine_pm1(&a, &b), "d={d}");
        }
    }

    #[test]
    fn encode_bits_matches_scalar_encode() {
        let mut rng = Rng::new(3);
        for d in [64usize, 100, 130, 512, 2048] {
            let im = ItemMemory::generate(d as u64, 64, 16, d);
            let bim = BitItemMemory::from_item_memory(&im);
            for peaks in [0usize, 1, 10, 40] {
                let levels = sparse_levels(&mut rng, 64, 16, peaks);
                let want = hd::encode(&levels, &im);
                let got = encode_bits(&levels, &bim).to_hv();
                assert_eq!(got, want, "d={d} peaks={peaks}");
            }
        }
    }

    #[test]
    fn tie_rule_is_plus_one() {
        // Exactly cancelling contributions (see encoder::tests): acc == 0
        // everywhere must produce +1 everywhere, i.e. all-zero sign bits.
        let mut im = ItemMemory::generate(4, 2, 3, 64);
        im.id_hvs = vec![vec![1; 64], vec![1; 64]];
        im.level_hvs = vec![vec![1; 64], vec![1; 64], vec![-1; 64]];
        let bim = BitItemMemory::from_item_memory(&im);
        let hv = encode_bits(&[1, 2], &bim).to_hv();
        assert!(hv.iter().all(|&x| x == 1));
    }

    #[test]
    fn fused_encode_pack_matches_reference() {
        let mut rng = Rng::new(5);
        for d in [512usize, 2000, 2048] {
            let im = ItemMemory::generate(7 ^ d as u64, 128, 32, d);
            let bim = BitItemMemory::from_item_memory(&im);
            let mut scratch = EncodeScratch::default();
            let mut words = vec![0u64; bim.w];
            for n in 1usize..=4 {
                let levels = sparse_levels(&mut rng, 128, 32, 30);
                let want = pack(&hd::encode(&levels, &im), n);
                let mut got = vec![f32::NAN; padded_packed_len(d, n)];
                encode_pack_into(&levels, &bim, n, &mut scratch, &mut words, &mut got);
                assert_eq!(got, want, "d={d} n={n}");
            }
        }
    }

    #[test]
    fn pack_bits_matches_pack() {
        let mut rng = Rng::new(6);
        for d in [64usize, 100, 300, 2048] {
            let hv = rand_hv(&mut rng, d);
            let b = BitHv::from_hv(&hv);
            for n in 1usize..=4 {
                let mut got = vec![f32::NAN; padded_packed_len(d, n)];
                pack_bits_into(&b.words, d, n, &mut got);
                assert_eq!(got, pack(&hv, n), "d={d} n={n}");
            }
        }
    }
}
