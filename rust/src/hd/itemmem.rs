//! Item memory: the ID and level hypervector codebooks (paper §II-A).
//!
//! ID HVs (one per m/z feature position) are i.i.d. random +/-1 — near
//! orthogonal in high dimension. Level HVs represent quantized intensity
//! values; following the ID-level scheme used by HyperSpec/HyperOMS they
//! interpolate between two random endpoint HVs so that nearby intensity
//! levels map to similar HVs (correlated codebook), while distant levels
//! approach orthogonality.

use crate::util::Rng;

use super::Hv;

#[derive(Clone, Debug)]
pub struct ItemMemory {
    /// (F, D) position/ID hypervectors.
    pub id_hvs: Vec<Hv>,
    /// (m, D) intensity-level hypervectors.
    pub level_hvs: Vec<Hv>,
    pub dim: usize,
}

impl ItemMemory {
    /// Deterministically generate codebooks for `features` positions and
    /// `levels` intensity levels in dimension `d`.
    pub fn generate(seed: u64, features: usize, levels: usize, d: usize) -> Self {
        assert!(levels >= 2, "need at least 2 levels");
        let mut rng = Rng::new(seed);

        let id_hvs: Vec<Hv> = (0..features)
            .map(|_| (0..d).map(|_| rng.pm1()).collect())
            .collect();

        // Level codebook: start from LV_0 random; LV_m-1 flips a fresh
        // random half... classic scheme: flip d/(2*(levels-1)) positions per
        // step so LV_0 and LV_{m-1} differ in ~d/2 positions (orthogonal).
        let base: Hv = (0..d).map(|_| rng.pm1()).collect();
        let mut level_hvs = Vec::with_capacity(levels);
        level_hvs.push(base.clone());
        let flips_per_step = d / (2 * (levels - 1));
        let mut order: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut order);
        let mut cur = base;
        for step in 0..levels - 1 {
            for &idx in order
                .iter()
                .skip(step * flips_per_step)
                .take(flips_per_step)
            {
                cur[idx] = -cur[idx];
            }
            level_hvs.push(cur.clone());
        }

        ItemMemory {
            id_hvs,
            level_hvs,
            dim: d,
        }
    }

    pub fn features(&self) -> usize {
        self.id_hvs.len()
    }

    pub fn levels(&self) -> usize {
        self.level_hvs.len()
    }

    /// Flatten to row-major f32 buffers for the PJRT encoder artifact.
    pub fn id_hvs_f32(&self) -> Vec<f32> {
        self.id_hvs
            .iter()
            .flat_map(|hv| hv.iter().map(|&x| x as f32))
            .collect()
    }

    pub fn level_hvs_f32(&self) -> Vec<f32> {
        self.level_hvs
            .iter()
            .flat_map(|hv| hv.iter().map(|&x| x as f32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::cosine_pm1;

    #[test]
    fn deterministic_generation() {
        let a = ItemMemory::generate(7, 16, 8, 512);
        let b = ItemMemory::generate(7, 16, 8, 512);
        assert_eq!(a.id_hvs, b.id_hvs);
        assert_eq!(a.level_hvs, b.level_hvs);
    }

    #[test]
    fn id_hvs_near_orthogonal() {
        let im = ItemMemory::generate(1, 32, 8, 4096);
        for i in 0..8 {
            for j in 0..i {
                let c = cosine_pm1(&im.id_hvs[i], &im.id_hvs[j]);
                assert!(c.abs() < 0.1, "ids {i},{j}: {c}");
            }
        }
    }

    #[test]
    fn level_hvs_monotone_similarity() {
        let im = ItemMemory::generate(2, 4, 16, 4096);
        // Similarity to level 0 decreases monotonically with level index.
        let mut last = 1.1;
        for k in 0..16 {
            let c = cosine_pm1(&im.level_hvs[0], &im.level_hvs[k]);
            assert!(c < last + 0.05, "level {k}: {c} vs {last}");
            last = c;
        }
        // Extremes are near orthogonal.
        let ends = cosine_pm1(&im.level_hvs[0], &im.level_hvs[15]);
        assert!(ends.abs() < 0.15, "{ends}");
    }

    #[test]
    fn f32_export_shapes() {
        let im = ItemMemory::generate(3, 16, 8, 256);
        assert_eq!(im.id_hvs_f32().len(), 16 * 256);
        assert_eq!(im.level_hvs_f32().len(), 8 * 256);
        assert!(im.id_hvs_f32().iter().all(|&x| x == 1.0 || x == -1.0));
    }
}
