//! Hyperdimensional computing (paper §II-A, §III-B).
//!
//! Two bit-identical host implementations live here, mirroring the
//! two-backend-seam architecture of the crate (see `backend/` for the MVM
//! seam, `encode/` for the encode seam):
//!
//! * **Scalar reference** ([`encoder::encode`] + [`pack::pack`]) — the
//!   element-serial `i32` oracle every faster path is checked against.
//! * **Word-packed kernels** ([`bitpacked`]) — `u64` sign-bit HVs
//!   ([`BitHv`]), XOR binding with bit-sliced counter accumulation,
//!   popcount similarity, and a fused encode+pack that writes packed f32
//!   rows directly. This is the SpecHD/HyperOMS observation that +/-1
//!   arithmetic is word-parallel, applied to the host hot path.
//!
//! The production pipeline can also encode on the AOT jax artifacts
//! (`runtime`, feature `pjrt`); all paths are bit-for-bit interchangeable
//! (`rust/tests/encode_equivalence.rs`).

pub mod bitpacked;
pub mod encoder;
pub mod itemmem;
pub mod pack;

pub use bitpacked::{BitHv, BitItemMemory};
pub use encoder::encode;
pub use itemmem::ItemMemory;
pub use pack::{pack, pack_into, packed_len, padded_packed_len};

/// Binary hypervector: elements are +/-1 stored as i8.
pub type Hv = Vec<i8>;

/// Per-element products are +/-1, so a partial sum over a chunk this size
/// fits an i32 with room to spare; chunked accumulation avoids the
/// per-element widening to i64 the old loop paid.
const DOT_CHUNK: usize = 4096;

/// Dot-product similarity of two +/-1 hypervectors. Equals
/// `D - 2 * hamming_distance` — the similarity both pipelines rank by.
/// Accumulates in i32 per [`DOT_CHUNK`]-sized chunk (exact: each chunk's
/// sum is bounded by the chunk length), folding into i64 across chunks.
pub fn dot(a: &[i8], b: &[i8]) -> i64 {
    assert_eq!(a.len(), b.len());
    a.chunks(DOT_CHUNK)
        .zip(b.chunks(DOT_CHUNK))
        .map(|(ca, cb)| {
            let mut acc = 0i32;
            for (&x, &y) in ca.iter().zip(cb) {
                acc += (x as i32) * (y as i32);
            }
            acc as i64
        })
        .sum()
}

/// Hamming distance between +/-1 hypervectors.
pub fn hamming(a: &[i8], b: &[i8]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Normalized similarity in [-1, 1].
pub fn cosine_pm1(a: &[i8], b: &[i8]) -> f64 {
    dot(a, b) as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    #[test]
    fn dot_hamming_identity() {
        let mut rng = Rng::new(1);
        let a = rand_hv(&mut rng, 1024);
        let b = rand_hv(&mut rng, 1024);
        let d = dot(&a, &b);
        let h = hamming(&a, &b) as i64;
        assert_eq!(d, 1024 - 2 * h);
    }

    #[test]
    fn self_dot_is_dimension() {
        let mut rng = Rng::new(2);
        let a = rand_hv(&mut rng, 2048);
        assert_eq!(dot(&a, &a), 2048);
        assert_eq!(hamming(&a, &a), 0);
    }

    #[test]
    fn chunked_dot_matches_naive_across_chunk_boundary() {
        let mut rng = Rng::new(4);
        // Straddles DOT_CHUNK so the i64 fold across chunks is exercised.
        for d in [1usize, DOT_CHUNK - 1, DOT_CHUNK, DOT_CHUNK + 1, 10_000] {
            let a = rand_hv(&mut rng, d);
            let b = rand_hv(&mut rng, d);
            let naive: i64 = a.iter().zip(&b).map(|(&x, &y)| (x as i64) * (y as i64)).sum();
            assert_eq!(dot(&a, &b), naive, "d={d}");
        }
    }

    #[test]
    fn random_hvs_near_orthogonal() {
        let mut rng = Rng::new(3);
        let a = rand_hv(&mut rng, 8192);
        let b = rand_hv(&mut rng, 8192);
        // |cos| ~ O(1/sqrt(D)): 5 sigma bound.
        assert!(cosine_pm1(&a, &b).abs() < 5.0 / (8192f64).sqrt());
    }
}
