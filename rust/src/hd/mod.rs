//! Hyperdimensional computing (paper §II-A, §III-B) — rust reference path.
//!
//! The production pipeline encodes and packs on the AOT jax artifacts
//! (`runtime`); this module provides the bit-identical rust implementation
//! used for validation, for artifact-free runs, and for HD dimensions the
//! artifact set does not cover.

pub mod encoder;
pub mod itemmem;
pub mod pack;

pub use encoder::encode;
pub use itemmem::ItemMemory;
pub use pack::{pack, packed_len, padded_packed_len};

/// Binary hypervector: elements are +/-1 stored as i8.
pub type Hv = Vec<i8>;

/// Dot-product similarity of two +/-1 hypervectors. Equals
/// `D - 2 * hamming_distance` — the similarity both pipelines rank by.
pub fn dot(a: &[i8], b: &[i8]) -> i64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as i64) * (y as i64))
        .sum()
}

/// Hamming distance between +/-1 hypervectors.
pub fn hamming(a: &[i8], b: &[i8]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Normalized similarity in [-1, 1].
pub fn cosine_pm1(a: &[i8], b: &[i8]) -> f64 {
    dot(a, b) as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    #[test]
    fn dot_hamming_identity() {
        let mut rng = Rng::new(1);
        let a = rand_hv(&mut rng, 1024);
        let b = rand_hv(&mut rng, 1024);
        let d = dot(&a, &b);
        let h = hamming(&a, &b) as i64;
        assert_eq!(d, 1024 - 2 * h);
    }

    #[test]
    fn self_dot_is_dimension() {
        let mut rng = Rng::new(2);
        let a = rand_hv(&mut rng, 2048);
        assert_eq!(dot(&a, &a), 2048);
        assert_eq!(hamming(&a, &a), 0);
    }

    #[test]
    fn random_hvs_near_orthogonal() {
        let mut rng = Rng::new(3);
        let a = rand_hv(&mut rng, 8192);
        let b = rand_hv(&mut rng, 8192);
        // |cos| ~ O(1/sqrt(D)): 5 sigma bound.
        assert!(cosine_pm1(&a, &b).abs() < 5.0 / (8192f64).sqrt());
    }
}
