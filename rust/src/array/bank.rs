//! One 128x128 2T2R PCM bank: programmed state, endurance tracking, MVM.
//!
//! A bank stores one 128-column *segment* of up to 128 hypervectors (one HV
//! per row). HVs wider than 128 packed dimensions span multiple banks at
//! the same row index (paper §III-C: "each row in an array stores a
//! different segment of HV, with parts of the same HV distributed across
//! multiple arrays at the same row").

use super::adc::AdcConfig;
use super::transfer::imc_mvm_ref;
use super::ARRAY_DIM;
use crate::device::{Material, Programmer};
use crate::util::Rng;

/// Program/verify op counts a bank accumulates (consumed by the energy model).
#[derive(Clone, Copy, Debug, Default)]
pub struct BankCounters {
    pub program_pulses: u64,
    pub verify_reads: u64,
    pub mvm_ops: u64,
    pub row_reads: u64,
}

#[derive(Clone, Debug)]
pub struct ArrayBank {
    pub material: Material,
    /// Stored conductance differences, row-major 128x128.
    g: Vec<f32>,
    /// Rows currently holding valid data.
    row_valid: [bool; ARRAY_DIM],
    /// Per-row cumulative write (pulse) count — endurance tracking (§III-E:
    /// both stacks sustain > 1e8 cycles).
    row_writes: [u64; ARRAY_DIM],
    pub counters: BankCounters,
}

impl ArrayBank {
    pub fn new(material: Material) -> Self {
        ArrayBank {
            material,
            g: vec![0.0; ARRAY_DIM * ARRAY_DIM],
            row_valid: [false; ARRAY_DIM],
            row_writes: [0; ARRAY_DIM],
            counters: BankCounters::default(),
        }
    }

    /// Program one row with a 128-wide packed segment through the
    /// write-verify `programmer`. Returns pulses issued (for latency).
    pub fn program_row(
        &mut self,
        row: usize,
        segment: &[f32],
        programmer: &Programmer,
        rng: &mut Rng,
    ) -> u64 {
        assert!(row < ARRAY_DIM, "row {row} out of range");
        assert_eq!(segment.len(), ARRAY_DIM, "segment width");
        let (stored, pulses, reads) = programmer.program_slice(segment, rng);
        self.g[row * ARRAY_DIM..(row + 1) * ARRAY_DIM].copy_from_slice(&stored);
        self.row_valid[row] = true;
        // Endurance is consumed per *cycle of the row* (cells pulse in
        // parallel): average pulse depth = total pulses / row width.
        self.row_writes[row] += pulses.div_ceil(ARRAY_DIM as u64).max(1);
        self.counters.program_pulses += pulses;
        self.counters.verify_reads += reads;
        pulses
    }

    /// Mirror an externally programmed (already noisy) conductance segment
    /// into a row — used to load coordinator-programmed state (e.g. a
    /// `SearchEngine` library) into ISA banks without double-charging the
    /// programming work or re-drawing write noise.
    pub fn load_programmed_row(&mut self, row: usize, segment: &[f32]) {
        assert!(row < ARRAY_DIM, "row {row} out of range");
        assert_eq!(segment.len(), ARRAY_DIM, "segment width");
        self.g[row * ARRAY_DIM..(row + 1) * ARRAY_DIM].copy_from_slice(segment);
        self.row_valid[row] = true;
    }

    /// Whole-array IMC MVM: drive a 128-wide query segment on the SLs with
    /// all WLs active; returns 128 ADC-quantized per-row partial sums.
    /// Invalid rows return 0 (their cells stay at differential zero).
    pub fn mvm(&mut self, query_segment: &[f32], adc: AdcConfig) -> Vec<f32> {
        assert_eq!(query_segment.len(), ARRAY_DIM);
        self.counters.mvm_ops += 1;
        imc_mvm_ref(query_segment, &self.g, 1, ARRAY_DIM, ARRAY_DIM, adc)
    }

    /// Raw stored conductance differences (row-major 128x128) — the
    /// reference operand an MVM backend executes against.
    pub fn conductances(&self) -> &[f32] {
        &self.g
    }

    /// Normal (digital) read of one row through the sense amps.
    pub fn read_row(&mut self, row: usize) -> &[f32] {
        assert!(row < ARRAY_DIM);
        self.counters.row_reads += 1;
        &self.g[row * ARRAY_DIM..(row + 1) * ARRAY_DIM]
    }

    pub fn row_is_valid(&self, row: usize) -> bool {
        self.row_valid[row]
    }

    pub fn invalidate_row(&mut self, row: usize) {
        self.row_valid[row] = false;
    }

    pub fn valid_rows(&self) -> usize {
        self.row_valid.iter().filter(|&&v| v).count()
    }

    /// Worst-case per-row write count vs the material's endurance budget.
    pub fn endurance_fraction_used(&self) -> f64 {
        let max = *self.row_writes.iter().max().unwrap_or(&0);
        max as f64 / self.material.params().endurance_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{MlcConfig, NoiseModel};

    fn mk_bank_and_prog(wv: u32) -> (ArrayBank, Programmer) {
        let bank = ArrayBank::new(Material::TiTe2Gst467);
        let prog = Programmer::new(
            NoiseModel::new(Material::TiTe2Gst467, MlcConfig::new(3)),
            wv,
        );
        (bank, prog)
    }

    #[test]
    fn program_then_mvm_recovers_similarity() {
        let (mut bank, prog) = mk_bank_and_prog(6);
        let mut rng = Rng::new(1);
        let seg: Vec<f32> = (0..ARRAY_DIM).map(|_| rng.range_i64(-3, 3) as f32).collect();
        bank.program_row(0, &seg, &prog, &mut rng);
        // negated copy on row 1
        let neg: Vec<f32> = seg.iter().map(|x| -x).collect();
        bank.program_row(1, &neg, &prog, &mut rng);

        let scores = bank.mvm(&seg, AdcConfig::ideal());
        assert!(scores[0] > 0.0, "self-similarity positive: {}", scores[0]);
        assert!(scores[1] < 0.0, "anti-similarity negative: {}", scores[1]);
        assert!(
            (scores[0] + scores[1]).abs() < 0.2 * scores[0],
            "roughly symmetric"
        );
        // unprogrammed rows contribute zero
        assert_eq!(scores[5], 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let (mut bank, prog) = mk_bank_and_prog(2);
        let mut rng = Rng::new(2);
        let seg = vec![1.0; ARRAY_DIM];
        bank.program_row(3, &seg, &prog, &mut rng);
        bank.mvm(&seg, AdcConfig::ideal());
        bank.read_row(3);
        assert!(bank.counters.program_pulses >= ARRAY_DIM as u64);
        assert_eq!(bank.counters.verify_reads, 2 * ARRAY_DIM as u64);
        assert_eq!(bank.counters.mvm_ops, 1);
        assert_eq!(bank.counters.row_reads, 1);
        assert_eq!(bank.valid_rows(), 1);
    }

    #[test]
    fn endurance_tracking() {
        let (mut bank, prog) = mk_bank_and_prog(0);
        let mut rng = Rng::new(3);
        let seg = vec![3.0; ARRAY_DIM];
        for _ in 0..100 {
            bank.program_row(0, &seg, &prog, &mut rng);
        }
        let used = bank.endurance_fraction_used();
        // 100 clustering iterations consume a ~1e-6 sliver of the 1e8
        // endurance budget — the §III-E "over 1e6 clustering processes" claim.
        assert!(used >= 100.0 / 1e8 && used < 1e-5, "{used}");
    }

    #[test]
    #[should_panic(expected = "row")]
    fn rejects_out_of_range_row() {
        let (mut bank, prog) = mk_bank_and_prog(0);
        let mut rng = Rng::new(4);
        bank.program_row(128, &vec![0.0; ARRAY_DIM], &prog, &mut rng);
    }
}
