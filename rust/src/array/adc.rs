//! Reconfigurable flash ADC (Table 1, §III-D "Reconfigurable ADC bits").
//!
//! The physical unit is a 6-bit flash ADC with 63 dynamic comparators; the
//! effective precision is modulated to 1..=6 bits by partially enabling
//! comparators (no hardware change), trading accuracy for energy. The
//! transfer function mirrors the Pallas kernel bit-exactly:
//! `adc(s) = clip(round_away(s / lsb), -(qmax+1), qmax) * lsb`.
//!
//! For exact agreement across rust / XLA / numpy the full-scale is always
//! rounded up to a power of two (see `imc_mvm.py::adc_params`).



use super::{ADC_MAX_BITS, ARRAY_DIM};
use crate::util::{pow2_at_least, round_away};

/// ADC operating point: effective bits + full-scale clip voltage
/// (normalized to packed-value units).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcConfig {
    pub bits: u32,
    /// Full-scale input magnitude; always a power of two.
    pub clip: f32,
}

impl AdcConfig {
    pub fn new(bits: u32, clip: f32) -> Self {
        assert!(
            (1..=ADC_MAX_BITS).contains(&bits),
            "ADC bits must be 1..=6, got {bits}"
        );
        let clip = pow2_at_least(clip as f64) as f32;
        AdcConfig { bits, clip }
    }

    /// Paper-default operating point for a given packing factor n: the
    /// per-array partial sum is ~N(0, 128 * n^4) for uncorrelated HVs, so
    /// full-scale = 4 sigma = 4 n^2 sqrt(128), rounded up to a power of 2.
    pub fn default_for_packing(bits: u32, n: usize) -> Self {
        let sigma = (n * n) as f64 * (ARRAY_DIM as f64).sqrt();
        AdcConfig::new(bits, (4.0 * sigma) as f32)
    }

    /// LSB size.
    #[inline]
    pub fn lsb(&self) -> f32 {
        self.clip / (1i64 << (self.bits - 1)) as f32
    }

    /// Largest positive output code.
    #[inline]
    pub fn qmax(&self) -> f32 {
        ((1i64 << (self.bits - 1)) - 1) as f32
    }

    /// Quantize one bit-line partial sum.
    #[inline]
    pub fn quantize(&self, s: f32) -> f32 {
        let lsb = self.lsb();
        let qmax = self.qmax();
        round_away(s / lsb).clamp(-(qmax + 1.0), qmax) * lsb
    }

    /// Comparators enabled at this precision (63 for 6-bit flash).
    #[inline]
    pub fn comparators_enabled(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// An effectively-transparent ADC used for ideal-accuracy experiments:
    /// lsb = 1 and a code range far beyond any reachable partial sum, so
    /// `quantize` is the identity on the integer partial sums. (Bypasses
    /// the 1..=6 physical-bits check on purpose — this is a modeling tool,
    /// not a hardware configuration.)
    pub fn ideal() -> Self {
        AdcConfig {
            bits: 24,
            clip: (1u32 << 23) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_rounding_of_clip() {
        let a = AdcConfig::new(6, 407.3);
        assert_eq!(a.clip, 512.0);
        assert_eq!(a.lsb(), 16.0);
        assert_eq!(a.qmax(), 31.0);
    }

    #[test]
    fn default_operating_points() {
        // n = 3: 4 * 9 * sqrt(128) ~= 407 -> 512; n = 1: ~45 -> 64.
        assert_eq!(AdcConfig::default_for_packing(6, 3).clip, 512.0);
        assert_eq!(AdcConfig::default_for_packing(6, 1).clip, 64.0);
        assert_eq!(AdcConfig::default_for_packing(6, 2).clip, 256.0);
    }

    #[test]
    fn quantize_matches_formula() {
        let a = AdcConfig::new(6, 512.0);
        assert_eq!(a.quantize(42.0), 48.0); // 42/16=2.625 -> 3 -> 48
        assert_eq!(a.quantize(-73.0), -80.0); // -4.5625 -> -5 -> -80
        assert_eq!(a.quantize(2.0), 0.0);
        assert_eq!(a.quantize(10_000.0), 31.0 * 16.0); // clips at qmax
        assert_eq!(a.quantize(-10_000.0), -32.0 * 16.0); // clips at -(qmax+1)
    }

    #[test]
    fn one_bit_adc_two_codes() {
        let a = AdcConfig::new(1, 64.0);
        assert_eq!(a.qmax(), 0.0);
        assert_eq!(a.quantize(100.0), 0.0);
        assert_eq!(a.quantize(-100.0), -64.0);
        assert_eq!(a.comparators_enabled(), 1);
    }

    #[test]
    fn comparator_counts() {
        assert_eq!(AdcConfig::new(6, 512.0).comparators_enabled(), 63);
        assert_eq!(AdcConfig::new(4, 512.0).comparators_enabled(), 15);
    }

    #[test]
    #[should_panic(expected = "ADC bits")]
    fn rejects_seven_bits() {
        AdcConfig::new(7, 512.0);
    }

    #[test]
    fn ideal_adc_is_identity_on_integers() {
        let a = AdcConfig::ideal();
        assert_eq!(a.lsb(), 1.0);
        for s in [-1152.0f32, -7.0, 0.0, 3.0, 1152.0] {
            assert_eq!(a.quantize(s), s);
        }
    }
}
