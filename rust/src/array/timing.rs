//! Cycle-accurate timing model (paper §III-C, §IV-A, supplementary S.B).
//!
//! The system clocks at 500 MHz (40 nm CMOS). Headline facts from the
//! paper: a full in-array MVM — DAC input generation, analog MAC on all
//! activated rows, and the shared-ADC conversion sweep — takes **10
//! cycles**; programming a PCM array (one pulse round) takes **20 ns (10
//! cycles)**; most peripheral component operations complete in one cycle.



#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    /// Core clock (Hz). Paper: 500 MHz.
    pub clock_hz: f64,
    /// Cycles for one whole-array IMC MVM including DAC setup (paper: 10).
    pub mvm_cycles: u64,
    /// Cycles per programming pulse round (paper: 20 ns = 10 cycles).
    pub program_cycles: u64,
    /// Cycles for a normal row read through the sense amps.
    pub read_cycles: u64,
    /// Cycles for one verify read + compare during write-verify.
    pub verify_cycles: u64,
    /// ASIC encoder cycles per spectrum (pipelined HLS block: one feature
    /// position per cycle).
    pub encode_cycles_per_feature: u64,
    /// ASIC packing cycles per packed output element.
    pub pack_cycles_per_element: u64,
    /// ASIC cycles per distance-matrix merge update element (complete
    /// linkage max + compare).
    pub merge_cycles_per_element: u64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel {
            clock_hz: 500e6,
            mvm_cycles: 10,
            program_cycles: 10,
            read_cycles: 1,
            verify_cycles: 2,
            encode_cycles_per_feature: 1,
            pack_cycles_per_element: 1,
            merge_cycles_per_element: 1,
        }
    }
}

impl TimingModel {
    #[inline]
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    #[inline]
    pub fn cycles_to_s(&self, cycles: u64) -> f64 {
        cycles as f64 * self.cycle_s()
    }

    /// Latency of one whole-array MVM.
    pub fn mvm_s(&self) -> f64 {
        self.cycles_to_s(self.mvm_cycles)
    }

    /// Latency of one programming pulse round.
    pub fn program_pulse_s(&self) -> f64 {
        self.cycles_to_s(self.program_cycles)
    }

    /// Latency to encode one spectrum of `features` positions in the ASIC.
    pub fn encode_s(&self, features: usize) -> f64 {
        self.cycles_to_s(self.encode_cycles_per_feature * features as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_numbers() {
        let t = TimingModel::default();
        assert_eq!(t.cycle_s(), 2e-9);
        assert_eq!(t.mvm_s(), 20e-9); // 10 cycles @ 500 MHz = 20 ns
        assert_eq!(t.program_pulse_s(), 20e-9); // paper: 20 ns
    }

    #[test]
    fn encode_latency_scales_with_features() {
        let t = TimingModel::default();
        assert_eq!(t.encode_s(512), 512.0 * 2e-9);
    }
}
