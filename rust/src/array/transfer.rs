//! Reference analog-IMC MVM transfer function — the rust mirror of the L1
//! Pallas kernel (`python/compile/kernels/imc_mvm.py`) and the jnp oracle
//! (`kernels/ref.py`).
//!
//! Used (a) by integration tests to check the PJRT-executed artifact
//! bit-exactly, and (b) as a no-artifacts fallback execution path so the
//! simulator is usable without a built `artifacts/` tree.

use super::adc::AdcConfig;
use super::dac::dac_quantize;
use super::ARRAY_DIM;

/// scores[b][r] = sum over 128-col tiles of ADC( DAC(q_tile) . g_tile ).
///
/// * `queries`: B x C row-major, packed query HVs.
/// * `refs`:    R x C row-major, stored (noisy) conductance differences.
/// * C must be a multiple of [`ARRAY_DIM`]; R and B are unconstrained here
///   (the physical row-block granularity is enforced by the coordinator).
pub fn imc_mvm_ref(
    queries: &[f32],
    refs: &[f32],
    b: usize,
    r: usize,
    c: usize,
    adc: AdcConfig,
) -> Vec<f32> {
    assert_eq!(queries.len(), b * c, "queries shape");
    assert_eq!(refs.len(), r * c, "refs shape");
    assert_eq!(c % ARRAY_DIM, 0, "C must be a multiple of {ARRAY_DIM}");

    // DAC once per query element (the SL drivers hold the driven levels).
    let dacq: Vec<f32> = queries.iter().map(|&x| dac_quantize(x)).collect();

    let tiles = c / ARRAY_DIM;
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &dacq[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            let mut acc = 0f32;
            for t in 0..tiles {
                let lo = t * ARRAY_DIM;
                let hi = lo + ARRAY_DIM;
                let mut part = 0f32;
                for k in lo..hi {
                    part += qrow[k] * grow[k];
                }
                acc += adc.quantize(part);
            }
            out[bi * r + ri] = acc;
        }
    }
    out
}

/// Exact (no DAC/ADC) dot-product scores — the "digital" upper bound used
/// by the HyperSpec/HyperOMS-style software baselines.
pub fn exact_mvm(queries: &[f32], refs: &[f32], b: usize, r: usize, c: usize) -> Vec<f32> {
    assert_eq!(queries.len(), b * c);
    assert_eq!(refs.len(), r * c);
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &queries[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            out[bi * r + ri] = qrow.iter().zip(grow).map(|(a, g)| a * g).sum();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
        (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
    }

    #[test]
    fn ideal_adc_equals_exact() {
        let mut rng = Rng::new(1);
        let (b, r, c) = (4, 8, 256);
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let got = imc_mvm_ref(&q, &g, b, r, c, AdcConfig::ideal());
        let want = exact_mvm(&q, &g, b, r, c);
        assert_eq!(got, want);
    }

    #[test]
    fn quantized_adc_changes_scores_but_preserves_order_of_extremes() {
        let mut rng = Rng::new(2);
        let (b, r, c) = (1, 3, 128);
        // Row 0 identical to the query (max similarity), row 1 its negation,
        // row 2 random.
        let q = rand_packed(&mut rng, c, 1);
        let mut g = q.clone();
        g.extend(q.iter().map(|x| -x));
        g.extend(rand_packed(&mut rng, c, 1));
        let adc = AdcConfig::new(6, 64.0);
        let s = imc_mvm_ref(&q, &g, b, r, c, adc);
        assert!(s[0] > s[2] && s[2] > s[1], "{s:?}");
    }

    #[test]
    fn tilewise_adc_matters() {
        // A sum that cancels *across* tiles but saturates within each tile
        // must differ from the exact dot product: +big in tile 0, -big in
        // tile 1, with a tiny clip.
        let (b, r, c) = (1, 1, 256);
        let mut q = vec![1f32; c];
        let g = vec![3f32; c];
        for x in q.iter_mut().skip(128) {
            *x = -1.0;
        }
        let exact = exact_mvm(&q, &g, b, r, c)[0];
        assert_eq!(exact, 0.0);
        let adc = AdcConfig::new(2, 64.0); // qmax=1, lsb=32: +384 clips to 32, -384 to -64
        let s = imc_mvm_ref(&q, &g, b, r, c, adc)[0];
        assert_eq!(s, 32.0 - 64.0);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_untiled_c() {
        imc_mvm_ref(&[0.0; 100], &[0.0; 100], 1, 1, 100, AdcConfig::ideal());
    }
}
