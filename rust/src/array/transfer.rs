//! Reference analog-IMC MVM transfer function — the rust mirror of the L1
//! Pallas kernel (`python/compile/kernels/imc_mvm.py`) and the jnp oracle
//! (`kernels/ref.py`).
//!
//! Used (a) by integration tests to check the PJRT-executed artifact
//! bit-exactly, and (b) as a no-artifacts fallback execution path so the
//! simulator is usable without a built `artifacts/` tree.
//!
//! # The lane-ordered accumulation contract (PR 6)
//!
//! Inside each 128-column tile the dot product is **not** accumulated in
//! ascending `k`. Instead the canonical order is an 8-lane partial-sum
//! layout:
//!
//! 1. lane `l` (`0..8`) sums the products at columns `k % 8 == l`, in
//!    ascending `k` — eight independent f32 accumulators, the shape the
//!    autovectorizer turns into one 8-wide SIMD accumulator;
//! 2. the eight lanes are reduced by the fixed binary tree
//!    `((l0 + l4) + (l2 + l6)) + ((l1 + l5) + (l3 + l7))`
//!    (see [`lane_tree_reduce`]).
//!
//! This order is *the* definition of a tile dot product everywhere in the
//! repo: [`imc_mvm_ref`] is its scalar oracle (coded lane-major, explicit
//! loops), [`lane_tile_dot`] is the vectorizable coding (chunk-major,
//! eight in-flight accumulators), and the two are bit-identical because
//! each lane performs the identical f32 add sequence either way. Changing
//! the order is a breaking change to every committed score: the pinned-bit
//! regression test below fails loudly on any accidental reassociation.
//!
//! Why the order changed in PR 6: ascending-`k` accumulation serializes
//! 128 dependent f32 adds, which the autovectorizer must preserve and so
//! cannot vectorize. Eight independent lanes vectorize cleanly with no new
//! dependencies and no nightly features. For *integer* packed data —
//! DAC levels times integer conductance targets, every partial sum exactly
//! representable in f32 — any association order gives identical results,
//! so the switch only redefines scores on non-integer (write-verify-noised)
//! conductances.

use super::adc::AdcConfig;
use super::dac::dac_quantize;
use super::ARRAY_DIM;

/// Partial-sum lanes per tile dot product (the canonical accumulation
/// order splits each 128-column tile across `k % MVM_LANES`).
pub const MVM_LANES: usize = 8;

// The lane layout assumes tiles split evenly into lanes.
const _: () = assert!(ARRAY_DIM % MVM_LANES == 0);

/// The fixed lane-reduction tree: `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`.
///
/// Pairs are `MVM_LANES/2` apart (the shape of one in-register shuffle
/// reduction of an 8-wide accumulator), then even/odd subtrees combine.
/// This exact association order is part of the kernel contract — every
/// score in the repo depends on it bit-for-bit.
#[inline]
pub fn lane_tree_reduce(l: &[f32; MVM_LANES]) -> f32 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

/// One lane-ordered 128-column tile dot product — the vectorizable coding
/// of the canonical accumulation order (chunk-major: walk 16 chunks of 8,
/// keeping all 8 lane accumulators in flight so LLVM maps them onto one
/// SIMD register). Bit-identical to the lane-major scalar coding in
/// [`imc_mvm_ref`] because every lane sees the identical add sequence.
///
/// Both slices must be exactly [`ARRAY_DIM`] long.
#[inline]
pub fn lane_tile_dot(q: &[f32], g: &[f32]) -> f32 {
    let q = &q[..ARRAY_DIM];
    let g = &g[..ARRAY_DIM];
    let mut lanes = [0f32; MVM_LANES];
    for (qc, gc) in q.chunks_exact(MVM_LANES).zip(g.chunks_exact(MVM_LANES)) {
        for (lane, (&a, &b)) in lanes.iter_mut().zip(qc.iter().zip(gc)) {
            *lane += a * b;
        }
    }
    lane_tree_reduce(&lanes)
}

/// scores[b][r] = sum over 128-col tiles of ADC( DAC(q_tile) . g_tile ).
///
/// * `queries`: B x C row-major, packed query HVs.
/// * `refs`:    R x C row-major, stored (noisy) conductance differences.
/// * C must be a multiple of [`ARRAY_DIM`]; R and B are unconstrained here
///   (the physical row-block granularity is enforced by the coordinator).
///
/// This is the scalar **oracle** for the lane-ordered accumulation
/// contract (module docs): each tile dot is computed lane-major — one
/// explicit scalar loop per lane, then [`lane_tree_reduce`] — so the fast
/// kernels have an independently-coded reference to be property-tested
/// against, not a second copy of themselves.
pub fn imc_mvm_ref(
    queries: &[f32],
    refs: &[f32],
    b: usize,
    r: usize,
    c: usize,
    adc: AdcConfig,
) -> Vec<f32> {
    assert_eq!(queries.len(), b * c, "queries shape");
    assert_eq!(refs.len(), r * c, "refs shape");
    assert_eq!(c % ARRAY_DIM, 0, "C must be a multiple of {ARRAY_DIM}");

    // DAC once per query element (the SL drivers hold the driven levels).
    let dacq: Vec<f32> = queries.iter().map(|&x| dac_quantize(x)).collect();

    let tiles = c / ARRAY_DIM;
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &dacq[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            let mut acc = 0f32;
            for t in 0..tiles {
                let lo = t * ARRAY_DIM;
                // Lane-major scalar coding of the canonical order: lane l
                // sums columns k % 8 == l in ascending k, then the fixed
                // tree reduces the eight lanes.
                let mut lanes = [0f32; MVM_LANES];
                for (l, lane) in lanes.iter_mut().enumerate() {
                    let mut k = lo + l;
                    while k < lo + ARRAY_DIM {
                        *lane += qrow[k] * grow[k];
                        k += MVM_LANES;
                    }
                }
                acc += adc.quantize(lane_tree_reduce(&lanes));
            }
            out[bi * r + ri] = acc;
        }
    }
    out
}

/// Query rows per blocking step of [`imc_mvm_blocked_into`]: small enough
/// that the per-sub-tile accumulator scratch (`QUERY_BLOCK x ARRAY_DIM`
/// f32 = 8 KB) lives comfortably in L1 next to the 64 KB reference tile.
const QUERY_BLOCK: usize = 16;

/// Cache-blocked, segment-aware variant of [`imc_mvm_ref`]: scores `b`
/// packed query rows against the reference rows named by `segments` —
/// physical row ranges into the row-major `panel` (`panel.len() / c`
/// rows), concatenated left-to-right into the output columns. Writes the
/// `b x sum(segment lens)` row-major scores into `out` (caller-owned, so
/// serving loops reuse one buffer across batches).
///
/// DAC-quantizes `queries` internally, then runs
/// [`imc_mvm_blocked_dacq_into`]; batch loops that score the same queries
/// against many segment groups should quantize once and call the `dacq`
/// variant directly (the engine's `ScoreScratch` does).
pub fn imc_mvm_blocked_into(
    queries: &[f32],
    panel: &[f32],
    segments: &[std::ops::Range<usize>],
    b: usize,
    c: usize,
    adc: AdcConfig,
    out: &mut [f32],
) {
    assert_eq!(queries.len(), b * c, "queries shape");
    // DAC once per query element, exactly as the reference kernel does.
    let dacq: Vec<f32> = queries.iter().map(|&x| dac_quantize(x)).collect();
    imc_mvm_blocked_dacq_into(&dacq, panel, segments, b, c, adc, out);
}

/// [`imc_mvm_blocked_into`] over **already DAC-quantized** queries.
///
/// `dacq` must hold `b x c` values already passed through
/// [`dac_quantize`]; because the DAC is idempotent on its own output,
/// scoring pre-quantized queries is bit-identical to quantizing again —
/// this entry point only skips the redundant pass and its allocation.
///
/// # Bit-identity with the gathered reference path
///
/// The blocking only reorders *which output* is worked on next — never the
/// arithmetic inside one output. For every `(query, reference)` pair the
/// accumulation is exactly [`imc_mvm_ref`]'s: column tiles visited in
/// ascending order, each tile reduced in the canonical lane order
/// ([`lane_tile_dot`], chunk-major coding of the same lanes), one ADC
/// quantization per tile, partial sums added in tile order. f32 addition
/// is performed in the identical sequence, so every score is bit-identical
/// to gathering the segment rows into a dense matrix and calling
/// [`imc_mvm_ref`] (locked in by `rust/tests/segmented_equivalence.rs`).
///
/// # Blocking structure
///
/// Queries advance in [`QUERY_BLOCK`]-row blocks; within a block, each
/// segment is walked in [`ARRAY_DIM`]-row panels, and each panel's scores
/// accumulate column-tile-by-column-tile into a small scratch sub-tile.
/// The inner `t -> (query, panel-row)` order means one 128x128 reference
/// tile (64 KB) is reused by every query of the block while hot, instead
/// of being re-streamed from memory once per query — the reference
/// kernel's behavior at large `r`.
pub fn imc_mvm_blocked_dacq_into(
    dacq: &[f32],
    panel: &[f32],
    segments: &[std::ops::Range<usize>],
    b: usize,
    c: usize,
    adc: AdcConfig,
    out: &mut [f32],
) {
    assert_eq!(dacq.len(), b * c, "queries shape");
    assert!(c > 0 && c % ARRAY_DIM == 0, "C must be a positive multiple of {ARRAY_DIM}");
    assert_eq!(panel.len() % c, 0, "panel shape");
    let panel_rows = panel.len() / c;
    let r: usize = segments.iter().map(|s| s.len()).sum();
    for s in segments {
        assert!(s.start <= s.end && s.end <= panel_rows, "segment {s:?} out of panel");
    }
    assert_eq!(out.len(), b * r, "out shape");

    let tiles = c / ARRAY_DIM;
    let mut acc = [0f32; QUERY_BLOCK * ARRAY_DIM];
    let mut q0 = 0;
    while q0 < b {
        let qn = QUERY_BLOCK.min(b - q0);
        // Output-column cursor across the concatenated segments.
        let mut oc = 0usize;
        for seg in segments {
            let mut p0 = seg.start;
            while p0 < seg.end {
                let pn = ARRAY_DIM.min(seg.end - p0);
                let sub = &mut acc[..qn * pn];
                sub.fill(0.0);
                for t in 0..tiles {
                    let lo = t * ARRAY_DIM;
                    for qi in 0..qn {
                        let qoff = (q0 + qi) * c + lo;
                        let qrow = &dacq[qoff..qoff + ARRAY_DIM];
                        for pi in 0..pn {
                            let goff = (p0 + pi) * c + lo;
                            let grow = &panel[goff..goff + ARRAY_DIM];
                            let part = lane_tile_dot(qrow, grow);
                            // lint: reassoc-ok (cross-tile ADC sums run in ascending tile order — the imc_mvm_ref association, pinned by lane_order_pinned_bits)
                            sub[qi * pn + pi] += adc.quantize(part);
                        }
                    }
                }
                for qi in 0..qn {
                    let ooff = (q0 + qi) * r + oc;
                    out[ooff..ooff + pn].copy_from_slice(&sub[qi * pn..(qi + 1) * pn]);
                }
                oc += pn;
                p0 += pn;
            }
        }
        q0 += qn;
    }
}

/// Exact (no DAC/ADC) dot-product scores — the "digital" upper bound used
/// by the HyperSpec/HyperOMS-style software baselines.
pub fn exact_mvm(queries: &[f32], refs: &[f32], b: usize, r: usize, c: usize) -> Vec<f32> {
    assert_eq!(queries.len(), b * c);
    assert_eq!(refs.len(), r * c);
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &queries[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            // lint: reassoc-ok (digital software baseline, deliberately outside the IMC lane contract; never compared bit-for-bit)
            out[bi * r + ri] = qrow.iter().zip(grow).map(|(a, g)| a * g).sum();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
        (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
    }

    #[test]
    fn ideal_adc_equals_exact() {
        let mut rng = Rng::new(1);
        let (b, r, c) = (4, 8, 256);
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let got = imc_mvm_ref(&q, &g, b, r, c, AdcConfig::ideal());
        let want = exact_mvm(&q, &g, b, r, c);
        assert_eq!(got, want);
    }

    #[test]
    fn quantized_adc_changes_scores_but_preserves_order_of_extremes() {
        let mut rng = Rng::new(2);
        let (b, r, c) = (1, 3, 128);
        // Row 0 identical to the query (max similarity), row 1 its negation,
        // row 2 random.
        let q = rand_packed(&mut rng, c, 1);
        let mut g = q.clone();
        g.extend(q.iter().map(|x| -x));
        g.extend(rand_packed(&mut rng, c, 1));
        let adc = AdcConfig::new(6, 64.0);
        let s = imc_mvm_ref(&q, &g, b, r, c, adc);
        assert!(s[0] > s[2] && s[2] > s[1], "{s:?}");
    }

    #[test]
    fn tilewise_adc_matters() {
        // A sum that cancels *across* tiles but saturates within each tile
        // must differ from the exact dot product: +big in tile 0, -big in
        // tile 1, with a tiny clip.
        let (b, r, c) = (1, 1, 256);
        let mut q = vec![1f32; c];
        let g = vec![3f32; c];
        for x in q.iter_mut().skip(128) {
            *x = -1.0;
        }
        let exact = exact_mvm(&q, &g, b, r, c)[0];
        assert_eq!(exact, 0.0);
        let adc = AdcConfig::new(2, 64.0); // qmax=1, lsb=32: +384 clips to 32, -384 to -64
        let s = imc_mvm_ref(&q, &g, b, r, c, adc)[0];
        assert_eq!(s, 32.0 - 64.0);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_untiled_c() {
        imc_mvm_ref(&[0.0; 100], &[0.0; 100], 1, 1, 100, AdcConfig::ideal());
    }

    /// The canonical lane order pinned to exact f32 bits on a
    /// hand-computable non-integer tile (integer data is exact under any
    /// association order and would hide a reassociation, so the
    /// conductances here are deliberately non-dyadic). Constants generated
    /// by the numpy float32 model in
    /// `python/tests/test_blocked_kernel_model.py` — an accidental change
    /// to the lane count, lane walk, or reduce tree fails here loudly.
    #[test]
    fn lane_order_pinned_bits() {
        let q: Vec<f32> = (0..ARRAY_DIM).map(|k| ((k * 7) % 8) as f32 - 4.0).collect();
        let g: Vec<f32> = (0..ARRAY_DIM).map(|k| (k as f32 - 64.0) / 100.0).collect();
        let lane = lane_tile_dot(&q, &g);
        assert_eq!(lane.to_bits(), 0xbff5_c288, "lane-ordered tile dot drifted: {lane}");

        // The lane-major oracle coding must agree exactly (1x1 job, one
        // tile, ideal-but-wide ADC is still quantizing — so pin through
        // the raw tile dot, not the post-ADC score).
        let mut lanes = [0f32; MVM_LANES];
        for (l, lane) in lanes.iter_mut().enumerate() {
            let mut k = l;
            while k < ARRAY_DIM {
                *lane += q[k] * g[k];
                k += MVM_LANES;
            }
        }
        assert_eq!(lane_tree_reduce(&lanes).to_bits(), lane.to_bits());

        // And the pre-PR-6 ascending-k order gives a *different* f32 — the
        // tile really exercises reassociation sensitivity.
        let asc: f32 = q.iter().zip(&g).fold(0f32, |acc, (&a, &b)| acc + a * b);
        assert_eq!(asc.to_bits(), 0xbff5_c290);
        assert_ne!(asc.to_bits(), lane.to_bits());
    }

    /// Non-integer conductances exercise f32 rounding, so oracle-vs-fast
    /// equality here fails under any lane-semantics drift between the two
    /// codings (the integer-data tests below are exact under *any* order).
    #[test]
    fn blocked_matches_ref_on_noninteger_panels() {
        let mut rng = Rng::new(41);
        for trial in 0..10u64 {
            let (b, r, c) = (1 + rng.below(20), 1 + rng.below(200), [128, 256, 384][rng.below(3)]);
            let q = rand_packed(&mut rng, b * c, 3);
            let g: Vec<f32> = (0..r * c)
                .map(|_| rng.range_i64(-3, 3) as f32 + rng.range_i64(-400, 400) as f32 / 7000.0)
                .collect();
            let adc = [AdcConfig::new(6, 512.0), AdcConfig::new(3, 128.0)][rng.below(2)];
            let want = imc_mvm_ref(&q, &g, b, r, c, adc);
            let mut got = vec![f32::NAN; b * r];
            imc_mvm_blocked_into(&q, &g, &[0..r], b, c, adc, &mut got);
            assert_eq!(got, want, "trial {trial}");
        }
    }

    /// Gather the segment rows into a dense matrix — the oracle the
    /// blocked kernel must match bit-for-bit.
    fn gather_rows(panel: &[f32], segments: &[std::ops::Range<usize>], c: usize) -> Vec<f32> {
        let mut g = Vec::new();
        for s in segments {
            g.extend_from_slice(&panel[s.start * c..s.end * c]);
        }
        g
    }

    #[test]
    fn blocked_dense_matches_ref_bitwise() {
        let mut rng = Rng::new(31);
        // b > QUERY_BLOCK so multiple query blocks run; r > 128 so
        // multiple row panels run; non-pow2 raggedness everywhere.
        let (b, r, c) = (37, 300, 384);
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        for adc in [AdcConfig::ideal(), AdcConfig::new(6, 512.0), AdcConfig::new(3, 128.0)] {
            let want = imc_mvm_ref(&q, &g, b, r, c, adc);
            let mut got = vec![f32::NAN; b * r];
            imc_mvm_blocked_into(&q, &g, &[0..r], b, c, adc, &mut got);
            assert_eq!(got, want, "adc {adc:?}");
        }
    }

    #[test]
    fn blocked_segmented_matches_gathered_ref_bitwise() {
        let mut rng = Rng::new(32);
        let (panel_rows, c) = (500, 256);
        let panel = rand_packed(&mut rng, panel_rows * c, 3);
        let q = rand_packed(&mut rng, 5 * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        // Ragged segments: empty, single-row, straddling the 128-row tile
        // boundary, and out-of-order-sized ranges.
        let segs: Vec<std::ops::Range<usize>> =
            vec![3..3, 10..11, 100..260, 0..1, 300..500, 42..42];
        let gathered = gather_rows(&panel, &segs, c);
        let r: usize = segs.iter().map(|s| s.len()).sum();
        let want = imc_mvm_ref(&q, &gathered, 5, r, c, adc);
        let mut got = vec![f32::NAN; 5 * r];
        imc_mvm_blocked_into(&q, &panel, &segs, 5, c, adc, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_dacq_matches_unquantized_entry() {
        // Pre-quantizing is bit-identical (DAC idempotence), not just close.
        let mut rng = Rng::new(33);
        let (b, r, c) = (7, 90, 256);
        let q: Vec<f32> = (0..b * c).map(|_| rng.range_i64(-40, 40) as f32 / 8.0).collect();
        let g = rand_packed(&mut rng, r * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        let mut want = vec![f32::NAN; b * r];
        imc_mvm_blocked_into(&q, &g, &[0..r], b, c, adc, &mut want);
        let dacq: Vec<f32> = q.iter().map(|&x| dac_quantize(x)).collect();
        let mut got = vec![f32::NAN; b * r];
        imc_mvm_blocked_dacq_into(&dacq, &g, &[0..r], b, c, adc, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_empty_inputs() {
        let adc = AdcConfig::ideal();
        let g = vec![1.0f32; 4 * 128];
        // No queries.
        imc_mvm_blocked_into(&[], &g, &[0..4], 0, 128, adc, &mut []);
        // No candidate rows (only empty segments).
        let q = vec![1.0f32; 2 * 128];
        imc_mvm_blocked_into(&q, &g, &[2..2], 2, 128, adc, &mut []);
        imc_mvm_blocked_into(&q, &g, &[], 2, 128, adc, &mut []);
    }

    #[test]
    #[should_panic(expected = "positive multiple")]
    fn blocked_rejects_zero_width() {
        // c == 0 used to slip through `panel.len() % c.max(1)`; the guard
        // must reject the degenerate width outright.
        imc_mvm_blocked_into(&[], &[], &[], 0, 0, AdcConfig::ideal(), &mut []);
    }

    #[test]
    #[should_panic(expected = "out of panel")]
    fn blocked_rejects_out_of_range_segment() {
        let g = vec![0f32; 4 * 128];
        imc_mvm_blocked_into(&[0.0; 128], &g, &[2..5], 1, 128, AdcConfig::ideal(), &mut [0.0; 3]);
    }
}
