//! Reference analog-IMC MVM transfer function — the rust mirror of the L1
//! Pallas kernel (`python/compile/kernels/imc_mvm.py`) and the jnp oracle
//! (`kernels/ref.py`).
//!
//! Used (a) by integration tests to check the PJRT-executed artifact
//! bit-exactly, and (b) as a no-artifacts fallback execution path so the
//! simulator is usable without a built `artifacts/` tree.

use super::adc::AdcConfig;
use super::dac::dac_quantize;
use super::ARRAY_DIM;

/// scores[b][r] = sum over 128-col tiles of ADC( DAC(q_tile) . g_tile ).
///
/// * `queries`: B x C row-major, packed query HVs.
/// * `refs`:    R x C row-major, stored (noisy) conductance differences.
/// * C must be a multiple of [`ARRAY_DIM`]; R and B are unconstrained here
///   (the physical row-block granularity is enforced by the coordinator).
pub fn imc_mvm_ref(
    queries: &[f32],
    refs: &[f32],
    b: usize,
    r: usize,
    c: usize,
    adc: AdcConfig,
) -> Vec<f32> {
    assert_eq!(queries.len(), b * c, "queries shape");
    assert_eq!(refs.len(), r * c, "refs shape");
    assert_eq!(c % ARRAY_DIM, 0, "C must be a multiple of {ARRAY_DIM}");

    // DAC once per query element (the SL drivers hold the driven levels).
    let dacq: Vec<f32> = queries.iter().map(|&x| dac_quantize(x)).collect();

    let tiles = c / ARRAY_DIM;
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &dacq[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            let mut acc = 0f32;
            for t in 0..tiles {
                let lo = t * ARRAY_DIM;
                let hi = lo + ARRAY_DIM;
                let mut part = 0f32;
                for k in lo..hi {
                    part += qrow[k] * grow[k];
                }
                acc += adc.quantize(part);
            }
            out[bi * r + ri] = acc;
        }
    }
    out
}

/// Query rows per blocking step of [`imc_mvm_blocked_into`]: small enough
/// that the per-sub-tile accumulator scratch (`QUERY_BLOCK x ARRAY_DIM`
/// f32 = 8 KB) lives comfortably in L1 next to the 64 KB reference tile.
const QUERY_BLOCK: usize = 16;

/// Cache-blocked, segment-aware variant of [`imc_mvm_ref`]: scores `b`
/// packed query rows against the reference rows named by `segments` —
/// physical row ranges into the row-major `panel` (`panel.len() / c`
/// rows), concatenated left-to-right into the output columns. Writes the
/// `b x sum(segment lens)` row-major scores into `out` (caller-owned, so
/// serving loops reuse one buffer across batches).
///
/// # Bit-identity with the gathered reference path
///
/// The blocking only reorders *which output* is worked on next — never the
/// arithmetic inside one output. For every `(query, reference)` pair the
/// accumulation is exactly [`imc_mvm_ref`]'s: column tiles visited in
/// ascending order, the 128 products of each tile summed in ascending `k`,
/// one ADC quantization per tile, partial sums added in tile order. f32
/// addition is performed in the identical sequence, so every score is
/// bit-identical to gathering the segment rows into a dense matrix and
/// calling [`imc_mvm_ref`] (locked in by `rust/tests/segmented_equivalence.rs`).
///
/// # Blocking structure
///
/// Queries advance in [`QUERY_BLOCK`]-row blocks; within a block, each
/// segment is walked in [`ARRAY_DIM`]-row panels, and each panel's scores
/// accumulate column-tile-by-column-tile into a small scratch sub-tile.
/// The inner `t -> (query, panel-row)` order means one 128x128 reference
/// tile (64 KB) is reused by every query of the block while hot, instead
/// of being re-streamed from memory once per query — the reference
/// kernel's behavior at large `r`.
pub fn imc_mvm_blocked_into(
    queries: &[f32],
    panel: &[f32],
    segments: &[std::ops::Range<usize>],
    b: usize,
    c: usize,
    adc: AdcConfig,
    out: &mut [f32],
) {
    assert_eq!(queries.len(), b * c, "queries shape");
    assert_eq!(c % ARRAY_DIM, 0, "C must be a multiple of {ARRAY_DIM}");
    assert_eq!(panel.len() % c.max(1), 0, "panel shape");
    let panel_rows = panel.len() / c.max(1);
    let r: usize = segments.iter().map(|s| s.len()).sum();
    for s in segments {
        assert!(s.start <= s.end && s.end <= panel_rows, "segment {s:?} out of panel");
    }
    assert_eq!(out.len(), b * r, "out shape");

    // DAC once per query element, exactly as the reference kernel does.
    let dacq: Vec<f32> = queries.iter().map(|&x| dac_quantize(x)).collect();

    let tiles = c / ARRAY_DIM;
    let mut acc = [0f32; QUERY_BLOCK * ARRAY_DIM];
    let mut q0 = 0;
    while q0 < b {
        let qn = QUERY_BLOCK.min(b - q0);
        // Output-column cursor across the concatenated segments.
        let mut oc = 0usize;
        for seg in segments {
            let mut p0 = seg.start;
            while p0 < seg.end {
                let pn = ARRAY_DIM.min(seg.end - p0);
                let sub = &mut acc[..qn * pn];
                sub.fill(0.0);
                for t in 0..tiles {
                    let lo = t * ARRAY_DIM;
                    for qi in 0..qn {
                        let qoff = (q0 + qi) * c + lo;
                        let qrow = &dacq[qoff..qoff + ARRAY_DIM];
                        for pi in 0..pn {
                            let goff = (p0 + pi) * c + lo;
                            let grow = &panel[goff..goff + ARRAY_DIM];
                            let mut part = 0f32;
                            for k in 0..ARRAY_DIM {
                                part += qrow[k] * grow[k];
                            }
                            sub[qi * pn + pi] += adc.quantize(part);
                        }
                    }
                }
                for qi in 0..qn {
                    let ooff = (q0 + qi) * r + oc;
                    out[ooff..ooff + pn].copy_from_slice(&sub[qi * pn..(qi + 1) * pn]);
                }
                oc += pn;
                p0 += pn;
            }
        }
        q0 += qn;
    }
}

/// Exact (no DAC/ADC) dot-product scores — the "digital" upper bound used
/// by the HyperSpec/HyperOMS-style software baselines.
pub fn exact_mvm(queries: &[f32], refs: &[f32], b: usize, r: usize, c: usize) -> Vec<f32> {
    assert_eq!(queries.len(), b * c);
    assert_eq!(refs.len(), r * c);
    let mut out = vec![0f32; b * r];
    for bi in 0..b {
        let qrow = &queries[bi * c..(bi + 1) * c];
        for ri in 0..r {
            let grow = &refs[ri * c..(ri + 1) * c];
            out[bi * r + ri] = qrow.iter().zip(grow).map(|(a, g)| a * g).sum();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
        (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
    }

    #[test]
    fn ideal_adc_equals_exact() {
        let mut rng = Rng::new(1);
        let (b, r, c) = (4, 8, 256);
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let got = imc_mvm_ref(&q, &g, b, r, c, AdcConfig::ideal());
        let want = exact_mvm(&q, &g, b, r, c);
        assert_eq!(got, want);
    }

    #[test]
    fn quantized_adc_changes_scores_but_preserves_order_of_extremes() {
        let mut rng = Rng::new(2);
        let (b, r, c) = (1, 3, 128);
        // Row 0 identical to the query (max similarity), row 1 its negation,
        // row 2 random.
        let q = rand_packed(&mut rng, c, 1);
        let mut g = q.clone();
        g.extend(q.iter().map(|x| -x));
        g.extend(rand_packed(&mut rng, c, 1));
        let adc = AdcConfig::new(6, 64.0);
        let s = imc_mvm_ref(&q, &g, b, r, c, adc);
        assert!(s[0] > s[2] && s[2] > s[1], "{s:?}");
    }

    #[test]
    fn tilewise_adc_matters() {
        // A sum that cancels *across* tiles but saturates within each tile
        // must differ from the exact dot product: +big in tile 0, -big in
        // tile 1, with a tiny clip.
        let (b, r, c) = (1, 1, 256);
        let mut q = vec![1f32; c];
        let g = vec![3f32; c];
        for x in q.iter_mut().skip(128) {
            *x = -1.0;
        }
        let exact = exact_mvm(&q, &g, b, r, c)[0];
        assert_eq!(exact, 0.0);
        let adc = AdcConfig::new(2, 64.0); // qmax=1, lsb=32: +384 clips to 32, -384 to -64
        let s = imc_mvm_ref(&q, &g, b, r, c, adc)[0];
        assert_eq!(s, 32.0 - 64.0);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_untiled_c() {
        imc_mvm_ref(&[0.0; 100], &[0.0; 100], 1, 1, 100, AdcConfig::ideal());
    }

    /// Gather the segment rows into a dense matrix — the oracle the
    /// blocked kernel must match bit-for-bit.
    fn gather_rows(panel: &[f32], segments: &[std::ops::Range<usize>], c: usize) -> Vec<f32> {
        let mut g = Vec::new();
        for s in segments {
            g.extend_from_slice(&panel[s.start * c..s.end * c]);
        }
        g
    }

    #[test]
    fn blocked_dense_matches_ref_bitwise() {
        let mut rng = Rng::new(31);
        // b > QUERY_BLOCK so multiple query blocks run; r > 128 so
        // multiple row panels run; non-pow2 raggedness everywhere.
        let (b, r, c) = (37, 300, 384);
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        for adc in [AdcConfig::ideal(), AdcConfig::new(6, 512.0), AdcConfig::new(3, 128.0)] {
            let want = imc_mvm_ref(&q, &g, b, r, c, adc);
            let mut got = vec![f32::NAN; b * r];
            imc_mvm_blocked_into(&q, &g, &[0..r], b, c, adc, &mut got);
            assert_eq!(got, want, "adc {adc:?}");
        }
    }

    #[test]
    fn blocked_segmented_matches_gathered_ref_bitwise() {
        let mut rng = Rng::new(32);
        let (panel_rows, c) = (500, 256);
        let panel = rand_packed(&mut rng, panel_rows * c, 3);
        let q = rand_packed(&mut rng, 5 * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        // Ragged segments: empty, single-row, straddling the 128-row tile
        // boundary, and out-of-order-sized ranges.
        let segs: Vec<std::ops::Range<usize>> =
            vec![3..3, 10..11, 100..260, 0..1, 300..500, 42..42];
        let gathered = gather_rows(&panel, &segs, c);
        let r: usize = segs.iter().map(|s| s.len()).sum();
        let want = imc_mvm_ref(&q, &gathered, 5, r, c, adc);
        let mut got = vec![f32::NAN; 5 * r];
        imc_mvm_blocked_into(&q, &panel, &segs, 5, c, adc, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_empty_inputs() {
        let adc = AdcConfig::ideal();
        let g = vec![1.0f32; 4 * 128];
        // No queries.
        imc_mvm_blocked_into(&[], &g, &[0..4], 0, 128, adc, &mut []);
        // No candidate rows (only empty segments).
        let q = vec![1.0f32; 2 * 128];
        imc_mvm_blocked_into(&q, &g, &[2..2], 2, 128, adc, &mut []);
        imc_mvm_blocked_into(&q, &g, &[], 2, 128, adc, &mut []);
    }

    #[test]
    #[should_panic(expected = "out of panel")]
    fn blocked_rejects_out_of_range_segment() {
        let g = vec![0f32; 4 * 128];
        imc_mvm_blocked_into(&[0.0; 128], &g, &[2..5], 1, 128, AdcConfig::ideal(), &mut [0.0; 3]);
    }
}
