//! 3-bit source-line DAC (Table 1) — input quantization.
//!
//! Mirrors `python/compile/kernels/imc_mvm.py::_imc_mvm_kernel`'s DAC step:
//! `clip(round_away(x), -2^(b-1), 2^(b-1)-1)`.

use super::DAC_BITS;
use crate::util::round_away;

/// Quantize one source-line drive value.
#[inline]
pub fn dac_quantize(x: f32) -> f32 {
    dac_quantize_bits(x, DAC_BITS)
}

/// Quantize with an explicit bit width (tests sweep this).
#[inline]
pub fn dac_quantize_bits(x: f32, bits: u32) -> f32 {
    let lo = -((1i64 << (bits - 1)) as f32);
    let hi = ((1i64 << (bits - 1)) - 1) as f32;
    round_away(x).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_on_packed_alphabet() {
        // Packed values for n <= 3 fit the 3-bit range exactly.
        for v in -3..=3 {
            assert_eq!(dac_quantize(v as f32), v as f32);
        }
    }

    #[test]
    fn clips_out_of_range() {
        assert_eq!(dac_quantize(100.0), 3.0);
        assert_eq!(dac_quantize(-100.0), -4.0);
        assert_eq!(dac_quantize(4.0), 3.0);
    }

    #[test]
    fn rounds_half_away_from_zero() {
        assert_eq!(dac_quantize(0.5), 1.0);
        assert_eq!(dac_quantize(-0.5), -1.0);
        assert_eq!(dac_quantize(1.4), 1.0);
    }
}
