//! Analog IMC array simulator (paper §III-C, Table 1).
//!
//! One bank is a 128x128 array of 2T2R PCM cell pairs with per-column
//! 3-bit DACs on the source lines, 16 shared 6-bit flash ADCs on the bit
//! lines, and SL/WL driver peripherals. The numeric transfer function here
//! is the *same math* as the L1 Pallas kernel (bit-exact for power-of-two
//! ADC full-scales); this module additionally owns the cycle-accurate
//! timing model used by the energy/latency accounting.
//!
//! Since PR 6 every tile dot product follows the **lane-ordered
//! accumulation contract**: eight `k % 8` partial-sum lanes reduced by a
//! fixed binary tree (see `transfer` module docs). [`imc_mvm_ref`] is the
//! scalar oracle for that order; [`lane_tile_dot`] is the vectorizable
//! coding every fast kernel uses. Integer packed data is exact under any
//! association order, so the contract only redefines scores on noisy
//! (non-integer) conductances — but there it is binding and pinned to
//! exact f32 bits by regression tests.

pub mod adc;
pub mod bank;
pub mod dac;
pub mod timing;
pub mod transfer;

pub use adc::AdcConfig;
pub use bank::ArrayBank;
pub use dac::dac_quantize;
pub use timing::TimingModel;
pub use transfer::{
    imc_mvm_blocked_dacq_into, imc_mvm_blocked_into, imc_mvm_ref, lane_tile_dot,
    lane_tree_reduce, MVM_LANES,
};

/// Array geometry (Table 1): 128x128 2T2R cells per bank.
pub const ARRAY_DIM: usize = 128;
/// Source-line DAC resolution (Table 1).
pub const DAC_BITS: u32 = 3;
/// Flash-ADC maximum resolution (Table 1); reconfigurable 1..=6 (§III-D).
pub const ADC_MAX_BITS: u32 = 6;
/// ADC units per bank; each shared across eight rows (Table 1).
pub const ADC_UNITS: usize = 16;
