//! Instruction sequences with validation and a tiny assembler-style
//! textual form (useful for the `specpcm isa` CLI and examples).

use super::inst::Instruction;

#[derive(Clone, Debug, Default)]
pub struct Program {
    pub instructions: Vec<Instruction>,
}

impl Program {
    pub fn new() -> Self {
        Program::default()
    }

    pub fn push(&mut self, inst: Instruction) -> &mut Self {
        self.instructions.push(inst);
        self
    }

    /// Validate every instruction's field ranges.
    pub fn validate(&self) -> Result<(), String> {
        for (pc, inst) in self.instructions.iter().enumerate() {
            inst.validate().map_err(|e| format!("pc {pc}: {e}"))?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.instructions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instructions.is_empty()
    }

    /// Render as assembler text (one instruction per line).
    pub fn disassemble(&self) -> String {
        self.instructions
            .iter()
            .map(|i| match *i {
                Instruction::StoreHv {
                    buf,
                    arr_idx,
                    col_addr,
                    row_addr,
                    mlc_bits,
                    write_cycles,
                } => format!(
                    "STORE_HV buf={buf} arr={arr_idx} col={col_addr} row={row_addr} mlc={mlc_bits} wv={write_cycles}"
                ),
                Instruction::ReadHv {
                    buf,
                    data_size,
                    arr_idx,
                    col_addr,
                    row_addr,
                    mlc_bits,
                } => format!(
                    "READ_HV buf={buf} size={data_size} arr={arr_idx} col={col_addr} row={row_addr} mlc={mlc_bits}"
                ),
                Instruction::MvmCompute {
                    buf,
                    arr_idx,
                    row_addr,
                    num_activated_row,
                    adc_bits,
                    mlc_bits,
                } => format!(
                    "MVM_COMPUTE buf={buf} arr={arr_idx} row={row_addr} nrows={num_activated_row} adc={adc_bits} mlc={mlc_bits}"
                ),
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Parse the `disassemble` format back into a program.
    pub fn assemble(text: &str) -> Result<Program, String> {
        let mut prog = Program::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let mnemonic = parts.next().ok_or(format!("line {lineno}: empty"))?;
            let mut fields = std::collections::HashMap::new();
            for p in parts {
                let (k, v) = p
                    .split_once('=')
                    .ok_or(format!("line {lineno}: bad field '{p}'"))?;
                let v: u64 = v
                    .parse()
                    .map_err(|_| format!("line {lineno}: bad value '{v}'"))?;
                fields.insert(k.to_string(), v);
            }
            let get = |k: &str| -> Result<u64, String> {
                fields
                    .get(k)
                    .copied()
                    .ok_or(format!("line {lineno}: missing field '{k}'"))
            };
            let inst = match mnemonic {
                "STORE_HV" => Instruction::StoreHv {
                    buf: get("buf")? as u8,
                    arr_idx: get("arr")? as u16,
                    col_addr: get("col")? as u8,
                    row_addr: get("row")? as u8,
                    mlc_bits: get("mlc")? as u8,
                    write_cycles: get("wv")? as u8,
                },
                "READ_HV" => Instruction::ReadHv {
                    buf: get("buf")? as u8,
                    data_size: get("size")? as u16,
                    arr_idx: get("arr")? as u16,
                    col_addr: get("col")? as u8,
                    row_addr: get("row")? as u8,
                    mlc_bits: get("mlc")? as u8,
                },
                "MVM_COMPUTE" => Instruction::MvmCompute {
                    buf: get("buf")? as u8,
                    arr_idx: get("arr")? as u16,
                    row_addr: get("row")? as u8,
                    num_activated_row: get("nrows")? as u8,
                    adc_bits: get("adc")? as u8,
                    mlc_bits: get("mlc")? as u8,
                },
                other => return Err(format!("line {lineno}: unknown mnemonic '{other}'")),
            };
            prog.push(inst);
        }
        Ok(prog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Program {
        let mut p = Program::new();
        p.push(Instruction::StoreHv {
            buf: 0,
            arr_idx: 3,
            col_addr: 0,
            row_addr: 17,
            mlc_bits: 3,
            write_cycles: 3,
        });
        p.push(Instruction::MvmCompute {
            buf: 1,
            arr_idx: 3,
            row_addr: 0,
            num_activated_row: 128,
            adc_bits: 6,
            mlc_bits: 3,
        });
        p
    }

    #[test]
    fn validate_ok() {
        assert!(sample().validate().is_ok());
    }

    #[test]
    fn asm_roundtrip() {
        let p = sample();
        let text = p.disassemble();
        let q = Program::assemble(&text).unwrap();
        assert_eq!(p.instructions, q.instructions);
    }

    #[test]
    fn assemble_skips_comments_and_blanks() {
        let text = "# a comment\n\nSTORE_HV buf=0 arr=1 col=0 row=2 mlc=3 wv=1\n";
        let p = Program::assemble(text).unwrap();
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn assemble_rejects_garbage() {
        assert!(Program::assemble("FROB x=1").is_err());
        assert!(Program::assemble("STORE_HV buf=0").is_err());
        assert!(Program::assemble("STORE_HV buf=zz arr=1 col=0 row=2 mlc=3 wv=1").is_err());
    }
}
