//! ISA executor: binds instruction programs to simulated array banks.
//!
//! Data movement uses numbered staging buffers (`set_buffer` /
//! `take_result`), mirroring the paper's near-memory data path between the
//! ASIC encoder/packer and the PCM arrays. Every executed instruction
//! updates an [`OpCounts`] so ISA-level runs feed the same energy model as
//! the high-level pipelines; `MVM_COMPUTE` executes through the same
//! pluggable [`BackendDispatcher`] the pipelines use (reference by
//! default — swap in a parallel dispatcher with [`Executor::with_backend`]).

use std::collections::HashMap;

use crate::array::{AdcConfig, ArrayBank, ARRAY_DIM};
use crate::backend::{BackendDispatcher, MvmJob};
use crate::coordinator::SearchEngine;
use crate::device::{Material, MlcConfig, NoiseModel, Programmer};
use crate::energy::OpCounts;
use crate::util::Rng;

use super::inst::Instruction;
use super::program::Program;

/// Output of one executed program.
#[derive(Clone, Debug, Default)]
pub struct ExecResult {
    /// MVM score vectors in instruction order (one per MVM_COMPUTE).
    pub mvm_scores: Vec<Vec<f32>>,
    /// Row reads in instruction order (one per READ_HV).
    pub row_reads: Vec<Vec<f32>>,
    pub ops: OpCounts,
}

pub struct Executor {
    pub banks: Vec<ArrayBank>,
    pub material: Material,
    backend: BackendDispatcher,
    buffers: HashMap<u8, Vec<f32>>,
    rng: Rng,
}

impl Executor {
    pub fn new(num_banks: usize, material: Material, seed: u64) -> Self {
        Executor {
            banks: (0..num_banks).map(|_| ArrayBank::new(material)).collect(),
            material,
            backend: BackendDispatcher::reference(),
            buffers: HashMap::new(),
            // lint: rng-ok (the ISA executor owns an independent caller-seeded device-noise stream; it is not part of the serving shard chain)
            rng: Rng::new(seed),
        }
    }

    /// Route `MVM_COMPUTE` through a different backend dispatcher (scores
    /// are bit-identical across backends by contract).
    pub fn with_backend(mut self, backend: BackendDispatcher) -> Self {
        self.backend = backend;
        self
    }

    /// Build an executor whose banks mirror a [`SearchEngine`]'s programmed
    /// library: each reference row's 128-wide segments are loaded onto the
    /// physical banks of its allocator slot, so hand-written ISA programs
    /// (`MVM_COMPUTE` / `READ_HV`) execute against the very conductances
    /// the engine serves query batches from. The engine already paid the
    /// programming energy — loading mirrors state without re-charging it.
    pub fn from_engine(engine: &SearchEngine) -> Self {
        let mut ex = Executor::new(
            engine.cfg.num_banks,
            engine.cfg.material,
            engine.cfg.seed,
        );
        for (ri, &slot) in engine.slots().iter().enumerate() {
            let row = engine.noisy_row(ri);
            for (si, bank) in engine.banks_of(slot).into_iter().enumerate() {
                let seg = &row[si * ARRAY_DIM..(si + 1) * ARRAY_DIM];
                ex.banks[bank].load_programmed_row(slot.row, seg);
            }
        }
        ex
    }

    /// Stage a 128-wide data segment into a numbered buffer.
    pub fn set_buffer(&mut self, buf: u8, data: Vec<f32>) {
        assert_eq!(data.len(), ARRAY_DIM, "buffers hold one array segment");
        self.buffers.insert(buf, data);
    }

    pub fn run(&mut self, program: &Program) -> Result<ExecResult, String> {
        program.validate()?;
        let mut result = ExecResult::default();

        for (pc, inst) in program.instructions.iter().enumerate() {
            match *inst {
                Instruction::StoreHv {
                    buf,
                    arr_idx,
                    row_addr,
                    mlc_bits,
                    write_cycles,
                    ..
                } => {
                    let segment = self
                        .buffers
                        .get(&buf)
                        .ok_or(format!("pc {pc}: buffer {buf} not staged"))?
                        .clone();
                    let bank = self
                        .banks
                        .get_mut(arr_idx as usize)
                        .ok_or(format!("pc {pc}: arr_idx {arr_idx} out of range"))?;
                    let prog = Programmer::new(
                        NoiseModel::new(self.material, MlcConfig::new(mlc_bits)),
                        write_cycles as u32,
                    );
                    let pulses = bank.program_row(row_addr as usize, &segment, &prog, &mut self.rng);
                    // Cells in a row are pulsed in parallel: the number of
                    // 20 ns rounds is the worst-case per-cell pulse depth,
                    // approximated by the average (total / row width).
                    // lint: charge-ok (ISA accounting is per-instruction by design; ProgramHv is its single programming charge)
                    result.ops.program_rounds += pulses.div_ceil(ARRAY_DIM as u64).max(1);
                    // lint: charge-ok (verify reads for the same ProgramHv instruction)
                    result.ops.verify_rounds += write_cycles as u64;
                }
                Instruction::ReadHv {
                    arr_idx, row_addr, ..
                } => {
                    let bank = self
                        .banks
                        .get_mut(arr_idx as usize)
                        .ok_or(format!("pc {pc}: arr_idx {arr_idx} out of range"))?;
                    let row = bank.read_row(row_addr as usize).to_vec();
                    // lint: charge-ok (one ReadHv instruction = one row read)
                    result.ops.row_reads += 1;
                    result.row_reads.push(row);
                }
                Instruction::MvmCompute {
                    buf,
                    arr_idx,
                    num_activated_row,
                    adc_bits,
                    mlc_bits,
                    ..
                } => {
                    let query = self
                        .buffers
                        .get(&buf)
                        .ok_or(format!("pc {pc}: buffer {buf} not staged"))?
                        .clone();
                    let bank = self
                        .banks
                        .get_mut(arr_idx as usize)
                        .ok_or(format!("pc {pc}: arr_idx {arr_idx} out of range"))?;
                    let adc =
                        AdcConfig::default_for_packing(adc_bits as u32, mlc_bits as usize);
                    bank.counters.mvm_ops += 1;
                    // One whole-array MVM = a 1 x 128 score tile over the
                    // bank's stored conductances, executed (and op-counted)
                    // by the same dispatcher the pipelines use.
                    let job =
                        MvmJob::new(&query, 1, bank.conductances(), ARRAY_DIM, ARRAY_DIM, adc);
                    let mut scores = self
                        .backend
                        .execute(&job, &mut result.ops)
                        .map_err(|e| format!("pc {pc}: MVM_COMPUTE failed: {e}"))?;
                    scores.truncate(num_activated_row as usize);
                    result.mvm_scores.push(scores);
                }
            }
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(buf: u8, arr: u16, row: u8, wv: u8) -> Instruction {
        Instruction::StoreHv {
            buf,
            arr_idx: arr,
            col_addr: 0,
            row_addr: row,
            mlc_bits: 3,
            write_cycles: wv,
        }
    }

    #[test]
    fn store_then_mvm_finds_stored_row() {
        let mut ex = Executor::new(2, Material::TiTe2Gst467, 1);
        let seg: Vec<f32> = (0..ARRAY_DIM)
            .map(|i| ((i % 7) as i64 - 3) as f32)
            .collect();
        ex.set_buffer(0, seg.clone());

        let mut p = Program::new();
        p.push(store(0, 1, 5, 6));
        p.push(Instruction::MvmCompute {
            buf: 0,
            arr_idx: 1,
            row_addr: 0,
            num_activated_row: 128,
            adc_bits: 6,
            mlc_bits: 3,
        });
        let r = ex.run(&p).unwrap();
        assert_eq!(r.mvm_scores.len(), 1);
        let scores = &r.mvm_scores[0];
        // Row 5 holds the (noisy) segment; its self-similarity dominates.
        let (best, _) = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        assert_eq!(best, 5);
        assert_eq!(r.ops.mvm_ops, 1);
        assert!(r.ops.program_rounds >= 1);
    }

    #[test]
    fn read_hv_returns_programmed_row() {
        let mut ex = Executor::new(1, Material::TiTe2Gst467, 2);
        let seg = vec![3.0f32; ARRAY_DIM];
        ex.set_buffer(0, seg);
        let mut p = Program::new();
        p.push(store(0, 0, 7, 8));
        p.push(Instruction::ReadHv {
            buf: 1,
            data_size: 128,
            arr_idx: 0,
            col_addr: 0,
            row_addr: 7,
            mlc_bits: 3,
        });
        let r = ex.run(&p).unwrap();
        assert_eq!(r.row_reads.len(), 1);
        // With 8 write-verify cycles the stored values sit near 3.0.
        let mean: f32 = r.row_reads[0].iter().sum::<f32>() / ARRAY_DIM as f32;
        assert!((mean - 3.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn backend_swap_is_bit_identical() {
        // The same program on the same seed through the reference and the
        // parallel dispatcher must produce identical scores.
        let run_with = |backend: BackendDispatcher| {
            let mut ex = Executor::new(2, Material::TiTe2Gst467, 1).with_backend(backend);
            let seg: Vec<f32> = (0..ARRAY_DIM)
                .map(|i| ((i % 7) as i64 - 3) as f32)
                .collect();
            ex.set_buffer(0, seg);
            let mut p = Program::new();
            p.push(store(0, 1, 5, 6));
            p.push(Instruction::MvmCompute {
                buf: 0,
                arr_idx: 1,
                row_addr: 0,
                num_activated_row: 128,
                adc_bits: 6,
                mlc_bits: 3,
            });
            ex.run(&p).unwrap()
        };
        let a = run_with(BackendDispatcher::reference());
        let b = run_with(BackendDispatcher::parallel(4));
        assert_eq!(a.mvm_scores, b.mvm_scores);
        assert_eq!(a.ops.mvm_ops, b.ops.mvm_ops);
    }

    #[test]
    fn from_engine_mirrors_programmed_library() {
        use crate::config::SpecPcmConfig;
        use crate::ms::SearchDataset;

        let cfg = SpecPcmConfig {
            hd_dim: 512, // packed width 256 -> 2 segments per HV
            num_banks: 8,
            bucket_width: 5.0,
            ..SpecPcmConfig::paper_search()
        };
        let ds = SearchDataset::generate("t", 51, 10, 4, 0.8, 0.2, 0, 0);
        let engine =
            crate::coordinator::SearchEngine::program(cfg, &ds, &BackendDispatcher::reference())
                .unwrap();
        let mut ex = Executor::from_engine(&engine);

        // Every reference row occupies one valid ISA-bank row per segment.
        let valid: usize = ex.banks.iter().map(|b| b.valid_rows()).sum();
        assert_eq!(valid, engine.n_refs() * 2);

        // READ_HV on row 0's first segment returns exactly the engine's
        // stored noisy conductances — the same bits search_batch scores
        // against.
        let slot = engine.slots()[0];
        let bank = engine.banks_of(slot)[0];
        let mut p = Program::new();
        p.push(Instruction::ReadHv {
            buf: 0,
            data_size: 128,
            arr_idx: bank as u16,
            col_addr: 0,
            row_addr: slot.row as u8,
            mlc_bits: 3,
        });
        let r = ex.run(&p).unwrap();
        assert_eq!(&r.row_reads[0][..], &engine.noisy_row(0)[..ARRAY_DIM]);
        assert_eq!(r.ops.row_reads, 1);
    }

    #[test]
    fn missing_buffer_errors() {
        let mut ex = Executor::new(1, Material::TiTe2Gst467, 3);
        let mut p = Program::new();
        p.push(store(9, 0, 0, 0));
        assert!(ex.run(&p).unwrap_err().contains("buffer 9"));
    }

    #[test]
    fn bad_arr_idx_errors() {
        let mut ex = Executor::new(1, Material::TiTe2Gst467, 4);
        ex.set_buffer(0, vec![0.0; ARRAY_DIM]);
        let mut p = Program::new();
        p.push(store(0, 5, 0, 0));
        assert!(ex.run(&p).unwrap_err().contains("arr_idx"));
    }
}
