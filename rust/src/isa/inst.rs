//! Instruction definitions (Table S2).



/// One SpecPCM instruction. Data payloads (the HV segments) travel through
/// a data buffer identified by `buf`, mirroring the paper's
/// "PCM[arr_idx, col_addr, row_addr] <- data" semantics without embedding
/// bulk data in the instruction stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instruction {
    /// STORE_HV (data, arr_idx, col_addr, row_addr, MLC_bits, write_cycles)
    StoreHv {
        /// Data-buffer slot holding the packed segment to program.
        buf: u8,
        arr_idx: u16,
        col_addr: u8,
        row_addr: u8,
        /// Bits per cell used by dimension packing (1..=4).
        mlc_bits: u8,
        /// Write-verify cycles (0..=15).
        write_cycles: u8,
    },
    /// READ_HV (data_size, arr_idx, col_addr, row_addr, MLC_bits)
    ReadHv {
        buf: u8,
        data_size: u16,
        arr_idx: u16,
        col_addr: u8,
        row_addr: u8,
        mlc_bits: u8,
    },
    /// MVM_COMPUTE (row_addr, num_activated_row, ADC_bits, MLC_bits)
    MvmCompute {
        /// Data-buffer slot holding the driven query segment.
        buf: u8,
        arr_idx: u16,
        row_addr: u8,
        num_activated_row: u8,
        adc_bits: u8,
        mlc_bits: u8,
    },
}

impl Instruction {
    pub fn opcode(&self) -> u8 {
        match self {
            Instruction::StoreHv { .. } => 0x1,
            Instruction::ReadHv { .. } => 0x2,
            Instruction::MvmCompute { .. } => 0x3,
        }
    }

    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instruction::StoreHv { .. } => "STORE_HV",
            Instruction::ReadHv { .. } => "READ_HV",
            Instruction::MvmCompute { .. } => "MVM_COMPUTE",
        }
    }

    /// Validate field ranges (the encoder also enforces these widths).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            Instruction::StoreHv {
                mlc_bits,
                write_cycles,
                ..
            } => {
                if !(1..=4).contains(&mlc_bits) {
                    return Err(format!("STORE_HV: mlc_bits {mlc_bits} not in 1..=4"));
                }
                if write_cycles > 15 {
                    return Err(format!("STORE_HV: write_cycles {write_cycles} > 15"));
                }
            }
            Instruction::ReadHv { mlc_bits, .. } => {
                if !(1..=4).contains(&mlc_bits) {
                    return Err(format!("READ_HV: mlc_bits {mlc_bits} not in 1..=4"));
                }
            }
            Instruction::MvmCompute {
                adc_bits,
                mlc_bits,
                num_activated_row,
                ..
            } => {
                if !(1..=6).contains(&adc_bits) {
                    return Err(format!("MVM_COMPUTE: adc_bits {adc_bits} not in 1..=6"));
                }
                if !(1..=4).contains(&mlc_bits) {
                    return Err(format!("MVM_COMPUTE: mlc_bits {mlc_bits} not in 1..=4"));
                }
                if num_activated_row == 0 {
                    return Err("MVM_COMPUTE: num_activated_row must be > 0".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcodes_distinct() {
        let s = Instruction::StoreHv {
            buf: 0,
            arr_idx: 0,
            col_addr: 0,
            row_addr: 0,
            mlc_bits: 3,
            write_cycles: 0,
        };
        let r = Instruction::ReadHv {
            buf: 0,
            data_size: 128,
            arr_idx: 0,
            col_addr: 0,
            row_addr: 0,
            mlc_bits: 3,
        };
        let m = Instruction::MvmCompute {
            buf: 0,
            arr_idx: 0,
            row_addr: 0,
            num_activated_row: 128,
            adc_bits: 6,
            mlc_bits: 3,
        };
        assert_ne!(s.opcode(), r.opcode());
        assert_ne!(r.opcode(), m.opcode());
        assert_eq!(s.mnemonic(), "STORE_HV");
    }

    #[test]
    fn validation_catches_bad_fields() {
        let bad = Instruction::StoreHv {
            buf: 0,
            arr_idx: 0,
            col_addr: 0,
            row_addr: 0,
            mlc_bits: 5,
            write_cycles: 0,
        };
        assert!(bad.validate().is_err());
        let bad_adc = Instruction::MvmCompute {
            buf: 0,
            arr_idx: 0,
            row_addr: 0,
            num_activated_row: 128,
            adc_bits: 7,
            mlc_bits: 3,
        };
        assert!(bad_adc.validate().is_err());
    }
}
