//! Binary instruction encoding: each instruction packs into one 64-bit
//! word (opcode in the top nibble). Round-trip `decode(encode(i)) == i` is
//! property-tested from `rust/tests/proptest_isa.rs`.

use super::inst::Instruction;

const OP_SHIFT: u32 = 60;

pub fn encode(inst: &Instruction) -> u64 {
    let op = (inst.opcode() as u64) << OP_SHIFT;
    match *inst {
        Instruction::StoreHv {
            buf,
            arr_idx,
            col_addr,
            row_addr,
            mlc_bits,
            write_cycles,
        } => {
            op | (buf as u64) << 52
                | (arr_idx as u64) << 36
                | (col_addr as u64) << 28
                | (row_addr as u64) << 20
                | (mlc_bits as u64) << 16
                | (write_cycles as u64) << 12
        }
        Instruction::ReadHv {
            buf,
            data_size,
            arr_idx,
            col_addr,
            row_addr,
            mlc_bits,
        } => {
            op | (buf as u64) << 52
                | (arr_idx as u64) << 36
                | (col_addr as u64) << 28
                | (row_addr as u64) << 20
                | (mlc_bits as u64) << 16
                | (data_size as u64)
        }
        Instruction::MvmCompute {
            buf,
            arr_idx,
            row_addr,
            num_activated_row,
            adc_bits,
            mlc_bits,
        } => {
            op | (buf as u64) << 52
                | (arr_idx as u64) << 36
                | (row_addr as u64) << 20
                | (mlc_bits as u64) << 16
                | (num_activated_row as u64) << 8
                | (adc_bits as u64)
        }
    }
}

pub fn decode(word: u64) -> Result<Instruction, String> {
    let op = (word >> OP_SHIFT) & 0xF;
    let buf = ((word >> 52) & 0xFF) as u8;
    let arr_idx = ((word >> 36) & 0xFFFF) as u16;
    let col_addr = ((word >> 28) & 0xFF) as u8;
    let row_addr = ((word >> 20) & 0xFF) as u8;
    let mlc_bits = ((word >> 16) & 0xF) as u8;
    match op {
        0x1 => Ok(Instruction::StoreHv {
            buf,
            arr_idx,
            col_addr,
            row_addr,
            mlc_bits,
            write_cycles: ((word >> 12) & 0xF) as u8,
        }),
        0x2 => Ok(Instruction::ReadHv {
            buf,
            data_size: (word & 0xFFFF) as u16,
            arr_idx,
            col_addr,
            row_addr,
            mlc_bits,
        }),
        0x3 => Ok(Instruction::MvmCompute {
            buf,
            arr_idx,
            row_addr,
            num_activated_row: ((word >> 8) & 0xFF) as u8,
            adc_bits: (word & 0xFF) as u8,
            mlc_bits,
        }),
        _ => Err(format!("unknown opcode {op:#x}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_store() {
        let i = Instruction::StoreHv {
            buf: 7,
            arr_idx: 1234,
            col_addr: 0,
            row_addr: 99,
            mlc_bits: 3,
            write_cycles: 5,
        };
        assert_eq!(decode(encode(&i)).unwrap(), i);
    }

    #[test]
    fn roundtrip_read() {
        let i = Instruction::ReadHv {
            buf: 1,
            data_size: 65535,
            arr_idx: 0xFFFF,
            col_addr: 255,
            row_addr: 255,
            mlc_bits: 4,
        };
        assert_eq!(decode(encode(&i)).unwrap(), i);
    }

    #[test]
    fn roundtrip_mvm() {
        let i = Instruction::MvmCompute {
            buf: 255,
            arr_idx: 42,
            row_addr: 0,
            num_activated_row: 128,
            adc_bits: 6,
            mlc_bits: 2,
        };
        assert_eq!(decode(encode(&i)).unwrap(), i);
    }

    #[test]
    fn rejects_unknown_opcode() {
        assert!(decode(0xF << OP_SHIFT).is_err());
        assert!(decode(0).is_err());
    }
}
