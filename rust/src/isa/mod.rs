//! Instruction Set Architecture (paper §III-F, Table S2).
//!
//! Three memory-operation instructions control the IMC system from
//! software: `STORE_HV` (program, with write-verify and MLC-bits fields),
//! `READ_HV` (normal row read) and `MVM_COMPUTE` (in-memory dot product
//! with row-activation count and ADC precision fields). The executor binds
//! a program to a set of array banks and accounts every op in the energy
//! model's `OpCounts`.

pub mod encode;
pub mod exec;
pub mod inst;
pub mod program;

pub use encode::{decode, encode};
pub use exec::{ExecResult, Executor};
pub use inst::Instruction;
pub use program::Program;
