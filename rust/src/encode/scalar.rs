//! Scalar reference encode backend — the element-serial oracle
//! (`hd::encode` + `hd::pack_into`) every faster encode path is checked
//! against.

use crate::hd;
use crate::util::error::Result;

use super::{EncodeBackend, EncodeJob};

/// Executes encode+pack with the single-threaded scalar kernels. One
/// intermediate `Vec<i8>` HV per spectrum, packed straight into the
/// caller's output row (no per-row f32 allocation).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarEncodeBackend;

impl EncodeBackend for ScalarEncodeBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn encode_pack(&self, job: &EncodeJob, out: &mut [f32]) -> Result<()> {
        assert_eq!(out.len(), job.out_len(), "output buffer shape");
        for (lv, row) in job.levels.iter().zip(out.chunks_mut(job.cp)) {
            let hv = hd::encode(lv, job.im);
            hd::pack_into(&hv, job.n, row);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hd::{BitItemMemory, ItemMemory};

    #[test]
    fn matches_encode_plus_pack() {
        let im = ItemMemory::generate(9, 32, 8, 512);
        let bits = BitItemMemory::from_item_memory(&im);
        let levels: Vec<Vec<u16>> = (0..4)
            .map(|i| (0..32).map(|j| ((i + j) % 8) as u16).collect())
            .collect();
        let job = EncodeJob::new(&levels, &im, &bits, 3);
        let mut out = vec![f32::NAN; job.out_len()];
        ScalarEncodeBackend.encode_pack(&job, &mut out).unwrap();
        for (i, lv) in levels.iter().enumerate() {
            let want = hd::pack(&hd::encode(lv, &im), 3);
            assert_eq!(&out[i * job.cp..(i + 1) * job.cp], &want[..], "row {i}");
        }
    }
}
