//! Word-packed encode backend: the `hd::bitpacked` fused kernel, one
//! spectrum at a time on the caller's thread. Scratch (counter planes +
//! sign-word buffer) is allocated once per batch, not per spectrum.

use crate::hd::bitpacked::{encode_pack_into, EncodeScratch};
use crate::util::error::Result;

use super::{EncodeBackend, EncodeJob};

/// Executes encode+pack with the u64 sign-bit kernels — bit-identical to
/// the scalar path, roughly an order of magnitude faster at paper-scale
/// dims (see `hotpath_microbench`).
#[derive(Clone, Copy, Debug, Default)]
pub struct BitpackedEncodeBackend;

impl EncodeBackend for BitpackedEncodeBackend {
    fn name(&self) -> &'static str {
        "bitpacked"
    }

    fn encode_pack(&self, job: &EncodeJob, out: &mut [f32]) -> Result<()> {
        assert_eq!(out.len(), job.out_len(), "output buffer shape");
        let mut scratch = EncodeScratch::default();
        let mut words = vec![0u64; job.bits.w];
        for (lv, row) in job.levels.iter().zip(out.chunks_mut(job.cp)) {
            encode_pack_into(lv, job.bits, job.n, &mut scratch, &mut words, row);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encode::ScalarEncodeBackend;
    use crate::hd::{BitItemMemory, ItemMemory};
    use crate::util::Rng;

    #[test]
    fn bit_identical_to_scalar_backend() {
        let mut rng = Rng::new(21);
        // 2000 is deliberately not a multiple of 64: tail-word masking.
        for d in [512usize, 2000, 2048] {
            let im = ItemMemory::generate(d as u64, 64, 16, d);
            let bits = BitItemMemory::from_item_memory(&im);
            let levels: Vec<Vec<u16>> = (0..5)
                .map(|_| {
                    let mut v = vec![0u16; 64];
                    for _ in 0..20 {
                        v[rng.below(64)] = 1 + rng.below(15) as u16;
                    }
                    v
                })
                .collect();
            let job = EncodeJob::new(&levels, &im, &bits, 3);
            let mut want = vec![0f32; job.out_len()];
            ScalarEncodeBackend.encode_pack(&job, &mut want).unwrap();
            let mut got = vec![f32::NAN; job.out_len()];
            BitpackedEncodeBackend.encode_pack(&job, &mut got).unwrap();
            assert_eq!(got, want, "d={d}");
        }
    }
}
