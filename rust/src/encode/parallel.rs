//! Thread-sharded parallel encode backend: the batch's spectra are split
//! into contiguous row chunks across `std::thread::scope` workers, each
//! running the word-packed kernel with its own scratch into a disjoint
//! `&mut` stripe of the output buffer. Per-spectrum arithmetic is the
//! bitpacked kernel unchanged, so results are bit-identical to both the
//! bitpacked and scalar backends for every thread count.

use crate::hd::bitpacked::{encode_pack_into, EncodeScratch};
use crate::util::error::Result;

use super::bitpacked::BitpackedEncodeBackend;
use super::{EncodeBackend, EncodeJob};

/// Minimum scalar multiply-accumulate-equivalent work (`nq * d`) before
/// spawning threads pays for itself; smaller batches run the bitpacked
/// kernel on the caller's thread. Single-spectrum query batches are
/// common in serving, so this guard matters for end-to-end wall time.
const MIN_PARALLEL_WORK: usize = 1 << 16;

/// Shards [`EncodeJob`]s across `threads` scoped workers.
#[derive(Clone, Copy, Debug)]
pub struct ParallelEncodeBackend {
    threads: usize,
}

impl ParallelEncodeBackend {
    /// `threads = 0` auto-detects (`std::thread::available_parallelism`).
    pub fn new(threads: usize) -> Self {
        ParallelEncodeBackend { threads }
    }

    /// The worker count jobs actually run with.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

impl Default for ParallelEncodeBackend {
    fn default() -> Self {
        ParallelEncodeBackend::new(0)
    }
}

impl EncodeBackend for ParallelEncodeBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn encode_pack(&self, job: &EncodeJob, out: &mut [f32]) -> Result<()> {
        assert_eq!(out.len(), job.out_len(), "output buffer shape");
        let nq = job.nq();
        let threads = self.effective_threads().min(nq.max(1));
        if threads <= 1 || nq * job.bits.d < MIN_PARALLEL_WORK {
            return BitpackedEncodeBackend.encode_pack(job, out);
        }

        // Contiguous spectrum-row chunks; the last chunk absorbs the
        // ragged remainder. `chunks_mut` hands each worker a disjoint
        // &mut stripe.
        let chunk_rows = nq.div_ceil(threads);
        std::thread::scope(|s| {
            for (ci, out_chunk) in out.chunks_mut(chunk_rows * job.cp).enumerate() {
                let q0 = ci * chunk_rows;
                let qn = out_chunk.len() / job.cp;
                let levels = &job.levels[q0..q0 + qn];
                let (bits, n, cp) = (job.bits, job.n, job.cp);
                s.spawn(move || {
                    let mut scratch = EncodeScratch::default();
                    let mut words = vec![0u64; bits.w];
                    for (lv, row) in levels.iter().zip(out_chunk.chunks_mut(cp)) {
                        encode_pack_into(lv, bits, n, &mut scratch, &mut words, row);
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encode::ScalarEncodeBackend;
    use crate::hd::{BitItemMemory, ItemMemory};
    use crate::util::Rng;

    fn sparse_batch(rng: &mut Rng, b: usize, f: usize, m: usize) -> Vec<Vec<u16>> {
        (0..b)
            .map(|_| {
                let mut v = vec![0u16; f];
                for _ in 0..30 {
                    v[rng.below(f)] = 1 + rng.below(m - 1) as u16;
                }
                v
            })
            .collect()
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(31);
        let im = ItemMemory::generate(31, 128, 32, 2048);
        let bits = BitItemMemory::from_item_memory(&im);
        // 37 rows x 2048 dims is above the threading cutoff.
        let levels = sparse_batch(&mut rng, 37, 128, 32);
        let job = EncodeJob::new(&levels, &im, &bits, 3);
        let mut want = vec![0f32; job.out_len()];
        ScalarEncodeBackend.encode_pack(&job, &mut want).unwrap();
        for threads in [1usize, 2, 3, 8, 64] {
            let mut got = vec![f32::NAN; job.out_len()];
            ParallelEncodeBackend::new(threads)
                .encode_pack(&job, &mut got)
                .unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn tiny_batch_takes_serial_path_and_empty_batch_is_fine() {
        let im = ItemMemory::generate(32, 16, 4, 256);
        let bits = BitItemMemory::from_item_memory(&im);
        let levels = vec![vec![1u16; 16]; 2];
        let job = EncodeJob::new(&levels, &im, &bits, 2);
        let mut got = vec![0f32; job.out_len()];
        ParallelEncodeBackend::new(8).encode_pack(&job, &mut got).unwrap();
        let mut want = vec![0f32; job.out_len()];
        ScalarEncodeBackend.encode_pack(&job, &mut want).unwrap();
        assert_eq!(got, want);

        let empty: Vec<Vec<u16>> = Vec::new();
        let job = EncodeJob::new(&empty, &im, &bits, 2);
        ParallelEncodeBackend::new(8).encode_pack(&job, &mut []).unwrap();
    }

    #[test]
    fn auto_threads_resolve() {
        assert!(ParallelEncodeBackend::new(0).effective_threads() >= 1);
        assert_eq!(ParallelEncodeBackend::new(5).effective_threads(), 5);
    }
}
