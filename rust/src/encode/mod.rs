//! Pluggable HD encode+pack execution backends — the second backend seam,
//! mirroring `backend/` (the MVM seam).
//!
//! PR 1 made the MVM score tile a swappable, bank-sharded layer; this
//! module does the same for the remaining host hot path, the HD frontend:
//!
//! * [`EncodeJob`] — one batch of quantized level vectors to encode+pack
//!   into row-major packed f32 rows, carrying both codebook views (the
//!   scalar [`ItemMemory`] and the word-packed [`BitItemMemory`]).
//! * [`EncodeBackend`] — the execution contract: `encode_pack(&EncodeJob,
//!   &mut out)`. Every implementation must be **bit-identical** to
//!   `hd::encode` + `hd::pack` (same `sign(0) = +1` tie rule, same zero
//!   padding) — backends change *where* the arithmetic runs, never *what*
//!   it computes (`rust/tests/encode_equivalence.rs`).
//! * [`ScalarEncodeBackend`] — the element-serial reference path.
//! * [`BitpackedEncodeBackend`] — the u64 word-packed kernels
//!   (`hd::bitpacked`): XOR binding, bit-sliced counter accumulation,
//!   fused encode+pack.
//! * [`ParallelEncodeBackend`] — shards the batch's spectra across
//!   `std::thread::scope` workers, each running the bitpacked kernel.
//!
//! Selection is routed through `backend::BackendDispatcher` (the same
//! object the MVM path runs through) and configured via the `[backend]`
//! section's `encode_kind` key or the `--encode-backend` CLI flag.

pub mod bitpacked;
pub mod parallel;
pub mod scalar;

pub use bitpacked::BitpackedEncodeBackend;
pub use parallel::ParallelEncodeBackend;
pub use scalar::ScalarEncodeBackend;

use crate::hd::{padded_packed_len, BitItemMemory, ItemMemory};
use crate::util::error::Result;

/// Which encode backend the dispatcher routes the frontend to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EncodeKind {
    /// Element-serial rust reference path (bit-exact oracle).
    Scalar,
    /// Word-packed u64 kernel, single-threaded.
    Bitpacked,
    /// Spectra sharded across threads, bitpacked kernel per shard
    /// (default).
    Parallel,
}

impl EncodeKind {
    pub fn name(self) -> &'static str {
        match self {
            EncodeKind::Scalar => "scalar",
            EncodeKind::Bitpacked => "bitpacked",
            EncodeKind::Parallel => "parallel",
        }
    }

    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "scalar" | "ref" | "reference" => Ok(EncodeKind::Scalar),
            "bitpacked" => Ok(EncodeKind::Bitpacked),
            "parallel" => Ok(EncodeKind::Parallel),
            other => Err(format!(
                "unknown encode backend '{other}' (want scalar|bitpacked|parallel)"
            )),
        }
    }
}

/// One encode+pack batch job: `levels.len()` quantized level vectors to
/// turn into row-major `levels.len() x cp` packed f32 rows.
#[derive(Clone, Copy, Debug)]
pub struct EncodeJob<'a> {
    /// Quantized level vectors, one per spectrum (each `im.features()`
    /// long; level 0 = empty bin).
    pub levels: &'a [Vec<u16>],
    /// Scalar codebooks (the reference path reads these).
    pub im: &'a ItemMemory,
    /// Word-packed codebooks, derived once per frontend (the bitpacked
    /// and parallel paths read these).
    pub bits: &'a BitItemMemory,
    /// Packing factor n (MLC bits per cell).
    pub n: usize,
    /// Padded packed row width (`hd::padded_packed_len(d, n)`).
    pub cp: usize,
}

impl<'a> EncodeJob<'a> {
    pub fn new(
        levels: &'a [Vec<u16>],
        im: &'a ItemMemory,
        bits: &'a BitItemMemory,
        n: usize,
    ) -> Self {
        assert_eq!(im.dim, bits.d, "codebook dims disagree");
        let cp = padded_packed_len(im.dim, n);
        EncodeJob { levels, im, bits, n, cp }
    }

    /// Spectra in the batch.
    pub fn nq(&self) -> usize {
        self.levels.len()
    }

    /// Expected output buffer length.
    pub fn out_len(&self) -> usize {
        self.nq() * self.cp
    }
}

/// The execution contract every encode backend implements. `out` is the
/// row-major `nq x cp` destination; implementations must fill every
/// element (including the zero padding region of each row).
pub trait EncodeBackend: Send + Sync {
    /// Short stable identifier (telemetry / CLI echo).
    fn name(&self) -> &'static str;

    /// Encode+pack one batch into `out` (`job.out_len()` long).
    fn encode_pack(&self, job: &EncodeJob, out: &mut [f32]) -> Result<()>;
}

/// Build the backend a config's `encode_kind` asks for (`threads` only
/// matters for the parallel kind; 0 = auto-detect).
pub fn backend_of_kind(kind: EncodeKind, threads: usize) -> Box<dyn EncodeBackend> {
    match kind {
        EncodeKind::Scalar => Box::new(ScalarEncodeBackend),
        EncodeKind::Bitpacked => Box::new(BitpackedEncodeBackend),
        EncodeKind::Parallel => Box::new(ParallelEncodeBackend::new(threads)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [EncodeKind::Scalar, EncodeKind::Bitpacked, EncodeKind::Parallel] {
            assert_eq!(EncodeKind::from_name(k.name()).unwrap(), k);
        }
        assert_eq!(EncodeKind::from_name("ref").unwrap(), EncodeKind::Scalar);
        assert!(EncodeKind::from_name("gpu").is_err());
    }

    #[test]
    fn backend_factory_honours_kind() {
        assert_eq!(backend_of_kind(EncodeKind::Scalar, 0).name(), "scalar");
        assert_eq!(backend_of_kind(EncodeKind::Bitpacked, 0).name(), "bitpacked");
        assert_eq!(backend_of_kind(EncodeKind::Parallel, 4).name(), "parallel");
    }

    #[test]
    fn job_shapes() {
        let im = ItemMemory::generate(1, 8, 4, 256);
        let bits = BitItemMemory::from_item_memory(&im);
        let levels = vec![vec![0u16; 8]; 3];
        let job = EncodeJob::new(&levels, &im, &bits, 3);
        assert_eq!(job.cp, 128);
        assert_eq!(job.nq(), 3);
        assert_eq!(job.out_len(), 384);
    }
}
