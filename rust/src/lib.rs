//! # SpecPCM — PCM-based analog in-memory computing for mass spectrometry
//!
//! Reproduction of *SpecPCM: A Low-power PCM-based In-Memory Computing
//! Accelerator for Full-stack Mass Spectrometry Analysis* (Fan et al., 2024)
//! as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, python)** — the analog-IMC MVM Pallas kernel
//!   and the ID-level HD encoder jax graph, AOT-lowered to HLO text in
//!   `artifacts/` by `make artifacts`.
//! * **Layer 3 (this crate)** — the coordinator: PCM device + array
//!   simulator, ISA, energy/latency accounting, clustering and DB-search
//!   pipelines, baselines and the CLI. The hot-path numeric work executes
//!   through a pluggable [`backend`] layer: a scalar reference path, a
//!   bank-sharded host-parallel path (default), and — behind the `pjrt`
//!   cargo feature — the AOT artifacts through PJRT (`runtime`). The
//!   default build pulls **zero external crates** and runs fully offline;
//!   python never runs at request time.
//!
//! Module map (see DESIGN.md §4 for the substrate inventory):
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`device`] | §III-E, Fig. 7, Table S1 | superlattice PCM material models, MLC noise, write-verify, drift |
//! | [`array`] | §III-C, Table 1 | 128x128 2T2R array: DAC/ADC transfer, cycle model, banks |
//! | [`hd`] | §II-A, §III-B | hypervectors, ID-level encoding, dimension packing (scalar reference + word-packed `bitpacked` kernels) |
//! | [`encode`] | §III-B host path | pluggable encode+pack execution: scalar / bitpacked / spectra-sharded parallel |
//! | [`ms`] | §II-B | spectra, synthetic workloads, preprocessing, bucketing |
//! | [`energy`] | §IV, Tables S3/1, Fig. 8 | power/area/latency accounting (mergeable `OpCounts`) |
//! | [`isa`] | §III-F, Table S2 | STORE_HV / READ_HV / MVM_COMPUTE instruction set |
//! | [`cluster`] | Fig. 1, §III-C | complete-linkage HAC over IMC distances |
//! | [`search`] | Fig. 2, §III-C | Hamming similarity search + target-decoy FDR |
//! | [`baselines`] | §IV-A | Falcon/msCRUSH/HyperSpec/HyperOMS/ANN-SoLo-like comparators |
//! | [`backend`] | §III-C bank tiling | pluggable MVM execution: ref / bank-sharded parallel / PJRT, utilization-routing dispatcher |
//! | [`runtime`] | DESIGN.md §2 | PJRT client, artifact registry, executor cache (feature `pjrt`) |
//! | [`coordinator`] | DESIGN.md §2, Table 3 | capacity allocator, batcher, program-once/query-many `SearchEngine`, sharded multi-engine serving, pipeline drivers |
//! | [`config`] | §IV-A | TOML config system + paper presets, `[backend]` section (incl. `shards`) |
//! | [`telemetry`] | — | counters and report tables |
//! | [`util`] | — | RNG, JSON/kv parsers, crate-wide `error::{Error, Result}` |

pub mod array;
pub mod backend;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod encode;
pub mod energy;
pub mod hd;
pub mod isa;
pub mod ms;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod search;
pub mod telemetry;
pub mod util;

pub use config::SpecPcmConfig;
