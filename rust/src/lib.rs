//! # SpecPCM — PCM-based analog in-memory computing for mass spectrometry
//!
//! Reproduction of *SpecPCM: A Low-power PCM-based In-Memory Computing
//! Accelerator for Full-stack Mass Spectrometry Analysis* (Fan et al., 2024)
//! as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, python)** — the analog-IMC MVM Pallas kernel
//!   and the ID-level HD encoder jax graph, AOT-lowered to HLO text in
//!   `artifacts/` by `make artifacts`.
//! * **Layer 3 (this crate)** — the coordinator: PCM device + array
//!   simulator, ISA, energy/latency accounting, clustering and DB-search
//!   pipelines, baselines and the CLI. The hot-path numeric work executes
//!   through a pluggable [`backend`] layer: a scalar reference path, a
//!   bank-sharded host-parallel path (default), and — behind the `pjrt`
//!   cargo feature — the AOT artifacts through PJRT (`runtime`). The
//!   default build pulls **zero external crates** and runs fully offline;
//!   python never runs at request time.
//!
//! Module map (see DESIGN.md §4 for the substrate inventory):
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`device`] | §III-E, Fig. 7, Table S1 | superlattice PCM material models, MLC noise, write-verify, drift |
//! | [`array`] | §III-C, Table 1 | 128x128 2T2R array: DAC/ADC transfer, cycle model, banks |
//! | [`hd`] | §II-A, §III-B | hypervectors, ID-level encoding, dimension packing (scalar reference + word-packed `bitpacked` kernels) |
//! | [`encode`] | §III-B host path | pluggable encode+pack execution: scalar / bitpacked / spectra-sharded parallel |
//! | [`ms`] | §II-B | spectra, synthetic workloads, preprocessing, bucketing |
//! | [`energy`] | §IV, Tables S3/1, Fig. 8 | power/area/latency accounting (mergeable `OpCounts`) |
//! | [`isa`] | §III-F, Table S2 | STORE_HV / READ_HV / MVM_COMPUTE instruction set |
//! | [`cluster`] | Fig. 1, §III-C | complete-linkage HAC over IMC distances |
//! | [`search`] | Fig. 2, §III-C | Hamming similarity search + target-decoy FDR |
//! | [`baselines`] | §IV-A | Falcon/msCRUSH/HyperSpec/HyperOMS/ANN-SoLo-like comparators |
//! | [`backend`] | §III-C bank tiling | pluggable MVM execution: ref / bank-sharded parallel / PJRT, utilization-routing dispatcher |
//! | [`runtime`] | DESIGN.md §2 | PJRT client, artifact registry, executor cache (feature `pjrt`) |
//! | [`coordinator`] | DESIGN.md §2, Table 3 | capacity allocator, batcher, program-once/query-many `SearchEngine`, sharded multi-engine serving, pipeline drivers |
//! | [`config`] | §IV-A | TOML config system + paper presets, `[backend]` section (incl. `shards`) |
//! | [`telemetry`] | — | counters and report tables |
//! | [`util`] | — | RNG, JSON/kv parsers, `sync::lock_unpoisoned`, crate-wide `error::{Error, Result}` |
//!
//! # Enforced contracts
//!
//! Everything above serves one invariant: **backend, layout, and shard
//! choices change host wall time only — scores and [`energy::OpCounts`]
//! stay bit-identical to the scalar reference path.** The equivalence
//! suites in `rust/tests/` enforce it dynamically; the contract linter
//! (`python3 python/tools/lint_contracts.py`, run in CI as the
//! `Contract lint` step) rejects the code shapes that historically broke
//! it *statically*. Six rules, each with a per-line allowlist marker
//! `// lint: <tag>-ok (<reason>)` and an `--explain RULE` mode:
//!
//! * **C1-REASSOC — float-accumulation discipline.** Every f32 sum on
//!   the scoring path uses the lane contract: 8 `k % 8` lanes combined
//!   by the fixed tree reduce, implemented once by
//!   [`array::lane_tile_dot`] / [`array::lane_tree_reduce`] with
//!   [`array::imc_mvm_ref`] as the scalar oracle. Ad-hoc `+=` loops,
//!   `.sum::<f32>()`, or float `fold`s in `array`/`backend`/`hd` pick a
//!   different association and break bit-identity in the last ulp.
//!   Backed dynamically by `backend_equivalence.rs`,
//!   `segmented_equivalence.rs`, and the pinned-bits regression test
//!   `lane_order_pinned_bits`.
//! * **C2-CHARGE — central OpCounts charging.** `OpCounts` fields are
//!   mutated only at the central charging sites
//!   (`GroupCharges::charge`, `MvmJob::count_ops`,
//!   `HdFrontend::count_encode_ops`, `program_refs`): the
//!   `ceil(rows/128)` tile term is not linear across row splits, so
//!   decentralized charging over-counts — the PR 4 bug class. Backed by
//!   the op-count equality asserts in `engine_equivalence.rs` and
//!   `segmented_equivalence.rs`.
//! * **C3-SYNC — Sync-engine discipline.** No `RefCell`/`Rc` in
//!   `coordinator`/`backend`/`encode` (the shard fan-out drives engines
//!   from scoped threads), and every blocking `Mutex::lock()` goes
//!   through [`util::sync::lock_unpoisoned`] so poisoning panics name
//!   the lock. Backed by the `engine_is_sync_shareable` compile-time
//!   assertion and the sharded serving suite.
//! * **C4-RNG — RNG chaining discipline.** Programming-noise RNG
//!   construction happens only inside `ProgramContext`
//!   (`ProgramContext::noise_rng`); shards chain state via
//!   `noise_rng_state`, never re-seed, because write-verify early exit
//!   makes per-row RNG consumption data-dependent. Backed by the
//!   sharded-vs-monolithic bit-identity asserts in
//!   `segmented_equivalence.rs`.
//! * **C5-UNSAFE — unsafe hygiene.** The crate is `unsafe`-free by
//!   contract (`#![forbid(unsafe_code)]` below); any future audited
//!   exception must carry a `// SAFETY:` comment. Backed by the
//!   allowed-to-fail nightly Miri CI step over the `array`/`hd` kernel
//!   tests.
//! * **C6-TIME — logical-clock discipline.** No `std::time`
//!   (`Instant`/`SystemTime`) in `rust/src` non-test code: serving
//!   behavior — deadlines, backoff, refresh scheduling, drift — runs on
//!   the deterministic logical clock (`SearchEngine::advance_age`, the
//!   front door's tick stream, the remote supervisor's attempt clock) so
//!   traces and fault schedules replay tick-for-tick. Wall time is
//!   host-side *telemetry* only (`StageTimer`, benches). Backed by the
//!   zero-wall-clock chaos schedules in `worker_fault_tolerance.rs` and
//!   the replay determinism asserts in `scheduler_equivalence.rs`.

// The deny wall is deliberately conservative: lints that are true today
// and must stay true, not aspirational style lints. C5-UNSAFE (above)
// fails the contract linter if the forbid is ever dropped.
#![forbid(unsafe_code)]
#![deny(unused_must_use, non_ascii_idents, unused_extern_crates)]

pub mod array;
pub mod backend;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod encode;
pub mod energy;
pub mod hd;
pub mod isa;
pub mod ms;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod search;
pub mod telemetry;
pub mod util;

pub use config::SpecPcmConfig;
