//! Small shared utilities: deterministic RNG, rounding, padding helpers,
//! and the crate-wide [`error`] type (no `anyhow` — offline environment).

pub mod error;
pub mod json;
pub mod kv;
pub mod rng;
pub mod sync;

pub use error::{Error, Result};
pub use json::Json;
pub use rng::{Rng, RngState};
pub use sync::lock_unpoisoned;

/// Round half away from zero — matches `jnp.sign(x)*jnp.floor(|x|+0.5)` used
/// by the Pallas kernel and the python oracle. (This is also what
/// `f32::round` does; the alias exists to make the shared contract visible.)
#[inline]
pub fn round_away(x: f32) -> f32 {
    x.round()
}

/// Smallest multiple of `m` that is >= `x`.
#[inline]
pub fn ceil_to(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Smallest power of two >= `x` (x > 0).
#[inline]
pub fn pow2_at_least(x: f64) -> f64 {
    assert!(x > 0.0, "pow2_at_least requires x > 0");
    2f64.powi(x.log2().ceil() as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_away_halves() {
        assert_eq!(round_away(0.5), 1.0);
        assert_eq!(round_away(-0.5), -1.0);
        assert_eq!(round_away(2.5), 3.0);
        assert_eq!(round_away(-2.5), -3.0);
        assert_eq!(round_away(2.4), 2.0);
    }

    #[test]
    fn ceil_to_multiples() {
        assert_eq!(ceil_to(683, 128), 768);
        assert_eq!(ceil_to(2731, 128), 2816);
        assert_eq!(ceil_to(128, 128), 128);
        assert_eq!(ceil_to(1, 128), 128);
    }

    #[test]
    fn pow2_bounds() {
        assert_eq!(pow2_at_least(407.3), 512.0);
        assert_eq!(pow2_at_least(512.0), 512.0);
        assert_eq!(pow2_at_least(45.25), 64.0);
        assert_eq!(pow2_at_least(1.0), 1.0);
    }
}
