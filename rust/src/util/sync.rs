//! The crate's one blessed way to take a `Mutex`: [`lock_unpoisoned`].
//!
//! Engine state shared across the shard fan-out (query-HV cache, encode
//! cache stats, the PJRT runtime handle) lives behind `Mutex`es. A
//! poisoned lock means a worker thread panicked mid-update; recovering
//! the possibly-inconsistent value would quietly break the bit-identity
//! contract, so the only sane response is to propagate the panic — but a
//! bare `.lock().unwrap()` dies with a message that names nothing.
//! `lock_unpoisoned(&m, "query cache")` dies naming the lock, which is
//! the difference between a five-second triage and a stack-trace hunt.
//!
//! Contract lint rule `C3-SYNC` (see `python/tools/lint_contracts.py`)
//! flags every other `.lock()` call in the crate, and `clippy.toml`
//! disallows `Mutex::lock` outside this module, so this helper stays the
//! single idiom. `try_lock()` is intentionally *not* wrapped: the
//! non-blocking scratch-buffer fallback in `coordinator::engine` handles
//! contention (and poisoning) explicitly.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, panicking with a message that names the lock (`what`) if a
/// previous holder panicked. Use for every blocking lock in the crate.
#[allow(clippy::disallowed_methods)] // the one blessed `Mutex::lock` call
pub fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>, what: &str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(_) => panic!("{what} mutex poisoned: a thread panicked while holding it"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn locks_and_returns_guard() {
        let m = Mutex::new(7usize);
        *lock_unpoisoned(&m, "test counter") += 1;
        assert_eq!(*lock_unpoisoned(&m, "test counter"), 8);
    }

    #[test]
    #[should_panic(expected = "test counter mutex poisoned")]
    fn poisoned_lock_panics_with_name() {
        let m = Arc::new(Mutex::new(0usize));
        let m2 = Arc::clone(&m);
        let handle = std::thread::spawn(move || {
            let _guard = lock_unpoisoned(&m2, "test counter");
            panic!("poison the lock");
        });
        assert!(handle.join().is_err());
        let _ = lock_unpoisoned(&m, "test counter");
    }
}
