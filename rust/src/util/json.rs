//! Minimal JSON parser (offline environment: no serde). Supports the full
//! JSON grammar the artifact manifest uses: objects, arrays, strings with
//! escapes, numbers, booleans, null.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {pos}", c as char))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_num(b, pos),
        _ => Err(format!("unexpected byte at {pos}")),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            c => {
                // UTF-8 passthrough: copy the raw byte run.
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos]).map_err(|_| "invalid utf8")?,
                );
                let _ = c;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"schema": 1, "artifacts": [{"name": "mvm_c768", "params": {"c": 768}, "ok": true, "x": null}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("schema").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("mvm_c768"));
        assert_eq!(
            arts[0].get("params").unwrap().get("c").unwrap().as_usize(),
            Some(768)
        );
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(arts[0].get("x"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
