//! Minimal error type for the coordinator stack (offline environment — no
//! `anyhow`). One string-backed [`Error`] with a `context` combinator plus
//! the [`bail!`]/[`ensure!`] macros covers every fallible path in the
//! crate; the default build stays dependency-free.

use std::fmt;

/// A human-readable error with an optional chain of context prefixes.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prefix the error with a context line (`"{ctx}: {self}"`).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` (the `anyhow::Context` shape, minus the
/// dependency).
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("inner"))
    }

    #[test]
    fn display_and_context() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn from_string_via_question_mark() {
        fn f() -> Result<()> {
            Err("plain".to_string())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "plain");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), String> = Err("io".into());
        let e = r.context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: io");
        let r: std::result::Result<(), String> = Err("x".into());
        let e = r.with_context(|| format!("artifact {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "artifact 7: x");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(n: usize) -> Result<usize> {
            crate::ensure!(n < 10, "n {n} too large");
            if n == 0 {
                crate::bail!("zero not allowed");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "n 12 too large");
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
    }

    #[test]
    fn parse_errors_convert() {
        fn f(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert_eq!(f("-0.5").unwrap(), -0.5);
        assert!(f("zz").is_err());
    }
}
