//! Deterministic, dependency-free RNG (xoshiro256++ seeded via SplitMix64).
//!
//! Everything stochastic in the simulator — synthetic spectra, ID/level
//! hypervectors, PCM programming noise — flows through this generator so a
//! run is reproducible from a single `u64` seed across platforms. The
//! generator matches the published xoshiro256++ reference implementation.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller deviate.
    gauss_spare: Option<f64>,
}

/// The complete serializable state of an [`Rng`], for handing a chained
/// stream across a process boundary (remote shard workers re-program
/// bit-identically from the coordinator's snapshot). `gauss_spare` is
/// part of the state by necessity: programming noise draws Box-Muller
/// *pairs*, so a snapshot taken after an odd number of `gaussian()` calls
/// must carry the cached second deviate or the restored stream desyncs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent child stream (for per-bank / per-worker RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the full generator state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            gauss_spare: self.gauss_spare,
        }
    }

    /// Restore a generator from a snapshot: the restored stream continues
    /// exactly where `state()` left off, including a pending Box-Muller
    /// spare. This is state *transport*, not a new seed, so it composes
    /// with the C4-RNG chaining discipline rather than violating it.
    pub fn from_state(state: RngState) -> Rng {
        Rng {
            s: state.s,
            gauss_spare: state.gauss_spare,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our simulation purposes.
        (self.uniform() * n as f64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Random +/-1 value.
    #[inline]
    pub fn pm1(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_reference_vector() {
        // First output for the all-SplitMix64(0) seeding, fixed by our
        // construction — guards against accidental algorithm changes.
        let mut r1 = Rng::new(0);
        let mut r2 = Rng::new(0);
        assert_eq!(r1.next_u64(), r2.next_u64());
        let mut r3 = Rng::new(1);
        assert_ne!(Rng::new(0).next_u64(), r3.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 40);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 40);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn state_round_trip_resumes_exactly() {
        let mut a = Rng::new(0x5eed);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trip_preserves_gaussian_spare() {
        // An odd number of gaussian() draws leaves a cached Box-Muller
        // spare; the snapshot must carry it or the restored stream skips
        // one deviate and every later draw desyncs.
        let mut a = Rng::new(0xbeef);
        a.gaussian();
        let st = a.state();
        assert!(st.gauss_spare.is_some());
        let mut b = Rng::from_state(st);
        for _ in 0..50 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
