//! Minimal `key = value` config format (TOML subset; offline environment —
//! no toml crate). Supports comments (#), strings ("..."), integers,
//! floats, booleans, flat arrays of numbers `[a, b, c]` and one level of
//! `[section]` headers (keys inside a section parse as `section.key`).
//! Exactly the shapes `SpecPcmConfig` needs.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum KvValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    NumArray(Vec<f64>),
}

impl KvValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            KvValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            KvValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            KvValue::Float(f) => Some(*f),
            KvValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            KvValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_num_array(&self) -> Option<&[f64]> {
        match self {
            KvValue::NumArray(a) => Some(a),
            _ => None,
        }
    }
}

pub fn parse(text: &str) -> Result<BTreeMap<String, KvValue>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            // Section headers take trailing comments like every other line.
            let inner = inner.split('#').next().unwrap().trim();
            let name = inner
                .strip_suffix(']')
                .ok_or(format!("line {}: unterminated [section]", ln + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", ln + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or(format!("line {}: expected 'key = value'", ln + 1))?;
        let key = if section.is_empty() {
            key.trim().to_string()
        } else {
            format!("{section}.{}", key.trim())
        };
        let val = val.trim();
        // Strip trailing comments outside strings.
        let val = if val.starts_with('"') {
            val
        } else {
            val.split('#').next().unwrap().trim()
        };
        let parsed = parse_value(val).map_err(|e| format!("line {}: {e}", ln + 1))?;
        out.insert(key, parsed);
    }
    Ok(out)
}

fn parse_value(val: &str) -> Result<KvValue, String> {
    if let Some(stripped) = val.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        return Ok(KvValue::Str(inner.to_string()));
    }
    if val == "true" {
        return Ok(KvValue::Bool(true));
    }
    if val == "false" {
        return Ok(KvValue::Bool(false));
    }
    if let Some(inner) = val.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut nums = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            nums.push(p.parse::<f64>().map_err(|_| format!("bad number '{p}'"))?);
        }
        return Ok(KvValue::NumArray(nums));
    }
    if let Ok(i) = val.parse::<i64>() {
        return Ok(KvValue::Int(i));
    }
    if let Ok(f) = val.parse::<f64>() {
        return Ok(KvValue::Float(f));
    }
    Err(format!("cannot parse value '{val}'"))
}

/// Format helpers for the writer side.
pub fn fmt_section(name: &str) -> String {
    format!("\n[{name}]\n")
}

pub fn fmt_str(k: &str, v: &str) -> String {
    format!("{k} = \"{v}\"\n")
}

pub fn fmt_num(k: &str, v: impl std::fmt::Display) -> String {
    format!("{k} = {v}\n")
}

pub fn fmt_arr(k: &str, v: &[f32]) -> String {
    let items: Vec<String> = v.iter().map(|x| format!("{x}")).collect();
    format!("{k} = [{}]\n", items.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_config() {
        let m = parse(
            "# comment\n\
             task = \"search\"\n\
             hd_dim = 8192\n\
             fdr = 0.01 # inline comment\n\
             use_artifacts = true\n\
             sweep = [0.1, 0.2, 0.3]\n",
        )
        .unwrap();
        assert_eq!(m["task"].as_str(), Some("search"));
        assert_eq!(m["hd_dim"].as_i64(), Some(8192));
        assert_eq!(m["fdr"].as_f64(), Some(0.01));
        assert_eq!(m["use_artifacts"].as_bool(), Some(true));
        assert_eq!(m["sweep"].as_num_array().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_via_writers() {
        let mut text = String::new();
        text += &fmt_str("name", "x");
        text += &fmt_num("n", 42);
        text += &fmt_arr("a", &[1.0, 2.5]);
        let m = parse(&text).unwrap();
        assert_eq!(m["name"].as_str(), Some("x"));
        assert_eq!(m["n"].as_i64(), Some(42));
        assert_eq!(m["a"].as_num_array(), Some(&[1.0, 2.5][..]));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = [1, z]").is_err());
        assert!(parse("[backend\nkind = \"ref\"").is_err());
        assert!(parse("[]\nk = 1").is_err());
    }

    #[test]
    fn sections_prefix_keys() {
        let m = parse(
            "top = 1\n\
             [backend]  # execution settings\n\
             kind = \"parallel\"  # comment\n\
             threads = 8\n",
        )
        .unwrap();
        assert_eq!(m["top"].as_i64(), Some(1));
        assert_eq!(m["backend.kind"].as_str(), Some("parallel"));
        assert_eq!(m["backend.threads"].as_i64(), Some(8));
        assert!(!m.contains_key("kind"));
    }

    #[test]
    fn fmt_section_roundtrip() {
        let text = format!(
            "{}{}{}",
            fmt_num("top", 3),
            fmt_section("backend"),
            fmt_str("kind", "ref")
        );
        let m = parse(&text).unwrap();
        assert_eq!(m["backend.kind"].as_str(), Some("ref"));
        assert_eq!(m["top"].as_i64(), Some(3));
    }
}
