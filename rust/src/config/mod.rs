//! Configuration system: the ISA-exposed knobs (§III-D/F) plus system
//! geometry and the `[backend]` execution section, loadable from a
//! `key = value` file (TOML subset — see `util::kv`; no toml crate in
//! this offline environment) with the paper's §IV-A defaults as presets.

use crate::backend::BackendKind;
use crate::device::{FaultModel, Material};
use crate::encode::EncodeKind;
use crate::util::kv::{self, KvValue};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Clustering,
    Search,
}

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::Clustering => "clustering",
            Task::Search => "search",
        }
    }

    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "clustering" => Ok(Task::Clustering),
            "search" => Ok(Task::Search),
            other => Err(format!("unknown task '{other}'")),
        }
    }
}

fn material_name(m: Material) -> &'static str {
    match m {
        Material::Sb2Te3Gst467 => "sb2te3_gst467",
        Material::TiTe2Gst467 => "tite2_gst467",
    }
}

fn material_from_name(s: &str) -> Result<Material, String> {
    match s {
        "sb2te3_gst467" => Ok(Material::Sb2Te3Gst467),
        "tite2_gst467" => Ok(Material::TiTe2Gst467),
        other => Err(format!("unknown material '{other}'")),
    }
}

/// `[backend]` section: how the coordinator executes its two host hot
/// paths — MVM score tiles (`kind`) and HD encode+pack batches
/// (`encode_kind`); see `backend::BackendDispatcher`. Results are
/// bit-identical across every kind; only host wall-time differs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackendConfig {
    /// MVM backend: `"ref"` | `"parallel"` | `"pjrt"`.
    pub kind: BackendKind,
    /// Encode backend: `"scalar"` | `"bitpacked"` | `"parallel"`.
    pub encode_kind: EncodeKind,
    /// Worker threads for the parallel backends (0 = auto-detect; shared
    /// by the MVM and encode seams).
    pub threads: usize,
    /// Minimum padded-tile utilization before the dispatcher routes an
    /// MVM job to the primary backend instead of the scalar fallback
    /// (measured crossover ~0.3 for the fixed-geometry PJRT artifact).
    pub min_utilization: f64,
    /// Library shards for DB search (the third seam,
    /// `coordinator::sharded`): 0 = auto-compute the minimum shard count
    /// whose per-shard library fits `num_banks` banks (1 when it already
    /// fits), N = force exactly N engines of `num_banks` banks each.
    pub shards: usize,
    /// Reference-row stripe height for the parallel backend's
    /// `nq < threads` path (candidate rows per stripe, rounded up to a
    /// 128-row tile): 0 = size automatically from the worker count and the
    /// MAC budget. Score-neutral — stripes change wall time only.
    pub stripe_rows: usize,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig {
            kind: BackendKind::Parallel,
            encode_kind: EncodeKind::Parallel,
            threads: 0,
            min_utilization: 0.3,
            shards: 0,
            stripe_rows: 0,
        }
    }
}

/// `[remote]` section: the supervision policy of the multi-process shard
/// serving layer (`coordinator::remote`). Every duration is in **logical
/// ticks** on the supervisor's deterministic clock — the same discipline
/// as `SearchEngine::advance_age`; wall time never enters (contract
/// C6-TIME), so retry/timeout behavior replays bit-identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RemoteConfig {
    /// Logical ticks a shard request may take before the supervisor
    /// declares it timed out (must be >= 1).
    pub deadline_ticks: u64,
    /// Wire attempts retried per request after the first failure (0
    /// disables retries: one failure degrades the shard immediately).
    pub retries: u32,
    /// Base of the exponential retry backoff: attempt `k` waits
    /// `backoff_base_ticks << k` logical ticks (must be >= 1).
    pub backoff_base_ticks: u64,
    /// Consecutive failures that open a worker's circuit breaker (must be
    /// >= 1); an open breaker skips the worker until a respawn probe
    /// succeeds.
    pub breaker_threshold: u32,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            deadline_ticks: 1024,
            retries: 3,
            backoff_base_ticks: 8,
            breaker_threshold: 4,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SpecPcmConfig {
    pub task: Task,
    /// HD dimension D (paper defaults: 2048 clustering / 8192 search).
    pub hd_dim: usize,
    /// Bits per cell == packing factor n (1..=3 in the paper's sweep).
    pub mlc_bits: u8,
    /// Effective flash-ADC precision (1..=6).
    pub adc_bits: u32,
    /// Write-verify cycles (paper defaults: 0 clustering / 3 search).
    pub write_verify: u32,
    /// PCM material stack (paper §III-E assigns one per task).
    pub material: Material,
    /// Parallel 128x128 banks in the system.
    pub num_banks: usize,
    /// Precursor bucket width (Da).
    pub bucket_width: f64,
    /// m/z feature positions F.
    pub features: usize,
    /// Intensity quantization levels m.
    pub levels: usize,
    /// RNG seed for the whole run.
    pub seed: u64,
    /// Merge threshold sweep for clustering quality curves.
    pub threshold_sweep: Vec<f32>,
    /// FDR for DB-search identification (paper: 1%).
    pub fdr: f64,
    /// Use the PJRT artifacts when available (fall back to the rust
    /// reference path otherwise). Only consulted when `backend.kind` is
    /// `pjrt`.
    pub use_artifacts: bool,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// MVM execution backend (`[backend]` section).
    pub backend: BackendConfig,
    /// Cell fault injection for drift-aware serving studies (`[fault]`
    /// section; disabled in every preset so defaults reproduce the
    /// fault-free results byte-for-byte).
    pub fault: FaultModel,
    /// Remote shard-worker supervision policy (`[remote]` section).
    pub remote: RemoteConfig,
}

impl Default for SpecPcmConfig {
    fn default() -> Self {
        SpecPcmConfig::paper_clustering()
    }
}

impl SpecPcmConfig {
    /// §IV-A clustering defaults: D=2048, 3-bit MLC, 6-bit ADC, **no**
    /// write-verify (clustering tolerates programming error), Sb2Te3 stack.
    /// The bucket width is wider than a real precursor tolerance so the
    /// synthetic buckets mix several peptide groups (DESIGN.md §5).
    pub fn paper_clustering() -> Self {
        SpecPcmConfig {
            task: Task::Clustering,
            hd_dim: 2048,
            mlc_bits: 3,
            adc_bits: 6,
            write_verify: 0,
            material: Material::default_for_clustering(),
            num_banks: 128,
            bucket_width: 20.0,
            features: 512,
            levels: 64,
            seed: 0x1234_5678,
            threshold_sweep: (1..=40).map(|i| i as f32 * 0.02).collect(),
            fdr: 0.01,
            use_artifacts: true,
            artifacts_dir: "artifacts".into(),
            backend: BackendConfig::default(),
            fault: FaultModel::disabled(),
            remote: RemoteConfig::default(),
        }
    }

    /// §IV-A DB-search defaults: D=8192, 3-bit MLC, 6-bit ADC, 3
    /// write-verify cycles, TiTe2 stack.
    pub fn paper_search() -> Self {
        SpecPcmConfig {
            task: Task::Search,
            hd_dim: 8192,
            material: Material::default_for_search(),
            write_verify: 3,
            bucket_width: 5.0,
            ..SpecPcmConfig::paper_clustering()
        }
    }

    pub fn from_toml(text: &str) -> Result<Self, String> {
        let map = kv::parse(text)?;
        let mut cfg = SpecPcmConfig::paper_clustering();
        for (key, val) in &map {
            match key.as_str() {
                "task" => {
                    cfg.task = Task::from_name(val.as_str().ok_or("task: want string")?)?;
                    // Switch task-dependent defaults unless overridden below.
                    if cfg.task == Task::Search && !map.contains_key("material") {
                        cfg.material = Material::default_for_search();
                    }
                }
                "hd_dim" => cfg.hd_dim = get_usize(val, key)?,
                "mlc_bits" => cfg.mlc_bits = get_usize(val, key)? as u8,
                "adc_bits" => cfg.adc_bits = get_usize(val, key)? as u32,
                "write_verify" => cfg.write_verify = get_usize(val, key)? as u32,
                "material" => {
                    cfg.material = material_from_name(val.as_str().ok_or("material: want string")?)?
                }
                "num_banks" => cfg.num_banks = get_usize(val, key)?,
                "bucket_width" => cfg.bucket_width = val.as_f64().ok_or("bucket_width")?,
                "features" => cfg.features = get_usize(val, key)?,
                "levels" => cfg.levels = get_usize(val, key)?,
                "seed" => cfg.seed = get_usize(val, key)? as u64,
                "fdr" => cfg.fdr = val.as_f64().ok_or("fdr")?,
                "use_artifacts" => cfg.use_artifacts = val.as_bool().ok_or("use_artifacts")?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = val.as_str().ok_or("artifacts_dir")?.to_string()
                }
                "threshold_sweep" => {
                    cfg.threshold_sweep = val
                        .as_num_array()
                        .ok_or("threshold_sweep: want [..]")?
                        .iter()
                        .map(|&x| x as f32)
                        .collect()
                }
                "backend.kind" => {
                    cfg.backend.kind =
                        BackendKind::from_name(val.as_str().ok_or("backend.kind: want string")?)?
                }
                "backend.encode_kind" => {
                    cfg.backend.encode_kind = EncodeKind::from_name(
                        val.as_str().ok_or("backend.encode_kind: want string")?,
                    )?
                }
                "backend.threads" => cfg.backend.threads = get_usize(val, key)?,
                "backend.shards" => cfg.backend.shards = get_usize(val, key)?,
                "backend.stripe_rows" => cfg.backend.stripe_rows = get_usize(val, key)?,
                "backend.min_utilization" => {
                    cfg.backend.min_utilization =
                        val.as_f64().ok_or("backend.min_utilization")?
                }
                "fault.stuck_at_rate" => {
                    cfg.fault.stuck_at_rate = val.as_f64().ok_or("fault.stuck_at_rate")?
                }
                "fault.program_fail_rate" => {
                    cfg.fault.program_fail_rate =
                        val.as_f64().ok_or("fault.program_fail_rate")?
                }
                "fault.stuck_g" => {
                    cfg.fault.stuck_g = val.as_f64().ok_or("fault.stuck_g")? as f32
                }
                "remote.deadline_ticks" => {
                    cfg.remote.deadline_ticks = get_usize(val, key)? as u64
                }
                "remote.retries" => cfg.remote.retries = get_usize(val, key)? as u32,
                "remote.backoff_base_ticks" => {
                    cfg.remote.backoff_base_ticks = get_usize(val, key)? as u64
                }
                "remote.breaker_threshold" => {
                    cfg.remote.breaker_threshold = get_usize(val, key)? as u32
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s += &kv::fmt_str("task", self.task.name());
        s += &kv::fmt_num("hd_dim", self.hd_dim);
        s += &kv::fmt_num("mlc_bits", self.mlc_bits);
        s += &kv::fmt_num("adc_bits", self.adc_bits);
        s += &kv::fmt_num("write_verify", self.write_verify);
        s += &kv::fmt_str("material", material_name(self.material));
        s += &kv::fmt_num("num_banks", self.num_banks);
        s += &kv::fmt_num("bucket_width", self.bucket_width);
        s += &kv::fmt_num("features", self.features);
        s += &kv::fmt_num("levels", self.levels);
        s += &kv::fmt_num("seed", self.seed);
        s += &kv::fmt_num("fdr", self.fdr);
        s += &kv::fmt_num("use_artifacts", self.use_artifacts);
        s += &kv::fmt_str("artifacts_dir", &self.artifacts_dir);
        s += &kv::fmt_arr("threshold_sweep", &self.threshold_sweep);
        // Section keys must follow every top-level key (TOML semantics).
        s += &kv::fmt_section("backend");
        s += &kv::fmt_str("kind", self.backend.kind.name());
        s += &kv::fmt_str("encode_kind", self.backend.encode_kind.name());
        s += &kv::fmt_num("threads", self.backend.threads);
        s += &kv::fmt_num("min_utilization", self.backend.min_utilization);
        s += &kv::fmt_num("shards", self.backend.shards);
        s += &kv::fmt_num("stripe_rows", self.backend.stripe_rows);
        s += &kv::fmt_section("fault");
        s += &kv::fmt_num("stuck_at_rate", self.fault.stuck_at_rate);
        s += &kv::fmt_num("program_fail_rate", self.fault.program_fail_rate);
        s += &kv::fmt_num("stuck_g", self.fault.stuck_g);
        s += &kv::fmt_section("remote");
        s += &kv::fmt_num("deadline_ticks", self.remote.deadline_ticks);
        s += &kv::fmt_num("retries", self.remote.retries);
        s += &kv::fmt_num("backoff_base_ticks", self.remote.backoff_base_ticks);
        s += &kv::fmt_num("breaker_threshold", self.remote.breaker_threshold);
        s
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_toml(&text)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(1..=4).contains(&self.mlc_bits) {
            return Err(format!("mlc_bits {} not in 1..=4", self.mlc_bits));
        }
        if !(1..=6).contains(&self.adc_bits) {
            return Err(format!("adc_bits {} not in 1..=6", self.adc_bits));
        }
        if self.hd_dim == 0 || self.hd_dim % 2 != 0 {
            return Err(format!("hd_dim {} must be positive and even", self.hd_dim));
        }
        if self.num_banks == 0 {
            return Err("num_banks must be > 0".into());
        }
        if !(0.0..0.5).contains(&self.fdr) {
            return Err(format!("fdr {} out of range", self.fdr));
        }
        if !(0.0..=1.0).contains(&self.backend.min_utilization) {
            return Err(format!(
                "backend.min_utilization {} not in [0, 1]",
                self.backend.min_utilization
            ));
        }
        for (name, rate) in [
            ("fault.stuck_at_rate", self.fault.stuck_at_rate),
            ("fault.program_fail_rate", self.fault.program_fail_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("{name} {rate} not in [0, 1]"));
            }
        }
        if self.fault.stuck_at_rate + self.fault.program_fail_rate > 1.0 {
            return Err(format!(
                "fault rates sum to {} > 1",
                self.fault.stuck_at_rate + self.fault.program_fail_rate
            ));
        }
        if self.remote.deadline_ticks == 0 {
            return Err("remote.deadline_ticks must be >= 1".into());
        }
        if self.remote.backoff_base_ticks == 0 {
            return Err("remote.backoff_base_ticks must be >= 1".into());
        }
        if self.remote.breaker_threshold == 0 {
            return Err("remote.breaker_threshold must be >= 1".into());
        }
        Ok(())
    }

    /// Packing factor n.
    pub fn packing(&self) -> usize {
        self.mlc_bits as usize
    }
}

fn get_usize(v: &KvValue, key: &str) -> Result<usize, String> {
    v.as_i64()
        .filter(|&x| x >= 0)
        .map(|x| x as usize)
        .ok_or(format!("{key}: want non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_section_iva() {
        let c = SpecPcmConfig::paper_clustering();
        assert_eq!(c.hd_dim, 2048);
        assert_eq!(c.mlc_bits, 3);
        assert_eq!(c.adc_bits, 6);
        assert_eq!(c.write_verify, 0);
        assert_eq!(c.material, Material::Sb2Te3Gst467);

        let s = SpecPcmConfig::paper_search();
        assert_eq!(s.hd_dim, 8192);
        assert_eq!(s.write_verify, 3);
        assert_eq!(s.material, Material::TiTe2Gst467);
        assert_eq!(s.fdr, 0.01);
    }

    #[test]
    fn toml_roundtrip() {
        let c = SpecPcmConfig::paper_search();
        let text = c.to_toml();
        let back = SpecPcmConfig::from_toml(&text).unwrap();
        assert_eq!(back.hd_dim, c.hd_dim);
        assert_eq!(back.material, c.material);
        assert_eq!(back.task, c.task);
        assert_eq!(back.threshold_sweep.len(), c.threshold_sweep.len());
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let c = SpecPcmConfig::from_toml("hd_dim = 4096\nmlc_bits = 2\n").unwrap();
        assert_eq!(c.hd_dim, 4096);
        assert_eq!(c.mlc_bits, 2);
        assert_eq!(c.adc_bits, 6); // default
    }

    #[test]
    fn task_switch_pulls_material_default() {
        let c = SpecPcmConfig::from_toml("task = \"search\"\n").unwrap();
        assert_eq!(c.material, Material::TiTe2Gst467);
        let c2 = SpecPcmConfig::from_toml("task = \"search\"\nmaterial = \"sb2te3_gst467\"\n")
            .unwrap();
        assert_eq!(c2.material, Material::Sb2Te3Gst467);
    }

    #[test]
    fn validation_rejects_bad_values() {
        assert!(SpecPcmConfig::from_toml("mlc_bits = 9").is_err());
        assert!(SpecPcmConfig::from_toml("adc_bits = 0").is_err());
        assert!(SpecPcmConfig::from_toml("hd_dim = 0").is_err());
        assert!(SpecPcmConfig::from_toml("fdr = 0.9").is_err());
        assert!(SpecPcmConfig::from_toml("mystery = 1").is_err());
        assert!(SpecPcmConfig::from_toml("[backend]\nkind = \"gpu\"").is_err());
        assert!(SpecPcmConfig::from_toml("[backend]\nmin_utilization = 1.5").is_err());
    }

    #[test]
    fn backend_section_roundtrip_and_defaults() {
        let d = SpecPcmConfig::paper_clustering();
        assert_eq!(d.backend.kind, BackendKind::Parallel);
        assert_eq!(d.backend.encode_kind, EncodeKind::Parallel);
        assert_eq!(d.backend.threads, 0);
        assert!((d.backend.min_utilization - 0.3).abs() < 1e-12);

        let c = SpecPcmConfig::from_toml(
            "hd_dim = 1024\n[backend]\nkind = \"ref\"\nencode_kind = \"bitpacked\"\n\
             threads = 4\nmin_utilization = 0.5\nshards = 3\nstripe_rows = 256\n",
        )
        .unwrap();
        assert_eq!(c.backend.kind, BackendKind::Reference);
        assert_eq!(c.backend.encode_kind, EncodeKind::Bitpacked);
        assert_eq!(c.backend.threads, 4);
        assert_eq!(c.backend.min_utilization, 0.5);
        assert_eq!(c.backend.shards, 3);
        assert_eq!(c.backend.stripe_rows, 256);
        // Defaults stay auto (0).
        assert_eq!(SpecPcmConfig::paper_search().backend.shards, 0);
        assert_eq!(SpecPcmConfig::paper_search().backend.stripe_rows, 0);
        assert!(SpecPcmConfig::from_toml("[backend]\nstripe_rows = -1").is_err());

        // to_toml emits the section and parses back identically.
        let back = SpecPcmConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.backend, c.backend);

        // Unknown encode kinds are rejected like unknown MVM kinds.
        assert!(SpecPcmConfig::from_toml("[backend]\nencode_kind = \"gpu\"").is_err());
    }

    #[test]
    fn fault_section_roundtrip_defaults_and_validation() {
        // Presets ship with faults disabled — the byte-identity baseline.
        let d = SpecPcmConfig::paper_search();
        assert_eq!(d.fault, FaultModel::disabled());
        assert!(!d.fault.is_active());

        let c = SpecPcmConfig::from_toml(
            "hd_dim = 1024\n[fault]\nstuck_at_rate = 0.001\n\
             program_fail_rate = 0.002\nstuck_g = 2.5\n",
        )
        .unwrap();
        assert_eq!(c.fault.stuck_at_rate, 0.001);
        assert_eq!(c.fault.program_fail_rate, 0.002);
        assert_eq!(c.fault.stuck_g, 2.5);
        assert!(c.fault.is_active());

        // to_toml emits the section and parses back identically.
        let back = SpecPcmConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.fault, c.fault);

        // Rates must be probabilities and jointly at most 1.
        assert!(SpecPcmConfig::from_toml("[fault]\nstuck_at_rate = 1.5").is_err());
        assert!(SpecPcmConfig::from_toml("[fault]\nprogram_fail_rate = -0.1").is_err());
        assert!(SpecPcmConfig::from_toml(
            "[fault]\nstuck_at_rate = 0.7\nprogram_fail_rate = 0.7\n"
        )
        .is_err());
    }

    #[test]
    fn remote_section_roundtrip_defaults_and_validation() {
        let d = SpecPcmConfig::paper_search();
        assert_eq!(d.remote, RemoteConfig::default());
        assert_eq!(d.remote.deadline_ticks, 1024);
        assert_eq!(d.remote.retries, 3);
        assert_eq!(d.remote.backoff_base_ticks, 8);
        assert_eq!(d.remote.breaker_threshold, 4);

        let c = SpecPcmConfig::from_toml(
            "hd_dim = 1024\n[remote]\ndeadline_ticks = 64\nretries = 1\n\
             backoff_base_ticks = 2\nbreaker_threshold = 1\n",
        )
        .unwrap();
        assert_eq!(c.remote.deadline_ticks, 64);
        assert_eq!(c.remote.retries, 1);
        assert_eq!(c.remote.backoff_base_ticks, 2);
        assert_eq!(c.remote.breaker_threshold, 1);

        // Zero retries is a valid policy (fail fast, degrade immediately).
        let c = SpecPcmConfig::from_toml("[remote]\nretries = 0\n").unwrap();
        assert_eq!(c.remote.retries, 0);

        // to_toml emits the section and parses back identically.
        let back = SpecPcmConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.remote, c.remote);

        // Zero/negative durations and thresholds are typed-out.
        assert!(SpecPcmConfig::from_toml("[remote]\ndeadline_ticks = 0").is_err());
        assert!(SpecPcmConfig::from_toml("[remote]\nbackoff_base_ticks = 0").is_err());
        assert!(SpecPcmConfig::from_toml("[remote]\nbreaker_threshold = 0").is_err());
        assert!(SpecPcmConfig::from_toml("[remote]\nretries = -1").is_err());
        assert!(SpecPcmConfig::from_toml("[remote]\ndeadline_ticks = 1.5").is_err());
    }
}
