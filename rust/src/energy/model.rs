//! Event-driven energy/latency accounting (supplementary S.B methodology).
//!
//! The pipelines record *operation counts* (MVMs, programming pulse rounds,
//! verify reads, ASIC encode/pack/merge work); this model converts them to
//! joules and seconds using the Table S3 component powers, the Table S1
//! per-pulse PCM programming energies, and the §III-C cycle counts, with
//! `num_banks` banks operating in parallel.



use crate::array::timing::TimingModel;
use crate::array::ARRAY_DIM;
use crate::device::Material;

use super::components::{Component, BANK_TOTAL_POWER_MW, COMPONENTS};

fn component_power_mw(c: Component) -> f64 {
    COMPONENTS
        .iter()
        .find(|s| s.component == c)
        .unwrap()
        .total_power_mw
}

/// Operation counts accumulated by a pipeline run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Whole-array IMC MVM operations (one 128x128 bank, one input vector).
    pub mvm_ops: u64,
    /// Row-programming pulse rounds (one round programs a 128-cell row in
    /// parallel, 20 ns each).
    pub program_rounds: u64,
    /// Write-verify read rounds (row-parallel reads + compare).
    pub verify_rounds: u64,
    /// Normal row reads through the sense amps.
    pub row_reads: u64,
    /// Spectra encoded by the near-memory ASIC.
    pub encode_spectra: u64,
    /// Feature positions per spectrum (ASIC encode cycles scale with this).
    pub features: u64,
    /// Packed elements produced by the ASIC packer.
    pub pack_elements: u64,
    /// Distance-matrix merge-update element operations (complete linkage).
    pub merge_elements: u64,
}

impl OpCounts {
    pub fn add(&mut self, other: &OpCounts) {
        self.mvm_ops += other.mvm_ops;
        self.program_rounds += other.program_rounds;
        self.verify_rounds += other.verify_rounds;
        self.row_reads += other.row_reads;
        self.encode_spectra += other.encode_spectra;
        self.features = self.features.max(other.features);
        self.pack_elements += other.pack_elements;
        self.merge_elements += other.merge_elements;
    }
}

// Mergeable accounting: parallel backends accumulate per-shard counts and
// fold them after join (`+=` / `Sum`). Event counts sum; `features` is a
// workload property, not an event count, so merging takes the max.

impl std::ops::AddAssign<&OpCounts> for OpCounts {
    fn add_assign(&mut self, other: &OpCounts) {
        self.add(other);
    }
}

impl std::ops::AddAssign for OpCounts {
    fn add_assign(&mut self, other: OpCounts) {
        self.add(&other);
    }
}

impl std::iter::Sum for OpCounts {
    fn sum<I: Iterator<Item = OpCounts>>(iter: I) -> OpCounts {
        iter.fold(OpCounts::default(), |mut acc, o| {
            acc.add(&o);
            acc
        })
    }
}

/// GPU/CPU reference envelope for the energy-efficiency comparison
/// (§IV-B: "GPU-based tools typically operate at an average power of
/// 450 W").
#[derive(Clone, Copy, Debug)]
pub struct GpuEnvelope {
    pub avg_power_w: f64,
}

impl Default for GpuEnvelope {
    fn default() -> Self {
        GpuEnvelope { avg_power_w: 450.0 }
    }
}

impl GpuEnvelope {
    pub fn energy_j(&self, latency_s: f64) -> f64 {
        self.avg_power_w * latency_s
    }
}

/// Energy/latency report for one pipeline run.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyReport {
    pub mvm_j: f64,
    pub program_j: f64,
    pub verify_j: f64,
    pub read_j: f64,
    pub asic_j: f64,
    pub imc_latency_s: f64,
    pub program_latency_s: f64,
    pub asic_latency_s: f64,
}

impl EnergyReport {
    pub fn total_j(&self) -> f64 {
        self.mvm_j + self.program_j + self.verify_j + self.read_j + self.asic_j
    }

    /// Sequential (upper-bound) latency.
    pub fn total_latency_s(&self) -> f64 {
        self.imc_latency_s + self.program_latency_s + self.asic_latency_s
    }

    /// Overlapped latency: the ASIC pipeline hides behind the IMC/memory
    /// work (the design's steady-state behaviour).
    pub fn overlapped_latency_s(&self) -> f64 {
        (self.imc_latency_s + self.program_latency_s).max(self.asic_latency_s)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EnergyLatencyModel {
    pub timing: TimingModel,
    pub material: Material,
    /// Effective flash-ADC bits (energy scales with enabled comparators).
    pub adc_bits: u32,
    /// Banks operating in parallel.
    pub num_banks: usize,
    /// ASIC dynamic power (mW) while active — encoder + packer + merge
    /// logic; tiny vs the bank (supplementary: <0.5% area).
    pub asic_power_mw: f64,
}

impl EnergyLatencyModel {
    pub fn new(material: Material, adc_bits: u32, num_banks: usize) -> Self {
        EnergyLatencyModel {
            timing: TimingModel::default(),
            material,
            adc_bits,
            num_banks,
            asic_power_mw: 0.08,
        }
    }

    /// ADC energy scale vs the full 6-bit flash: enabled comparators
    /// (2^b - 1) / 63 — §III-D: a 4-bit flash costs ~4x less than 6-bit.
    pub fn adc_energy_scale(&self) -> f64 {
        ((1u64 << self.adc_bits) - 1) as f64 / 63.0
    }

    /// Energy of one whole-array MVM (10 cycles of bank activity with the
    /// ADC scaled to its enabled precision).
    pub fn mvm_op_j(&self) -> f64 {
        let adc_mw = component_power_mw(Component::FlashAdc);
        let bank_mw = BANK_TOTAL_POWER_MW - adc_mw + adc_mw * self.adc_energy_scale();
        bank_mw * 1e-3 * self.timing.mvm_s()
    }

    /// Energy of one row-programming pulse round: 128 cells pulsed in
    /// parallel (Table S1 per-pulse energy) + SL-driver activity.
    pub fn program_round_j(&self) -> f64 {
        let cells = ARRAY_DIM as f64;
        let pcm = self.material.params().prog_energy_pj * 1e-12 * cells;
        let drivers =
            component_power_mw(Component::SlGenDrive) * 1e-3 * self.timing.program_pulse_s();
        pcm + drivers
    }

    /// Energy of one verify/normal row read (read gen + sense amps for
    /// `read_cycles`).
    pub fn row_read_j(&self) -> f64 {
        let mw = component_power_mw(Component::ReadGen) + component_power_mw(Component::SenseAmp);
        mw * 1e-3 * self.timing.cycles_to_s(self.timing.read_cycles)
    }

    /// Convert op counts into an energy/latency report.
    pub fn report(&self, ops: &OpCounts) -> EnergyReport {
        let t = &self.timing;
        let banks = self.num_banks.max(1) as f64;

        let mvm_j = ops.mvm_ops as f64 * self.mvm_op_j();
        let program_j = ops.program_rounds as f64 * self.program_round_j();
        let verify_j = ops.verify_rounds as f64 * self.row_read_j();
        let read_j = ops.row_reads as f64 * self.row_read_j();

        let asic_cycles = ops.encode_spectra * ops.features * t.encode_cycles_per_feature
            + ops.pack_elements * t.pack_cycles_per_element
            + ops.merge_elements * t.merge_cycles_per_element;
        let asic_latency_s = t.cycles_to_s(asic_cycles);
        let asic_j = self.asic_power_mw * 1e-3 * asic_latency_s;

        let imc_latency_s = (ops.mvm_ops as f64 / banks).ceil() * t.mvm_s()
            + (ops.row_reads as f64 / banks).ceil() * t.cycles_to_s(t.read_cycles);
        let program_latency_s = ((ops.program_rounds as f64 / banks).ceil())
            * t.program_pulse_s()
            + (ops.verify_rounds as f64 / banks).ceil() * t.cycles_to_s(t.verify_cycles);

        EnergyReport {
            mvm_j,
            program_j,
            verify_j,
            read_j,
            asic_j,
            imc_latency_s,
            program_latency_s,
            asic_latency_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> EnergyLatencyModel {
        EnergyLatencyModel::new(Material::TiTe2Gst467, 6, 64)
    }

    #[test]
    fn mvm_energy_at_6_bits_is_bank_power_times_20ns() {
        let m = model();
        let e = m.mvm_op_j();
        assert!((e - 15.59e-3 * 20e-9).abs() < 1e-15, "{e}");
    }

    #[test]
    fn four_bit_adc_roughly_quarter_adc_energy() {
        // §III-D: 4-bit flash ~4x cheaper than 6-bit.
        let m6 = EnergyLatencyModel::new(Material::TiTe2Gst467, 6, 1);
        let m4 = EnergyLatencyModel::new(Material::TiTe2Gst467, 4, 1);
        let ratio = m6.adc_energy_scale() / m4.adc_energy_scale();
        assert!((ratio - 4.2).abs() < 0.3, "{ratio}");
    }

    #[test]
    fn programming_dominated_by_pcm_pulse_energy() {
        let m = model();
        let e = m.program_round_j();
        let pcm_only = 2.88e-12 * 128.0;
        assert!(e > pcm_only && e < pcm_only * 1.2, "{e} vs {pcm_only}");
    }

    #[test]
    fn sb2te3_programs_cheaper() {
        let sb = EnergyLatencyModel::new(Material::Sb2Te3Gst467, 6, 1);
        let ti = EnergyLatencyModel::new(Material::TiTe2Gst467, 6, 1);
        assert!(sb.program_round_j() < ti.program_round_j());
    }

    #[test]
    fn latency_scales_down_with_banks() {
        let ops = OpCounts {
            mvm_ops: 6400,
            ..Default::default()
        };
        let m1 = EnergyLatencyModel::new(Material::TiTe2Gst467, 6, 1);
        let m64 = EnergyLatencyModel::new(Material::TiTe2Gst467, 6, 64);
        let r1 = m1.report(&ops);
        let r64 = m64.report(&ops);
        assert!((r1.imc_latency_s / r64.imc_latency_s - 64.0).abs() < 1.0);
        // Energy does NOT scale with banks (same total work).
        assert_eq!(r1.mvm_j, r64.mvm_j);
    }

    #[test]
    fn op_counts_merge_like_add() {
        let a = OpCounts {
            mvm_ops: 10,
            features: 512,
            program_rounds: 3,
            ..Default::default()
        };
        let b = OpCounts {
            mvm_ops: 5,
            features: 256,
            verify_rounds: 7,
            ..Default::default()
        };
        let mut via_add_assign = a;
        via_add_assign += &b;
        let via_sum: OpCounts = [a, b].into_iter().sum();
        assert_eq!(via_add_assign.mvm_ops, 15);
        assert_eq!(via_add_assign.features, 512); // max, not sum
        assert_eq!(via_add_assign.program_rounds, 3);
        assert_eq!(via_add_assign.verify_rounds, 7);
        assert_eq!(via_sum.mvm_ops, via_add_assign.mvm_ops);
        assert_eq!(via_sum.features, via_add_assign.features);

        // Parallel-shard shape: folding any number of shards (including
        // empty ones) keeps `features` at the workload's single value
        // instead of multiplying it by the shard count.
        let shards = [a, b, OpCounts::default(), a];
        let folded: OpCounts = shards.into_iter().sum();
        assert_eq!(folded.features, 512);
        assert_eq!(folded.mvm_ops, 25);
        assert_eq!(folded.program_rounds, 6);
    }

    #[test]
    fn gpu_envelope_energy() {
        let g = GpuEnvelope::default();
        assert_eq!(g.energy_j(2.0), 900.0);
    }

    #[test]
    fn report_totals_add_up() {
        let ops = OpCounts {
            mvm_ops: 100,
            program_rounds: 50,
            verify_rounds: 20,
            row_reads: 10,
            encode_spectra: 64,
            features: 512,
            pack_elements: 64 * 683,
            merge_elements: 1000,
        };
        let r = model().report(&ops);
        let total = r.mvm_j + r.program_j + r.verify_j + r.read_j + r.asic_j;
        assert!((r.total_j() - total).abs() < 1e-18);
        assert!(r.total_latency_s() >= r.overlapped_latency_s());
    }
}
