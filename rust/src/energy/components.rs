//! Per-bank component specifications — Table 1 (configuration) merged with
//! Table S3 (post-layout unit power/area at 40 nm, 500 MHz).
//!
//! One bank = one 128x128 2T2R array plus its peripherals. "Total" values
//! in Table S3 are per bank; unit counts come from Table 1 (e.g. 16 flash
//! ADCs each shared across eight rows; 128 DACs, one per column).



#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Component {
    PcmArray,
    FlashAdc,
    Dac,
    SlGenDrive,
    ReadGen,
    WlDecodeDrive,
    SenseAmp,
    Selectors,
}

#[derive(Clone, Copy, Debug)]
pub struct ComponentSpec {
    pub component: Component,
    pub name: &'static str,
    /// Unit power (µW); None where Table S3 only reports a total.
    pub unit_power_uw: Option<f64>,
    /// Unit area (µm²); None where Table S3 only reports a total.
    pub unit_area_um2: Option<f64>,
    /// Units per bank (Table 1).
    pub units_per_bank: u32,
    /// Total power per bank (mW) — Table S3.
    pub total_power_mw: f64,
    /// Total area per bank (mm²) — Table S3.
    pub total_area_mm2: f64,
}

/// Table S3, row by row.
pub const COMPONENTS: [ComponentSpec; 8] = [
    ComponentSpec {
        component: Component::PcmArray,
        name: "PCM Array",
        unit_power_uw: Some(0.22),
        unit_area_um2: Some(0.5),
        units_per_bank: 16384, // 128x128 cells
        total_power_mw: 3.58,
        total_area_mm2: 0.0082,
    },
    ComponentSpec {
        component: Component::FlashAdc,
        name: "Flash ADC",
        unit_power_uw: Some(320.0),
        unit_area_um2: Some(920.0),
        units_per_bank: 16, // each shared between eight rows (Table 1)
        total_power_mw: 5.12,
        total_area_mm2: 0.0147,
    },
    ComponentSpec {
        component: Component::Dac,
        name: "DAC",
        unit_power_uw: Some(6.56),
        unit_area_um2: Some(32.0),
        units_per_bank: 128, // one per column (Table 1)
        total_power_mw: 0.84,
        total_area_mm2: 0.0041,
    },
    ComponentSpec {
        component: Component::SlGenDrive,
        name: "SL Gen / Drive",
        unit_power_uw: Some(52.5),
        unit_area_um2: Some(72.47),
        units_per_bank: 64, // each shared between four cols (Table 1)
        total_power_mw: 3.36,
        total_area_mm2: 0.0046,
    },
    ComponentSpec {
        component: Component::ReadGen,
        name: "Read Gen",
        unit_power_uw: None,
        unit_area_um2: None,
        units_per_bank: 2, // two per row, activated for the target row
        total_power_mw: 0.51,
        total_area_mm2: 0.0018,
    },
    ComponentSpec {
        component: Component::WlDecodeDrive,
        name: "WL Decode / Drive",
        unit_power_uw: Some(4.05),
        unit_area_um2: Some(10.68),
        units_per_bank: 256, // two drivers per row (Table 1)
        total_power_mw: 1.04,
        total_area_mm2: 0.0027,
    },
    ComponentSpec {
        component: Component::SenseAmp,
        name: "Sense Amp",
        unit_power_uw: Some(20.0),
        unit_area_um2: Some(75.9),
        units_per_bank: 32, // each shared between four cols (Table 1)
        total_power_mw: 0.64,
        total_area_mm2: 0.0024,
    },
    ComponentSpec {
        component: Component::Selectors,
        name: "Selectors",
        unit_power_uw: None,
        unit_area_um2: None,
        units_per_bank: 0,
        total_power_mw: 0.50,
        total_area_mm2: 0.0017,
    },
];

/// Table S3 totals per bank.
pub const BANK_TOTAL_POWER_MW: f64 = 15.59;
pub const BANK_TOTAL_AREA_MM2: f64 = 0.0402;

/// ASIC near-memory block areas (supplementary S.B): encoder 44 µm², other
/// logic 69 µm² — "negligible (less than 0.5%)" vs the arrays.
pub const ASIC_ENCODER_AREA_UM2: f64 = 44.0;
pub const ASIC_OTHER_AREA_UM2: f64 = 69.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_table_s3() {
        let p: f64 = COMPONENTS.iter().map(|c| c.total_power_mw).sum();
        let a: f64 = COMPONENTS.iter().map(|c| c.total_area_mm2).sum();
        assert!((p - BANK_TOTAL_POWER_MW).abs() < 1e-9, "power {p}");
        assert!((a - BANK_TOTAL_AREA_MM2).abs() < 1e-9, "area {a}");
    }

    #[test]
    fn adc_dominates_area() {
        // Fig. 8: the flash ADC is the largest area consumer — the reason
        // the design shares one ADC across eight rows.
        let adc = COMPONENTS
            .iter()
            .find(|c| c.component == Component::FlashAdc)
            .unwrap();
        for c in &COMPONENTS {
            if c.component != Component::FlashAdc {
                assert!(adc.total_area_mm2 > c.total_area_mm2, "{}", c.name);
            }
        }
    }

    #[test]
    fn unit_times_count_consistent_with_totals() {
        // Where Table S3 gives unit values, units * unit_power should land
        // within ~2x of the reported total (the table rounds and some
        // components duty-cycle).
        for c in &COMPONENTS {
            if let Some(up) = c.unit_power_uw {
                if c.units_per_bank > 0 {
                    let derived_mw = up * c.units_per_bank as f64 / 1000.0;
                    let ratio = derived_mw / c.total_power_mw;
                    assert!(
                        (0.4..=2.5).contains(&ratio),
                        "{}: derived {derived_mw} vs total {} (ratio {ratio})",
                        c.name,
                        c.total_power_mw
                    );
                }
            }
        }
    }

    #[test]
    fn asic_area_negligible() {
        let asic = ASIC_ENCODER_AREA_UM2 + ASIC_OTHER_AREA_UM2;
        assert!(asic / (BANK_TOTAL_AREA_MM2 * 1e6) < 0.005);
    }
}
