//! Area breakdown (Fig. 8) derived from the Table S3 constants.

use super::components::{COMPONENTS, BANK_TOTAL_AREA_MM2};

/// (name, area_mm2, fraction) per component, descending by area — the
//  Fig. 8 pie chart as data.
pub fn area_breakdown() -> Vec<(&'static str, f64, f64)> {
    let mut rows: Vec<(&'static str, f64, f64)> = COMPONENTS
        .iter()
        .map(|c| {
            (
                c.name,
                c.total_area_mm2,
                c.total_area_mm2 / BANK_TOTAL_AREA_MM2,
            )
        })
        .collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let total: f64 = area_breakdown().iter().map(|r| r.2).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adc_is_largest_slice() {
        // Fig. 8's headline: ADC ~37% of the bank.
        let rows = area_breakdown();
        assert_eq!(rows[0].0, "Flash ADC");
        assert!(rows[0].2 > 0.30 && rows[0].2 < 0.45, "{}", rows[0].2);
    }
}
