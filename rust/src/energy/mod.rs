//! Energy, power and area accounting (paper §IV, Tables 1/S3, Fig. 8).
//!
//! The methodology mirrors the paper's in-house simulator (supplementary
//! S.B): component-level unit power/area from post-layout measurement at
//! 40 nm / 500 MHz (Table S3), combined with per-operation event counts
//! from the array simulator, plus the Table S1 per-pulse PCM programming
//! energies.

pub mod area;
pub mod components;
pub mod model;

pub use area::area_breakdown;
pub use components::{Component, ComponentSpec, COMPONENTS};
pub use model::{EnergyLatencyModel, EnergyReport, GpuEnvelope, OpCounts};
