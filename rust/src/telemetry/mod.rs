//! Lightweight counters, stage timers and report-table formatting used by
//! the pipelines, benches and the CLI.

use std::collections::BTreeMap;
// lint: time-ok (StageTimer is host wall-time telemetry, never results-affecting)
use std::time::Instant;

/// Query-HV cache hit/miss counters (the engine's encode cache; see
/// `coordinator::SearchEngine`). A "hit" is any spectrum whose packed HV
/// was served without running the encode kernel — from an earlier batch
/// or from a duplicate earlier in the same batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodeCacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl EncodeCacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction in [0, 1] (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    /// Fold another counter into this one (mirrors `OpCounts::add`, so
    /// shard/batch aggregation is one fold).
    pub fn merge(&mut self, other: &EncodeCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

impl std::ops::AddAssign<&EncodeCacheStats> for EncodeCacheStats {
    fn add_assign(&mut self, rhs: &EncodeCacheStats) {
        self.merge(rhs);
    }
}

impl std::ops::AddAssign for EncodeCacheStats {
    fn add_assign(&mut self, rhs: Self) {
        self.merge(&rhs);
    }
}

impl std::iter::Sum for EncodeCacheStats {
    fn sum<I: Iterator<Item = EncodeCacheStats>>(iter: I) -> EncodeCacheStats {
        iter.fold(EncodeCacheStats::default(), |mut acc, s| {
            acc.merge(&s);
            acc
        })
    }
}

/// Per-engine device staleness/health snapshot (drift-aware serving; see
/// `coordinator::SearchEngine::device_health`). Attached to every
/// `BatchOutcome`, so serving loops can watch the panel age and trigger a
/// `RefreshPolicy` pass between batches.
///
/// Aggregation rule (deliberately asymmetric, like `OpCounts::features`):
/// ages and losses are *workload properties* — merged via max, the
/// stalest segment dominates — while fault/refresh counts are event
/// counts over disjoint rows and sum across shards.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceHealth {
    /// Seconds since the stalest live row was last programmed.
    pub max_age_seconds: f64,
    /// Estimated conductance fraction lost on that stalest row
    /// (`1 - drift_factor(max_age)`), in [0, 1).
    pub est_conductance_loss: f64,
    /// Fault cells injected at the live rows' latest programming events.
    pub injected_faults: u64,
    /// Row re-programming (refresh epoch) events among live rows.
    pub refreshes: u64,
}

impl DeviceHealth {
    /// Fold another snapshot in (max ages/losses, sum counts) — the shard
    /// aggregation used by `ShardedSearchEngine`.
    pub fn merge(&mut self, other: &DeviceHealth) {
        self.max_age_seconds = self.max_age_seconds.max(other.max_age_seconds);
        self.est_conductance_loss = self.est_conductance_loss.max(other.est_conductance_loss);
        self.injected_faults += other.injected_faults;
        self.refreshes += other.refreshes;
    }
}

impl std::ops::AddAssign<&DeviceHealth> for DeviceHealth {
    fn add_assign(&mut self, rhs: &DeviceHealth) {
        self.merge(rhs);
    }
}

impl std::ops::AddAssign for DeviceHealth {
    fn add_assign(&mut self, rhs: Self) {
        self.merge(&rhs);
    }
}

impl std::iter::Sum for DeviceHealth {
    fn sum<I: Iterator<Item = DeviceHealth>>(iter: I) -> DeviceHealth {
        iter.fold(DeviceHealth::default(), |mut acc, s| {
            acc.merge(&s);
            acc
        })
    }
}

/// Per-trace serving-front-door telemetry (see `coordinator::scheduler`):
/// how well the dynamic batcher kept the 128x128 tiles full and how long
/// requests waited in the queue. Every duration is in **logical ticks**
/// (the front door's deterministic clock, same discipline as
/// `SearchEngine::advance_age`), never wall time, so identical traces
/// produce identical telemetry on any host.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FrontDoorStats {
    /// Requests accepted from the arrival trace.
    pub requests: u64,
    /// Batches flushed into `search_batch`.
    pub batches: u64,
    /// Flushes fired by the tile-fill size trigger.
    pub size_flushes: u64,
    /// Flushes fired by the logical-tick deadline trigger.
    pub deadline_flushes: u64,
    /// Flushes forced by a full bounded queue (backpressure).
    pub backpressure_flushes: u64,
    /// End-of-trace drain flushes.
    pub drain_flushes: u64,
    /// Deepest queue occupancy observed (after enqueue, before flush).
    pub max_queue_depth: u64,
    /// The tile-fill target batches aim for (queries per flush).
    pub fill_target: u64,
    /// Mean batch fill fraction in [0, 1]: batch length / fill target,
    /// averaged over flushed batches.
    pub mean_fill_fraction: f64,
    /// Queue-latency percentiles over every request, in logical ticks
    /// (flush tick minus arrival tick; nearest-rank).
    pub p50_wait_ticks: u64,
    pub p99_wait_ticks: u64,
    pub max_wait_ticks: u64,
    /// `RefreshPolicy::maintain` increments run in idle gaps.
    pub maintain_calls: u64,
    /// Rows re-programmed by those in-gap maintain increments.
    pub refreshed_rows: u64,
}

impl FrontDoorStats {
    /// One-line human summary, printed by the CLI serve report next to
    /// the device-health line.
    pub fn summary(&self) -> String {
        format!(
            "front door: {} requests in {} batches (fill {:.0}% of target {}), \
             max queue depth {}, wait p50/p99/max {}/{}/{} ticks, \
             {} in-gap maintains ({} rows refreshed)",
            self.requests,
            self.batches,
            self.mean_fill_fraction * 100.0,
            self.fill_target,
            self.max_queue_depth,
            self.p50_wait_ticks,
            self.p99_wait_ticks,
            self.max_wait_ticks,
            self.maintain_calls,
            self.refreshed_rows
        )
    }

    /// Fold another trace's stats in, so multi-flush / multi-trace serving
    /// (the remote supervisor's per-epoch segments) aggregates to one
    /// panel. Event counters sum; occupancy/latency extrema take the max.
    /// Percentiles cannot be re-derived without the raw waits, so the
    /// merged p50/p99 are the max over segments — a deliberately
    /// conservative (pessimistic) bound, same spirit as
    /// [`DeviceHealth::merge`] letting the stalest segment dominate.
    /// `mean_fill_fraction` is re-weighted by each side's batch count so
    /// the merged mean equals the mean over all flushed batches.
    pub fn merge(&mut self, other: &FrontDoorStats) {
        let total_batches = self.batches + other.batches;
        if total_batches > 0 {
            self.mean_fill_fraction = (self.mean_fill_fraction * self.batches as f64
                + other.mean_fill_fraction * other.batches as f64)
                / total_batches as f64;
        }
        self.requests += other.requests;
        self.batches = total_batches;
        self.size_flushes += other.size_flushes;
        self.deadline_flushes += other.deadline_flushes;
        self.backpressure_flushes += other.backpressure_flushes;
        self.drain_flushes += other.drain_flushes;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.fill_target = self.fill_target.max(other.fill_target);
        self.p50_wait_ticks = self.p50_wait_ticks.max(other.p50_wait_ticks);
        self.p99_wait_ticks = self.p99_wait_ticks.max(other.p99_wait_ticks);
        self.max_wait_ticks = self.max_wait_ticks.max(other.max_wait_ticks);
        self.maintain_calls += other.maintain_calls;
        self.refreshed_rows += other.refreshed_rows;
    }
}

impl std::ops::AddAssign<&FrontDoorStats> for FrontDoorStats {
    fn add_assign(&mut self, rhs: &FrontDoorStats) {
        self.merge(rhs);
    }
}

impl std::ops::AddAssign for FrontDoorStats {
    fn add_assign(&mut self, rhs: Self) {
        self.merge(&rhs);
    }
}

impl std::iter::Sum for FrontDoorStats {
    fn sum<I: Iterator<Item = FrontDoorStats>>(iter: I) -> FrontDoorStats {
        iter.fold(FrontDoorStats::default(), |mut acc, s| {
            acc.merge(&s);
            acc
        })
    }
}

/// Nearest-rank percentile over a **sorted ascending** slice; `p` in
/// [0, 1]. Returns 0 for an empty slice (the front door's "no requests"
/// case). `p = 0` is the minimum, `p = 1` the maximum.
pub fn percentile_u64(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let p = p.clamp(0.0, 1.0);
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

/// Named wall-clock stage timings (the Fig. 3-style latency breakdown).
#[derive(Debug, Default, Clone)]
pub struct StageTimer {
    stages: BTreeMap<String, f64>,
    order: Vec<String>,
}

impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a stage name (accumulates across calls).
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        // lint: time-ok (stage breakdown is host telemetry; results never read it)
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, stage: &str, seconds: f64) {
        if !self.stages.contains_key(stage) {
            self.order.push(stage.to_string());
        }
        *self.stages.entry(stage.to_string()).or_insert(0.0) += seconds;
    }

    pub fn get(&self, stage: &str) -> f64 {
        self.stages.get(stage).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.stages.values().sum()
    }

    /// (stage, seconds, fraction) rows in insertion order.
    pub fn breakdown(&self) -> Vec<(String, f64, f64)> {
        let total = self.total().max(f64::MIN_POSITIVE);
        self.order
            .iter()
            .map(|s| (s.clone(), self.stages[s], self.stages[s] / total))
            .collect()
    }
}

/// One field value of a machine-readable bench record (see
/// [`render_json_records`]). Kept deliberately tiny — flat records of
/// numbers/strings/bools are all the perf-trajectory files need, and the
/// offline build has no serde.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonField {
    U(u64),
    F(f64),
    S(String),
    B(bool),
}

impl JsonField {
    fn render(&self) -> String {
        match self {
            JsonField::U(v) => v.to_string(),
            // `{:?}` on f64 round-trips (shortest representation that
            // parses back exactly); JSON has no NaN/Inf, so map those to
            // null rather than emit an unparsable token.
            JsonField::F(v) if v.is_finite() => format!("{v:?}"),
            JsonField::F(_) => "null".to_string(),
            JsonField::S(v) => {
                let mut out = String::with_capacity(v.len() + 2);
                out.push('"');
                for ch in v.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            JsonField::B(v) => v.to_string(),
        }
    }
}

/// Render flat `(key, value)` records as a pretty-printed JSON array of
/// objects — the machine-readable side channel benches write next to
/// their human text tables (e.g. `BENCH_serving.json`, the perf
/// trajectory seed). Keys are emitted in the given order.
pub fn render_json_records(records: &[Vec<(&str, JsonField)>]) -> String {
    let mut out = String::from("[\n");
    for (i, rec) in records.iter().enumerate() {
        out.push_str("  {");
        for (j, (k, v)) in rec.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push_str(&JsonField::S(k.to_string()).render());
            out.push_str(": ");
            out.push_str(&v.render());
        }
        out.push('}');
        if i + 1 < records.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push(']');
    out
}

/// Render rows as a fixed-width text table (benches print these).
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = format!("== {title} ==\n");
    out += &fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out += "\n";
    out += &sep;
    out += "\n";
    for row in rows {
        out += &fmt_row(row);
        out += "\n";
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_stats_accumulate_and_rate() {
        let mut s = EncodeCacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s += EncodeCacheStats { hits: 3, misses: 1 };
        s += EncodeCacheStats { hits: 1, misses: 0 };
        assert_eq!(s.total(), 5);
        assert!((s.hit_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn cache_stats_merge_by_ref_and_sum() {
        let a = EncodeCacheStats { hits: 2, misses: 3 };
        let b = EncodeCacheStats { hits: 5, misses: 1 };
        let mut m = a;
        m += &b; // by-ref AddAssign, mirroring OpCounts
        assert_eq!(m, EncodeCacheStats { hits: 7, misses: 4 });

        // Shard aggregation as one fold.
        let folded: EncodeCacheStats = [a, b, EncodeCacheStats::default()].into_iter().sum();
        assert_eq!(folded, m);
    }

    #[test]
    fn device_health_merges_max_ages_and_sums_counts() {
        let a = DeviceHealth {
            max_age_seconds: 100.0,
            est_conductance_loss: 0.01,
            injected_faults: 3,
            refreshes: 1,
        };
        let b = DeviceHealth {
            max_age_seconds: 40.0,
            est_conductance_loss: 0.04,
            injected_faults: 2,
            refreshes: 4,
        };
        let mut m = a;
        m += &b;
        assert_eq!(m.max_age_seconds, 100.0);
        assert_eq!(m.est_conductance_loss, 0.04);
        assert_eq!(m.injected_faults, 5);
        assert_eq!(m.refreshes, 5);

        let folded: DeviceHealth = [a, b, DeviceHealth::default()].into_iter().sum();
        assert_eq!(folded, m);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty slice: the front door's "no requests" case is 0, not a panic.
        assert_eq!(percentile_u64(&[], 0.0), 0);
        assert_eq!(percentile_u64(&[], 0.5), 0);
        assert_eq!(percentile_u64(&[], 1.0), 0);

        // Single element: every percentile is that element.
        for p in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_u64(&[7], p), 7, "p={p}");
        }

        // All-equal values: rank arithmetic can't matter.
        let eq = [5u64; 9];
        for p in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_u64(&eq, p), 5, "p={p}");
        }

        // p=0 is the minimum, p=1 the maximum; out-of-range p clamps.
        let sorted = [1u64, 2, 3, 4, 100];
        assert_eq!(percentile_u64(&sorted, 0.0), 1);
        assert_eq!(percentile_u64(&sorted, 1.0), 100);
        assert_eq!(percentile_u64(&sorted, -3.0), 1);
        assert_eq!(percentile_u64(&sorted, 2.0), 100);
        // Nearest-rank median of five.
        assert_eq!(percentile_u64(&sorted, 0.5), 3);
    }

    #[test]
    fn front_door_stats_merge_across_flush_batches() {
        let a = FrontDoorStats {
            requests: 100,
            batches: 4,
            size_flushes: 3,
            deadline_flushes: 1,
            backpressure_flushes: 0,
            drain_flushes: 0,
            max_queue_depth: 9,
            fill_target: 128,
            mean_fill_fraction: 0.5,
            p50_wait_ticks: 2,
            p99_wait_ticks: 10,
            max_wait_ticks: 12,
            maintain_calls: 2,
            refreshed_rows: 64,
        };
        let b = FrontDoorStats {
            requests: 50,
            batches: 1,
            size_flushes: 0,
            deadline_flushes: 0,
            backpressure_flushes: 1,
            drain_flushes: 1,
            max_queue_depth: 30,
            fill_target: 128,
            mean_fill_fraction: 1.0,
            p50_wait_ticks: 5,
            p99_wait_ticks: 8,
            max_wait_ticks: 40,
            maintain_calls: 0,
            refreshed_rows: 0,
        };
        let mut m = a.clone();
        m += &b;
        // Counters sum.
        assert_eq!(m.requests, 150);
        assert_eq!(m.batches, 5);
        assert_eq!(m.size_flushes, 3);
        assert_eq!(m.deadline_flushes, 1);
        assert_eq!(m.backpressure_flushes, 1);
        assert_eq!(m.drain_flushes, 1);
        assert_eq!(m.maintain_calls, 2);
        assert_eq!(m.refreshed_rows, 64);
        // Extrema max; percentiles take the pessimistic max per side.
        assert_eq!(m.max_queue_depth, 30);
        assert_eq!(m.max_wait_ticks, 40);
        assert_eq!(m.p50_wait_ticks, 5);
        assert_eq!(m.p99_wait_ticks, 10);
        // Batch-weighted mean fill: (0.5*4 + 1.0*1) / 5.
        assert!((m.mean_fill_fraction - 0.6).abs() < 1e-12);

        // Merging an empty (default) side is a no-op on the mean.
        let mut e = FrontDoorStats::default();
        e += &a;
        assert_eq!(e, a);

        // Sum folds the same way.
        let folded: FrontDoorStats = [a, b].into_iter().sum();
        assert_eq!(folded, m);
    }

    #[test]
    fn timer_accumulates() {
        let mut t = StageTimer::new();
        t.add("encode", 1.0);
        t.add("encode", 0.5);
        t.add("search", 2.5);
        assert_eq!(t.get("encode"), 1.5);
        assert_eq!(t.total(), 4.0);
        let b = t.breakdown();
        assert_eq!(b[0].0, "encode");
        assert!((b[0].2 - 0.375).abs() < 1e-12);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = StageTimer::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.get("work") >= 0.0);
    }

    #[test]
    fn json_records_render_and_escape() {
        let records = vec![
            vec![
                ("kernel", JsonField::S("seg\"mented\n".into())),
                ("threads", JsonField::U(4)),
                ("qps", JsonField::F(1234.5)),
                ("ok", JsonField::B(true)),
            ],
            vec![("qps", JsonField::F(f64::NAN))],
        ];
        let s = render_json_records(&records);
        assert!(s.starts_with("[\n"));
        assert!(s.ends_with(']'));
        assert!(s.contains("\"kernel\": \"seg\\\"mented\\n\""), "{s}");
        assert!(s.contains("\"threads\": 4"));
        assert!(s.contains("\"qps\": 1234.5"));
        assert!(s.contains("\"ok\": true"));
        // Non-finite floats become null, never an unparsable token.
        assert!(s.contains("\"qps\": null"));
        // Exactly one comma between the two records.
        assert_eq!(s.matches("},").count(), 1);

        assert_eq!(render_json_records(&[]), "[\n]");
    }

    #[test]
    fn table_renders_all_rows() {
        let s = render_table(
            "T",
            &["tool", "latency"],
            &[
                vec!["falcon".into(), "573s".into()],
                vec!["specpcm".into(), "5.46s".into()],
            ],
        );
        assert!(s.contains("falcon"));
        assert!(s.contains("specpcm"));
        assert!(s.contains("== T =="));
    }
}
