//! PCM device models (paper §III-E, Fig. 7, Table S1, supplementary S.B).
//!
//! Two superlattice material stacks are modeled with the paper's measured
//! parameters: Sb2Te3/Ge4Sb6Te7 (low programming energy — used for the
//! write-intensive clustering arrays) and TiTe2/Ge4Sb6Te7 (long retention,
//! low error rate — used for the read-intensive DB-search arrays).
//!
//! Noise follows the supplementary protocol: a programmed weight W is read
//! back as `W_hat = W * (1 + eta)` with `eta ~ N(0, sigma^2)`; sigma is
//! derived from the bit-error-rate curve measured against write-verify
//! cycles (Fig. 7) and the MLC level spacing.

pub mod material;
pub mod mlc;
pub mod noise;
pub mod drift;
pub mod fault;
pub mod programming;

pub use material::{Material, MaterialParams};
pub use mlc::MlcConfig;
pub use noise::NoiseModel;
pub use drift::DriftModel;
pub use fault::FaultModel;
pub use programming::{ProgramOutcome, Programmer};
