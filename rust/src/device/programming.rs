//! Cell programming with write-verify (paper §III-C "Programming" and
//! §III-D "Write-verify cycles").
//!
//! Each write-verify cycle reads the cell back, compares against the
//! target level and applies a corrective pulse when outside tolerance
//! (higher-amplitude pulse if under-programmed, iterative pulse otherwise).
//! Here the *outcome* distribution is taken from the calibrated
//! [`NoiseModel`] (which inverts the measured Fig. 7 BER curve), while the
//! pulse count — which determines energy and latency — follows the
//! iterative procedure.

use super::fault::FaultModel;
use super::mlc::MlcConfig;
use super::noise::NoiseModel;
use crate::util::Rng;

/// Result of programming one packed value into a 2T2R pair.
#[derive(Clone, Copy, Debug)]
pub struct ProgramOutcome {
    /// Conductance difference actually stored (after residual error).
    pub stored: f32,
    /// Total programming pulses issued (1 initial + corrective pulses).
    pub pulses: u32,
    /// Verify reads performed (== write_verify cycles requested).
    pub verify_reads: u32,
}

/// Programs packed values with a configured number of write-verify cycles.
#[derive(Clone, Debug)]
pub struct Programmer {
    pub noise: NoiseModel,
    pub write_verify: u32,
    /// Cell fault injection applied after each cell's pulse train
    /// (disabled by default; see [`FaultModel`] for the draw discipline).
    pub fault: FaultModel,
    /// Precomputed sigma(k) for k = 0..=write_verify. `NoiseModel::sigma`
    /// inverts the BER fit by bisection (hundreds of erfc evaluations);
    /// caching it here took programming from ~87% of the clustering
    /// pipeline's host time to noise level (EXPERIMENTS.md §Perf).
    sigma_table: Vec<f64>,
}

impl Programmer {
    pub fn new(noise: NoiseModel, write_verify: u32) -> Self {
        let sigma_table = (0..=write_verify).map(|k| noise.sigma(k)).collect();
        Programmer {
            noise,
            write_verify,
            fault: FaultModel::disabled(),
            sigma_table,
        }
    }

    /// Builder: enable fault injection on every subsequent programming
    /// event (applied per cell, after that cell's noise draws, so the
    /// RNG interleave is fixed per cell regardless of row/shard splits).
    pub fn with_faults(mut self, fault: FaultModel) -> Self {
        self.fault = fault;
        self
    }

    /// Residual multiplicative sigma after the configured verify cycles.
    #[inline]
    pub fn residual_sigma(&self) -> f64 {
        self.sigma_table[self.write_verify as usize]
    }

    /// Program a single packed value.
    ///
    /// The corrective-pulse count is sampled from the same convergence
    /// process the BER fit models: after cycle k the residual sigma is
    /// `sigma(k)`, and a corrective pulse fires whenever the current
    /// readback misses the half-spacing tolerance.
    pub fn program(&self, target: f32, rng: &mut Rng) -> ProgramOutcome {
        let mlc: MlcConfig = self.noise.mlc;
        debug_assert!(mlc.contains(target as i32), "target {target} out of MLC range");

        // Fast path shared by the clustering default (no write-verify):
        // exactly one pulse, one draw from sigma(0).
        if self.write_verify == 0 {
            return ProgramOutcome {
                stored: self.noise.noisy_weight(target, self.sigma_table[0], rng),
                pulses: 1,
                verify_reads: 0,
            };
        }

        let half = (mlc.level_spacing() / 2.0) as f32;
        let mut pulses = 1u32; // initial SET/RESET pulse
        let mut stored = self.noise.noisy_weight(target, self.sigma_table[0], rng);

        for k in 1..=self.write_verify {
            if (stored - target).abs() <= half * 0.5 {
                // Within tight tolerance: verify passes, no more pulses.
                break;
            }
            // Corrective pulse narrows the distribution to sigma(k).
            stored = self.noise.noisy_weight(target, self.sigma_table[k as usize], rng);
            pulses += 1;
        }

        // Whatever the pulse trajectory, the *ensemble* statistics of the
        // final state follow the calibrated residual sigma; resample from
        // it so downstream accuracy only depends on the Fig. 7 fit.
        let stored = self
            .noise
            .noisy_weight(target, self.residual_sigma(), rng);

        ProgramOutcome {
            stored,
            pulses,
            verify_reads: self.write_verify,
        }
    }

    /// Program a full row/segment; returns stored values plus total pulse,
    /// verify-read, and injected-fault counts for the energy model and
    /// health telemetry. Fault draws interleave per cell — one uniform
    /// draw after each cell's noise draws when the model is active, zero
    /// draws when disabled — so per-row RNG consumption is identical
    /// whether rows are programmed monolithically or shard by shard.
    pub fn program_slice(&self, targets: &[f32], rng: &mut Rng) -> (Vec<f32>, u64, u64, u64) {
        let mut stored = Vec::with_capacity(targets.len());
        let (mut pulses, mut reads, mut faults) = (0u64, 0u64, 0u64);
        for &t in targets {
            let o = self.program(t, rng);
            let v = match self.fault.apply(rng) {
                Some(faulty) => {
                    faults += 1;
                    faulty
                }
                None => o.stored,
            };
            stored.push(v);
            pulses += o.pulses as u64;
            reads += o.verify_reads as u64;
        }
        (stored, pulses, reads, faults)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Material, MlcConfig};

    fn programmer(wv: u32) -> Programmer {
        Programmer::new(
            NoiseModel::new(Material::TiTe2Gst467, MlcConfig::new(3)),
            wv,
        )
    }

    #[test]
    fn zero_write_verify_single_pulse() {
        let p = programmer(0);
        let mut rng = Rng::new(1);
        let o = p.program(3.0, &mut rng);
        assert_eq!(o.pulses, 1);
        assert_eq!(o.verify_reads, 0);
    }

    #[test]
    fn more_verify_cycles_tighter_distribution() {
        let mut rng = Rng::new(2);
        let spread = |wv: u32, rng: &mut Rng| -> f64 {
            let p = programmer(wv);
            let n = 20_000;
            let mut sq = 0.0;
            for _ in 0..n {
                let o = p.program(3.0, rng);
                let e = (o.stored - 3.0) as f64;
                sq += e * e;
            }
            (sq / n as f64).sqrt()
        };
        let s0 = spread(0, &mut rng);
        let s3 = spread(3, &mut rng);
        let s6 = spread(6, &mut rng);
        assert!(s0 > s3 && s3 > s6, "{s0} {s3} {s6}");
    }

    #[test]
    fn pulse_count_bounded_by_cycles() {
        let p = programmer(5);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let o = p.program(-3.0, &mut rng);
            assert!(o.pulses >= 1 && o.pulses <= 6);
        }
    }

    #[test]
    fn program_slice_accounting() {
        let p = programmer(2);
        let mut rng = Rng::new(4);
        let targets = vec![3.0, -1.0, 0.0, 1.0, -3.0];
        let (stored, pulses, reads, faults) = p.program_slice(&targets, &mut rng);
        assert_eq!(stored.len(), 5);
        assert!(pulses >= 5);
        assert_eq!(reads, 10); // 2 verify reads per value
        assert_eq!(faults, 0); // model disabled by default
        assert_eq!(stored[2], 0.0); // differential zero preserved
    }

    #[test]
    fn disabled_faults_leave_stream_and_values_byte_identical() {
        let p = programmer(3);
        let q = programmer(3).with_faults(FaultModel::disabled());
        let targets = vec![3.0, -2.0, 0.0, 1.0];
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = p.program_slice(&targets, &mut r1);
        let b = q.program_slice(&targets, &mut r2);
        assert_eq!(a, b);
        assert_eq!(r1.next_u64(), r2.next_u64(), "stream positions diverged");
    }

    #[test]
    fn certain_program_failure_zeroes_every_cell() {
        let p = programmer(2).with_faults(FaultModel::new(0.0, 1.0, 3.0));
        let mut rng = Rng::new(6);
        let targets = vec![3.0, -3.0, 1.0];
        let (stored, _, _, faults) = p.program_slice(&targets, &mut rng);
        assert_eq!(stored, vec![0.0, 0.0, 0.0]);
        assert_eq!(faults, 3);
    }

    #[test]
    fn stuck_at_pins_cells_to_stuck_g() {
        let p = programmer(0).with_faults(FaultModel::new(1.0, 0.0, 2.0));
        let mut rng = Rng::new(7);
        let (stored, _, _, faults) = p.program_slice(&[-3.0, 3.0], &mut rng);
        assert_eq!(stored, vec![2.0, 2.0]);
        assert_eq!(faults, 2);
    }

    #[test]
    fn fault_draws_interleave_per_cell_across_row_splits() {
        // Programming [a, b] in one slice call must equal programming [a]
        // then [b] with the same live RNG — the property the sharded
        // chained-stream contract rests on, now with fault draws in the
        // stream.
        let p = programmer(3).with_faults(FaultModel::new(0.2, 0.1, 3.0));
        let targets = vec![3.0, -1.0, 2.0, -3.0];
        let mut whole_rng = Rng::new(8);
        let whole = p.program_slice(&targets, &mut whole_rng);
        let mut split_rng = Rng::new(8);
        let first = p.program_slice(&targets[..2], &mut split_rng);
        let second = p.program_slice(&targets[2..], &mut split_rng);
        let mut stored = first.0.clone();
        stored.extend_from_slice(&second.0);
        assert_eq!(whole.0, stored);
        assert_eq!(whole.1, first.1 + second.1);
        assert_eq!(whole.3, first.3 + second.3);
        assert_eq!(whole_rng.next_u64(), split_rng.next_u64());
    }
}
