//! Superlattice PCM material parameters (paper Table S1, measured).



/// The two nanocomposite-superlattice stacks characterized in the paper
/// (both on Ge4Sb6Te7 with 40 nm TiN bottom electrodes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Material {
    /// Sb2Te3 / Ge4Sb6Te7 — lower programming current/energy, shorter
    /// retention. The paper assigns this stack to the **clustering** arrays
    /// whose contents are rewritten every merge iteration.
    Sb2Te3Gst467,
    /// TiTe2 / Ge4Sb6Te7 — 2.6x higher programming energy but >1e5 h
    /// retention at 105C and lower error rate. Assigned to the **DB-search**
    /// arrays which are programmed once and read intensively.
    TiTe2Gst467,
}

/// Measured device parameters, straight from Table S1.
#[derive(Clone, Copy, Debug)]
pub struct MaterialParams {
    /// Programming current (µA).
    pub prog_current_ua: f64,
    /// Programming voltage (V). The paper quotes 0.65–0.8 V (Sb2Te3) and
    /// 0.85–1.0 V (TiTe2) with higher voltages for higher resistance
    /// levels; this is the Table S1 nominal point.
    pub prog_voltage_v: f64,
    /// Energy of one programming pulse (pJ).
    pub prog_energy_pj: f64,
    /// Retention at 105C (hours).
    pub retention_105c_h: f64,
    /// Low resistance state (kOhm).
    pub lrs_kohm: f64,
    /// Resistance on/off ratio.
    pub on_off_ratio: f64,
    /// Endurance (program/erase cycles); §III-E: both stacks exceed 1e8.
    pub endurance_cycles: f64,
    /// Resistance-drift exponent nu in R(t) = R0 (t/t0)^nu. Superlattice
    /// stacks show strongly reduced drift vs. conventional GST [30]; the
    /// TiTe2 stack is the more stable of the two (model fit, see DESIGN.md
    /// §5 substitution table).
    pub drift_nu: f64,
    /// Bit-error-rate curve vs write-verify cycles for 3-bit MLC
    /// (Fig. 7 fit): `ber(w) = floor + (ber0 - floor) * exp(-k * w)`.
    pub ber0: f64,
    pub ber_floor: f64,
    pub ber_decay_k: f64,
}

impl Material {
    pub const ALL: [Material; 2] = [Material::Sb2Te3Gst467, Material::TiTe2Gst467];

    pub fn params(self) -> MaterialParams {
        match self {
            Material::Sb2Te3Gst467 => MaterialParams {
                prog_current_ua: 80.0,
                prog_voltage_v: 0.7,
                prog_energy_pj: 1.12,
                retention_105c_h: 30.0,
                lrs_kohm: 30.0,
                on_off_ratio: 150.0,
                endurance_cycles: 1e8,
                drift_nu: 0.02,
                ber0: 0.15,
                ber_floor: 0.015,
                ber_decay_k: 0.55,
            },
            Material::TiTe2Gst467 => MaterialParams {
                prog_current_ua: 160.0,
                prog_voltage_v: 0.9,
                prog_energy_pj: 2.88,
                retention_105c_h: 1e5,
                lrs_kohm: 10.0,
                on_off_ratio: 100.0,
                endurance_cycles: 1e8,
                drift_nu: 0.005,
                ber0: 0.12,
                ber_floor: 0.008,
                ber_decay_k: 0.6,
            },
        }
    }

    /// The task assignment the paper makes in §III-E.
    pub fn default_for_clustering() -> Material {
        Material::Sb2Te3Gst467
    }

    pub fn default_for_search() -> Material {
        Material::TiTe2Gst467
    }

    pub fn name(self) -> &'static str {
        match self {
            Material::Sb2Te3Gst467 => "Sb2Te3/Ge4Sb6Te7",
            Material::TiTe2Gst467 => "TiTe2/Ge4Sb6Te7",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_s1_values() {
        let sb = Material::Sb2Te3Gst467.params();
        assert_eq!(sb.prog_current_ua, 80.0);
        assert_eq!(sb.prog_voltage_v, 0.7);
        assert_eq!(sb.prog_energy_pj, 1.12);
        assert_eq!(sb.retention_105c_h, 30.0);
        assert_eq!(sb.lrs_kohm, 30.0);
        assert_eq!(sb.on_off_ratio, 150.0);

        let ti = Material::TiTe2Gst467.params();
        assert_eq!(ti.prog_current_ua, 160.0);
        assert_eq!(ti.prog_voltage_v, 0.9);
        assert_eq!(ti.prog_energy_pj, 2.88);
        assert_eq!(ti.retention_105c_h, 1e5);
        assert_eq!(ti.lrs_kohm, 10.0);
        assert_eq!(ti.on_off_ratio, 100.0);
    }

    #[test]
    fn tite2_costs_2_6x_energy() {
        // §III-E: "at the cost of 2.6x higher programming energy".
        let ratio = Material::TiTe2Gst467.params().prog_energy_pj
            / Material::Sb2Te3Gst467.params().prog_energy_pj;
        assert!((ratio - 2.57).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn task_assignment_matches_paper() {
        assert_eq!(Material::default_for_clustering(), Material::Sb2Te3Gst467);
        assert_eq!(Material::default_for_search(), Material::TiTe2Gst467);
    }

    #[test]
    fn tite2_lower_error_floor() {
        let sb = Material::Sb2Te3Gst467.params();
        let ti = Material::TiTe2Gst467.params();
        assert!(ti.ber_floor < sb.ber_floor);
        assert!(ti.drift_nu < sb.drift_nu);
    }
}
