//! Multi-level-cell configuration and packed-value <-> level mapping.
//!
//! Dimension packing (§III-B) sums `n` adjacent +/-1 elements, so a packed
//! value lies in `{-n, ..., +n}`. One 2T2R differential pair stores it as
//! the conductance difference G+ - G-; with `n` bits per cell each leg
//! resolves `2^n` levels, exactly covering the packed alphabet.



/// Bits per PCM cell (1 = SLC, 2 = MLC2, 3 = MLC3 — the paper's sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlcConfig {
    pub bits_per_cell: u8,
}

impl MlcConfig {
    pub fn new(bits_per_cell: u8) -> Self {
        assert!(
            (1..=4).contains(&bits_per_cell),
            "bits_per_cell must be 1..=4, got {bits_per_cell}"
        );
        MlcConfig { bits_per_cell }
    }

    /// The packing factor n equals bits per cell (§III-B).
    #[inline]
    pub fn packing(self) -> usize {
        self.bits_per_cell as usize
    }

    /// Conductance levels resolvable per cell leg.
    #[inline]
    pub fn levels(self) -> usize {
        1 << self.bits_per_cell
    }

    /// Largest |packed value| a differential pair must represent.
    #[inline]
    pub fn max_abs_value(self) -> i32 {
        self.bits_per_cell as i32
    }

    /// All representable packed values. Full groups of n +/-1 elements have
    /// parity n; zero-padded remainder groups can produce the in-between
    /// parities too, so the full alphabet is every integer in [-n, n].
    pub fn alphabet(self) -> Vec<i32> {
        let n = self.max_abs_value();
        (-n..=n).collect()
    }

    /// Validate that a packed value is representable.
    #[inline]
    pub fn contains(self, v: i32) -> bool {
        v.abs() <= self.max_abs_value()
    }

    /// Normalized distance between adjacent *occupied* packed levels of
    /// full groups ({-n, -n+2, ...}), used by the noise model to convert a
    /// bit-error rate into a conductance sigma.
    #[inline]
    pub fn level_spacing(self) -> f64 {
        2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_mlc_levels() {
        assert_eq!(MlcConfig::new(1).levels(), 2);
        assert_eq!(MlcConfig::new(2).levels(), 4);
        assert_eq!(MlcConfig::new(3).levels(), 8);
    }

    #[test]
    fn packing_equals_bits() {
        for b in 1..=4u8 {
            assert_eq!(MlcConfig::new(b).packing(), b as usize);
        }
    }

    #[test]
    fn alphabet_bounds() {
        let a = MlcConfig::new(3).alphabet();
        assert_eq!(*a.first().unwrap(), -3);
        assert_eq!(*a.last().unwrap(), 3);
        assert!(MlcConfig::new(3).contains(0));
        assert!(!MlcConfig::new(3).contains(4));
    }

    #[test]
    #[should_panic(expected = "bits_per_cell")]
    fn rejects_zero_bits() {
        MlcConfig::new(0);
    }
}
