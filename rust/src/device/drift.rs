//! Resistance drift (supplementary S.B + [30]).
//!
//! PCM resistance drifts as a power law `R(t) = R0 * (t/t0)^nu`; the
//! superlattice stacks used here have strongly reduced, interface-controlled
//! drift. The conductance (what the IMC MVM reads) correspondingly decays as
//! `G(t) = G0 * (t/t0)^-nu`. The DB-search pipeline applies this to stored
//! reference conductances as storage ages; clustering arrays are rewritten
//! every iteration so drift is negligible there (paper §III-E).

use super::material::Material;

/// Power-law drift model with the conventional t0 = 1 s reference.
#[derive(Clone, Copy, Debug)]
pub struct DriftModel {
    pub nu: f64,
}

impl DriftModel {
    pub fn for_material(material: Material) -> Self {
        DriftModel {
            nu: material.params().drift_nu,
        }
    }

    /// Multiplicative conductance factor after `t_seconds` (t >= t0 = 1 s).
    pub fn conductance_factor(&self, t_seconds: f64) -> f64 {
        let t = t_seconds.max(1.0);
        t.powf(-self.nu)
    }

    /// Apply drift to a stored packed weight.
    pub fn drifted(&self, w: f32, t_seconds: f64) -> f32 {
        (w as f64 * self.conductance_factor(t_seconds)) as f32
    }

    /// Age a whole stored row/segment at once, bit-identical to calling
    /// [`Self::drifted`] per element but with the `powf` behind
    /// [`Self::conductance_factor`] hoisted to one evaluation per call —
    /// the shape the engine's serving-panel rebuild needs (one factor per
    /// equal-age row, `cp` multiplies).
    ///
    /// At `t_seconds <= 1.0` the factor is exactly `1.0`, and
    /// `(w as f64 * 1.0) as f32` round-trips every finite f32 bit-exactly,
    /// so a zero-age rebuild reproduces the stored panel byte for byte.
    pub fn drift_slice_into(&self, ws: &[f32], t_seconds: f64, out: &mut [f32]) {
        assert_eq!(ws.len(), out.len(), "drift_slice_into length mismatch");
        let factor = self.conductance_factor(t_seconds);
        for (o, &w) in out.iter_mut().zip(ws) {
            *o = (w as f64 * factor) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_drift_at_t0() {
        let d = DriftModel::for_material(Material::TiTe2Gst467);
        assert_eq!(d.conductance_factor(1.0), 1.0);
    }

    #[test]
    fn drift_monotone_decreasing() {
        let d = DriftModel::for_material(Material::Sb2Te3Gst467);
        let f1 = d.conductance_factor(10.0);
        let f2 = d.conductance_factor(1000.0);
        let f3 = d.conductance_factor(1e6);
        assert!(f1 > f2 && f2 > f3);
        assert!(f3 > 0.0);
    }

    #[test]
    fn superlattice_drift_is_small() {
        // After a day, the TiTe2 stack loses well under 1% conductance —
        // consistent with the paper's "reduced resistance drift" claim
        // enabling stable MLC.
        let d = DriftModel::for_material(Material::TiTe2Gst467);
        let day = 86_400.0;
        assert!(d.conductance_factor(day) > 0.93);
    }

    #[test]
    fn tite2_drifts_less_than_sb2te3() {
        let ti = DriftModel::for_material(Material::TiTe2Gst467);
        let sb = DriftModel::for_material(Material::Sb2Te3Gst467);
        let t = 3600.0;
        assert!(ti.conductance_factor(t) > sb.conductance_factor(t));
    }

    #[test]
    fn slice_aging_matches_per_weight_drifted() {
        let d = DriftModel::for_material(Material::Sb2Te3Gst467);
        let ws: Vec<f32> = vec![3.0, -3.0, 0.0, 1.5, -0.25, 2.0];
        for t in [0.0, 1.0, 3600.0, 1e9] {
            let mut out = vec![f32::NAN; ws.len()];
            d.drift_slice_into(&ws, t, &mut out);
            for (o, &w) in out.iter().zip(&ws) {
                assert_eq!(o.to_bits(), d.drifted(w, t).to_bits(), "t={t}");
            }
        }
    }

    #[test]
    fn zero_age_slice_is_byte_identical_and_zero_stays_zero() {
        let d = DriftModel::for_material(Material::TiTe2Gst467);
        let ws: Vec<f32> = vec![1.0, -2.5, 0.0, -0.0, 3.0];
        let mut out = vec![f32::NAN; ws.len()];
        d.drift_slice_into(&ws, 0.0, &mut out);
        for (o, w) in out.iter().zip(&ws) {
            assert_eq!(o.to_bits(), w.to_bits());
        }
        // Differential zero survives any horizon.
        d.drift_slice_into(&ws, 1e12, &mut out);
        assert_eq!(out[2], 0.0);
    }
}
