//! Cell fault injection at programming time (robustness extension beyond
//! the paper's fault-free storage assumption).
//!
//! Two endurance fault classes are modeled, both manifesting when a cell
//! is (re-)programmed:
//!
//! * **program failure** — the SET/RESET pulse train fails to move the
//!   cell and the differential pair reads back as 0 (no stored weight);
//! * **stuck-at-G** — the cell is pinned at a fixed conductance
//!   `stuck_g` regardless of the target (e.g. a shorted or saturated
//!   device).
//!
//! Faults are drawn from the same chained noise-RNG stream as programming
//! noise, **one `uniform()` draw per cell, unconditionally, whenever the
//! model is active** — never data-dependent — so a monolithic engine and
//! a sharded one consume identical per-row draw counts and stay
//! bit-identical (contract C4-RNG). With the model disabled (the default)
//! zero draws are consumed, which is what makes faults-off serving
//! byte-identical to a pre-fault-model engine.

use crate::util::Rng;

/// Per-programming-event fault injection rates. Rates are probabilities
/// per cell per programming event; a refreshed cell re-rolls its faults
/// (transient endurance failures, not permanent defect maps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultModel {
    /// Probability a programmed cell sticks at `stuck_g`.
    pub stuck_at_rate: f64,
    /// Probability the pulse train fails and the cell stores 0.
    pub program_fail_rate: f64,
    /// Conductance a stuck cell reads back as (packed-weight units).
    pub stuck_g: f32,
}

impl Default for FaultModel {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultModel {
    /// No faults, no RNG draws — the bit-compatible default.
    pub fn disabled() -> Self {
        FaultModel {
            stuck_at_rate: 0.0,
            program_fail_rate: 0.0,
            stuck_g: 3.0,
        }
    }

    pub fn new(stuck_at_rate: f64, program_fail_rate: f64, stuck_g: f32) -> Self {
        FaultModel {
            stuck_at_rate,
            program_fail_rate,
            stuck_g,
        }
    }

    /// Whether any fault class can fire (and thus whether programming
    /// consumes fault draws).
    pub fn is_active(&self) -> bool {
        self.stuck_at_rate > 0.0 || self.program_fail_rate > 0.0
    }

    /// Roll the fault outcome for one just-programmed cell. Consumes
    /// exactly one draw when active, zero when disabled. Returns the
    /// faulty stored value, or `None` when the cell programs cleanly.
    pub fn apply(&self, rng: &mut Rng) -> Option<f32> {
        if !self.is_active() {
            return None;
        }
        let u = rng.uniform();
        if u < self.program_fail_rate {
            Some(0.0)
        } else if u < self.program_fail_rate + self.stuck_at_rate {
            Some(self.stuck_g)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_draws_nothing() {
        let f = FaultModel::disabled();
        assert!(!f.is_active());
        let mut rng = Rng::new(7);
        let before = rng.next_u64();
        let mut rng2 = Rng::new(7);
        let _ = rng2.next_u64();
        assert_eq!(f.apply(&mut rng2), None);
        // The stream is untouched: the next draw matches a fresh clone.
        let mut rng3 = Rng::new(7);
        let _ = rng3.next_u64();
        assert_eq!(rng2.next_u64(), rng3.next_u64());
        let _ = before;
    }

    #[test]
    fn active_model_draws_exactly_once_per_apply() {
        let f = FaultModel::new(0.1, 0.1, 3.0);
        assert!(f.is_active());
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let _ = f.apply(&mut a);
        let _ = b.uniform();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fault_classes_fire_at_roughly_their_rates() {
        let f = FaultModel::new(0.05, 0.02, 3.0);
        let mut rng = Rng::new(11);
        let n = 100_000;
        let (mut stuck, mut failed) = (0u32, 0u32);
        for _ in 0..n {
            match f.apply(&mut rng) {
                Some(v) if v == 3.0 => stuck += 1,
                Some(_) => failed += 1,
                None => {}
            }
        }
        let stuck_rate = stuck as f64 / n as f64;
        let fail_rate = failed as f64 / n as f64;
        assert!((stuck_rate - 0.05).abs() < 0.005, "stuck {stuck_rate}");
        assert!((fail_rate - 0.02).abs() < 0.005, "fail {fail_rate}");
    }

    #[test]
    fn certain_failure_always_zeroes() {
        let f = FaultModel::new(0.0, 1.0, 3.0);
        let mut rng = Rng::new(13);
        for _ in 0..32 {
            assert_eq!(f.apply(&mut rng), Some(0.0));
        }
    }
}
