//! PCM read/programming noise model (supplementary "Noise model" + Fig. 7).
//!
//! The supplementary fits measured resistance distributions to a normal:
//! a stored weight W reads back as `W_hat = W * (1 + eta)`, `eta ~ N(0,
//! sigma^2)`. We connect sigma to the *measured* Fig. 7 bit-error-rate
//! curve: a level is misread when the multiplicative excursion crosses half
//! the packed-level spacing, i.e. for the outermost level `|W| = n`:
//! `BER(w) ~= 2 * Q( (spacing/2) / (n * sigma(w)) )`.
//!
//! Given the fitted `BER(write_verify_cycles)` per material we invert this
//! to `sigma(write_verify_cycles)`, which the programmer applies when a
//! cell is written.

use super::material::Material;
use super::mlc::MlcConfig;
use crate::util::Rng;

/// Standard normal tail function Q(x) = P(Z > x).
pub fn qfunc(x: f64) -> f64 {
    0.5 * erfc(x / std::f64::consts::SQRT_2)
}

/// Inverse of Q via bisection (monotone decreasing); |error| < 1e-10.
pub fn inv_qfunc(p: f64) -> f64 {
    assert!((0.0..0.5).contains(&p) || p == 0.5, "inv_qfunc domain: {p}");
    if p == 0.5 {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.0, 40.0);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if qfunc(mid) > p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// erfc via the Numerical-Recipes rational Chebyshev approximation
/// (|relative error| < 1.2e-7 — ample for a BER model).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Per-configuration noise model.
#[derive(Clone, Debug)]
pub struct NoiseModel {
    pub material: Material,
    pub mlc: MlcConfig,
}

impl NoiseModel {
    pub fn new(material: Material, mlc: MlcConfig) -> Self {
        NoiseModel { material, mlc }
    }

    /// Fig. 7 fit: bit error rate after `write_verify` cycles.
    pub fn ber(&self, write_verify: u32) -> f64 {
        let p = self.material.params();
        p.ber_floor + (p.ber0 - p.ber_floor) * (-p.ber_decay_k * write_verify as f64).exp()
    }

    /// Multiplicative sigma achieving `ber(write_verify)` on the outermost
    /// MLC level (the worst case that dominates the measured BER).
    pub fn sigma(&self, write_verify: u32) -> f64 {
        let ber = self.ber(write_verify);
        let half_spacing = self.mlc.level_spacing() / 2.0;
        let n = self.mlc.max_abs_value() as f64;
        half_spacing / (n * inv_qfunc(ber / 2.0))
    }

    /// Apply programming noise to an ideal packed weight.
    #[inline]
    pub fn noisy_weight(&self, w: f32, sigma: f64, rng: &mut Rng) -> f32 {
        if w == 0.0 {
            // Both legs of the 2T2R pair at the same level: differential
            // zero is preserved (common-mode noise cancels at the BL pair).
            0.0
        } else {
            w * (1.0 + (sigma * rng.gaussian()) as f32)
        }
    }

    /// Empirical BER of a (value, noisy read) ensemble — used by the Fig. 7
    /// bench to confirm the round-trip sigma -> BER matches the fit.
    pub fn empirical_ber(&self, write_verify: u32, trials: usize, rng: &mut Rng) -> f64 {
        let sigma = self.sigma(write_verify);
        let n = self.mlc.max_abs_value() as f32;
        let half = (self.mlc.level_spacing() / 2.0) as f32;
        let mut errors = 0usize;
        for _ in 0..trials {
            let w = n; // outermost level, as in the sigma derivation
            let w_hat = self.noisy_weight(w, sigma, rng);
            if (w_hat - w).abs() > half {
                errors += 1;
            }
        }
        errors as f64 / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qfunc_known_points() {
        // erfc approximation is good to ~1.2e-7 relative.
        assert!((qfunc(0.0) - 0.5).abs() < 1e-6);
        assert!((qfunc(1.0) - 0.158655).abs() < 1e-5);
        assert!((qfunc(2.0) - 0.022750).abs() < 1e-5);
        assert!((qfunc(3.0) - 0.001350).abs() < 1e-5);
    }

    #[test]
    fn inv_qfunc_roundtrip() {
        for &p in &[0.4, 0.1, 0.05, 0.01, 0.001] {
            let x = inv_qfunc(p);
            assert!((qfunc(x) - p).abs() < 1e-8, "p={p}");
        }
    }

    #[test]
    fn ber_decreases_with_write_verify() {
        // Fig. 7: BER falls monotonically with write-verify cycles.
        for m in Material::ALL {
            let nm = NoiseModel::new(m, MlcConfig::new(3));
            let mut last = f64::INFINITY;
            for w in 0..8 {
                let b = nm.ber(w);
                assert!(b < last, "material {m:?} cycle {w}");
                last = b;
            }
            assert!(nm.ber(0) > 0.1, "starts above 10% (paper §II-C)");
            assert!(nm.ber(20) < 0.02, "approaches the floor");
        }
    }

    #[test]
    fn sigma_monotone_in_write_verify() {
        let nm = NoiseModel::new(Material::TiTe2Gst467, MlcConfig::new(3));
        assert!(nm.sigma(0) > nm.sigma(3));
        assert!(nm.sigma(3) > nm.sigma(10));
    }

    #[test]
    fn empirical_ber_matches_fit() {
        let nm = NoiseModel::new(Material::TiTe2Gst467, MlcConfig::new(3));
        let mut rng = Rng::new(1234);
        for wv in [0, 3] {
            let emp = nm.empirical_ber(wv, 200_000, &mut rng);
            let fit = nm.ber(wv);
            assert!(
                (emp - fit).abs() / fit < 0.1,
                "wv={wv}: empirical {emp} vs fit {fit}"
            );
        }
    }

    #[test]
    fn zero_weight_stays_zero() {
        let nm = NoiseModel::new(Material::TiTe2Gst467, MlcConfig::new(3));
        let mut rng = Rng::new(1);
        assert_eq!(nm.noisy_weight(0.0, 0.5, &mut rng), 0.0);
    }
}
