//! Spectral clustering (paper Fig. 1, §III-C "IMC for clustering").
//!
//! Within each precursor bucket, pairwise HV distances come from the IMC
//! MVM; the near-memory ASIC then runs complete-linkage agglomerative
//! merging until a distance threshold, exactly the HyperSpec-style flow
//! the paper accelerates.

pub mod linkage;
pub mod quality;

pub use linkage::{complete_linkage, Dendrogram, Merge};
pub use quality::{quality_curve, ClusterQuality};

/// Convert an IMC similarity score into a normalized distance in [0, 2]:
/// `d = 1 - score / d_max` where `d_max` is the self-similarity scale
/// (binary dimension D for exact HD scores; the same packed self-score
/// scale for packed scores).
pub fn score_to_distance(score: f32, d_max: f32) -> f32 {
    1.0 - score / d_max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_range() {
        assert_eq!(score_to_distance(2048.0, 2048.0), 0.0); // identical
        assert_eq!(score_to_distance(0.0, 2048.0), 1.0); // orthogonal
        assert_eq!(score_to_distance(-2048.0, 2048.0), 2.0); // opposite
    }
}
