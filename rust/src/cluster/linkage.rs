//! Complete-linkage hierarchical agglomerative clustering (§III-C: "The
//! ASIC employs the complete linkage method, where the maximum distance
//! between one element from each of two clusters determines the distance
//! between the clusters").
//!
//! Implemented with the standard O(N^2) nearest-neighbor-chain-free update
//! (Lance–Williams for complete linkage: `d(k, i∪j) = max(d(k,i), d(k,j))`)
//! over a condensed distance matrix — the same matrix the PCM arrays
//! produce and the near-memory ASIC updates in place.

/// One merge step: clusters `a` and `b` (indices into the current forest)
/// joined at `distance`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Merge {
    pub a: usize,
    pub b: usize,
    pub distance: f32,
}

/// Full merge history; cutting it at a threshold yields flat clusters.
#[derive(Clone, Debug)]
pub struct Dendrogram {
    pub n: usize,
    pub merges: Vec<Merge>,
    /// Total distance-matrix element updates performed (ASIC merge work —
    /// feeds `OpCounts::merge_elements`).
    pub update_elements: u64,
}

impl Dendrogram {
    /// Flat clusters from cutting all merges with distance <= threshold.
    /// Returns a label per item (labels are arbitrary but consistent).
    pub fn cut(&self, threshold: f32) -> Vec<usize> {
        let mut parent: Vec<usize> = (0..self.n).collect();
        fn find(parent: &mut Vec<usize>, x: usize) -> usize {
            let mut root = x;
            while parent[root] != root {
                root = parent[root];
            }
            let mut cur = x;
            while parent[cur] != root {
                let next = parent[cur];
                parent[cur] = root;
                cur = next;
            }
            root
        }
        for m in &self.merges {
            if m.distance <= threshold {
                let (ra, rb) = (find(&mut parent, m.a), find(&mut parent, m.b));
                if ra != rb {
                    parent[ra] = rb;
                }
            }
        }
        // Relabel roots densely.
        let mut labels = vec![usize::MAX; self.n];
        let mut next = 0;
        for i in 0..self.n {
            let r = find(&mut parent, i);
            if labels[r] == usize::MAX {
                labels[r] = next;
                next += 1;
            }
            labels[i] = labels[r];
        }
        labels
    }
}

/// Run complete-linkage HAC over a dense symmetric distance matrix
/// (row-major `n x n`, only the upper triangle is read). Merging stops when
/// the smallest remaining inter-cluster distance exceeds `max_distance`
/// (pass `f32::INFINITY` for a full dendrogram).
pub fn complete_linkage(dist: &[f32], n: usize, max_distance: f32) -> Dendrogram {
    assert_eq!(dist.len(), n * n, "distance matrix shape");
    if n == 0 {
        return Dendrogram {
            n,
            merges: vec![],
            update_elements: 0,
        };
    }

    // Working copy: d[i][j] for active clusters; usize::MAX marks merged-
    // away clusters. Item i starts as cluster i.
    let mut d = dist.to_vec();
    let mut active: Vec<bool> = vec![true; n];
    let mut merges = Vec::with_capacity(n - 1);
    let mut updates = 0u64;

    loop {
        // Find the closest active pair.
        let mut best = (usize::MAX, usize::MAX, f32::INFINITY);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..n {
                if !active[j] {
                    continue;
                }
                let dij = d[i * n + j];
                if dij < best.2 {
                    best = (i, j, dij);
                }
            }
        }
        let (i, j, dij) = best;
        if i == usize::MAX || dij > max_distance {
            break;
        }

        // Merge j into i (complete linkage: max).
        for k in 0..n {
            if active[k] && k != i && k != j {
                let dik = d[i * n + k];
                let djk = d[j * n + k];
                let m = dik.max(djk);
                d[i * n + k] = m;
                d[k * n + i] = m;
                updates += 1;
            }
        }
        active[j] = false;
        merges.push(Merge {
            a: i,
            b: j,
            distance: dij,
        });

        if merges.len() == n - 1 {
            break;
        }
    }

    Dendrogram {
        n,
        merges,
        update_elements: updates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Distance matrix from 1-D points (abs difference).
    fn dist_1d(points: &[f32]) -> Vec<f32> {
        let n = points.len();
        let mut d = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                d[i * n + j] = (points[i] - points[j]).abs();
            }
        }
        d
    }

    #[test]
    fn two_obvious_groups() {
        // {0.0, 0.1, 0.2} and {10.0, 10.1}
        let pts = [0.0, 0.1, 0.2, 10.0, 10.1];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 5, f32::INFINITY);
        let labels = dend.cut(1.0);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn complete_linkage_uses_max() {
        // Points 0, 1, 2.1: single linkage would chain all three below
        // threshold 1.2; complete linkage keeps {0,1} apart from 2.1
        // because max(d(0,2.1)) = 2.1 > 1.2.
        let pts = [0.0, 1.0, 2.1];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 3, f32::INFINITY);
        let labels = dend.cut(1.2);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[0], labels[2]);
    }

    #[test]
    fn max_distance_stops_merging() {
        let pts = [0.0, 0.1, 5.0];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 3, 1.0);
        assert_eq!(dend.merges.len(), 1); // only the close pair merges
    }

    #[test]
    fn merge_distances_monotone_nondecreasing() {
        let pts = [0.0, 0.3, 1.0, 1.1, 4.0, 4.05, 9.0];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 7, f32::INFINITY);
        assert_eq!(dend.merges.len(), 6);
        for w in dend.merges.windows(2) {
            assert!(w[0].distance <= w[1].distance);
        }
    }

    #[test]
    fn singletons_stay_singletons() {
        let pts = [0.0, 100.0, 200.0];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 3, 1.0);
        let labels = dend.cut(1.0);
        let set: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn empty_and_single() {
        let dend = complete_linkage(&[], 0, 1.0);
        assert!(dend.merges.is_empty());
        let dend1 = complete_linkage(&[0.0], 1, 1.0);
        assert!(dend1.merges.is_empty());
        assert_eq!(dend1.cut(1.0), vec![0]);
    }

    #[test]
    fn update_counts_accumulate() {
        let pts = [0.0, 0.1, 0.2, 0.3];
        let d = dist_1d(&pts);
        let dend = complete_linkage(&d, 4, f32::INFINITY);
        // 3 merges over 4 items: 2 + 1 + 0 updates minimum.
        assert!(dend.update_elements >= 3);
    }
}
