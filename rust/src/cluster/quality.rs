//! Clustering quality metrics (paper §IV-A "Quality Metrics" and Fig. 9).
//!
//! * **clustered spectra ratio** — clustered spectra / total spectra, where
//!   a spectrum counts as clustered when it lands in a cluster of size >= 2.
//! * **incorrect clustering ratio** — among clustered spectra, the fraction
//!   whose ground-truth peptide differs from their cluster's majority
//!   peptide (the falcon/HyperSpec convention).
//!
//! Fig. 9 plots clustered ratio against incorrect ratio while sweeping the
//! merge threshold; [`quality_curve`] reproduces that sweep.

use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterQuality {
    pub threshold: f32,
    pub clustered_ratio: f64,
    pub incorrect_ratio: f64,
    pub n_clusters: usize,
}

/// Evaluate one flat clustering against ground-truth labels.
/// `truth[i]` is the ground-truth peptide of spectrum i.
pub fn evaluate(labels: &[usize], truth: &[u32], threshold: f32) -> ClusterQuality {
    assert_eq!(labels.len(), truth.len());
    let n = labels.len();
    if n == 0 {
        return ClusterQuality {
            threshold,
            clustered_ratio: 0.0,
            incorrect_ratio: 0.0,
            n_clusters: 0,
        };
    }

    let mut members: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, &l) in labels.iter().enumerate() {
        members.entry(l).or_default().push(i);
    }

    let mut clustered = 0usize;
    let mut incorrect = 0usize;
    let mut n_clusters = 0usize;
    for mem in members.values() {
        if mem.len() < 2 {
            continue;
        }
        n_clusters += 1;
        clustered += mem.len();
        // Majority ground-truth peptide within the cluster.
        let mut counts: HashMap<u32, usize> = HashMap::new();
        for &i in mem {
            *counts.entry(truth[i]).or_default() += 1;
        }
        let majority = counts.values().copied().max().unwrap();
        incorrect += mem.len() - majority;
    }

    ClusterQuality {
        threshold,
        clustered_ratio: clustered as f64 / n as f64,
        incorrect_ratio: if clustered > 0 {
            incorrect as f64 / clustered as f64
        } else {
            0.0
        },
        n_clusters,
    }
}

/// Sweep merge thresholds over a dendrogram, producing the Fig. 9 curve
/// (clustered ratio as a function of incorrect ratio).
pub fn quality_curve(
    dendrogram: &super::linkage::Dendrogram,
    truth: &[u32],
    thresholds: &[f32],
) -> Vec<ClusterQuality> {
    thresholds
        .iter()
        .map(|&t| evaluate(&dendrogram.cut(t), truth, t))
        .collect()
}

/// Interpolate the clustered ratio at a fixed incorrect ratio (the paper
/// reports quality "at an incorrect clustering ratio of 1.5%").
pub fn clustered_at_incorrect(curve: &[ClusterQuality], incorrect: f64) -> f64 {
    let mut best = 0.0f64;
    for q in curve {
        if q.incorrect_ratio <= incorrect && q.clustered_ratio > best {
            best = q.clustered_ratio;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering() {
        let labels = vec![0, 0, 1, 1, 2];
        let truth = vec![10, 10, 20, 20, 30];
        let q = evaluate(&labels, &truth, 0.5);
        assert_eq!(q.clustered_ratio, 4.0 / 5.0); // singleton not clustered
        assert_eq!(q.incorrect_ratio, 0.0);
        assert_eq!(q.n_clusters, 2);
    }

    #[test]
    fn impure_cluster_counted() {
        let labels = vec![0, 0, 0, 0];
        let truth = vec![1, 1, 1, 2];
        let q = evaluate(&labels, &truth, 0.5);
        assert_eq!(q.clustered_ratio, 1.0);
        assert_eq!(q.incorrect_ratio, 0.25);
    }

    #[test]
    fn all_singletons() {
        let labels = vec![0, 1, 2];
        let truth = vec![1, 1, 1];
        let q = evaluate(&labels, &truth, 0.0);
        assert_eq!(q.clustered_ratio, 0.0);
        assert_eq!(q.incorrect_ratio, 0.0);
    }

    #[test]
    fn clustered_at_incorrect_picks_best_valid() {
        let curve = vec![
            ClusterQuality { threshold: 0.1, clustered_ratio: 0.2, incorrect_ratio: 0.001, n_clusters: 5 },
            ClusterQuality { threshold: 0.3, clustered_ratio: 0.5, incorrect_ratio: 0.01, n_clusters: 9 },
            ClusterQuality { threshold: 0.5, clustered_ratio: 0.7, incorrect_ratio: 0.05, n_clusters: 12 },
        ];
        assert_eq!(clustered_at_incorrect(&curve, 0.015), 0.5);
        assert_eq!(clustered_at_incorrect(&curve, 0.1), 0.7);
    }
}
