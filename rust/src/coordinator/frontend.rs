//! HD encode+pack frontend: one call per spectra batch, executed on the
//! PJRT encoder artifact when the dispatcher carries a runtime and the
//! (D, n) variant exists, with the bit-identical rust path (`hd::encode` +
//! `hd::pack`) as fallback for artifact-free runs and for sweep dimensions
//! outside the variant set.

use crate::backend::BackendDispatcher;
use crate::config::SpecPcmConfig;
use crate::energy::OpCounts;
use crate::hd::{self, ItemMemory};
use crate::ms::{preprocess, PreprocessConfig, Spectrum};
#[cfg(feature = "pjrt")]
use crate::runtime::{Manifest, Runtime};
use crate::util::error::Result;

pub struct HdFrontend {
    pub im: ItemMemory,
    pub d: usize,
    pub n: usize,
    pub packed_width: usize,
    preprocess_cfg: PreprocessConfig,
    /// Cached f32 codebooks for the artifact path.
    id_hvs_f32: Vec<f32>,
    level_hvs_f32: Vec<f32>,
}

impl HdFrontend {
    pub fn new(cfg: &SpecPcmConfig) -> Self {
        let preprocess_cfg = PreprocessConfig {
            bins: cfg.features,
            levels: cfg.levels,
            ..PreprocessConfig::default()
        };
        let im = ItemMemory::generate(cfg.seed ^ 0x1d, cfg.features, cfg.levels, cfg.hd_dim);
        let id_hvs_f32 = im.id_hvs_f32();
        let level_hvs_f32 = im.level_hvs_f32();
        HdFrontend {
            packed_width: hd::padded_packed_len(cfg.hd_dim, cfg.packing()),
            d: cfg.hd_dim,
            n: cfg.packing(),
            im,
            preprocess_cfg,
            id_hvs_f32,
            level_hvs_f32,
        }
    }

    /// Preprocess spectra into quantized level vectors (ASIC input stage).
    pub fn levels_of(&self, spectra: &[&Spectrum]) -> Vec<Vec<u16>> {
        spectra
            .iter()
            .map(|s| preprocess(s, &self.preprocess_cfg))
            .collect()
    }

    /// Encode + pack a set of spectra into row-major packed HVs
    /// (`spectra.len() x packed_width`). Uses the PJRT encoder artifact
    /// when the dispatcher carries a runtime with the (D, n) variant;
    /// counts ASIC encode and pack work either way.
    pub fn encode_pack(
        &self,
        spectra: &[&Spectrum],
        backend: &BackendDispatcher,
        ops: &mut OpCounts,
    ) -> Result<Vec<f32>> {
        let levels = self.levels_of(spectra);
        ops.encode_spectra += spectra.len() as u64;
        // `features` is a workload property, not an event count: merge via
        // max so accumulating across calls (or parallel shards, see
        // `OpCounts::add`) never sums it into nonsense.
        ops.features = ops.features.max(self.preprocess_cfg.bins as u64);
        ops.pack_elements += (spectra.len() * self.packed_width) as u64;

        #[cfg(feature = "pjrt")]
        if let Some(rt) = backend.runtime() {
            let name = Manifest::enc_pack_name(self.d, self.n);
            let mut rt = rt.borrow_mut();
            if rt.manifest.get(&name).is_some() {
                return self.encode_pack_artifact(&levels, &mut rt);
            }
        }
        #[cfg(not(feature = "pjrt"))]
        let _ = backend;
        Ok(self.encode_pack_rust(&levels))
    }

    /// Pure-rust reference path.
    fn encode_pack_rust(&self, levels: &[Vec<u16>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(levels.len() * self.packed_width);
        for lv in levels {
            let hv = hd::encode(lv, &self.im);
            out.extend_from_slice(&hd::pack(&hv, self.n));
        }
        out
    }

    /// PJRT artifact path: batches of the manifest's B spectra.
    #[cfg(feature = "pjrt")]
    fn encode_pack_artifact(&self, levels: &[Vec<u16>], rt: &mut Runtime) -> Result<Vec<f32>> {
        let b = rt.manifest.batch;
        let f = rt.manifest.features;
        let mut out = Vec::with_capacity(levels.len() * self.packed_width);
        for chunk in levels.chunks(b) {
            let mut batch = vec![0i32; b * f];
            for (i, lv) in chunk.iter().enumerate() {
                for (j, &v) in lv.iter().enumerate() {
                    batch[i * f + j] = v as i32;
                }
            }
            let packed =
                rt.encode_pack(self.d, self.n, &batch, &self.id_hvs_f32, &self.level_hvs_f32)?;
            // Keep only the real rows of this batch.
            out.extend_from_slice(&packed[..chunk.len() * self.packed_width]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ms::dataset::ClusteringDataset;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 512,
            mlc_bits: 3,
            ..SpecPcmConfig::paper_clustering()
        }
    }

    #[test]
    fn rust_path_shapes_and_range() {
        let cfg = small_cfg();
        let fe = HdFrontend::new(&cfg);
        let ds = ClusteringDataset::generate("t", 1, 5, 2, 3, 2, 0);
        let refs: Vec<&Spectrum> = ds.spectra.iter().collect();
        let mut ops = OpCounts::default();
        let packed = fe
            .encode_pack(&refs, &BackendDispatcher::reference(), &mut ops)
            .unwrap();
        assert_eq!(packed.len(), refs.len() * fe.packed_width);
        assert!(packed.iter().all(|&v| v.abs() <= 3.0));
        assert_eq!(ops.encode_spectra, refs.len() as u64);
    }

    #[test]
    fn identical_spectra_identical_hvs() {
        let cfg = small_cfg();
        let fe = HdFrontend::new(&cfg);
        let ds = ClusteringDataset::generate("t", 2, 1, 2, 2, 0, 0);
        let s = &ds.spectra[0];
        let be = BackendDispatcher::reference();
        let mut ops = OpCounts::default();
        let p1 = fe.encode_pack(&[s], &be, &mut ops).unwrap();
        let p2 = fe.encode_pack(&[s], &be, &mut ops).unwrap();
        assert_eq!(p1, p2);
        // Accumulating calls max-merge the workload-property counter
        // instead of overwriting or summing it.
        assert_eq!(ops.features, cfg.features as u64);
        assert_eq!(ops.encode_spectra, 2);
    }
}
