//! HD encode+pack frontend: one call per spectra batch. Routing order:
//! the PJRT encoder artifact when the dispatcher carries a runtime and
//! the (D, n) variant exists, else the dispatcher's configured
//! `encode::EncodeBackend` (scalar reference / word-packed bitpacked /
//! spectra-sharded parallel) — all bit-identical by contract, so the
//! choice affects host wall time only.

use crate::backend::BackendDispatcher;
use crate::config::SpecPcmConfig;
use crate::encode::EncodeJob;
use crate::energy::OpCounts;
use crate::hd::{self, BitItemMemory, ItemMemory};
use crate::ms::{preprocess, PreprocessConfig, Spectrum};
#[cfg(feature = "pjrt")]
use crate::runtime::{Manifest, Runtime};
use crate::util::error::Result;

pub struct HdFrontend {
    pub im: ItemMemory,
    /// Word-packed codebooks, derived once from `im` for the bitpacked
    /// and parallel encode backends.
    pub bit_im: BitItemMemory,
    pub d: usize,
    pub n: usize,
    pub packed_width: usize,
    preprocess_cfg: PreprocessConfig,
    /// Cached f32 codebooks for the artifact path.
    id_hvs_f32: Vec<f32>,
    level_hvs_f32: Vec<f32>,
}

impl HdFrontend {
    pub fn new(cfg: &SpecPcmConfig) -> Self {
        let preprocess_cfg = PreprocessConfig {
            bins: cfg.features,
            levels: cfg.levels,
            ..PreprocessConfig::default()
        };
        let im = ItemMemory::generate(cfg.seed ^ 0x1d, cfg.features, cfg.levels, cfg.hd_dim);
        let bit_im = BitItemMemory::from_item_memory(&im);
        let id_hvs_f32 = im.id_hvs_f32();
        let level_hvs_f32 = im.level_hvs_f32();
        HdFrontend {
            packed_width: hd::padded_packed_len(cfg.hd_dim, cfg.packing()),
            d: cfg.hd_dim,
            n: cfg.packing(),
            im,
            bit_im,
            preprocess_cfg,
            id_hvs_f32,
            level_hvs_f32,
        }
    }

    /// Preprocess spectra into quantized level vectors (ASIC input stage).
    pub fn levels_of(&self, spectra: &[&Spectrum]) -> Vec<Vec<u16>> {
        spectra
            .iter()
            .map(|s| preprocess(s, &self.preprocess_cfg))
            .collect()
    }

    /// Charge the ASIC encode+pack op counts for `n_spectra` spectra.
    /// Split out from [`Self::encode_pack`] so the engine's query-HV cache
    /// can charge the *physical* work for every spectrum while skipping
    /// only the redundant host arithmetic (the cache changes host time,
    /// never accounting).
    pub fn count_encode_ops(&self, n_spectra: usize, ops: &mut OpCounts) {
        ops.encode_spectra += n_spectra as u64;
        // `features` is a workload property, not an event count: merge via
        // max so accumulating across calls (or parallel shards, see
        // `OpCounts::add`) never sums it into nonsense.
        ops.features = ops.features.max(self.preprocess_cfg.bins as u64);
        ops.pack_elements += (n_spectra * self.packed_width) as u64;
    }

    /// Encode + pack a set of spectra into row-major packed HVs
    /// (`spectra.len() x packed_width`); counts ASIC encode and pack work.
    pub fn encode_pack(
        &self,
        spectra: &[&Spectrum],
        backend: &BackendDispatcher,
        ops: &mut OpCounts,
    ) -> Result<Vec<f32>> {
        let levels = self.levels_of(spectra);
        self.count_encode_ops(spectra.len(), ops);
        self.encode_pack_levels(&levels, backend)
    }

    /// Encode + pack already-quantized level vectors (no op accounting —
    /// see [`Self::count_encode_ops`]). Uses the PJRT encoder artifact
    /// when available, else the dispatcher's encode backend.
    pub fn encode_pack_levels(
        &self,
        levels: &[Vec<u16>],
        backend: &BackendDispatcher,
    ) -> Result<Vec<f32>> {
        #[cfg(feature = "pjrt")]
        if let Some(rt) = backend.runtime() {
            let name = Manifest::enc_pack_name(self.d, self.n);
            let mut rt = crate::util::sync::lock_unpoisoned(rt, "pjrt runtime");
            if rt.manifest.get(&name).is_some() {
                return self.encode_pack_artifact(levels, &mut rt);
            }
        }
        let job = EncodeJob::new(levels, &self.im, &self.bit_im, self.n);
        let mut out = vec![0f32; job.out_len()];
        backend.encode_pack(&job, &mut out)?;
        Ok(out)
    }

    /// PJRT artifact path: batches of the manifest's B spectra.
    #[cfg(feature = "pjrt")]
    fn encode_pack_artifact(&self, levels: &[Vec<u16>], rt: &mut Runtime) -> Result<Vec<f32>> {
        let b = rt.manifest.batch;
        let f = rt.manifest.features;
        let mut out = Vec::with_capacity(levels.len() * self.packed_width);
        for chunk in levels.chunks(b) {
            let mut batch = vec![0i32; b * f];
            for (i, lv) in chunk.iter().enumerate() {
                for (j, &v) in lv.iter().enumerate() {
                    batch[i * f + j] = v as i32;
                }
            }
            let packed =
                rt.encode_pack(self.d, self.n, &batch, &self.id_hvs_f32, &self.level_hvs_f32)?;
            // Keep only the real rows of this batch.
            out.extend_from_slice(&packed[..chunk.len() * self.packed_width]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encode::EncodeKind;
    use crate::ms::dataset::ClusteringDataset;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 512,
            mlc_bits: 3,
            ..SpecPcmConfig::paper_clustering()
        }
    }

    #[test]
    fn rust_path_shapes_and_range() {
        let cfg = small_cfg();
        let fe = HdFrontend::new(&cfg);
        let ds = ClusteringDataset::generate("t", 1, 5, 2, 3, 2, 0);
        let refs: Vec<&Spectrum> = ds.spectra.iter().collect();
        let mut ops = OpCounts::default();
        let packed = fe
            .encode_pack(&refs, &BackendDispatcher::reference(), &mut ops)
            .unwrap();
        assert_eq!(packed.len(), refs.len() * fe.packed_width);
        assert!(packed.iter().all(|&v| v.abs() <= 3.0));
        assert_eq!(ops.encode_spectra, refs.len() as u64);
    }

    #[test]
    fn identical_spectra_identical_hvs() {
        let cfg = small_cfg();
        let fe = HdFrontend::new(&cfg);
        let ds = ClusteringDataset::generate("t", 2, 1, 2, 2, 0, 0);
        let s = &ds.spectra[0];
        let be = BackendDispatcher::reference();
        let mut ops = OpCounts::default();
        let p1 = fe.encode_pack(&[s], &be, &mut ops).unwrap();
        let p2 = fe.encode_pack(&[s], &be, &mut ops).unwrap();
        assert_eq!(p1, p2);
        // Accumulating calls max-merge the workload-property counter
        // instead of overwriting or summing it.
        assert_eq!(ops.features, cfg.features as u64);
        assert_eq!(ops.encode_spectra, 2);
    }

    #[test]
    fn encode_backends_agree_at_frontend_level() {
        let cfg = small_cfg();
        let fe = HdFrontend::new(&cfg);
        let ds = ClusteringDataset::generate("t", 3, 6, 2, 3, 4, 0);
        let refs: Vec<&Spectrum> = ds.spectra.iter().collect();
        let mut ops = OpCounts::default();
        let want = fe
            .encode_pack(&refs, &BackendDispatcher::reference(), &mut ops)
            .unwrap();
        for kind in [EncodeKind::Bitpacked, EncodeKind::Parallel] {
            let be = BackendDispatcher::reference().with_encode_kind(kind, 2);
            let got = fe.encode_pack(&refs, &be, &mut ops).unwrap();
            assert_eq!(got, want, "encode kind {}", kind.name());
        }
    }
}
