//! Shard layer: serving a reference library that overflows one engine's
//! bank capacity by partitioning it across several [`SearchEngine`]s
//! (paper Table 3 at real library scales; ROADMAP "sharded libraries" +
//! "concurrent serving" items).
//!
//! [`ShardPlan`] splits the global reference row order — targets followed
//! by decoys, exactly the order one monolithic engine would program —
//! into contiguous, disjoint, exhaustive row ranges, each sized to fit
//! one engine's banks. [`ShardedSearchEngine`] programs one engine per
//! range (each with its own [`super::ProgramContext`], `SegmentAllocator`
//! and bank pool) and fans every query batch out across the shards on
//! `std::thread::scope` threads.
//!
//! # Bit-identity contract
//!
//! A sharded engine over `k` shards of `B` banks each returns per-query
//! results **bit-identical** to one monolithic engine with `k * B` banks
//! (`rust/tests/engine_equivalence.rs`), because every ingredient is
//! partition-safe by construction:
//!
//! * **Programming noise**: shard `i+1`'s noise RNG starts from the exact
//!   state shard `i` finished with ([`SearchEngine::program_with_rng`] /
//!   [`SearchEngine::noise_rng_state`]), so the concatenated per-row
//!   noise stream equals the monolithic stream.
//! * **Query encode**: queries are encoded **once**, through shard 0's
//!   query-HV cache, and the packed rows are shared with every shard
//!   ([`SearchEngine::encode_queries`]) — no per-shard encode
//!   duplication, in host time or in op accounting.
//! * **Top-1 merge**: shards hold contiguous ascending row ranges and the
//!   cross-shard merge folds them in shard order with the same strict-`>`
//!   rule the in-engine merge uses, so ties keep resolving to the lowest
//!   global row index. Each shard engine lays *its* rows out
//!   bucket-contiguously for zero-copy segmented scoring (see the
//!   [`super::engine`] module docs), but its in-engine merge tie-breaks
//!   on **logical** rows — so the physical layout never leaks into
//!   results and this merge contract is untouched by the layout.
//! * **Decoys and FDR**: the contiguous split may land inside the decoy
//!   block; each shard gets its own targets/decoys subranges and
//!   classifies locally, and the FDR filter runs once over the merged
//!   per-query pairs — identical inputs, identical output.
//!
//! # Accounting
//!
//! Sharding changes *placement and host concurrency* only. Total
//! simulated ASIC work equals the monolithic equivalent: encode ops are
//! charged once per batch, and IMC/merge ops are charged from the merged
//! per-group candidate counts ([`super::engine::GroupCharges`]) rather
//! than per shard, so 128-row tile rounding never double-counts shard
//! boundaries. Energy/latency reports model the union bank pool
//! (`num_banks x n_shards`) — the physical hardware the sharded system
//! actually owns.
//!
//! # Drift and refresh across shards
//!
//! The drift-aware serving extensions stay partition-safe too:
//! [`ShardedSearchEngine::advance_age`] ticks every shard's logical clock
//! in lockstep, and [`ShardedSearchEngine::maintain`] pools per-shard
//! staleness candidates into **one global** [`RefreshPolicy`] selection
//! (deduped, budget counted per distinct bucket) before each shard
//! refreshes its portion. Refresh draws come from per-(global row, epoch)
//! RNG roots — shard `i`'s local row `l` is global row
//! `plan.range(i).start + l` — so the re-programmed conductances are
//! bit-identical to the monolithic engine refreshing the same buckets at
//! the same clock (`rust/tests/drift_equivalence.rs`).

use crate::backend::BackendDispatcher;
use crate::config::SpecPcmConfig;
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::{SearchDataset, Spectrum};
use crate::telemetry::{DeviceHealth, EncodeCacheStats, StageTimer};
use crate::util::error::{Error, Result};

use super::allocator::SegmentAllocator;
use super::engine::{
    chunk_ranges, fold_batches, BatchOutcome, CapacityError, Coverage, GroupCharges,
    ProgramContext, RefreshOutcome, RefreshPolicy, SearchEngine, ServingCost,
};
use super::pipeline::SearchOutcomeSummary;

/// A partition of the global reference row order (targets then decoys)
/// into contiguous shard ranges. Invariants — disjoint, exhaustive, and
/// order-preserving (range `i` ends where range `i+1` starts) — are
/// property-tested in `rust/tests/property_tests.rs`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    n_targets: usize,
    n_decoys: usize,
    /// Global row ranges, ascending and contiguous.
    ranges: Vec<std::ops::Range<usize>>,
}

impl ShardPlan {
    /// Split `n_targets + n_decoys` rows into `n_shards` contiguous
    /// ranges with sizes differing by at most one (earlier shards take
    /// the remainder; same `chunk_ranges` rule as `serve_chunked`).
    /// `n_shards` is clamped to `[1, rows.max(1)]` so no shard is ever
    /// empty (except the degenerate empty-library plan, which keeps one
    /// empty shard).
    pub fn balanced(n_targets: usize, n_decoys: usize, n_shards: usize) -> ShardPlan {
        ShardPlan {
            n_targets,
            n_decoys,
            ranges: chunk_ranges(n_targets + n_decoys, n_shards),
        }
    }

    /// Plan against `cfg`'s per-engine bank capacity. `n_shards = 0`
    /// auto-computes the minimum shard count that fits; an explicit count
    /// is validated (its largest shard must fit one engine) and returns
    /// the typed [`CapacityError`] otherwise.
    pub fn for_capacity(
        cfg: &SpecPcmConfig,
        n_targets: usize,
        n_decoys: usize,
        n_shards: usize,
    ) -> Result<ShardPlan, CapacityError> {
        let rows = n_targets + n_decoys;
        let packed = crate::hd::padded_packed_len(cfg.hd_dim, cfg.packing());
        let (capacity, segments) = match SegmentAllocator::try_new(cfg.num_banks, packed) {
            Ok(a) => (a.capacity(), a.segments()),
            Err(_) => (0, packed / crate::array::ARRAY_DIM),
        };
        let err = |needed: usize| CapacityError {
            rows_needed: needed,
            capacity,
            num_banks: cfg.num_banks,
            segments,
        };
        if capacity == 0 {
            return Err(err(rows));
        }
        let n = if n_shards == 0 {
            rows.div_ceil(capacity).max(1)
        } else {
            n_shards
        };
        let plan = ShardPlan::balanced(n_targets, n_decoys, n);
        let widest = plan.ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        if widest > capacity {
            return Err(err(widest));
        }
        Ok(plan)
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    pub fn n_rows(&self) -> usize {
        self.n_targets + self.n_decoys
    }

    /// Global row range of shard `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.ranges[i].clone()
    }

    pub fn ranges(&self) -> &[std::ops::Range<usize>] {
        &self.ranges
    }

    /// Target-library index range of shard `i` (may be empty when the
    /// whole shard sits inside the decoy block).
    pub fn target_range(&self, i: usize) -> std::ops::Range<usize> {
        let r = &self.ranges[i];
        r.start.min(self.n_targets)..r.end.min(self.n_targets)
    }

    /// Decoy index range of shard `i` (indices into the decoy list; may
    /// be empty when the shard sits inside the target block).
    pub fn decoy_range(&self, i: usize) -> std::ops::Range<usize> {
        let r = &self.ranges[i];
        r.start.max(self.n_targets) - self.n_targets..r.end.max(self.n_targets) - self.n_targets
    }
}

/// N [`SearchEngine`]s serving one partitioned library as a single
/// engine-shaped unit: program once per shard, fan every batch out on
/// scoped threads, merge per-query bests and accounting bit-identically
/// to the monolithic equivalent (module docs).
pub struct ShardedSearchEngine {
    pub cfg: SpecPcmConfig,
    plan: ShardPlan,
    shards: Vec<SearchEngine>,
    program_ops: OpCounts,
    program_report: EnergyReport,
    program_wall: StageTimer,
}

impl ShardedSearchEngine {
    /// Partition the dataset's reference library and program one engine
    /// per shard. `n_shards = 0` auto-computes the minimum count that
    /// fits `cfg`'s per-engine banks (1 when the library already fits —
    /// the result is then bit-identical to [`SearchEngine::program`],
    /// including the noise stream).
    pub fn program(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
        n_shards: usize,
    ) -> Result<Self> {
        let plan = ShardPlan::for_capacity(
            &cfg,
            dataset.library.len(),
            dataset.decoys.len(),
            n_shards,
        )?;
        Self::program_with_plan(cfg, dataset, backend, plan)
    }

    /// [`ShardedSearchEngine::program`] with a plan the caller already
    /// computed (and possibly printed) through [`ShardPlan::for_capacity`]
    /// — one planning call site, so what was validated is exactly what
    /// gets programmed. The plan must cover this dataset's library.
    pub fn program_with_plan(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
        plan: ShardPlan,
    ) -> Result<Self> {
        crate::ensure!(
            plan.n_targets() == dataset.library.len()
                && plan.n_rows() == dataset.library.len() + dataset.decoys.len(),
            "shard plan covers {} targets / {} rows, dataset has {} / {}",
            plan.n_targets(),
            plan.n_rows(),
            dataset.library.len(),
            dataset.library.len() + dataset.decoys.len()
        );

        // Chain the programming-noise RNG through the shards in row order
        // so the concatenated noise stream equals the monolithic one.
        let mut rng = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG);
        let mut shards = Vec::with_capacity(plan.n_shards());
        let mut program_ops = OpCounts::default();
        let mut program_wall = StageTimer::new();
        for i in 0..plan.n_shards() {
            let shard_ds = SearchDataset {
                name: dataset.name,
                library: dataset.library[plan.target_range(i)].to_vec(),
                decoys: dataset.decoys[plan.decoy_range(i)].to_vec(),
                queries: Vec::new(),
                identifiable_fraction: dataset.identifiable_fraction,
                paper_queries: dataset.paper_queries,
                paper_library: dataset.paper_library,
            };
            let mut engine = SearchEngine::program_with_rng(cfg.clone(), &shard_ds, backend, rng)?;
            // The shard's local row 0 is global row `range.start`: keys the
            // per-(global row, epoch) refresh streams so a sharded refresh
            // draws exactly what the monolithic engine would.
            engine.set_row_base(plan.range(i).start);
            rng = engine.noise_rng_state();
            program_ops += engine.program_ops();
            for (stage, t, _) in engine.program_wall().breakdown() {
                program_wall.add(&stage, t);
            }
            shards.push(engine);
        }

        // One-time report over the union bank pool (the hardware the
        // sharded system physically owns), equal to the monolithic
        // equivalent's report because the summed ops are equal.
        let model = Self::pool_model(&cfg, plan.n_shards());
        let program_report = model.report(&program_ops);

        Ok(ShardedSearchEngine {
            cfg,
            plan,
            shards,
            program_ops,
            program_report,
            program_wall,
        })
    }

    /// Energy/latency model of the union bank pool: `n_shards` engines of
    /// `cfg.num_banks` banks each.
    fn pool_model(cfg: &SpecPcmConfig, n_shards: usize) -> EnergyLatencyModel {
        EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks * n_shards.max(1))
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Reference rows programmed across every shard (targets + decoys).
    pub fn n_refs(&self) -> usize {
        self.shards.iter().map(|s| s.n_refs()).sum()
    }

    pub fn n_targets(&self) -> usize {
        self.plan.n_targets()
    }

    /// Total banks across every shard's pool.
    pub fn total_banks(&self) -> usize {
        self.cfg.num_banks * self.shards.len()
    }

    /// Shard `i`'s engine (placement introspection, tests).
    pub fn shard(&self, i: usize) -> &SearchEngine {
        &self.shards[i]
    }

    /// One-time library ops summed over every shard.
    pub fn program_ops(&self) -> &OpCounts {
        &self.program_ops
    }

    /// One-time programming energy/latency over the union bank pool.
    pub fn program_report(&self) -> &EnergyReport {
        &self.program_report
    }

    /// Cumulative query-HV cache stats (shard 0 owns the shared cache —
    /// queries are encoded once, not per shard).
    pub fn encode_cache_stats(&self) -> EncodeCacheStats {
        self.shards[0].encode_cache_stats()
    }

    pub fn clear_query_cache(&self) {
        self.shards[0].clear_query_cache();
    }

    /// Current logical serving clock — every shard ticks in lockstep.
    pub fn age_seconds(&self) -> f64 {
        self.shards[0].age_seconds()
    }

    /// Advance the deterministic serving clock on every shard (see
    /// [`SearchEngine::advance_age`]).
    pub fn advance_age(&mut self, seconds: f64) {
        for shard in &mut self.shards {
            shard.advance_age(seconds);
        }
    }

    /// Health summary over the whole sharded library: ages and losses max
    /// over the shards, fault and refresh counts sum ([`DeviceHealth`]'s
    /// asymmetric merge rule) — identical to the monolithic engine's
    /// summary because rows partition across shards.
    pub fn device_health(&self) -> DeviceHealth {
        self.shards.iter().map(|s| s.device_health()).sum()
    }

    /// One maintenance pass over the whole library: pool every shard's
    /// per-bucket staleness candidates, run **one global**
    /// [`RefreshPolicy::select`] (dedupe handles buckets straddling a
    /// shard boundary; the budget counts each bucket once), then let each
    /// shard refresh its portion of the picked buckets. Re-programmed
    /// `rows` and `ops` are shard-count-invariant; `buckets` counts
    /// per-shard segments, so a boundary bucket contributes once per
    /// shard that holds part of it.
    pub fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        let mut candidates = Vec::new();
        for shard in &self.shards {
            candidates.extend(shard.refresh_candidates());
        }
        let keys = policy.select(candidates);
        let mut out = RefreshOutcome::default();
        for shard in &mut self.shards {
            let shard_out = shard.refresh_buckets(&keys);
            out.buckets += shard_out.buckets;
            out.rows += shard_out.rows;
            out.ops += &shard_out.ops;
        }
        if out.rows > 0 {
            self.program_ops += &out.ops;
            let model = Self::pool_model(&self.cfg, self.shards.len());
            self.program_report = model.report(&self.program_ops);
        }
        out
    }

    /// Serve one query batch: encode once through shard 0's query-HV
    /// cache, fan the packed rows out across every shard on scoped
    /// threads, merge per-query bests in shard order (strict `>`, so ties
    /// keep the lowest global row) and charge ops from the merged
    /// per-group candidate counts. Wall-time stages sum the per-shard
    /// host time (threads run concurrently, so the sum is CPU time, not
    /// elapsed time).
    pub fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();

        self.shards[0]
            .frontend
            .count_encode_ops(queries.len(), &mut ops);
        let (packed, batch_cache) =
            wall.time("encode queries", || self.shards[0].encode_queries(queries, backend))?;

        let shard_scores = if self.shards.len() == 1 {
            vec![self.shards[0].score_packed(queries, &packed, backend)?]
        } else {
            let packed = &packed;
            let joined = std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .shards
                    .iter()
                    .map(|shard| s.spawn(move || shard.score_packed(queries, packed, backend)))
                    .collect();
                handles.into_iter().map(|h| h.join()).collect::<Vec<_>>()
            });
            let mut scores = Vec::with_capacity(joined.len());
            for (si, r) in joined.into_iter().enumerate() {
                // Preserve the panic payload — "thread panicked" alone
                // would hide which shard and why.
                let r = r.map_err(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    Error::msg(format!("shard {si} scoring thread panicked: {msg}"))
                })?;
                scores.push(r?);
            }
            scores
        };

        // Merge per-query bests in shard order; merge group candidate
        // counts and charge the monolithic-equivalent op totals.
        let mut best: Vec<(f32, f32, Option<u32>)> =
            vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];
        let mut charges = GroupCharges::default();
        for scored in &shard_scores {
            for (qi, &(t, d, m)) in scored.best.iter().enumerate() {
                if t > best[qi].0 {
                    best[qi].0 = t;
                    best[qi].2 = m;
                }
                if d > best[qi].1 {
                    best[qi].1 = d;
                }
            }
            charges.merge(&scored.charges);
            for (stage, t, _) in scored.wall.breakdown() {
                wall.add(&stage, t);
            }
        }
        charges.charge(self.shards[0].packed_width(), &mut ops);

        let pairs: Vec<(f32, f32)> = best.iter().map(|&(t, d, _)| (t, d)).collect();
        let matched: Vec<Option<u32>> = best.iter().map(|&(_, _, m)| m).collect();
        let report = Self::pool_model(&self.cfg, self.shards.len()).report(&ops);

        Ok(BatchOutcome {
            pairs,
            matched,
            ops,
            report,
            cache: batch_cache,
            health: self.device_health(),
            coverage: Coverage::full(self.n_refs() as u64),
            retries: 0,
            degraded_shards: 0,
            wall,
        })
    }

    /// Split `queries` into contiguous batches and serve each in order —
    /// same chunking contract as [`SearchEngine::serve_chunked`] (exactly
    /// `min(n_batches, queries.len()).max(1)` batches, sizes differing by
    /// at most one).
    pub fn serve_chunked(
        &self,
        queries: &[&Spectrum],
        n_batches: usize,
        backend: &BackendDispatcher,
    ) -> Result<Vec<BatchOutcome>> {
        chunk_ranges(queries.len(), n_batches)
            .into_iter()
            .map(|r| self.search_batch(&queries[r], backend))
            .collect()
    }

    /// Fold served batches into the one-time/marginal/amortized cost
    /// split (the one-time column covers every shard's programming).
    pub fn serving_cost(&self, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost::from_reports(&self.program_report, batches)
    }

    /// Pool accumulated batch outcomes into the one-shot summary shape —
    /// the same fold as [`SearchEngine::finalize`], with the one-time
    /// column summed over shards and the union-pool energy model, so the
    /// result is bit-identical to the monolithic equivalent's summary.
    pub fn finalize(
        &self,
        queries: &[&Spectrum],
        batches: &[BatchOutcome],
    ) -> Result<SearchOutcomeSummary> {
        let model = Self::pool_model(&self.cfg, self.shards.len());
        fold_batches(
            self.cfg.fdr,
            &model,
            &self.program_ops,
            &self.program_wall,
            queries,
            batches,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendDispatcher;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 12, // 2 groups x 128 rows = 256 reference slots
            ..SpecPcmConfig::paper_search()
        }
    }

    #[test]
    fn balanced_plan_is_contiguous_and_even() {
        let p = ShardPlan::balanced(100, 100, 3);
        assert_eq!(p.n_shards(), 3);
        assert_eq!(p.ranges(), &[0..67, 67..134, 134..200]);
        // Shard 1 straddles the target/decoy boundary at row 100.
        assert_eq!(p.target_range(1), 67..100);
        assert_eq!(p.decoy_range(1), 0..34);
        assert_eq!(p.target_range(2), 100..100);
        assert_eq!(p.decoy_range(2), 34..100);
    }

    #[test]
    fn plan_clamps_and_degenerates_gracefully() {
        // More shards than rows: one row per shard.
        let p = ShardPlan::balanced(2, 1, 10);
        assert_eq!(p.n_shards(), 3);
        // Empty library: a single empty shard.
        let p = ShardPlan::balanced(0, 0, 4);
        assert_eq!(p.n_shards(), 1);
        assert_eq!(p.range(0), 0..0);
    }

    #[test]
    fn for_capacity_auto_computes_minimum_shards() {
        let cfg = small_cfg(); // 256 slots per engine
        let p = ShardPlan::for_capacity(&cfg, 300, 300, 0).unwrap();
        assert_eq!(p.n_shards(), 3); // ceil(600 / 256)
        assert!(p.ranges().iter().all(|r| r.len() <= 256));

        // A fitting library auto-plans to one shard.
        let p = ShardPlan::for_capacity(&cfg, 100, 100, 0).unwrap();
        assert_eq!(p.n_shards(), 1);

        // An explicit under-provisioned count is a typed error.
        let e = ShardPlan::for_capacity(&cfg, 300, 300, 2).unwrap_err();
        assert_eq!(e.rows_needed, 300); // widest shard of 2
        assert_eq!(e.capacity, 256);

        // A single HV wider than all banks: zero capacity.
        let tiny = SpecPcmConfig {
            num_banks: 2,
            ..small_cfg()
        };
        let e = ShardPlan::for_capacity(&tiny, 10, 10, 0).unwrap_err();
        assert_eq!(e.capacity, 0);
    }

    #[test]
    fn sharded_engine_spans_overflowing_library() {
        // 180 targets + 180 decoys = 360 rows > 256 slots per engine.
        let ds = SearchDataset::generate("t", 21, 180, 12, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let cfg = small_cfg();
        assert!(SearchEngine::program(cfg.clone(), &ds, &be).is_err());

        let sharded = ShardedSearchEngine::program(cfg, &ds, &be, 0).unwrap();
        assert_eq!(sharded.n_shards(), 2);
        assert_eq!(sharded.n_refs(), 360);
        assert_eq!(sharded.n_targets(), 180);
        assert_eq!(sharded.total_banks(), 24);
        assert!(sharded.program_ops().program_rounds > 0);

        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let batch = sharded.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs.len(), queries.len());
        assert_eq!(batch.ops.program_rounds, 0);
        // Encode is charged once per batch, never per shard.
        assert_eq!(batch.ops.encode_spectra, queries.len() as u64);
        let out = sharded.finalize(&queries, &[batch]).unwrap();
        assert_eq!(out.total_queries, queries.len());
    }
}
