//! End-to-end pipeline drivers (paper Figs. 1 and 2).
//!
//! Both pipelines execute their IMC score tiles through a pluggable
//! [`BackendDispatcher`] (see `backend/`): the dispatcher charges the
//! physical array-op count (one MVM op = one 128x128 bank processing one
//! input vector) and routes the host arithmetic to the configured
//! backend — scalar reference, bank-sharded parallel, or the PJRT
//! artifact — all bit-identical by contract.

use std::collections::BTreeMap;

use crate::array::{AdcConfig, ARRAY_DIM};
use crate::backend::{BackendDispatcher, MvmJob};
use crate::cluster::{complete_linkage, ClusterQuality};
use crate::config::SpecPcmConfig;
use crate::device::{MlcConfig, NoiseModel, Programmer};
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::bucket::{bucket_by_precursor, candidate_keys_open, BucketKey};
use crate::ms::synth::PTM_SHIFTS;
use crate::ms::{ClusteringDataset, SearchDataset, Spectrum};
use crate::search::{fdr_filter, FdrResult};
use crate::telemetry::StageTimer;
use crate::util::error::Result;
use crate::util::Rng;

use super::frontend::HdFrontend;

/// Program packed reference HVs into PCM: applies write-verify-calibrated
/// noise and counts programming work. Returns the noisy conductances.
pub(crate) fn program_refs(
    packed: &[f32],
    n_rows: usize,
    cp: usize,
    programmer: &Programmer,
    rng: &mut Rng,
    ops: &mut OpCounts,
) -> Vec<f32> {
    assert_eq!(packed.len(), n_rows * cp);
    let segments = (cp / ARRAY_DIM) as u64;
    let mut noisy = Vec::with_capacity(packed.len());
    for row in 0..n_rows {
        let (stored, pulses, _reads) =
            programmer.program_slice(&packed[row * cp..(row + 1) * cp], rng);
        noisy.extend_from_slice(&stored);
        // A row round pulses all 128 cells of one segment in parallel.
        ops.program_rounds += pulses.div_ceil(ARRAY_DIM as u64).max(segments);
        ops.verify_rounds += programmer.write_verify as u64 * segments;
    }
    noisy
}

/// Normalized distance matrix from raw IMC scores: `d_ij = 1 - s_ij /
/// sqrt(s_ii * s_jj)`, clamped to [0, 2] (near-memory ASIC post-processing).
pub(crate) fn scores_to_distances(scores: &[f32], n: usize) -> Vec<f32> {
    let mut d = vec![0f32; n * n];
    let diag: Vec<f32> = (0..n).map(|i| scores[i * n + i].max(1.0)).collect();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let scale = (diag[i] * diag[j]).sqrt();
            d[i * n + j] = (1.0 - scores[i * n + j] / scale).clamp(0.0, 2.0);
        }
    }
    d
}

// ---------------------------------------------------------------------------
// Clustering
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ClusteringOutcome {
    /// Quality at each configured threshold, aggregated over all buckets.
    pub curve: Vec<ClusterQuality>,
    pub ops: OpCounts,
    pub report: EnergyReport,
    pub n_spectra: usize,
    pub n_buckets: usize,
    pub wall: StageTimer,
}

pub struct ClusteringPipeline {
    pub cfg: SpecPcmConfig,
    pub frontend: HdFrontend,
}

impl ClusteringPipeline {
    pub fn new(cfg: SpecPcmConfig) -> Self {
        let frontend = HdFrontend::new(&cfg);
        ClusteringPipeline { cfg, frontend }
    }

    pub fn run(
        &self,
        dataset: &ClusteringDataset,
        backend: &BackendDispatcher,
    ) -> Result<ClusteringOutcome> {
        let cfg = &self.cfg;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();
        let mut rng = Rng::new(cfg.seed ^ 0xc1);
        let programmer = Programmer::new(
            NoiseModel::new(cfg.material, MlcConfig::new(cfg.mlc_bits)),
            cfg.write_verify,
        );
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
        let cp = self.frontend.packed_width;

        let buckets = wall.time("bucketing", || {
            bucket_by_precursor(&dataset.spectra, cfg.bucket_width)
        });

        // Per-spectrum global cluster labels per threshold; singleton
        // buckets keep their own label.
        let n = dataset.spectra.len();
        let truth: Vec<u32> = dataset
            .spectra
            .iter()
            .map(|s| s.peptide_id.unwrap_or(u32::MAX))
            .collect();
        let mut labels_per_t: Vec<Vec<usize>> =
            vec![(0..n).collect(); cfg.threshold_sweep.len()];
        let mut next_label = n; // fresh labels beyond the singleton ids

        let mut n_buckets = 0usize;
        for (_key, members) in &buckets {
            if members.len() < 2 {
                continue;
            }
            n_buckets += 1;
            let specs: Vec<&Spectrum> = members.iter().map(|&i| &dataset.spectra[i]).collect();

            let packed = wall.time("encode+pack", || {
                self.frontend.encode_pack(&specs, backend, &mut ops)
            })?;

            let noisy = wall.time("program", || {
                program_refs(&packed, specs.len(), cp, &programmer, &mut rng, &mut ops)
            });

            let scores = wall.time("distance (IMC)", || {
                backend.execute(
                    &MvmJob::new(&packed, specs.len(), &noisy, specs.len(), cp, adc),
                    &mut ops,
                )
            })?;

            let (dend, dist_n) = wall.time("cluster (ASIC)", || {
                let d = scores_to_distances(&scores, specs.len());
                let max_t = cfg
                    .threshold_sweep
                    .iter()
                    .copied()
                    .fold(0.0f32, f32::max);
                (complete_linkage(&d, specs.len(), max_t), specs.len())
            });
            ops.merge_elements += dend.update_elements;
            debug_assert_eq!(dist_n, specs.len());

            for (ti, &t) in cfg.threshold_sweep.iter().enumerate() {
                let local = dend.cut(t);
                let n_local = local.iter().max().map(|m| m + 1).unwrap_or(0);
                for (li, &gi) in members.iter().enumerate() {
                    labels_per_t[ti][gi] = next_label + local[li];
                }
                let _ = n_local;
            }
            next_label += specs.len(); // safe upper bound on local labels
        }

        let curve: Vec<ClusterQuality> = cfg
            .threshold_sweep
            .iter()
            .enumerate()
            .map(|(ti, &t)| crate::cluster::quality::evaluate(&labels_per_t[ti], &truth, t))
            .collect();

        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let report = model.report(&ops);

        Ok(ClusteringOutcome {
            curve,
            ops,
            report,
            n_spectra: n,
            n_buckets,
            wall,
        })
    }
}

// ---------------------------------------------------------------------------
// DB search
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct SearchOutcomeSummary {
    /// Queries identified at the configured FDR.
    pub identified: usize,
    /// Identified queries whose matched peptide equals the ground truth.
    pub correct: usize,
    pub total_queries: usize,
    /// Ground-truth-correct identified peptide ids (for the Fig. S1 Venn).
    pub identified_peptides: Vec<u32>,
    /// Per-query (best target score, best decoy score) pairs — the raw
    /// separation signal (mean margin is the fine-grained noise metric the
    /// Fig. S3 sweeps report alongside identification counts).
    pub pairs: Vec<(f32, f32)>,
    pub fdr: FdrResult,
    pub ops: OpCounts,
    pub report: EnergyReport,
    pub wall: StageTimer,
}

impl SearchOutcomeSummary {
    /// Mean normalized separation between each query's best target and best
    /// decoy score, over queries with finite scores. Monotone in device
    /// noise: more write-verify (lower sigma) -> larger margin, even when
    /// the identification count has saturated.
    pub fn mean_margin(&self) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u32);
        for &(t, d) in &self.pairs {
            if t.is_finite() && d.is_finite() && t.abs() > 0.0 {
                sum += ((t - d) / t.abs().max(d.abs())) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

pub struct SearchPipeline {
    pub cfg: SpecPcmConfig,
    pub frontend: HdFrontend,
}

impl SearchPipeline {
    pub fn new(cfg: SpecPcmConfig) -> Self {
        let frontend = HdFrontend::new(&cfg);
        SearchPipeline { cfg, frontend }
    }

    pub fn run(
        &self,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
    ) -> Result<SearchOutcomeSummary> {
        let cfg = &self.cfg;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();
        let mut rng = Rng::new(cfg.seed ^ 0x5e);
        let programmer = Programmer::new(
            NoiseModel::new(cfg.material, MlcConfig::new(cfg.mlc_bits)),
            cfg.write_verify,
        );
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
        let cp = self.frontend.packed_width;

        // Reference set = targets followed by decoys.
        let all_refs: Vec<&Spectrum> = dataset
            .library
            .iter()
            .chain(dataset.decoys.iter())
            .collect();
        let n_targets = dataset.library.len();

        let packed_refs = wall.time("encode refs", || {
            self.frontend.encode_pack(&all_refs, backend, &mut ops)
        })?;
        let noisy_refs = wall.time("program refs", || {
            program_refs(
                &packed_refs,
                all_refs.len(),
                cp,
                &programmer,
                &mut rng,
                &mut ops,
            )
        });

        // Bucket references by precursor for candidate selection.
        let ref_spectra: Vec<Spectrum> = all_refs.iter().map(|s| (*s).clone()).collect();
        let ref_buckets = bucket_by_precursor(&ref_spectra, cfg.bucket_width);

        let queries: Vec<&Spectrum> = dataset.queries.iter().collect();
        let packed_queries = wall.time("encode queries", || {
            self.frontend.encode_pack(&queries, backend, &mut ops)
        })?;

        // Group queries by identical candidate-key sets so one IMC batch
        // shares one reference row block.
        let mut groups: BTreeMap<Vec<BucketKey>, Vec<usize>> = BTreeMap::new();
        for (qi, q) in queries.iter().enumerate() {
            let keys = candidate_keys_open(q.charge, q.precursor_mz, cfg.bucket_width, &PTM_SHIFTS);
            groups.entry(keys).or_default().push(qi);
        }

        // Per-query best (target score, decoy score) + matched peptide.
        let mut best: Vec<(f32, f32, Option<u32>)> =
            vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];

        for (keys, q_idxs) in &groups {
            let mut cand: Vec<usize> = keys
                .iter()
                .filter_map(|k| ref_buckets.get(k))
                .flatten()
                .copied()
                .collect();
            cand.sort_unstable();
            cand.dedup();
            if cand.is_empty() {
                continue;
            }

            // Gather candidate rows (targets + decoys interleaved by index).
            let mut cand_rows = Vec::with_capacity(cand.len() * cp);
            for &ri in &cand {
                cand_rows.extend_from_slice(&noisy_refs[ri * cp..(ri + 1) * cp]);
            }
            let mut q_rows = Vec::with_capacity(q_idxs.len() * cp);
            for &qi in q_idxs {
                q_rows.extend_from_slice(&packed_queries[qi * cp..(qi + 1) * cp]);
            }

            let scores = wall.time("similarity (IMC)", || {
                backend.execute(
                    &MvmJob::new(&q_rows, q_idxs.len(), &cand_rows, cand.len(), cp, adc),
                    &mut ops,
                )
            })?;

            wall.time("top-1 + merge (ASIC)", || {
                for (bi, &qi) in q_idxs.iter().enumerate() {
                    let row = &scores[bi * cand.len()..(bi + 1) * cand.len()];
                    for (ci, &ri) in cand.iter().enumerate() {
                        let s = row[ci];
                        if ri < n_targets {
                            if s > best[qi].0 {
                                best[qi].0 = s;
                                best[qi].2 = ref_spectra[ri].peptide_id;
                            }
                        } else if s > best[qi].1 {
                            best[qi].1 = s;
                        }
                    }
                }
            });
            ops.merge_elements += (q_idxs.len() * cand.len()) as u64;
        }

        let pairs: Vec<(f32, f32)> = best.iter().map(|&(t, d, _)| (t, d)).collect();
        let fdr = wall.time("FDR filter", || fdr_filter(&pairs, cfg.fdr));

        let mut correct = 0usize;
        let mut identified_peptides = Vec::new();
        for &qi in &fdr.accepted {
            if let (Some(matched), Some(truth)) = (best[qi].2, queries[qi].peptide_id) {
                if matched == truth {
                    correct += 1;
                    identified_peptides.push(matched);
                }
            }
        }
        identified_peptides.sort_unstable();
        identified_peptides.dedup();

        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let report = model.report(&ops);

        Ok(SearchOutcomeSummary {
            identified: fdr.accepted.len(),
            pairs,
            correct,
            total_queries: queries.len(),
            identified_peptides,
            fdr,
            ops,
            report,
            wall,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_to_distances_diag_zero_symmetric_range() {
        // 2 vectors: identical (s=100) and anti-correlated.
        let scores = vec![100.0, -80.0, -80.0, 100.0];
        let d = scores_to_distances(&scores, 2);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[3], 0.0);
        assert!((d[1] - 1.8).abs() < 1e-5);
        assert_eq!(d[1], d[2]);
    }

    #[test]
    fn clustering_pipeline_end_to_end_quality() {
        let cfg = SpecPcmConfig {
            hd_dim: 1024,
            bucket_width: 50.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_clustering()
        };
        let ds = ClusteringDataset::generate("t", 7, 12, 4, 6, 10, 0);
        let out = ClusteringPipeline::new(cfg)
            .run(&ds, &BackendDispatcher::reference())
            .unwrap();
        assert_eq!(out.n_spectra, ds.len());
        assert!(out.ops.mvm_ops > 0);
        assert!(out.report.total_j() > 0.0);
        // At some threshold, a decent fraction clusters with low error.
        let best = crate::cluster::quality::clustered_at_incorrect(&out.curve, 0.02);
        assert!(best > 0.3, "clustered {best} at 2% incorrect");
    }

    #[test]
    fn search_pipeline_end_to_end_identifies() {
        let cfg = SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_search()
        };
        let ds = SearchDataset::generate("t", 11, 60, 80, 0.8, 0.2, 0, 0);
        let out = SearchPipeline::new(cfg)
            .run(&ds, &BackendDispatcher::reference())
            .unwrap();
        assert_eq!(out.total_queries, 80);
        assert!(out.identified > 20, "identified {}", out.identified);
        // Most identifications must be ground-truth correct.
        assert!(
            out.correct as f64 >= 0.8 * out.identified as f64,
            "correct {} of {}",
            out.correct,
            out.identified
        );
        assert!(out.ops.mvm_ops > 0);
    }
}
