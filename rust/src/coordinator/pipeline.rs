//! End-to-end pipeline drivers (paper Figs. 1 and 2).
//!
//! Both pipelines execute their IMC score tiles through a pluggable
//! [`BackendDispatcher`] (see `backend/`): the dispatcher charges the
//! physical array-op count (one MVM op = one 128x128 bank processing one
//! input vector) and routes the host arithmetic to the configured
//! backend — scalar reference, bank-sharded parallel, or the PJRT
//! artifact — all bit-identical by contract.
//!
//! All PCM programming flows through an engine-style
//! [`super::ProgramContext`] (write-verify programmer + noise RNG stream +
//! bank-capacity [`super::SegmentAllocator`]): [`SearchPipeline::run`] is a
//! thin one-shot wrapper over the persistent [`super::SearchEngine`], and
//! [`ClusteringPipeline::run`] programs each precursor bucket transiently
//! through one shared context, releasing the bank rows after the bucket's
//! distance tile is computed.

use crate::array::{AdcConfig, ARRAY_DIM};
use crate::backend::{BackendDispatcher, MvmJob};
use crate::cluster::{complete_linkage, ClusterQuality};
use crate::config::SpecPcmConfig;
use crate::device::Programmer;
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::bucket::bucket_by_precursor;
use crate::ms::{ClusteringDataset, SearchDataset, Spectrum};
use crate::search::FdrResult;
use crate::telemetry::StageTimer;
use crate::util::error::Result;
use crate::util::Rng;

use super::engine::{ProgramContext, SearchEngine};
use super::frontend::HdFrontend;

/// Program packed reference HVs into PCM: applies write-verify-calibrated
/// noise and counts programming work. Returns the noisy conductances plus
/// the per-row injected-fault counts (all zero unless the programmer was
/// built `with_faults` — health telemetry sums them per segment).
pub(crate) fn program_refs(
    packed: &[f32],
    n_rows: usize,
    cp: usize,
    programmer: &Programmer,
    rng: &mut Rng,
    ops: &mut OpCounts,
) -> (Vec<f32>, Vec<u64>) {
    assert_eq!(packed.len(), n_rows * cp);
    let segments = (cp / ARRAY_DIM) as u64;
    let mut noisy = Vec::with_capacity(packed.len());
    let mut row_faults = Vec::with_capacity(n_rows);
    for row in 0..n_rows {
        let (stored, pulses, _reads, faults) =
            programmer.program_slice(&packed[row * cp..(row + 1) * cp], rng);
        noisy.extend_from_slice(&stored);
        row_faults.push(faults);
        // A row round pulses all 128 cells of one segment in parallel.
        // lint: charge-ok (program_refs IS the central programming charge — both pipelines and the engine charge rounds only through here)
        ops.program_rounds += pulses.div_ceil(ARRAY_DIM as u64).max(segments);
        // lint: charge-ok (verify reads charged alongside the rounds above)
        ops.verify_rounds += programmer.write_verify as u64 * segments;
    }
    (noisy, row_faults)
}

/// Normalized distance matrix from raw IMC scores: `d_ij = 1 - s_ij /
/// sqrt(s_ii * s_jj)`, clamped to [0, 2] (near-memory ASIC post-processing).
///
/// The raw score matrix is clean-query x noisy-reference, so `s_ij != s_ji`
/// in general; `complete_linkage` requires a symmetric input (it reads the
/// original lower triangle during merges), so the two directions are
/// averaged before normalizing — the resulting matrix is exactly symmetric
/// and the cut labels are independent of input row order.
pub(crate) fn scores_to_distances(scores: &[f32], n: usize) -> Vec<f32> {
    let mut d = vec![0f32; n * n];
    let diag: Vec<f32> = (0..n).map(|i| scores[i * n + i].max(1.0)).collect();
    for i in 0..n {
        for j in (i + 1)..n {
            let scale = (diag[i] * diag[j]).sqrt();
            let s = 0.5 * (scores[i * n + j] + scores[j * n + i]);
            let v = (1.0 - s / scale).clamp(0.0, 2.0);
            d[i * n + j] = v;
            d[j * n + i] = v;
        }
    }
    d
}

// ---------------------------------------------------------------------------
// Clustering
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ClusteringOutcome {
    /// Quality at each configured threshold, aggregated over all buckets.
    pub curve: Vec<ClusterQuality>,
    pub ops: OpCounts,
    pub report: EnergyReport,
    pub n_spectra: usize,
    pub n_buckets: usize,
    pub wall: StageTimer,
}

pub struct ClusteringPipeline {
    pub cfg: SpecPcmConfig,
    pub frontend: HdFrontend,
}

impl ClusteringPipeline {
    pub fn new(cfg: SpecPcmConfig) -> Self {
        let frontend = HdFrontend::new(&cfg);
        ClusteringPipeline { cfg, frontend }
    }

    pub fn run(
        &self,
        dataset: &ClusteringDataset,
        backend: &BackendDispatcher,
    ) -> Result<ClusteringOutcome> {
        let cfg = &self.cfg;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();
        let mut ctx = ProgramContext::new(cfg, self.frontend.packed_width, 0xc1)?;
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
        let cp = self.frontend.packed_width;

        let buckets = wall.time("bucketing", || {
            bucket_by_precursor(&dataset.spectra, cfg.bucket_width)
        });

        // Per-spectrum global cluster labels per threshold; singleton
        // buckets keep their own label.
        let n = dataset.spectra.len();
        let truth: Vec<u32> = dataset
            .spectra
            .iter()
            .map(|s| s.peptide_id.unwrap_or(u32::MAX))
            .collect();
        let mut labels_per_t: Vec<Vec<usize>> =
            vec![(0..n).collect(); cfg.threshold_sweep.len()];
        let mut next_label = n; // fresh labels beyond the singleton ids

        let mut n_buckets = 0usize;
        for (_key, members) in &buckets {
            if members.len() < 2 {
                continue;
            }
            n_buckets += 1;
            let specs: Vec<&Spectrum> = members.iter().map(|&i| &dataset.spectra[i]).collect();

            let packed = wall.time("encode+pack", || {
                self.frontend.encode_pack(&specs, backend, &mut ops)
            })?;

            let (noisy, slots, _faults) = wall.time("program", || {
                ctx.program_rows(&packed, specs.len(), cp, &mut ops)
            })?;

            let scores = wall.time("distance (IMC)", || {
                backend.execute(
                    &MvmJob::new(&packed, specs.len(), &noisy, specs.len(), cp, adc),
                    &mut ops,
                )
            })?;

            let (dend, dist_n) = wall.time("cluster (ASIC)", || {
                let d = scores_to_distances(&scores, specs.len());
                let max_t = cfg
                    .threshold_sweep
                    .iter()
                    .copied()
                    .fold(0.0f32, f32::max);
                (complete_linkage(&d, specs.len(), max_t), specs.len())
            });
            // lint: charge-ok (clustering's single dendrogram-merge charge, read off the completed linkage — no per-shard split exists)
            ops.merge_elements += dend.update_elements;
            debug_assert_eq!(dist_n, specs.len());

            for (ti, &t) in cfg.threshold_sweep.iter().enumerate() {
                let local = dend.cut(t);
                let n_local = local.iter().max().map(|m| m + 1).unwrap_or(0);
                for (li, &gi) in members.iter().enumerate() {
                    labels_per_t[ti][gi] = next_label + local[li];
                }
                let _ = n_local;
            }
            next_label += specs.len(); // safe upper bound on local labels

            // Clustering rows are transient: free the bank rows for the
            // next bucket once its distance tile has been consumed.
            ctx.release_rows(slots);
        }

        let curve: Vec<ClusterQuality> = cfg
            .threshold_sweep
            .iter()
            .enumerate()
            .map(|(ti, &t)| crate::cluster::quality::evaluate(&labels_per_t[ti], &truth, t))
            .collect();

        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let report = model.report(&ops);

        Ok(ClusteringOutcome {
            curve,
            ops,
            report,
            n_spectra: n,
            n_buckets,
            wall,
        })
    }
}

// ---------------------------------------------------------------------------
// DB search
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct SearchOutcomeSummary {
    /// Queries identified at the configured FDR.
    pub identified: usize,
    /// Identified queries whose matched peptide equals the ground truth.
    pub correct: usize,
    pub total_queries: usize,
    /// Ground-truth-correct identified peptide ids (for the Fig. S1 Venn).
    pub identified_peptides: Vec<u32>,
    /// Per-query (best target score, best decoy score) pairs — the raw
    /// separation signal (mean margin is the fine-grained noise metric the
    /// Fig. S3 sweeps report alongside identification counts).
    pub pairs: Vec<(f32, f32)>,
    pub fdr: FdrResult,
    pub ops: OpCounts,
    pub report: EnergyReport,
    pub wall: StageTimer,
}

impl SearchOutcomeSummary {
    /// Mean normalized separation between each query's best target and best
    /// decoy score, over queries with finite scores. Monotone in device
    /// noise: more write-verify (lower sigma) -> larger margin, even when
    /// the identification count has saturated.
    pub fn mean_margin(&self) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0u32);
        for &(t, d) in &self.pairs {
            if t.is_finite() && d.is_finite() && t.abs() > 0.0 {
                sum += ((t - d) / t.abs().max(d.abs())) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

/// One-shot DB-search driver: a thin wrapper over the persistent
/// [`SearchEngine`] that programs the library, serves every query in one
/// batch, and folds the result back into the classic summary shape. The
/// output is bit-identical to serving the same queries in any number of
/// `search_batch` calls (asserted in `rust/tests/engine_equivalence.rs`).
pub struct SearchPipeline {
    pub cfg: SpecPcmConfig,
}

impl SearchPipeline {
    pub fn new(cfg: SpecPcmConfig) -> Self {
        SearchPipeline { cfg }
    }

    pub fn run(
        &self,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
    ) -> Result<SearchOutcomeSummary> {
        let engine = SearchEngine::program(self.cfg.clone(), dataset, backend)?;
        let queries: Vec<&Spectrum> = dataset.queries.iter().collect();
        let batch = engine.search_batch(&queries, backend)?;
        engine.finalize(&queries, std::slice::from_ref(&batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_to_distances_diag_zero_symmetric_range() {
        // 2 vectors: identical (s=100) and anti-correlated.
        let scores = vec![100.0, -80.0, -80.0, 100.0];
        let d = scores_to_distances(&scores, 2);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[3], 0.0);
        assert!((d[1] - 1.8).abs() < 1e-5);
        assert_eq!(d[1], d[2]);
    }

    #[test]
    fn cut_labels_independent_of_row_order() {
        // High-noise config (no write-verify): clean-query x noisy-reference
        // scores are visibly asymmetric, which before the symmetrization fix
        // leaked into `complete_linkage`'s lower-triangle reads and made the
        // flat clusters depend on input row order.
        let cfg = SpecPcmConfig {
            hd_dim: 1024,
            write_verify: 0,
            ..SpecPcmConfig::paper_clustering()
        };
        let fe = HdFrontend::new(&cfg);
        let cp = fe.packed_width;
        let ds = ClusteringDataset::generate("t", 5, 2, 3, 3, 0, 0);
        let specs: Vec<&Spectrum> = ds.spectra.iter().collect();
        let n = specs.len();
        let be = BackendDispatcher::reference();
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());

        let mut ops = OpCounts::default();
        let packed = fe.encode_pack(&specs, &be, &mut ops).unwrap();
        let mut ctx = ProgramContext::new(&cfg, cp, 0xc1).unwrap();
        let (noisy, _slots, _faults) = ctx.program_rows(&packed, n, cp, &mut ops).unwrap();

        let labels_for = |order: &[usize]| -> Vec<usize> {
            let mut p = Vec::with_capacity(n * cp);
            let mut g = Vec::with_capacity(n * cp);
            for &i in order {
                p.extend_from_slice(&packed[i * cp..(i + 1) * cp]);
                g.extend_from_slice(&noisy[i * cp..(i + 1) * cp]);
            }
            let mut o = OpCounts::default();
            let scores = be
                .execute(&MvmJob::new(&p, n, &g, n, cp, adc), &mut o)
                .unwrap();
            let d = scores_to_distances(&scores, n);
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(d[i * n + j], d[j * n + i], "distance symmetry ({i},{j})");
                }
            }
            complete_linkage(&d, n, f32::INFINITY).cut(0.6)
        };

        let base_order: Vec<usize> = (0..n).collect();
        let base = labels_for(&base_order);
        let rev: Vec<usize> = (0..n).rev().collect();
        let permuted = labels_for(&rev);
        // Same partition up to relabeling: pairwise co-membership agrees
        // (original index i sits at position n-1-i in the reversed order).
        for a in 0..n {
            for b in 0..n {
                assert_eq!(
                    base[a] == base[b],
                    permuted[n - 1 - a] == permuted[n - 1 - b],
                    "co-membership of pair ({a},{b}) changed with row order"
                );
            }
        }
    }

    #[test]
    fn clustering_pipeline_end_to_end_quality() {
        let cfg = SpecPcmConfig {
            hd_dim: 1024,
            bucket_width: 50.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_clustering()
        };
        let ds = ClusteringDataset::generate("t", 7, 12, 4, 6, 10, 0);
        let out = ClusteringPipeline::new(cfg)
            .run(&ds, &BackendDispatcher::reference())
            .unwrap();
        assert_eq!(out.n_spectra, ds.len());
        assert!(out.ops.mvm_ops > 0);
        assert!(out.report.total_j() > 0.0);
        // At some threshold, a decent fraction clusters with low error.
        let best = crate::cluster::quality::clustered_at_incorrect(&out.curve, 0.02);
        assert!(best > 0.3, "clustered {best} at 2% incorrect");
    }

    #[test]
    fn search_pipeline_end_to_end_identifies() {
        let cfg = SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_search()
        };
        let ds = SearchDataset::generate("t", 11, 60, 80, 0.8, 0.2, 0, 0);
        let out = SearchPipeline::new(cfg)
            .run(&ds, &BackendDispatcher::reference())
            .unwrap();
        assert_eq!(out.total_queries, 80);
        assert!(out.identified > 20, "identified {}", out.identified);
        // Most identifications must be ground-truth correct.
        assert!(
            out.correct as f64 >= 0.8 * out.identified as f64,
            "correct {} of {}",
            out.correct,
            out.identified
        );
        assert!(out.ops.mvm_ops > 0);
    }
}
