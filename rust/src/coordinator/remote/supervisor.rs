//! Supervisor: a [`RemoteEngine`] that serves a sharded library through
//! per-shard **worker processes** instead of in-process
//! [`super::super::sharded::ShardedSearchEngine`] threads.
//!
//! One worker process per shard is spawned from the serving binary's
//! hidden `worker` subcommand and spoken to over stdin/stdout pipes with
//! the [`super::wire`] codec. The supervisor owns everything the fault
//! story needs:
//!
//! * **Deadlines, retries, backoff** — all on the deterministic logical
//!   clock ([`crate::config::RemoteConfig`]), never wall time (contract
//!   C6-TIME): each score attempt ticks the clock once, a failed attempt
//!   adds `backoff_base_ticks << attempt`, and a hang charges the full
//!   `deadline_ticks` before it is declared dead.
//! * **Respawn with bit-identical re-programming** — every slot stores
//!   its shard's initial chained noise-RNG state, row base, and reference
//!   slices, plus a global replay log of age/refresh mutations; respawn =
//!   spawn + `Program` + replay, after which the worker's conductances
//!   are bit-identical to a shard that never died.
//! * **Circuit breaker** — `breaker_threshold` consecutive failures open
//!   the breaker; an open shard gets exactly one half-open probe per
//!   batch instead of the full retry budget.
//! * **Graceful degradation** — a shard that exhausts its budget is
//!   skipped and the batch merges the survivors, tagging the outcome
//!   with a partial [`Coverage`] instead of failing.
//!
//! Failure handling state machine (per worker):
//!
//! ```text
//!            spawn+Program+replay ok
//!   [DOWN] ---------------------------> [UP] --score ok--> [UP]
//!     ^  \-- respawn fails --> [DOWN]    |
//!     |                                  | attempt fails (kill/hang/
//!     |   consecutive_failures >=        |  corrupt/app error)
//!     |   breaker_threshold              v
//!     +--------- [BREAKER OPEN] <--- [RETRYING] --budget spent--> skip
//!                     |                  | backoff += base << attempt,
//!                     | one half-open    | respawn, retry
//!                     v probe per batch  v
//!                  [UP on success]    [UP on success]
//! ```
//!
//! A seeded [`ChaosPlan`] injects kill/hang/corrupt-frame events at
//! logical ticks — the wire-level mirror of [`crate::device::FaultModel`]'s
//! seeded cell faults — so every fault-tolerance test is deterministic.
//!
//! Accounting follows the shard layer exactly: workers return
//! *chargeless* per-group candidate counts, the supervisor merges them
//! and charges once (contract C2-CHARGE), encode is charged once per
//! batch, and the energy model covers the union bank pool. With no
//! faults injected, results and cumulative [`OpCounts`] are bit-identical
//! to the in-process sharded engine (`rust/tests/worker_fault_tolerance.rs`).

use std::io::BufReader;
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::Mutex;

use crate::backend::BackendDispatcher;
use crate::config::{RemoteConfig, SpecPcmConfig};
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::bucket::BucketKey;
use crate::ms::{SearchDataset, Spectrum};
use crate::telemetry::{DeviceHealth, EncodeCacheStats, StageTimer};
use crate::util::error::{Error, Result};
use crate::util::sync::lock_unpoisoned;
use crate::util::RngState;

use super::super::engine::{
    chunk_ranges, fold_batches, BatchOutcome, Coverage, GroupCharges, ProgramContext,
    RefreshOutcome, RefreshPolicy, ServingCost,
};
use super::super::frontend::HdFrontend;
use super::super::pipeline::SearchOutcomeSummary;
use super::super::scheduler::ServeEngine;
use super::super::sharded::ShardPlan;
use super::wire::{self, FrameError, Request, Response};

/// A fault the chaos plan injects into one wire attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Kill the worker process before the attempt; the attempt then
    /// observes a dead pipe (broken pipe or EOF).
    Kill,
    /// The worker never answers: the attempt is charged the full
    /// `deadline_ticks` on the logical clock and declared dead. (Blocking
    /// pipe reads cannot be wall-clock-timed without violating C6-TIME,
    /// so the deadline is modeled at the transport seam.)
    Hang,
    /// The response frame arrives with its opcode byte corrupted — the
    /// codec rejects it with a typed decode error.
    CorruptFrame,
}

/// One scheduled fault: fires at the first score attempt against `shard`
/// whose logical tick is `>= tick`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    pub tick: u64,
    pub shard: usize,
    pub kind: ChaosKind,
}

/// A deterministic schedule of injected wire faults, in logical ticks —
/// the transport-level counterpart of [`crate::device::FaultModel`]'s
/// seeded cell faults. Events are consumed exactly once, in tick order
/// per shard.
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    /// No injected faults (production serving).
    pub fn none() -> ChaosPlan {
        ChaosPlan::default()
    }

    pub fn new(mut events: Vec<ChaosEvent>) -> ChaosPlan {
        events.sort_by_key(|e| e.tick);
        ChaosPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume the earliest event due for `shard` at logical time `now`.
    fn take(&mut self, shard: usize, now: u64) -> Option<ChaosKind> {
        let idx = self
            .events
            .iter()
            .position(|e| e.shard == shard && e.tick <= now)?;
        Some(self.events.remove(idx).kind)
    }
}

/// Counters the supervisor accumulates across the serving session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub workers: usize,
    pub workers_up: usize,
    pub breakers_open: usize,
    pub respawns: u64,
    pub retries: u64,
    pub degraded_batches: u64,
}

/// A live worker process: child + both pipe ends. Dropping it kills and
/// reaps the child (best-effort `Shutdown` first so a healthy worker
/// exits its loop cleanly).
struct WorkerProc {
    child: Child,
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
}

impl WorkerProc {
    /// One request/response round trip. `Ok` carries any decoded
    /// response, including `Response::Error` — the caller classifies.
    fn call(&mut self, req: &Request) -> Result<Response, FrameError> {
        wire::write_frame(&mut self.stdin, &req.encode())?;
        match wire::read_frame(&mut self.stdout)? {
            Some(payload) => Response::decode(&payload),
            None => Err(FrameError::Io("worker closed its response pipe".into())),
        }
    }

    /// The round trip with the response frame's opcode byte corrupted in
    /// flight (chaos only).
    fn call_corrupted(&mut self, req: &Request) -> Result<Response, FrameError> {
        wire::write_frame(&mut self.stdin, &req.encode())?;
        match wire::read_frame(&mut self.stdout)? {
            Some(mut payload) => {
                if let Some(b) = payload.first_mut() {
                    *b ^= 0xff;
                }
                Response::decode(&payload)
            }
            None => Err(FrameError::Io("worker closed its response pipe".into())),
        }
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = wire::write_frame(&mut self.stdin, &Request::Shutdown.encode());
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A mutation that must be replayed, in order, when a worker respawns so
/// its logical clock and refresh epochs match the shards that never died.
/// Replayed outcomes are discarded — their ops were charged when the
/// mutation first ran.
#[derive(Clone, Debug)]
enum ReplayOp {
    AdvanceAge(f64),
    Refresh(Vec<BucketKey>),
}

/// Everything one shard's supervision needs, including what a respawn
/// must re-program: the initial chained RNG state, the row base, and the
/// shard's reference slices.
struct WorkerSlot {
    proc: Option<WorkerProc>,
    initial_rng: RngState,
    row_base: u64,
    library: Vec<Spectrum>,
    decoys: Vec<Spectrum>,
    consecutive_failures: u32,
    breaker_open: bool,
    health: DeviceHealth,
}

impl WorkerSlot {
    fn up(&self) -> bool {
        self.proc.is_some()
    }
}

/// Mutable supervision state, behind one mutex (contract C3-SYNC) so
/// `search_batch` can keep the engine-shaped `&self` signature.
struct Supervisor {
    /// Logical serving clock: +1 per score attempt, +backoff on failure,
    /// +deadline_ticks on a hang. Deterministic — no wall time anywhere.
    clock: u64,
    /// Rendered config every (re)spawned worker programs from.
    cfg_toml: String,
    slots: Vec<WorkerSlot>,
    chaos: ChaosPlan,
    replay: Vec<ReplayOp>,
    stats: WorkerStats,
}

/// What one successful score attempt brings back from a shard.
struct ShardScored {
    best: Vec<(f32, f32, Option<u32>)>,
    charges: Vec<(Vec<BucketKey>, u64, u64)>,
    health: DeviceHealth,
}

/// The engine-shaped remote serving unit (see module docs). Constructed
/// by [`RemoteEngine::program`]; implements
/// [`super::super::scheduler::ServeEngine`] so the front door drives it
/// exactly like the in-process engines.
pub struct RemoteEngine {
    pub cfg: SpecPcmConfig,
    remote: RemoteConfig,
    plan: ShardPlan,
    exe: PathBuf,
    frontend: HdFrontend,
    program_ops: OpCounts,
    program_report: EnergyReport,
    program_wall: StageTimer,
    inner: Mutex<Supervisor>,
}

impl RemoteEngine {
    /// Partition the dataset like the in-process shard layer, spawn one
    /// worker per shard from `exe` (the serving binary; workers run its
    /// hidden `worker` subcommand), and program each over the wire with
    /// the chained noise-RNG state. `n_shards = 0` auto-computes the
    /// minimum count that fits `cfg`'s per-engine banks. Launch is
    /// fail-fast: a worker that cannot program is a hard error (chaos
    /// only ever targets serving attempts).
    pub fn program(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        n_shards: usize,
        exe: impl Into<PathBuf>,
        chaos: ChaosPlan,
    ) -> Result<Self> {
        let exe = exe.into();
        let plan = ShardPlan::for_capacity(
            &cfg,
            dataset.library.len(),
            dataset.decoys.len(),
            n_shards,
        )?;
        let remote = cfg.remote;
        let frontend = HdFrontend::new(&cfg);
        let cfg_toml = cfg.to_toml();

        // Chain the programming-noise RNG through the shards in row
        // order, exactly like the in-process shard layer, so the
        // concatenated noise stream equals the monolithic one.
        let mut rng = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG).state();
        let mut slots = Vec::with_capacity(plan.n_shards());
        let mut program_ops = OpCounts::default();
        let mut n_refs = 0u64;
        for i in 0..plan.n_shards() {
            let mut slot = WorkerSlot {
                proc: None,
                initial_rng: rng,
                row_base: plan.range(i).start as u64,
                library: dataset.library[plan.target_range(i)].to_vec(),
                decoys: dataset.decoys[plan.decoy_range(i)].to_vec(),
                consecutive_failures: 0,
                breaker_open: false,
                health: DeviceHealth::default(),
            };
            let mut proc = spawn_worker(&exe).map_err(|e| e.context(format!("shard {i}")))?;
            match proc
                .call(&Request::Program {
                    cfg_toml: cfg_toml.clone(),
                    row_base: slot.row_base,
                    rng,
                    library: slot.library.clone(),
                    decoys: slot.decoys.clone(),
                })
                .map_err(|e| Error::msg(format!("shard {i} program: {e}")))?
            {
                Response::Programmed {
                    rng: next,
                    ops,
                    n_refs: refs,
                } => {
                    rng = next;
                    program_ops += &ops;
                    n_refs += refs;
                }
                Response::Error(msg) => {
                    return Err(Error::msg(format!("shard {i} program failed: {msg}")))
                }
                other => {
                    return Err(Error::msg(format!(
                        "shard {i} program: unexpected response {other:?}"
                    )))
                }
            }
            slot.proc = Some(proc);
            slots.push(slot);
        }
        crate::ensure!(
            n_refs as usize == plan.n_rows(),
            "workers programmed {n_refs} rows, plan covers {}",
            plan.n_rows()
        );

        let program_report = pool_model(&cfg, plan.n_shards()).report(&program_ops);
        let stats = WorkerStats {
            workers: plan.n_shards(),
            workers_up: plan.n_shards(),
            ..WorkerStats::default()
        };
        Ok(RemoteEngine {
            cfg,
            remote,
            plan,
            exe,
            frontend,
            program_ops,
            program_report,
            program_wall: StageTimer::new(),
            inner: Mutex::new(Supervisor {
                clock: 0,
                cfg_toml,
                slots,
                chaos,
                replay: Vec::new(),
                stats,
            }),
        })
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards()
    }

    /// Reference rows programmed across every worker (targets + decoys).
    pub fn n_refs(&self) -> usize {
        self.plan.n_rows()
    }

    /// One-time library ops summed over every worker (grows when
    /// maintenance refreshes rows, mirroring the in-process layers).
    pub fn program_ops(&self) -> &OpCounts {
        &self.program_ops
    }

    pub fn program_report(&self) -> &EnergyReport {
        &self.program_report
    }

    /// Supervision counters (respawns, retries, degradation, breakers).
    pub fn worker_stats(&self) -> WorkerStats {
        let sup = lock_unpoisoned(&self.inner, "remote supervisor");
        let mut stats = sup.stats;
        stats.workers_up = sup.slots.iter().filter(|s| s.up()).count();
        stats.breakers_open = sup.slots.iter().filter(|s| s.breaker_open).count();
        stats
    }

    /// Current logical clock (ticks; tests assert deadline/backoff math).
    pub fn clock(&self) -> u64 {
        lock_unpoisoned(&self.inner, "remote supervisor").clock
    }

    /// Serve one query batch over the wire: encode once on the
    /// supervisor, fan the packed rows out to every worker with the full
    /// retry/breaker machinery, merge survivors in shard order (strict
    /// `>`, ties to the lowest global row) and charge ops from the merged
    /// per-group counts. Shards that exhaust their budget degrade the
    /// batch's [`Coverage`] instead of failing it; a batch with **zero**
    /// surviving shards is an error.
    pub fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();
        self.frontend.count_encode_ops(queries.len(), &mut ops);
        let levels = self.frontend.levels_of(queries);
        let packed = wall.time("encode queries", || {
            self.frontend.encode_pack_levels(&levels, backend)
        })?;
        // The supervisor encodes fresh per batch (no shared query-HV
        // cache across processes yet — ROADMAP headroom): all misses.
        let cache = EncodeCacheStats {
            hits: 0,
            misses: queries.len() as u64,
        };

        let req = Request::Score {
            cp: self.frontend.packed_width as u32,
            packed,
            meta: queries
                .iter()
                .map(|q| (q.charge, q.precursor_mz))
                .collect(),
        };

        let mut sup = lock_unpoisoned(&self.inner, "remote supervisor");
        let n_shards = self.plan.n_shards();
        let mut scored: Vec<Option<ShardScored>> = Vec::with_capacity(n_shards);
        let mut batch_retries = 0u64;
        for i in 0..n_shards {
            let got = wall.time("score shards", || {
                sup.score_shard(i, &req, &self.remote, &self.exe, &mut batch_retries)
            });
            scored.push(got);
        }
        let degraded_shards = scored.iter().filter(|s| s.is_none()).count() as u64;
        if degraded_shards > 0 {
            sup.stats.degraded_batches += 1;
        }
        sup.stats.retries += batch_retries;

        let mut rows_searched = 0u64;
        let mut best: Vec<(f32, f32, Option<u32>)> =
            vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];
        let mut charges = GroupCharges::default();
        let mut any = false;
        for (i, shard) in scored.into_iter().enumerate() {
            let Some(shard) = shard else { continue };
            any = true;
            rows_searched += self.plan.range(i).len() as u64;
            for (qi, &(t, d, m)) in shard.best.iter().enumerate() {
                if t > best[qi].0 {
                    best[qi].0 = t;
                    best[qi].2 = m;
                }
                if d > best[qi].1 {
                    best[qi].1 = d;
                }
            }
            for (keys, nq, nc) in shard.charges {
                charges.record(keys, nq as usize, nc as usize);
            }
            sup.slots[i].health = shard.health;
        }
        crate::ensure!(
            any || n_shards == 0,
            "all {n_shards} shards down: no coverage left to serve from"
        );
        charges.charge(self.frontend.packed_width, &mut ops);
        let health = sup.slots.iter().map(|s| s.health).sum();
        drop(sup);

        let pairs: Vec<(f32, f32)> = best.iter().map(|&(t, d, _)| (t, d)).collect();
        let matched: Vec<Option<u32>> = best.iter().map(|&(_, _, m)| m).collect();
        let report = pool_model(&self.cfg, n_shards).report(&ops);
        Ok(BatchOutcome {
            pairs,
            matched,
            ops,
            report,
            cache,
            health,
            coverage: Coverage {
                rows_searched,
                rows_total: self.plan.n_rows() as u64,
            },
            retries: batch_retries,
            degraded_shards,
            wall,
        })
    }

    /// Advance the deterministic serving clock on every worker and log
    /// the mutation for respawn replay. Wire failures mark the worker
    /// down (it catches up from the log when it respawns).
    pub fn advance_age(&mut self, seconds: f64) {
        let sup = self.inner.get_mut().unwrap_or_else(|e| e.into_inner());
        sup.replay.push(ReplayOp::AdvanceAge(seconds));
        for slot in &mut sup.slots {
            if let Some(proc) = slot.proc.as_mut() {
                if !matches!(proc.call(&Request::AdvanceAge(seconds)), Ok(Response::Aged)) {
                    slot.proc = None;
                }
            }
        }
    }

    /// One maintenance pass, shaped like the in-process shard layer: pool
    /// live workers' staleness candidates, one global policy selection,
    /// then each live worker refreshes its portion of the picked buckets.
    /// Down workers miss the pass live but replay it on respawn; wire
    /// failures mark the worker down and its outcome is dropped.
    pub fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        let sup = self.inner.get_mut().unwrap_or_else(|e| e.into_inner());
        let mut candidates = Vec::new();
        for slot in &mut sup.slots {
            if let Some(proc) = slot.proc.as_mut() {
                match proc.call(&Request::Candidates) {
                    Ok(Response::CandidateList(c)) => candidates.extend(c),
                    _ => slot.proc = None,
                }
            }
        }
        let keys = policy.select(candidates);
        let mut out = RefreshOutcome::default();
        for slot in &mut sup.slots {
            if let Some(proc) = slot.proc.as_mut() {
                match proc.call(&Request::Refresh(keys.clone())) {
                    Ok(Response::Refreshed {
                        buckets,
                        rows,
                        ops,
                    }) => {
                        out.buckets += buckets as usize;
                        out.rows += rows as usize;
                        out.ops += &ops;
                    }
                    _ => slot.proc = None,
                }
            }
        }
        sup.replay.push(ReplayOp::Refresh(keys));
        if out.rows > 0 {
            self.program_ops += &out.ops;
            self.program_report =
                pool_model(&self.cfg, self.plan.n_shards()).report(&self.program_ops);
        }
        out
    }

    /// Latest health over every worker (live workers refresh their
    /// snapshot on each served batch; down workers contribute their last
    /// known state).
    pub fn device_health(&self) -> DeviceHealth {
        let mut sup = lock_unpoisoned(&self.inner, "remote supervisor");
        for slot in &mut sup.slots {
            if let Some(proc) = slot.proc.as_mut() {
                if let Ok(Response::HealthReport(h)) = proc.call(&Request::Health) {
                    slot.health = h;
                }
            }
        }
        sup.slots.iter().map(|s| s.health).sum()
    }

    /// Same chunking contract as the in-process engines' `serve_chunked`.
    pub fn serve_chunked(
        &self,
        queries: &[&Spectrum],
        n_batches: usize,
        backend: &BackendDispatcher,
    ) -> Result<Vec<BatchOutcome>> {
        chunk_ranges(queries.len(), n_batches)
            .into_iter()
            .map(|r| self.search_batch(&queries[r], backend))
            .collect()
    }

    pub fn serving_cost(&self, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost::from_reports(&self.program_report, batches)
    }

    /// Fold served batches into the one-shot summary shape — identical to
    /// the in-process layers' fold, so a no-fault remote session's
    /// summary is bit-identical to the sharded engine's.
    pub fn finalize(
        &self,
        queries: &[&Spectrum],
        batches: &[BatchOutcome],
    ) -> Result<SearchOutcomeSummary> {
        let model = pool_model(&self.cfg, self.plan.n_shards());
        fold_batches(
            self.cfg.fdr,
            &model,
            &self.program_ops,
            &self.program_wall,
            queries,
            batches,
        )
    }
}

impl ServeEngine for RemoteEngine {
    fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        RemoteEngine::search_batch(self, queries, backend)
    }

    fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        RemoteEngine::maintain(self, policy)
    }

    fn device_health(&self) -> DeviceHealth {
        RemoteEngine::device_health(self)
    }
}

impl Supervisor {
    /// Score one shard with the full supervision machinery: chaos
    /// injection, logical-clock deadline accounting, bounded retries with
    /// exponential backoff, respawn-before-retry, and the circuit
    /// breaker. `None` means the shard degraded out of this batch.
    fn score_shard(
        &mut self,
        i: usize,
        req: &Request,
        remote: &RemoteConfig,
        exe: &PathBuf,
        batch_retries: &mut u64,
    ) -> Option<ShardScored> {
        // An open breaker gets one half-open probe instead of the full
        // retry budget.
        let budget = if self.slots[i].breaker_open {
            0
        } else {
            remote.retries
        };
        let mut attempt = 0u32;
        loop {
            if !self.slots[i].up() && !self.respawn(i, exe) {
                // Can't even get a process: burn the attempt.
            } else {
                self.clock += 1;
                let chaos = self.chaos.take(i, self.clock);
                match self.attempt(i, req, chaos, remote) {
                    Ok(scored) => {
                        let slot = &mut self.slots[i];
                        slot.consecutive_failures = 0;
                        slot.breaker_open = false;
                        return Some(scored);
                    }
                    Err(_) => {
                        // Any failed attempt poisons the worker: the pipe
                        // may hold half a frame, and a retry against live
                        // state could double-apply. Respawn-from-log is
                        // the only safe path (module docs).
                        let slot = &mut self.slots[i];
                        slot.proc = None;
                        slot.consecutive_failures += 1;
                        if slot.consecutive_failures >= remote.breaker_threshold {
                            slot.breaker_open = true;
                        }
                    }
                }
            }
            if attempt >= budget {
                return None;
            }
            self.clock += remote.backoff_base_ticks << attempt;
            attempt += 1;
            *batch_retries += 1;
        }
    }

    /// One wire attempt (with optional injected fault) against a live
    /// worker.
    fn attempt(
        &mut self,
        i: usize,
        req: &Request,
        chaos: Option<ChaosKind>,
        remote: &RemoteConfig,
    ) -> Result<ShardScored, FrameError> {
        let proc = self.slots[i]
            .proc
            .as_mut()
            .expect("attempt against a down worker");
        let resp = match chaos {
            Some(ChaosKind::Kill) => {
                let _ = proc.child.kill();
                let _ = proc.child.wait();
                proc.call(req)
            }
            Some(ChaosKind::Hang) => {
                self.clock += remote.deadline_ticks;
                Err(FrameError::Io(format!(
                    "deadline exceeded after {} ticks",
                    remote.deadline_ticks
                )))
            }
            Some(ChaosKind::CorruptFrame) => proc.call_corrupted(req),
            None => proc.call(req),
        }?;
        match resp {
            Response::Scored {
                best,
                charges,
                health,
            } => Ok(ShardScored {
                best,
                charges,
                health,
            }),
            Response::Error(msg) => Err(FrameError::BadPayload(format!("worker error: {msg}"))),
            other => Err(FrameError::BadPayload(format!(
                "unexpected response {other:?}"
            ))),
        }
    }

    /// Spawn + re-program a worker bit-identically (stored initial RNG
    /// state and row base), then replay the logged mutations so its
    /// logical clock and refresh epochs match the survivors. Replayed
    /// outcomes are discarded — already charged when they first ran.
    fn respawn(&mut self, i: usize, exe: &PathBuf) -> bool {
        let slot = &mut self.slots[i];
        let Ok(mut proc) = spawn_worker(exe) else {
            return false;
        };
        let programmed = proc.call(&Request::Program {
            cfg_toml: self.cfg_toml.clone(),
            row_base: slot.row_base,
            rng: slot.initial_rng,
            library: slot.library.clone(),
            decoys: slot.decoys.clone(),
        });
        if !matches!(programmed, Ok(Response::Programmed { .. })) {
            return false;
        }
        for op in &self.replay {
            let ok = match op {
                ReplayOp::AdvanceAge(s) => {
                    matches!(proc.call(&Request::AdvanceAge(*s)), Ok(Response::Aged))
                }
                ReplayOp::Refresh(keys) => matches!(
                    proc.call(&Request::Refresh(keys.clone())),
                    Ok(Response::Refreshed { .. })
                ),
            };
            if !ok {
                return false;
            }
        }
        slot.proc = Some(proc);
        self.stats.respawns += 1;
        true
    }
}

/// Energy/latency model of the union bank pool, same rule as the
/// in-process shard layer.
fn pool_model(cfg: &SpecPcmConfig, n_shards: usize) -> EnergyLatencyModel {
    EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks * n_shards.max(1))
}

/// Spawn one worker process running the hidden `worker` subcommand, both
/// pipes attached. Stderr passes through so worker panics surface.
fn spawn_worker(exe: &PathBuf) -> Result<WorkerProc> {
    let mut child = Command::new(exe)
        .arg("worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| Error::msg(format!("spawn worker {}: {e}", exe.display())))?;
    let stdin = child.stdin.take().ok_or_else(|| Error::msg("worker stdin missing"))?;
    let stdout = child
        .stdout
        .take()
        .map(BufReader::new)
        .ok_or_else(|| Error::msg("worker stdout missing"))?;
    Ok(WorkerProc {
        child,
        stdin,
        stdout,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plan_consumes_events_in_tick_order_per_shard() {
        let mut plan = ChaosPlan::new(vec![
            ChaosEvent {
                tick: 5,
                shard: 1,
                kind: ChaosKind::Hang,
            },
            ChaosEvent {
                tick: 2,
                shard: 0,
                kind: ChaosKind::Kill,
            },
            ChaosEvent {
                tick: 3,
                shard: 0,
                kind: ChaosKind::CorruptFrame,
            },
        ]);
        assert!(!plan.is_empty());
        // Not due yet.
        assert_eq!(plan.take(0, 1), None);
        // Due events come back in tick order, shard-filtered.
        assert_eq!(plan.take(0, 4), Some(ChaosKind::Kill));
        assert_eq!(plan.take(0, 4), Some(ChaosKind::CorruptFrame));
        assert_eq!(plan.take(0, 100), None);
        assert_eq!(plan.take(1, 4), None);
        assert_eq!(plan.take(1, 5), Some(ChaosKind::Hang));
        assert!(plan.is_empty());
        assert_eq!(ChaosPlan::none().take(0, u64::MAX), None);
    }
}
