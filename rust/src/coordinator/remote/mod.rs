//! Remote shard serving: per-shard worker **processes** behind a
//! supervising coordinator (ROADMAP "distributed serving").
//!
//! The in-process shard layer ([`super::sharded`]) fans batches out on
//! scoped threads; this module moves each shard into its own OS process
//! — the serving binary re-exec'd under the hidden `worker` subcommand —
//! and talks to it over stdin/stdout pipes with a compact length-prefixed
//! binary codec ([`wire`]; `util/json.rs` stays off the hot path).
//! Process isolation buys fault containment: a crashing, hanging, or
//! babbling shard can no longer take the whole serving session down.
//!
//! The split of responsibilities:
//!
//! * [`wire`] — the frame codec and message types. Floats travel as raw
//!   bits so a round trip is bit-exact; decoding is bounds-checked and
//!   returns typed [`wire::FrameError`]s on corrupt or truncated input —
//!   never a panic.
//! * [`worker`] — the request loop a worker process runs: program one
//!   shard from the wire (chained noise-RNG state, global row base),
//!   then score/age/refresh on demand. Workers return *chargeless*
//!   per-group candidate counts (contract C2-CHARGE) and never write
//!   anything but response frames to stdout.
//! * [`supervisor`] — [`RemoteEngine`]: deadline/retry/backoff on the
//!   deterministic logical clock, per-worker circuit breakers, respawn
//!   with bit-identical re-programming (stored RNG state + replay log),
//!   and graceful degradation to partial [`super::engine::Coverage`]
//!   when a shard stays down. A seeded [`ChaosPlan`] injects
//!   kill/hang/corrupt-frame faults deterministically for the
//!   fault-tolerance suite.
//!
//! With no faults injected, remote serving is **bit-identical** — scores
//! and cumulative op counts — to the in-process sharded engine
//! (`rust/tests/worker_fault_tolerance.rs`).

pub mod supervisor;
pub mod wire;
pub mod worker;

pub use supervisor::{ChaosEvent, ChaosKind, ChaosPlan, RemoteEngine, WorkerStats};
pub use wire::FrameError;
pub use worker::run_worker;
