//! Compact length-prefixed binary wire format between the supervisor and
//! its shard worker processes.
//!
//! Every message is one **frame**: a little-endian `u32` byte length
//! followed by exactly that many payload bytes, the first of which is the
//! opcode. The framing layer is deliberately paranoid — a corrupt or
//! truncated pipe must surface as a typed [`FrameError`], never a panic
//! or an unbounded allocation:
//!
//! * zero-length frames are rejected (`Empty` — every payload carries at
//!   least an opcode),
//! * lengths above [`MAX_FRAME`] are rejected *before* allocating
//!   (`Oversized`),
//! * EOF in the middle of a prefix or payload is `Truncated` (EOF **at**
//!   a frame boundary is the clean shutdown signal, `Ok(None)`),
//! * unknown opcodes and short/overlong payloads are `BadOpcode` /
//!   `BadPayload`.
//!
//! Scalar fields are fixed-width little-endian; floats travel as raw IEEE
//! bits (`to_bits`/`from_bits`), so a decoded [`RngState`] or score is
//! **bit-identical** to the encoded one — the whole remote layer's
//! equivalence contract rests on this round trip. `util::json` stays off
//! this path: JSON rendering is for artifacts, not the per-batch hot
//! loop.

use std::io::{Read, Write};

use crate::energy::OpCounts;
use crate::ms::bucket::BucketKey;
use crate::ms::{Peak, Spectrum};
use crate::telemetry::DeviceHealth;
use crate::util::error::Error;
use crate::util::rng::RngState;

use super::super::engine::RefreshOutcome;

/// Hard ceiling on one frame's payload (64 MiB) — far above any real
/// query batch, low enough that a corrupt length prefix can never drive
/// an unbounded allocation.
pub const MAX_FRAME: u32 = 1 << 26;

/// Typed failure of the framing / codec layer. Corrupt pipes produce one
/// of these — never a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Length prefix exceeds [`MAX_FRAME`].
    Oversized { len: u32 },
    /// Zero-length frame (a payload always carries at least an opcode).
    Empty,
    /// EOF mid-prefix or mid-payload.
    Truncated { expected: usize, got: usize },
    /// First payload byte is not a known opcode.
    BadOpcode(u8),
    /// Payload structure disagrees with its opcode.
    BadPayload(String),
    /// Underlying pipe I/O failure.
    Io(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Empty => write!(f, "zero-length frame"),
            FrameError::Truncated { expected, got } => {
                write!(f, "truncated frame: wanted {expected} bytes, got {got}")
            }
            FrameError::BadOpcode(op) => write!(f, "unknown opcode 0x{op:02x}"),
            FrameError::BadPayload(msg) => write!(f, "malformed payload: {msg}"),
            FrameError::Io(msg) => write!(f, "wire i/o: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<FrameError> for Error {
    fn from(e: FrameError) -> Error {
        Error::msg(e)
    }
}

/// Read one length-prefixed frame. `Ok(None)` is a clean EOF at a frame
/// boundary (the peer closed its pipe between messages); everything else
/// that is not a complete well-sized frame is a typed [`FrameError`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, FrameError> {
    let mut prefix = [0u8; 4];
    match read_exact_or_eof(r, &mut prefix)? {
        0 => return Ok(None),
        4 => {}
        got => return Err(FrameError::Truncated { expected: 4, got }),
    }
    let len = u32::from_le_bytes(prefix);
    if len == 0 {
        return Err(FrameError::Empty);
    }
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len });
    }
    let mut payload = vec![0u8; len as usize];
    match read_exact_or_eof(r, &mut payload)? {
        n if n == payload.len() => Ok(Some(payload)),
        got => Err(FrameError::Truncated {
            expected: len as usize,
            got,
        }),
    }
}

/// Write one length-prefixed frame and flush it (pipes buffer; the peer
/// blocks on the frame, so partial writes must never linger).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<(), FrameError> {
    if payload.is_empty() {
        return Err(FrameError::Empty);
    }
    if payload.len() > MAX_FRAME as usize {
        return Err(FrameError::Oversized {
            len: payload.len().min(u32::MAX as usize) as u32,
        });
    }
    let io = |e: std::io::Error| FrameError::Io(e.to_string());
    w.write_all(&(payload.len() as u32).to_le_bytes()).map_err(io)?;
    w.write_all(payload).map_err(io)?;
    w.flush().map_err(io)
}

/// Fill `buf`, tolerating EOF: returns how many bytes were read (equal to
/// `buf.len()` on success, less at EOF). Non-EOF I/O errors are `Io`.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, FrameError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e.to_string())),
        }
    }
    Ok(got)
}

// ---------------------------------------------------------------------
// Payload codec helpers: fixed-width little-endian scalars on a plain
// byte vector (writing) and a bounds-checked cursor (reading).

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    put_u32(out, v.to_bits());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_opt_u32(out: &mut Vec<u8>, v: Option<u32>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_u32(out, x);
        }
        None => put_u8(out, 0),
    }
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_f64(out, x);
        }
        None => put_u8(out, 0),
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_rng_state(out: &mut Vec<u8>, st: &RngState) {
    for &w in &st.s {
        put_u64(out, w);
    }
    put_opt_f64(out, st.gauss_spare);
}

fn put_op_counts(out: &mut Vec<u8>, ops: &OpCounts) {
    put_u64(out, ops.mvm_ops);
    put_u64(out, ops.program_rounds);
    put_u64(out, ops.verify_rounds);
    put_u64(out, ops.row_reads);
    put_u64(out, ops.encode_spectra);
    put_u64(out, ops.features);
    put_u64(out, ops.pack_elements);
    put_u64(out, ops.merge_elements);
}

fn put_health(out: &mut Vec<u8>, h: &DeviceHealth) {
    put_f64(out, h.max_age_seconds);
    put_f64(out, h.est_conductance_loss);
    put_u64(out, h.injected_faults);
    put_u64(out, h.refreshes);
}

fn put_bucket_key(out: &mut Vec<u8>, key: &BucketKey) {
    put_u8(out, key.0);
    put_i64(out, key.1);
}

fn put_spectrum(out: &mut Vec<u8>, s: &Spectrum) {
    put_u64(out, s.scan_id);
    put_f64(out, s.precursor_mz);
    put_u8(out, s.charge);
    put_opt_u32(out, s.peptide_id);
    put_u8(out, u8::from(s.is_decoy));
    put_f64(out, s.mod_shift);
    put_u32(out, s.peaks.len() as u32);
    for p in &s.peaks {
        put_f64(out, p.mz);
        put_f32(out, p.intensity);
    }
}

/// Bounds-checked payload cursor: every take reports a typed underrun
/// instead of panicking on a short slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.buf.len() - self.pos < n {
            return Err(FrameError::BadPayload(format!(
                "underrun: wanted {n} bytes at offset {}, payload is {} bytes",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64, FrameError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, FrameError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Element count for a sequence of `elem_size`-byte items, validated
    /// against the bytes actually remaining so a corrupt count can never
    /// drive an unbounded allocation.
    fn seq_len(&mut self, elem_size: usize, what: &str) -> Result<usize, FrameError> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        if n.saturating_mul(elem_size.max(1)) > remaining {
            return Err(FrameError::BadPayload(format!(
                "{what} count {n} exceeds the {remaining} payload bytes left"
            )));
        }
        Ok(n)
    }

    fn bool(&mut self) -> Result<bool, FrameError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(FrameError::BadPayload(format!("bad bool tag {t}"))),
        }
    }

    fn opt_u32(&mut self) -> Result<Option<u32>, FrameError> {
        Ok(if self.bool()? { Some(self.u32()?) } else { None })
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, FrameError> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    fn str(&mut self) -> Result<String, FrameError> {
        let n = self.seq_len(1, "string")?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|e| FrameError::BadPayload(format!("non-utf8 string: {e}")))
    }

    fn rng_state(&mut self) -> Result<RngState, FrameError> {
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = self.u64()?;
        }
        Ok(RngState {
            s,
            gauss_spare: self.opt_f64()?,
        })
    }

    fn op_counts(&mut self) -> Result<OpCounts, FrameError> {
        Ok(OpCounts {
            mvm_ops: self.u64()?,
            program_rounds: self.u64()?,
            verify_rounds: self.u64()?,
            row_reads: self.u64()?,
            encode_spectra: self.u64()?,
            features: self.u64()?,
            pack_elements: self.u64()?,
            merge_elements: self.u64()?,
        })
    }

    fn health(&mut self) -> Result<DeviceHealth, FrameError> {
        Ok(DeviceHealth {
            max_age_seconds: self.f64()?,
            est_conductance_loss: self.f64()?,
            injected_faults: self.u64()?,
            refreshes: self.u64()?,
        })
    }

    fn bucket_key(&mut self) -> Result<BucketKey, FrameError> {
        Ok((self.u8()?, self.i64()?))
    }

    fn spectrum(&mut self) -> Result<Spectrum, FrameError> {
        let scan_id = self.u64()?;
        let precursor_mz = self.f64()?;
        let charge = self.u8()?;
        let peptide_id = self.opt_u32()?;
        let is_decoy = self.bool()?;
        let mod_shift = self.f64()?;
        let n_peaks = self.seq_len(12, "peak")?;
        let mut peaks = Vec::with_capacity(n_peaks);
        for _ in 0..n_peaks {
            peaks.push(Peak {
                mz: self.f64()?,
                intensity: self.f32()?,
            });
        }
        Ok(Spectrum {
            scan_id,
            precursor_mz,
            charge,
            peaks,
            peptide_id,
            is_decoy,
            mod_shift,
        })
    }

    fn spectra(&mut self, what: &str) -> Result<Vec<Spectrum>, FrameError> {
        // A peak-less spectrum is 35 bytes; use that as the per-element
        // floor for the count sanity check.
        let n = self.seq_len(35, what)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.spectrum()?);
        }
        Ok(out)
    }

    /// Reject trailing garbage — a well-formed frame is consumed exactly.
    fn finish(self) -> Result<(), FrameError> {
        if self.pos != self.buf.len() {
            return Err(FrameError::BadPayload(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Messages.

const OP_PROGRAM: u8 = 0x01;
const OP_SCORE: u8 = 0x02;
const OP_ADVANCE_AGE: u8 = 0x03;
const OP_CANDIDATES: u8 = 0x04;
const OP_REFRESH: u8 = 0x05;
const OP_HEALTH: u8 = 0x06;
const OP_SHUTDOWN: u8 = 0x07;

const OP_PROGRAMMED: u8 = 0x81;
const OP_SCORED: u8 = 0x82;
const OP_AGED: u8 = 0x83;
const OP_CANDIDATE_LIST: u8 = 0x84;
const OP_REFRESHED: u8 = 0x85;
const OP_HEALTH_REPORT: u8 = 0x86;
const OP_SHUTTING_DOWN: u8 = 0x87;
const OP_ERROR: u8 = 0xff;

/// Supervisor → worker messages.
#[derive(Clone, Debug)]
pub enum Request {
    /// Program this worker's shard: full config, the shard's global row
    /// offset, the chained noise-RNG state to start from, and the shard's
    /// slice of the reference library (targets then decoys).
    Program {
        cfg_toml: String,
        row_base: u64,
        rng: RngState,
        library: Vec<Spectrum>,
        decoys: Vec<Spectrum>,
    },
    /// Score a batch of pre-packed query HVs (row-major `meta.len() x cp`
    /// rows). `meta` carries the only per-query fields candidate
    /// selection reads — `(charge, precursor_mz)` — so full spectra never
    /// cross the wire twice.
    Score {
        cp: u32,
        packed: Vec<f32>,
        meta: Vec<(u8, f64)>,
    },
    /// Advance the shard's deterministic serving clock.
    AdvanceAge(f64),
    /// Report per-bucket staleness candidates for global refresh selection.
    Candidates,
    /// Refresh the given bucket segments (the worker skips buckets it
    /// doesn't hold).
    Refresh(Vec<BucketKey>),
    /// Report the shard's device-health snapshot.
    Health,
    /// Clean shutdown.
    Shutdown,
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Program {
                cfg_toml,
                row_base,
                rng,
                library,
                decoys,
            } => {
                put_u8(&mut out, OP_PROGRAM);
                put_str(&mut out, cfg_toml);
                put_u64(&mut out, *row_base);
                put_rng_state(&mut out, rng);
                put_u32(&mut out, library.len() as u32);
                for s in library {
                    put_spectrum(&mut out, s);
                }
                put_u32(&mut out, decoys.len() as u32);
                for s in decoys {
                    put_spectrum(&mut out, s);
                }
            }
            Request::Score { cp, packed, meta } => {
                put_u8(&mut out, OP_SCORE);
                put_u32(&mut out, *cp);
                put_u32(&mut out, meta.len() as u32);
                for &(charge, mz) in meta {
                    put_u8(&mut out, charge);
                    put_f64(&mut out, mz);
                }
                put_u32(&mut out, packed.len() as u32);
                for &x in packed {
                    put_f32(&mut out, x);
                }
            }
            Request::AdvanceAge(seconds) => {
                put_u8(&mut out, OP_ADVANCE_AGE);
                put_f64(&mut out, *seconds);
            }
            Request::Candidates => put_u8(&mut out, OP_CANDIDATES),
            Request::Refresh(keys) => {
                put_u8(&mut out, OP_REFRESH);
                put_u32(&mut out, keys.len() as u32);
                for k in keys {
                    put_bucket_key(&mut out, k);
                }
            }
            Request::Health => put_u8(&mut out, OP_HEALTH),
            Request::Shutdown => put_u8(&mut out, OP_SHUTDOWN),
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Request, FrameError> {
        let mut r = Reader::new(payload);
        let req = match r.u8()? {
            OP_PROGRAM => {
                let cfg_toml = r.str()?;
                let row_base = r.u64()?;
                let rng = r.rng_state()?;
                let library = r.spectra("library spectrum")?;
                let decoys = r.spectra("decoy spectrum")?;
                Request::Program {
                    cfg_toml,
                    row_base,
                    rng,
                    library,
                    decoys,
                }
            }
            OP_SCORE => {
                let cp = r.u32()?;
                let n_meta = r.seq_len(9, "query meta")?;
                let mut meta = Vec::with_capacity(n_meta);
                for _ in 0..n_meta {
                    meta.push((r.u8()?, r.f64()?));
                }
                let n_packed = r.seq_len(4, "packed element")?;
                let mut packed = Vec::with_capacity(n_packed);
                for _ in 0..n_packed {
                    packed.push(r.f32()?);
                }
                // Checked arithmetic: a corrupt `cp` must produce a typed
                // error, not a debug-build multiply overflow.
                let want = (meta.len() as u64).checked_mul(cp as u64);
                if want != Some(packed.len() as u64) {
                    return Err(FrameError::BadPayload(format!(
                        "{} packed elements for {} queries of width {cp}",
                        packed.len(),
                        meta.len()
                    )));
                }
                Request::Score { cp, packed, meta }
            }
            OP_ADVANCE_AGE => Request::AdvanceAge(r.f64()?),
            OP_CANDIDATES => Request::Candidates,
            OP_REFRESH => {
                let n = r.seq_len(9, "bucket key")?;
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    keys.push(r.bucket_key()?);
                }
                Request::Refresh(keys)
            }
            OP_HEALTH => Request::Health,
            OP_SHUTDOWN => Request::Shutdown,
            op => return Err(FrameError::BadOpcode(op)),
        };
        r.finish()?;
        Ok(req)
    }
}

/// Worker → supervisor messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Shard programmed: the noise-RNG state after this shard (the chain
    /// hand-off for the next shard), the one-time programming ops, and
    /// the programmed row count.
    Programmed {
        rng: RngState,
        ops: OpCounts,
        n_refs: u64,
    },
    /// Batch scored: per-query `(best target, best decoy, matched
    /// peptide)` triples plus the **chargeless** per-group candidate
    /// counts — the coordinator merges groups across shards and charges
    /// centrally (contract C2-CHARGE; tile rounding is non-linear across
    /// shard splits).
    Scored {
        best: Vec<(f32, f32, Option<u32>)>,
        charges: Vec<(Vec<BucketKey>, u64, u64)>,
        health: DeviceHealth,
    },
    Aged,
    CandidateList(Vec<(BucketKey, f64)>),
    Refreshed {
        buckets: u64,
        rows: u64,
        ops: OpCounts,
    },
    HealthReport(DeviceHealth),
    ShuttingDown,
    /// The worker caught a handler error; the supervisor treats this like
    /// any other failed attempt (respawn + retry).
    Error(String),
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::Programmed { rng, ops, n_refs } => {
                put_u8(&mut out, OP_PROGRAMMED);
                put_rng_state(&mut out, rng);
                put_op_counts(&mut out, ops);
                put_u64(&mut out, *n_refs);
            }
            Response::Scored {
                best,
                charges,
                health,
            } => {
                put_u8(&mut out, OP_SCORED);
                put_u32(&mut out, best.len() as u32);
                for &(t, d, m) in best {
                    put_f32(&mut out, t);
                    put_f32(&mut out, d);
                    put_opt_u32(&mut out, m);
                }
                put_u32(&mut out, charges.len() as u32);
                for (keys, nq, nc) in charges {
                    put_u32(&mut out, keys.len() as u32);
                    for k in keys {
                        put_bucket_key(&mut out, k);
                    }
                    put_u64(&mut out, *nq);
                    put_u64(&mut out, *nc);
                }
                put_health(&mut out, health);
            }
            Response::Aged => put_u8(&mut out, OP_AGED),
            Response::CandidateList(cands) => {
                put_u8(&mut out, OP_CANDIDATE_LIST);
                put_u32(&mut out, cands.len() as u32);
                for (k, age) in cands {
                    put_bucket_key(&mut out, k);
                    put_f64(&mut out, *age);
                }
            }
            Response::Refreshed { buckets, rows, ops } => {
                put_u8(&mut out, OP_REFRESHED);
                put_u64(&mut out, *buckets);
                put_u64(&mut out, *rows);
                put_op_counts(&mut out, ops);
            }
            Response::HealthReport(h) => {
                put_u8(&mut out, OP_HEALTH_REPORT);
                put_health(&mut out, h);
            }
            Response::ShuttingDown => put_u8(&mut out, OP_SHUTTING_DOWN),
            Response::Error(msg) => {
                put_u8(&mut out, OP_ERROR);
                put_str(&mut out, msg);
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Response, FrameError> {
        let mut r = Reader::new(payload);
        let resp = match r.u8()? {
            OP_PROGRAMMED => Response::Programmed {
                rng: r.rng_state()?,
                ops: r.op_counts()?,
                n_refs: r.u64()?,
            },
            OP_SCORED => {
                let n_best = r.seq_len(9, "best triple")?;
                let mut best = Vec::with_capacity(n_best);
                for _ in 0..n_best {
                    best.push((r.f32()?, r.f32()?, r.opt_u32()?));
                }
                let n_groups = r.seq_len(20, "charge group")?;
                let mut charges = Vec::with_capacity(n_groups);
                for _ in 0..n_groups {
                    let n_keys = r.seq_len(9, "group key")?;
                    let mut keys = Vec::with_capacity(n_keys);
                    for _ in 0..n_keys {
                        keys.push(r.bucket_key()?);
                    }
                    charges.push((keys, r.u64()?, r.u64()?));
                }
                Response::Scored {
                    best,
                    charges,
                    health: r.health()?,
                }
            }
            OP_AGED => Response::Aged,
            OP_CANDIDATE_LIST => {
                let n = r.seq_len(17, "staleness candidate")?;
                let mut cands = Vec::with_capacity(n);
                for _ in 0..n {
                    cands.push((r.bucket_key()?, r.f64()?));
                }
                Response::CandidateList(cands)
            }
            OP_REFRESHED => Response::Refreshed {
                buckets: r.u64()?,
                rows: r.u64()?,
                ops: r.op_counts()?,
            },
            OP_HEALTH_REPORT => Response::HealthReport(r.health()?),
            OP_SHUTTING_DOWN => Response::ShuttingDown,
            OP_ERROR => Response::Error(r.str()?),
            op => return Err(FrameError::BadOpcode(op)),
        };
        r.finish()?;
        Ok(resp)
    }
}

/// Convert a [`RefreshOutcome`] into the wire's `Refreshed` fields.
pub fn refreshed_of(out: &RefreshOutcome) -> Response {
    Response::Refreshed {
        buckets: out.buckets as u64,
        rows: out.rows as u64,
        ops: out.ops,
    }
}

/// Convert a decoded `Refreshed` back into a [`RefreshOutcome`].
pub fn outcome_of(buckets: u64, rows: u64, ops: OpCounts) -> RefreshOutcome {
    RefreshOutcome {
        buckets: buckets as usize,
        rows: rows as usize,
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spectrum(scan: u64) -> Spectrum {
        Spectrum {
            scan_id: scan,
            precursor_mz: 512.75,
            charge: 2,
            peaks: vec![
                Peak {
                    mz: 101.25,
                    intensity: 0.5,
                },
                Peak {
                    mz: 230.0,
                    intensity: 1.0,
                },
            ],
            peptide_id: Some(7),
            is_decoy: false,
            mod_shift: -16.0,
        }
    }

    fn round_trip_request(req: &Request) -> Request {
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &req.encode()).unwrap();
        let payload = read_frame(&mut pipe.as_slice()).unwrap().unwrap();
        Request::decode(&payload).unwrap()
    }

    #[test]
    fn request_round_trips_bit_exactly() {
        let req = Request::Program {
            cfg_toml: "hd_dim = 2048\n".into(),
            row_base: 96,
            rng: RngState {
                s: [1, 2, 3, u64::MAX],
                gauss_spare: Some(-0.25),
            },
            library: vec![spectrum(1), spectrum(2)],
            decoys: vec![spectrum(3)],
        };
        match round_trip_request(&req) {
            Request::Program {
                cfg_toml,
                row_base,
                rng,
                library,
                decoys,
            } => {
                assert_eq!(cfg_toml, "hd_dim = 2048\n");
                assert_eq!(row_base, 96);
                assert_eq!(
                    rng,
                    RngState {
                        s: [1, 2, 3, u64::MAX],
                        gauss_spare: Some(-0.25)
                    }
                );
                assert_eq!(library.len(), 2);
                assert_eq!(library[0].scan_id, 1);
                assert_eq!(library[0].peaks.len(), 2);
                assert_eq!(library[0].peaks[1].mz, 230.0);
                assert_eq!(library[0].peptide_id, Some(7));
                assert_eq!(decoys[0].scan_id, 3);
            }
            other => panic!("decoded {other:?}"),
        }

        let req = Request::Score {
            cp: 2,
            packed: vec![1.0, -2.0, 0.5, f32::NEG_INFINITY],
            meta: vec![(2, 500.25), (3, 777.0)],
        };
        match round_trip_request(&req) {
            Request::Score { cp, packed, meta } => {
                assert_eq!(cp, 2);
                // NEG_INFINITY must survive bitwise — scores merge on it.
                assert_eq!(
                    packed.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    [1.0f32, -2.0, 0.5, f32::NEG_INFINITY]
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<_>>()
                );
                assert_eq!(meta, vec![(2, 500.25), (3, 777.0)]);
            }
            other => panic!("decoded {other:?}"),
        }

        match round_trip_request(&Request::AdvanceAge(3600.5)) {
            Request::AdvanceAge(s) => assert_eq!(s, 3600.5),
            other => panic!("decoded {other:?}"),
        }
        match round_trip_request(&Request::Refresh(vec![(2, -3), (3, 40)])) {
            Request::Refresh(keys) => assert_eq!(keys, vec![(2, -3), (3, 40)]),
            other => panic!("decoded {other:?}"),
        }
        assert!(matches!(
            round_trip_request(&Request::Candidates),
            Request::Candidates
        ));
        assert!(matches!(
            round_trip_request(&Request::Health),
            Request::Health
        ));
        assert!(matches!(
            round_trip_request(&Request::Shutdown),
            Request::Shutdown
        ));
    }

    #[test]
    fn response_round_trips_bit_exactly() {
        let cases = vec![
            Response::Programmed {
                rng: RngState {
                    s: [9, 8, 7, 6],
                    gauss_spare: None,
                },
                ops: OpCounts {
                    mvm_ops: 1,
                    program_rounds: 2,
                    verify_rounds: 3,
                    row_reads: 4,
                    encode_spectra: 5,
                    features: 6,
                    pack_elements: 7,
                    merge_elements: 8,
                },
                n_refs: 360,
            },
            Response::Scored {
                best: vec![
                    (1.5, -0.25, Some(3)),
                    (f32::NEG_INFINITY, f32::NEG_INFINITY, None),
                ],
                charges: vec![(vec![(2, 100), (2, 101)], 4, 250), (vec![(3, -1)], 1, 0)],
                health: DeviceHealth {
                    max_age_seconds: 10.0,
                    est_conductance_loss: 0.01,
                    injected_faults: 2,
                    refreshes: 5,
                },
            },
            Response::Aged,
            Response::CandidateList(vec![((2, 7), 120.5), ((3, -2), 0.0)]),
            Response::Refreshed {
                buckets: 3,
                rows: 17,
                ops: OpCounts::default(),
            },
            Response::HealthReport(DeviceHealth::default()),
            Response::ShuttingDown,
            Response::Error("shard exploded".into()),
        ];
        for resp in cases {
            let mut pipe = Vec::new();
            write_frame(&mut pipe, &resp.encode()).unwrap();
            let payload = read_frame(&mut pipe.as_slice()).unwrap().unwrap();
            assert_eq!(Response::decode(&payload).unwrap(), resp);
        }
    }

    #[test]
    fn clean_eof_at_frame_boundary_is_none() {
        let mut empty: &[u8] = &[];
        assert_eq!(read_frame(&mut empty).unwrap(), None);

        // A complete frame followed by EOF: one Some, then None.
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &Request::Health.encode()).unwrap();
        let mut r = pipe.as_slice();
        assert!(read_frame(&mut r).unwrap().is_some());
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn truncated_streams_are_typed_errors_not_panics() {
        // EOF mid-prefix.
        let mut r: &[u8] = &[5, 0];
        assert_eq!(
            read_frame(&mut r).unwrap_err(),
            FrameError::Truncated {
                expected: 4,
                got: 2
            }
        );

        // EOF mid-payload.
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &Request::AdvanceAge(1.0).encode()).unwrap();
        let cut = pipe.len() - 3;
        let mut r = &pipe[..cut];
        match read_frame(&mut r).unwrap_err() {
            FrameError::Truncated { expected, got } => {
                assert_eq!(expected, 9);
                assert_eq!(got, 6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zero_length_and_oversized_frames_are_rejected() {
        let zero = 0u32.to_le_bytes();
        let mut r: &[u8] = &zero;
        assert_eq!(read_frame(&mut r).unwrap_err(), FrameError::Empty);

        // An oversized length prefix errors *before* allocating: the
        // pipe holds only 4 bytes, so surviving this proves no 2 GiB
        // buffer was attempted.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        let mut r: &[u8] = &huge[..];
        assert_eq!(
            read_frame(&mut r).unwrap_err(),
            FrameError::Oversized { len: MAX_FRAME + 1 }
        );

        assert_eq!(
            write_frame(&mut Vec::new(), &[]).unwrap_err(),
            FrameError::Empty
        );
    }

    #[test]
    fn corrupt_payloads_are_typed_errors_not_panics() {
        // Unknown opcode.
        assert_eq!(
            Request::decode(&[0x44]).unwrap_err(),
            FrameError::BadOpcode(0x44)
        );
        assert_eq!(
            Response::decode(&[0x02]).unwrap_err(),
            FrameError::BadOpcode(0x02)
        );

        // Underrun inside a field.
        assert!(matches!(
            Request::decode(&[OP_ADVANCE_AGE, 1, 2]).unwrap_err(),
            FrameError::BadPayload(_)
        ));

        // A corrupt sequence count larger than the remaining payload is
        // rejected before allocation.
        let mut buf = vec![OP_REFRESH];
        put_u32(&mut buf, u32::MAX);
        assert!(matches!(
            Request::decode(&buf).unwrap_err(),
            FrameError::BadPayload(_)
        ));

        // Packed length inconsistent with nq * cp.
        let mut buf = vec![OP_SCORE];
        put_u32(&mut buf, 4); // cp
        put_u32(&mut buf, 1); // one query
        put_u8(&mut buf, 2);
        put_f64(&mut buf, 500.0);
        put_u32(&mut buf, 2); // but only 2 packed elements
        put_f32(&mut buf, 1.0);
        put_f32(&mut buf, 2.0);
        assert!(matches!(
            Request::decode(&buf).unwrap_err(),
            FrameError::BadPayload(_)
        ));

        // Trailing garbage after a complete message.
        let mut buf = Request::Health.encode();
        buf.push(0);
        assert!(matches!(
            Request::decode(&buf).unwrap_err(),
            FrameError::BadPayload(_)
        ));

        // Bad bool tag inside an Option.
        let mut buf = vec![OP_PROGRAMMED];
        for _ in 0..4 {
            put_u64(&mut buf, 0);
        }
        put_u8(&mut buf, 7); // gauss_spare tag must be 0/1
        assert!(matches!(
            Response::decode(&buf).unwrap_err(),
            FrameError::BadPayload(_)
        ));

        // Bit-flipped frames decode to *some* typed result, never panic:
        // sweep every single-bit corruption of a small frame.
        let good = Response::HealthReport(DeviceHealth::default()).encode();
        for byte in 0..good.len() {
            for bit in 0..8 {
                let mut bad = good.clone();
                bad[byte] ^= 1 << bit;
                let _ = Response::decode(&bad); // must not panic
            }
        }
    }
}
