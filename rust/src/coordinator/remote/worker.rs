//! Shard worker: the request loop a `specpcm worker` process runs over
//! its stdin/stdout pipes.
//!
//! The loop is generic over `Read`/`Write` so it unit-tests on in-memory
//! byte pipes; the hidden CLI subcommand binds it to the real stdio. A
//! worker owns exactly one [`SearchEngine`] shard, programmed from the
//! supervisor's `Program` request — full config text, the shard's global
//! row offset, and the **chained noise-RNG state** — so its stored
//! conductances are bit-identical to the corresponding in-process shard,
//! and the state it hands back lets the supervisor chain the next shard
//! (or respawn this one) bit-identically.
//!
//! Error discipline: anything recoverable — a malformed payload, an
//! engine error, a request before `Program` — becomes a
//! [`Response::Error`] frame and the loop continues; the supervisor
//! decides what to do. Only a lost framing layer (truncated/oversized
//! frame on the request pipe) or a dead response pipe exits the process,
//! because no further request boundary can be trusted. The worker never
//! writes anything to its stdout except response frames.

use std::io::{Read, Write};

use crate::backend::BackendDispatcher;
use crate::config::SpecPcmConfig;
use crate::ms::{SearchDataset, Spectrum};
use crate::util::error::Result;
use crate::util::Rng;

use super::super::engine::SearchEngine;
use super::wire::{self, Request, Response};

/// Dataset label of every remote shard (datasets carry a `&'static str`
/// name; the real name lives with the supervisor, not the shard).
const SHARD_DATASET_NAME: &str = "remote-shard";

struct WorkerState {
    engine: SearchEngine,
    backend: BackendDispatcher,
}

/// Serve requests until `Shutdown`, clean EOF, or a fatal wire failure.
pub fn run_worker<R: Read, W: Write>(input: &mut R, output: &mut W) -> Result<()> {
    let mut state: Option<WorkerState> = None;
    loop {
        let payload = match wire::read_frame(input) {
            Ok(Some(p)) => p,
            // Clean EOF at a frame boundary: the supervisor dropped the
            // pipe (its own shutdown path); exit without complaint.
            Ok(None) => return Ok(()),
            Err(e) => {
                // The request framing is lost — no later byte can be
                // trusted as a boundary. Best-effort error frame, then
                // exit.
                let _ = wire::write_frame(output, &Response::Error(format!("request frame: {e}")).encode());
                return Err(e.into());
            }
        };
        let (resp, shutdown) = match Request::decode(&payload) {
            Ok(Request::Shutdown) => (Response::ShuttingDown, true),
            Ok(req) => (handle(&mut state, req), false),
            // Framing held but the payload is corrupt: report and keep
            // serving — the next frame is still well-delimited.
            Err(e) => (Response::Error(format!("bad request: {e}")), false),
        };
        wire::write_frame(output, &resp.encode())?;
        if shutdown {
            return Ok(());
        }
    }
}

/// Dispatch one decoded request. Every failure becomes `Response::Error`
/// — a worker must never panic on wire-supplied data.
fn handle(state: &mut Option<WorkerState>, req: Request) -> Response {
    match req {
        Request::Program {
            cfg_toml,
            row_base,
            rng,
            library,
            decoys,
        } => {
            let cfg = match SpecPcmConfig::from_toml(&cfg_toml) {
                Ok(c) => c,
                Err(e) => return Response::Error(format!("config: {e}")),
            };
            let dataset = SearchDataset {
                name: SHARD_DATASET_NAME,
                library,
                decoys,
                queries: Vec::new(),
                identifiable_fraction: 0.0,
                paper_queries: 0,
                paper_library: 0,
            };
            let backend = BackendDispatcher::from_config(&cfg);
            let mut engine = match SearchEngine::program_with_rng(
                cfg,
                &dataset,
                &backend,
                Rng::from_state(rng),
            ) {
                Ok(e) => e,
                Err(e) => return Response::Error(format!("program: {e}")),
            };
            engine.set_row_base(row_base as usize);
            let resp = Response::Programmed {
                rng: engine.noise_rng_state().state(),
                ops: *engine.program_ops(),
                n_refs: engine.n_refs() as u64,
            };
            *state = Some(WorkerState { engine, backend });
            resp
        }
        Request::Score { cp, packed, meta } => {
            let Some(ws) = state.as_ref() else {
                return Response::Error("score before program".into());
            };
            if cp as usize != ws.engine.packed_width() {
                return Response::Error(format!(
                    "packed width {cp} != shard width {}",
                    ws.engine.packed_width()
                ));
            }
            // Candidate selection reads only (charge, precursor_mz);
            // rebuild minimal spectra around the wire meta — the peak
            // data already lives inside the packed HVs.
            let specs: Vec<Spectrum> = meta
                .iter()
                .map(|&(charge, precursor_mz)| Spectrum {
                    scan_id: 0,
                    precursor_mz,
                    charge,
                    peaks: Vec::new(),
                    peptide_id: None,
                    is_decoy: false,
                    mod_shift: 0.0,
                })
                .collect();
            let refs: Vec<&Spectrum> = specs.iter().collect();
            match ws.engine.score_packed(&refs, &packed, &ws.backend) {
                Ok(scored) => Response::Scored {
                    best: scored.best,
                    charges: scored
                        .charges
                        .entries()
                        .map(|(keys, nq, nc)| (keys.to_vec(), nq as u64, nc as u64))
                        .collect(),
                    health: ws.engine.device_health(),
                },
                Err(e) => Response::Error(format!("score: {e}")),
            }
        }
        Request::AdvanceAge(seconds) => {
            let Some(ws) = state.as_mut() else {
                return Response::Error("advance-age before program".into());
            };
            // `advance_age` asserts on bad durations; wire data must turn
            // into a typed response instead.
            if !(seconds.is_finite() && seconds >= 0.0) {
                return Response::Error(format!(
                    "advance-age: {seconds} is not a finite non-negative duration"
                ));
            }
            ws.engine.advance_age(seconds);
            Response::Aged
        }
        Request::Candidates => match state.as_ref() {
            Some(ws) => Response::CandidateList(ws.engine.refresh_candidates()),
            None => Response::Error("candidates before program".into()),
        },
        Request::Refresh(keys) => match state.as_mut() {
            Some(ws) => wire::refreshed_of(&ws.engine.refresh_buckets(&keys)),
            None => Response::Error("refresh before program".into()),
        },
        Request::Health => match state.as_ref() {
            Some(ws) => Response::HealthReport(ws.engine.device_health()),
            None => Response::Error("health before program".into()),
        },
        // Handled by the loop before dispatch.
        Request::Shutdown => Response::ShuttingDown,
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::ProgramContext;
    use super::*;
    use crate::ms::SearchDataset;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_search()
        }
    }

    /// Encode requests into one byte pipe, run the worker loop over it,
    /// and decode every response frame.
    fn drive(requests: &[Request]) -> Vec<Response> {
        let mut input = Vec::new();
        for req in requests {
            wire::write_frame(&mut input, &req.encode()).unwrap();
        }
        let mut output = Vec::new();
        run_worker(&mut input.as_slice(), &mut output).unwrap();
        let mut out = Vec::new();
        let mut r = output.as_slice();
        while let Some(payload) = wire::read_frame(&mut r).unwrap() {
            out.push(Response::decode(&payload).unwrap());
        }
        out
    }

    #[test]
    fn worker_loop_matches_in_process_engine_bitwise() {
        let cfg = small_cfg();
        let ds = SearchDataset::generate("t", 41, 30, 8, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();

        // In-process oracle.
        let oracle = SearchEngine::program(cfg.clone(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let (packed, _) = oracle.encode_queries(&queries, &be).unwrap();
        let want = oracle.score_packed(&queries, &packed, &be).unwrap();

        // The same work over the wire.
        let rng0 = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG).state();
        let meta: Vec<(u8, f64)> =
            queries.iter().map(|q| (q.charge, q.precursor_mz)).collect();
        let responses = drive(&[
            Request::Program {
                cfg_toml: cfg.to_toml(),
                row_base: 0,
                rng: rng0,
                library: ds.library.clone(),
                decoys: ds.decoys.clone(),
            },
            Request::Score {
                cp: oracle.packed_width() as u32,
                packed: packed.clone(),
                meta,
            },
            Request::Health,
            Request::Shutdown,
        ]);
        assert_eq!(responses.len(), 4);

        match &responses[0] {
            Response::Programmed { rng, ops, n_refs } => {
                assert_eq!(*rng, oracle.noise_rng_state().state());
                assert_eq!(*ops, *oracle.program_ops());
                assert_eq!(*n_refs, oracle.n_refs() as u64);
            }
            other => panic!("{other:?}"),
        }
        match &responses[1] {
            Response::Scored {
                best,
                charges,
                health,
            } => {
                assert_eq!(best.len(), want.best.len());
                for (got, want) in best.iter().zip(&want.best) {
                    assert_eq!(got.0.to_bits(), want.0.to_bits());
                    assert_eq!(got.1.to_bits(), want.1.to_bits());
                    assert_eq!(got.2, want.2);
                }
                let want_charges: Vec<(Vec<_>, u64, u64)> = want
                    .charges
                    .entries()
                    .map(|(k, nq, nc)| (k.to_vec(), nq as u64, nc as u64))
                    .collect();
                assert_eq!(*charges, want_charges);
                assert_eq!(*health, oracle.device_health());
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(responses[2], Response::HealthReport(oracle.device_health()));
        assert_eq!(responses[3], Response::ShuttingDown);
    }

    #[test]
    fn requests_before_program_are_typed_errors() {
        let responses = drive(&[
            Request::Score {
                cp: 4,
                packed: vec![0.0; 4],
                meta: vec![(2, 500.0)],
            },
            Request::Candidates,
            Request::Health,
            Request::AdvanceAge(1.0),
            Request::Refresh(vec![(2, 1)]),
            Request::Shutdown,
        ]);
        assert_eq!(responses.len(), 6);
        for resp in &responses[..5] {
            assert!(
                matches!(resp, Response::Error(msg) if msg.contains("before program")),
                "{resp:?}"
            );
        }
        assert_eq!(responses[5], Response::ShuttingDown);
    }

    #[test]
    fn bad_wire_data_is_reported_never_panics() {
        let cfg = small_cfg();
        let ds = SearchDataset::generate("t", 42, 10, 2, 0.8, 0.2, 0, 0);
        let rng0 = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG).state();
        let program = Request::Program {
            cfg_toml: cfg.to_toml(),
            row_base: 0,
            rng: rng0,
            library: ds.library.clone(),
            decoys: ds.decoys.clone(),
        };
        let responses = drive(&[
            Request::Program {
                cfg_toml: "mlc_bits = 99\n".into(),
                row_base: 0,
                rng: rng0,
                library: Vec::new(),
                decoys: Vec::new(),
            },
            program,
            // Wrong packed width for this shard.
            Request::Score {
                cp: 4,
                packed: vec![0.0; 4],
                meta: vec![(2, 500.0)],
            },
            // Engine would assert on these; the worker must type them out.
            Request::AdvanceAge(f64::NAN),
            Request::AdvanceAge(-1.0),
            Request::Shutdown,
        ]);
        assert!(matches!(&responses[0], Response::Error(m) if m.contains("config")));
        assert!(matches!(&responses[1], Response::Programmed { .. }));
        assert!(matches!(&responses[2], Response::Error(m) if m.contains("width")));
        assert!(matches!(&responses[3], Response::Error(m) if m.contains("finite")));
        assert!(matches!(&responses[4], Response::Error(m) if m.contains("finite")));
        assert_eq!(responses[5], Response::ShuttingDown);
    }

    #[test]
    fn corrupt_request_payload_keeps_the_loop_alive() {
        // A well-framed but undecodable payload: the worker reports it
        // and keeps serving the next frame.
        let mut input = Vec::new();
        wire::write_frame(&mut input, &[0x42, 1, 2, 3]).unwrap();
        wire::write_frame(&mut input, &Request::Health.encode()).unwrap();
        wire::write_frame(&mut input, &Request::Shutdown.encode()).unwrap();
        let mut output = Vec::new();
        run_worker(&mut input.as_slice(), &mut output).unwrap();

        let mut r = output.as_slice();
        let mut responses = Vec::new();
        while let Some(p) = wire::read_frame(&mut r).unwrap() {
            responses.push(Response::decode(&p).unwrap());
        }
        assert_eq!(responses.len(), 3);
        assert!(matches!(&responses[0], Response::Error(m) if m.contains("bad request")));
        // Health before program — still a typed response, loop alive.
        assert!(matches!(&responses[1], Response::Error(_)));
        assert_eq!(responses[2], Response::ShuttingDown);
    }

    #[test]
    fn truncated_request_stream_is_a_fatal_typed_error() {
        let mut input = Vec::new();
        wire::write_frame(&mut input, &Request::Health.encode()).unwrap();
        // A second frame cut off mid-payload.
        let mut second = Vec::new();
        wire::write_frame(&mut second, &Request::Shutdown.encode()).unwrap();
        input.extend_from_slice(&second[..second.len() - 1]);

        let mut output = Vec::new();
        let err = run_worker(&mut input.as_slice(), &mut output).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // The worker still flagged the failure on its response pipe.
        let mut r = output.as_slice();
        let first = Response::decode(&wire::read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert!(matches!(first, Response::Error(_)));
        let last = Response::decode(&wire::read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert!(matches!(last, Response::Error(m) if m.contains("request frame")));
    }
}
