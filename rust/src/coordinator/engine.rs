//! Persistent program-once / query-many DB-search engine (paper Table 3,
//! §III: the reference library is programmed into the PCM banks **once**
//! and query batches stream against it).
//!
//! # One-time vs. per-batch energy accounting
//!
//! [`SearchEngine::program`] encodes the target+decoy library, places every
//! reference HV on a physical (bank-group, row) slot through the
//! [`SegmentAllocator`], and programs the packed rows through the
//! write-verify [`ProgramContext`]. All of that work — ASIC encode+pack of
//! the library, programming pulse rounds, verify reads — is charged to the
//! engine's **one-time** [`OpCounts`]/[`EnergyReport`]
//! ([`SearchEngine::program_ops`] / [`SearchEngine::program_report`]) and is
//! *never* charged again, no matter how many batches are served.
//!
//! Each [`SearchEngine::search_batch`] call reuses the programmed noisy
//! conductances and returns a [`BatchOutcome`] whose ops/report cover only
//! the **marginal** per-batch work: query encode+pack, IMC score tiles, and
//! the ASIC top-1 merge. Amortized cost over a serving run is therefore
//! `program_report + sum(batch reports)`, which is exactly what
//! [`SearchEngine::finalize`] folds into the one-shot
//! [`SearchOutcomeSummary`] shape — bit-identical to a monolithic
//! [`super::SearchPipeline::run`] on the same dataset, regardless of how
//! the queries were split into batches.
//!
//! A library that does not fit the configured banks fails construction
//! with a typed [`CapacityError`] instead of silently ignoring `num_banks`
//! — and a library that overflows one engine can be split across several
//! via the shard layer ([`super::sharded::ShardedSearchEngine`]), which
//! builds on the [`SearchEngine::encode_queries`] /
//! [`SearchEngine::score_packed`] / [`GroupCharges`] primitives below.
//!
//! # Bucket-contiguous serving layout
//!
//! Serving is zero-copy on the reference side: after programming, the
//! engine physically reorders its host copy of the stored conductances so
//! that each precursor bucket's rows occupy one contiguous range
//! (`BucketKey -> Range<physical row>`, [`SearchEngine::bucket_row_range`]).
//! A candidate set from `candidate_keys_open` is then a handful of
//! contiguous panels handed to the backend as a segmented
//! [`MvmJob`](crate::backend::MvmJob) — no per-batch gather of reference
//! rows, and the per-group score/query buffers are reused across batches
//! through [`BackendDispatcher::execute_into`].
//!
//! The permutation happens strictly **after** write-verify programming, so
//! the data-dependent per-row noise RNG stream is consumed in the same
//! logical order (targets then decoys) as always — which is what keeps
//! sharded and monolithic engines programming bit-identical conductances.
//! A physical→logical row map ([`SearchEngine::logical_of_physical`])
//! translates scored columns back to logical rows for target/decoy
//! classification, peptide lookup and slot bookkeeping
//! ([`SearchEngine::slots`] / [`SearchEngine::noisy_row`] stay in logical
//! row order). The top-1 merge breaks score ties by **lowest logical
//! row** explicitly, reproducing the gathered path's ascending-logical
//! iteration bit-for-bit — and, downstream, the shard merge's
//! lowest-global-row contract.
//!
//! # Query-HV cache
//!
//! Real serving traffic repeats spectra (re-queries, overlapping batches,
//! replays), and before this cache every occurrence re-ran the HD encode
//! kernel. The engine now memoizes packed query HVs **keyed by the
//! quantized level vector** — the exact input of the encode kernel, so a
//! cache hit is bit-identical to a fresh encode by construction. Hits and
//! misses are surfaced on every [`BatchOutcome`] and cumulatively via
//! [`SearchEngine::encode_cache_stats`]. Op and energy accounting are
//! deliberately **unchanged**: the ASIC still performs the encode for
//! every spectrum, the cache only removes redundant *host* arithmetic
//! (exactly like backend selection, it can never change results or
//! simulated cost — `rust/tests/encode_equivalence.rs` locks this in).
//! The cache lives behind a `Mutex`, never a `RefCell`: `&SearchEngine`
//! is `Sync`, so the shard layer can fan one batch out across scoped
//! threads while hit/miss reporting keeps working per batch.
//!
//! # Drift, faults, and refresh epochs
//!
//! A programmed library is not frozen: PCM conductances decay by the
//! power-law [`DriftModel`] as storage ages, and programming events can
//! leave stuck or failed cells behind ([`crate::device::FaultModel`],
//! enabled through `cfg.fault`). The engine models a live serving horizon
//! with a **deterministic logical clock** — [`SearchEngine::advance_age`]
//! moves it forward; wall time is never consulted — and serves every batch
//! from an aged copy of the stored conductances: `programmed_logical`
//! holds what the cells stored at their last programming event, and the
//! bucket-contiguous serving panel is rebuilt from it through
//! [`DriftModel::drift_slice_into`] whenever the clock or the library
//! changes. At age 0 with faults disabled the panel is byte-identical to
//! the pre-drift engine, so existing results are reproduced exactly.
//!
//! *Detection*: [`SearchEngine::device_health`] summarizes staleness over
//! the live rows (max age since programming, estimated conductance loss,
//! injected-fault count, refresh count) and every [`BatchOutcome`] carries
//! the snapshot it was served under.
//!
//! *Recovery*: [`RefreshPolicy`] picks the stalest bucket segments
//! (threshold + budget) and [`SearchEngine::refresh_buckets`] re-programs
//! them in place — an **epoch swap**: each row's epoch increments and its
//! re-programming draws from a fresh per-`(global row, epoch)` RNG rooted
//! at [`ProgramContext::refresh_rng`], which makes refresh outcomes
//! independent of shard count and refresh order. Refresh work is charged
//! to the one-time ledger (`program_ops`/`program_report`), never to
//! batches. The library is also mutable while serving:
//! [`SearchEngine::add_references`] programs new rows through the same
//! chained noise stream and [`SearchEngine::remove_references`] releases
//! rows back to the [`SegmentAllocator`] for reuse, with the bucket layout
//! rebuilt in place after every mutation.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::array::{dac_quantize, AdcConfig};
use crate::backend::{BackendDispatcher, MvmJob};
use crate::config::SpecPcmConfig;
use crate::device::{DriftModel, MlcConfig, NoiseModel, Programmer};
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::bucket::{bucket_key, candidate_keys_open, BucketKey};
use crate::ms::synth::PTM_SHIFTS;
use crate::ms::{SearchDataset, Spectrum};
use crate::search::fdr_filter;
use crate::telemetry::{DeviceHealth, EncodeCacheStats, StageTimer};
use crate::util::error::{Error, Result};
use crate::util::sync::lock_unpoisoned;
use crate::util::Rng;

use super::allocator::{SegmentAllocator, Slot};
use super::frontend::HdFrontend;
use super::pipeline::{program_refs, SearchOutcomeSummary};

/// Typed error: a reference set that does not fit the configured banks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CapacityError {
    /// Reference rows the library needs (targets + decoys).
    pub rows_needed: usize,
    /// Row slots the configured banks provide.
    pub capacity: usize,
    pub num_banks: usize,
    /// 128-wide segments per packed HV.
    pub segments: usize,
}

impl std::fmt::Display for CapacityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "library needs {} reference rows, which exceeds the {} row slots \
             {} banks provide for {}-segment HVs; raise num_banks or shrink \
             the library",
            self.rows_needed, self.capacity, self.num_banks, self.segments
        )
    }
}

impl std::error::Error for CapacityError {}

impl From<CapacityError> for Error {
    fn from(e: CapacityError) -> Error {
        Error::msg(e)
    }
}

/// Shared PCM-programming state: the write-verify programmer, the
/// deterministic programming-noise RNG stream, and the bank-capacity
/// allocator. Both pipelines drive all array programming through one
/// context, so noise streams and physical placement are identical whether
/// rows are programmed in one shot (DB-search library) or transiently per
/// bucket (clustering).
pub struct ProgramContext {
    pub programmer: Programmer,
    pub allocator: SegmentAllocator,
    rng: Rng,
}

impl ProgramContext {
    /// Seed tag of the DB-search programming-noise stream (`seed ^ 0x5e`).
    pub const SEARCH_SEED_TAG: u64 = 0x5e;
    /// Seed tag of the clustering programming-noise stream (`seed ^ 0xc1`).
    pub const CLUSTER_SEED_TAG: u64 = 0xc1;
    /// Seed tag of the per-(row, epoch) refresh-programming streams.
    pub const REFRESH_SEED_TAG: u64 = 0xdf;

    /// `seed_tag` keeps the clustering and search noise streams distinct
    /// ([`Self::CLUSTER_SEED_TAG`] / [`Self::SEARCH_SEED_TAG`], matching
    /// the pre-engine pipelines).
    pub fn new(cfg: &SpecPcmConfig, packed_width: usize, seed_tag: u64) -> Result<Self> {
        Self::with_rng(cfg, packed_width, Self::noise_rng(cfg, seed_tag))
    }

    /// Root of a fresh programming-noise stream (`cfg.seed ^ seed_tag`).
    /// Together with [`ProgramContext::refresh_rng`] these are the *only*
    /// blessed `Rng::new` sites in engine code (contract lint rule
    /// C4-RNG): every downstream consumer — sharded programming in
    /// particular — must chain an existing state through
    /// [`ProgramContext::rng_state`] / `SearchEngine::noise_rng_state`
    /// instead of re-seeding, because per-row RNG consumption is
    /// data-dependent (write-verify converges early, and fault draws
    /// interleave per cell when injection is active) and re-seeding would
    /// desynchronize shards from the monolithic reference.
    pub fn noise_rng(cfg: &SpecPcmConfig, seed_tag: u64) -> Rng {
        Rng::new(cfg.seed ^ seed_tag)
    }

    /// Root of the refresh-programming stream for one `(global row,
    /// epoch)` re-programming event — the second blessed `Rng::new` site
    /// (rule C4-RNG). Refresh cannot chain the construction-time noise
    /// stream: which rows refresh, and in what order, depends on the
    /// policy and the shard partition, so a chained stream would break
    /// the sharded == monolithic contract. Keying the root on the
    /// *global* row index and the row's epoch instead makes every refresh
    /// outcome independent of shard count and refresh scheduling order
    /// (`rust/tests/drift_equivalence.rs`).
    pub fn refresh_rng(cfg: &SpecPcmConfig, global_row: u64, epoch: u64) -> Rng {
        // Golden-ratio mixing keeps nearby (row, epoch) pairs decorrelated
        // before SplitMix64 expands the seed inside `Rng::new`.
        let mixed = (cfg.seed ^ Self::REFRESH_SEED_TAG)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(global_row)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(epoch);
        Rng::new(mixed)
    }

    /// Construct with an explicit programming-noise RNG state. The shard
    /// layer chains contexts through this: shard `i+1` starts from the
    /// exact state shard `i` finished with, so the concatenated per-row
    /// noise stream is bit-identical to one monolithic context programming
    /// every row in sequence (RNG consumption per row is data-dependent —
    /// write-verify converges early — so only state hand-off, not seed
    /// arithmetic, can reproduce the stream).
    pub fn with_rng(cfg: &SpecPcmConfig, packed_width: usize, rng: Rng) -> Result<Self> {
        let programmer = Programmer::new(
            NoiseModel::new(cfg.material, MlcConfig::new(cfg.mlc_bits)),
            cfg.write_verify,
        )
        .with_faults(cfg.fault);
        let allocator = SegmentAllocator::try_new(cfg.num_banks, packed_width)?;
        Ok(ProgramContext {
            programmer,
            allocator,
            rng,
        })
    }

    /// Snapshot of the programming-noise RNG after everything programmed
    /// so far (the hand-off state for the next shard's context).
    pub fn rng_state(&self) -> Rng {
        self.rng.clone()
    }

    /// Typed pre-flight check: do `n_rows` more HVs fit the free slots?
    pub fn check_fit(&self, n_rows: usize) -> Result<(), CapacityError> {
        if n_rows > self.allocator.free_slots() {
            return Err(CapacityError {
                rows_needed: n_rows,
                capacity: self.allocator.capacity(),
                num_banks: self.allocator.num_banks(),
                segments: self.allocator.segments(),
            });
        }
        Ok(())
    }

    /// Allocate slots for and program `n_rows` packed rows (row-major
    /// `n_rows x cp`). Returns the noisy stored conductances, the physical
    /// slots, and the per-row injected-fault counts, or a
    /// [`CapacityError`] when the rows don't fit.
    pub fn program_rows(
        &mut self,
        packed: &[f32],
        n_rows: usize,
        cp: usize,
        ops: &mut OpCounts,
    ) -> Result<(Vec<f32>, Vec<Slot>, Vec<u64>)> {
        self.check_fit(n_rows)?;
        let mut slots = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            slots.push(self.allocator.alloc().expect("free slots were checked"));
        }
        let (noisy, row_faults) =
            program_refs(packed, n_rows, cp, &self.programmer, &mut self.rng, ops);
        Ok((noisy, slots, row_faults))
    }

    /// Release transient rows (clustering reprograms the banks per bucket).
    pub fn release_rows(&mut self, slots: Vec<Slot>) {
        for s in slots {
            self.allocator.release(s);
        }
    }
}

/// How much of the programmed library a batch's results actually cover.
///
/// In-process engines always search every live row, so coverage is full;
/// the remote supervisor ([`super::remote`]) degrades gracefully instead
/// of failing a batch when a shard worker stays down past its retry
/// budget, and tags the merged results with the surviving row fraction so
/// partial answers are visible, never silent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coverage {
    /// Live reference rows whose scores contributed to the merge.
    pub rows_searched: u64,
    /// Live reference rows the engine has programmed in total.
    pub rows_total: u64,
}

impl Coverage {
    /// Full coverage over `rows_total` rows (the in-process case).
    pub fn full(rows_total: u64) -> Coverage {
        Coverage {
            rows_searched: rows_total,
            rows_total,
        }
    }

    /// Searched fraction in [0, 1]; an empty library counts as full.
    pub fn fraction(&self) -> f64 {
        if self.rows_total == 0 {
            1.0
        } else {
            self.rows_searched as f64 / self.rows_total as f64
        }
    }

    pub fn is_full(&self) -> bool {
        self.rows_searched == self.rows_total
    }
}

impl Default for Coverage {
    fn default() -> Coverage {
        Coverage::full(0)
    }
}

/// Marginal result of serving one query batch against the programmed
/// library. Ops/report cover *only* this batch's work (query encode, IMC
/// scoring, top-1 merge) — the one-time library programming lives on the
/// engine.
#[derive(Clone, Debug, Default)]
pub struct BatchOutcome {
    /// Per-query best (target score, decoy score) pairs, in batch order.
    pub pairs: Vec<(f32, f32)>,
    /// Best-matching target peptide id per query, in batch order.
    pub matched: Vec<Option<u32>>,
    /// Marginal op counts for this batch only.
    pub ops: OpCounts,
    /// Energy/latency of the marginal ops alone.
    pub report: EnergyReport,
    /// Query-HV cache hits/misses for this batch (host-time telemetry;
    /// ops/report above are independent of the cache by design).
    pub cache: EncodeCacheStats,
    /// Device staleness/health snapshot the batch was served under (see
    /// the module docs' "Drift, faults, and refresh epochs" section).
    pub health: DeviceHealth,
    /// Library rows this batch's merge actually covered (always full for
    /// in-process engines; see [`Coverage`]).
    pub coverage: Coverage,
    /// Wire-level retries the remote supervisor spent on this batch
    /// (0 in process).
    pub retries: u64,
    /// Shard workers whose rows are missing from this batch's merge
    /// (0 = no degradation).
    pub degraded_shards: u64,
    pub wall: StageTimer,
}

/// When and how to re-program stale bucket segments between batches.
///
/// `select` is pure policy over `(bucket, staleness)` candidates; the
/// engine (or the shard layer, after pooling per-shard candidates into
/// one global selection) feeds the picked buckets to `refresh_buckets`.
#[derive(Clone, Copy, Debug)]
pub struct RefreshPolicy {
    /// Refresh a bucket only once its stalest row exceeds this age
    /// (seconds on the logical clock). `0.0` refreshes everything aged.
    pub max_age_seconds: f64,
    /// Most buckets re-programmed per maintenance pass (0 = unlimited) —
    /// bounds the programming-energy spike of one pass.
    pub budget: usize,
}

impl RefreshPolicy {
    /// Pick the buckets to refresh: drop candidates at or under the age
    /// threshold, order the rest stalest-first (ties by ascending bucket
    /// key, so selection is deterministic), dedupe — the shard layer
    /// reports boundary buckets once per shard — and cut at the budget.
    pub fn select(&self, mut candidates: Vec<(BucketKey, f64)>) -> Vec<BucketKey> {
        candidates.retain(|&(_, age)| age > self.max_age_seconds);
        candidates.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut seen = std::collections::BTreeSet::new();
        let mut picked = Vec::new();
        for (key, _) in candidates {
            if seen.insert(key) {
                picked.push(key);
                if self.budget != 0 && picked.len() == self.budget {
                    break;
                }
            }
        }
        picked
    }
}

/// What one refresh pass did: bucket segments touched, rows re-programmed,
/// and the programming ops charged to the one-time ledger. `rows` and
/// `ops` are shard-count-invariant; `buckets` counts per-engine segments,
/// so a bucket straddling a shard boundary counts once per shard.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefreshOutcome {
    pub buckets: usize,
    pub rows: usize,
    pub ops: OpCounts,
}

/// Per-logical-row lifecycle state for drift/refresh bookkeeping.
#[derive(Clone, Copy, Debug)]
struct RowState {
    /// Logical-clock time the row was last programmed.
    programmed_at: f64,
    /// Re-programming events this row has seen (0 = initial programming).
    epoch: u64,
    /// Cells fault injection corrupted at the last programming event.
    faults: u64,
    /// False once `remove_references` released the row (tombstone; the
    /// slot is back in the allocator pool and the row never serves again).
    live: bool,
}

/// One-time vs. marginal vs. amortized energy/latency split over a serving
/// run — the single place the accounting formulas live; the CLI, the
/// streaming example and the Table 3 bench only format it.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingCost {
    /// Library encode+program energy, paid once at engine construction.
    pub one_time_j: f64,
    /// Sum of the served batches' marginal energies.
    pub marginal_j: f64,
    /// One-time programming latency (sequential).
    pub one_time_s: f64,
    /// Sum of the served batches' overlapped latencies.
    pub marginal_s: f64,
    pub n_batches: usize,
}

impl ServingCost {
    /// Build the one-time/marginal split from a programming report plus
    /// the served batches' marginal reports — the single constructor
    /// behind both the engine's and the shard layer's `serving_cost`.
    pub fn from_reports(one_time: &EnergyReport, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost {
            one_time_j: one_time.total_j(),
            marginal_j: batches.iter().map(|b| b.report.total_j()).sum(),
            one_time_s: one_time.total_latency_s(),
            marginal_s: batches.iter().map(|b| b.report.overlapped_latency_s()).sum(),
            n_batches: batches.len(),
        }
    }

    pub fn amortized_j_per_batch(&self) -> f64 {
        (self.one_time_j + self.marginal_j) / self.n_batches.max(1) as f64
    }

    pub fn amortized_s_per_batch(&self) -> f64 {
        (self.one_time_s + self.marginal_s) / self.n_batches.max(1) as f64
    }

    /// Fold another engine's cost for the *same* serving run into this one
    /// (shard aggregation): energies and latencies sum — each shard's
    /// banks did its share of the physical work — while `n_batches` takes
    /// the max, because every shard saw the same fan-out batch sequence,
    /// not extra batches.
    pub fn merge(&mut self, other: &ServingCost) {
        self.one_time_j += other.one_time_j;
        self.marginal_j += other.marginal_j;
        self.one_time_s += other.one_time_s;
        self.marginal_s += other.marginal_s;
        self.n_batches = self.n_batches.max(other.n_batches);
    }
}

impl std::ops::AddAssign<&ServingCost> for ServingCost {
    fn add_assign(&mut self, other: &ServingCost) {
        self.merge(other);
    }
}

impl std::ops::AddAssign for ServingCost {
    fn add_assign(&mut self, other: ServingCost) {
        self.merge(&other);
    }
}

impl std::iter::Sum for ServingCost {
    fn sum<I: Iterator<Item = ServingCost>>(iter: I) -> ServingCost {
        iter.fold(ServingCost::default(), |mut acc, c| {
            acc.merge(&c);
            acc
        })
    }
}

/// Per-candidate-group scoring charges: for every distinct candidate-key
/// set served in a batch, the number of queries in the group and the
/// candidate reference rows scored against them. This is the input of the
/// tile-granular ASIC op accounting ([`GroupCharges::charge`]), kept
/// separate from score execution so the shard layer can *merge* the
/// per-shard candidate counts back into global groups before charging —
/// bank MVM ops round candidate rows up to whole 128-row tiles
/// (`MvmJob::bank_ops`), so charging per shard would over-count partial
/// tiles at shard boundaries relative to the monolithic equivalent.
/// Sharding must change placement and host concurrency only, never the
/// simulated ASIC work (`rust/tests/engine_equivalence.rs` locks this in).
#[derive(Clone, Debug, Default)]
pub struct GroupCharges {
    /// Candidate-key set -> (queries in group, candidate rows scored).
    by_group: BTreeMap<Vec<BucketKey>, (usize, usize)>,
}

impl GroupCharges {
    /// Record one group's scoring work (`n_cand` may be 0 for groups whose
    /// candidate set is empty on this shard — they still merge).
    pub fn record(&mut self, keys: Vec<BucketKey>, n_queries: usize, n_cand: usize) {
        let entry = self.by_group.entry(keys).or_insert((n_queries, 0));
        debug_assert_eq!(entry.0, n_queries, "group query count disagrees");
        entry.1 += n_cand;
    }

    /// Fold another shard's charges for the same query batch into this
    /// one: candidate counts sum per group (shards partition the library,
    /// so per-shard candidate sets are disjoint). Keys already present
    /// merge in place; a key vector is cloned only the first time a group
    /// appears, so each group key is allocated once per batch.
    pub fn merge(&mut self, other: &GroupCharges) {
        for (keys, &(nq, nc)) in &other.by_group {
            if let Some(entry) = self.by_group.get_mut(keys) {
                debug_assert_eq!(entry.0, nq, "group query count disagrees");
                entry.1 += nc;
            } else {
                self.by_group.insert(keys.clone(), (nq, nc));
            }
        }
    }

    /// Iterate the recorded groups as `(candidate keys, queries,
    /// candidate rows)` triples — what the remote wire ships back per
    /// shard so the *coordinator* merges and charges centrally (contract
    /// C2-CHARGE: pre-charging per worker would distort the tile counts
    /// exactly like per-shard charging would).
    pub fn entries(&self) -> impl Iterator<Item = (&[BucketKey], usize, usize)> {
        self.by_group
            .iter()
            .map(|(keys, &(nq, nc))| (keys.as_slice(), nq, nc))
    }

    /// Charge the batch's IMC scoring + ASIC top-1 merge ops: per group
    /// with a non-empty *global* candidate set, every query drives
    /// `ceil(n_cand / 128)` row tiles x `cp / 128` column tiles of bank
    /// MVMs (the [`crate::backend::MvmJob::bank_ops`] formula) and one
    /// merge-element comparison per candidate.
    pub fn charge(&self, cp: usize, ops: &mut OpCounts) {
        let col_tiles = (cp / crate::array::ARRAY_DIM) as u64;
        for &(nq, nc) in self.by_group.values() {
            if nc == 0 {
                continue;
            }
            let row_tiles = nc.div_ceil(crate::array::ARRAY_DIM) as u64;
            ops.mvm_ops += nq as u64 * row_tiles * col_tiles;
            ops.merge_elements += (nq * nc) as u64;
        }
    }
}

/// One engine's (or one shard's) scoring result for a query batch,
/// before op/energy folding: per-query bests, the per-group charge info,
/// and the host wall-time of the scoring stages.
#[derive(Clone, Debug)]
pub struct ShardScores {
    /// Per-query `(best target score, best decoy score, matched peptide)`
    /// in batch order; `(NEG_INFINITY, NEG_INFINITY, None)` when the
    /// query had no candidates on this engine.
    pub best: Vec<(f32, f32, Option<u32>)>,
    /// Per-candidate-group query/candidate counts for central charging.
    pub charges: GroupCharges,
    pub wall: StageTimer,
}

/// Program-once / query-many DB-search engine. See the module docs for the
/// one-time vs. per-batch energy-accounting split.
pub struct SearchEngine {
    pub cfg: SpecPcmConfig,
    pub frontend: HdFrontend,
    ctx: ProgramContext,
    adc: AdcConfig,
    cp: usize,
    /// Live target rows (maintained across add/remove mutations).
    n_targets: usize,
    /// Peptide id per *logical* reference row (targets then decoys, then
    /// any rows added live) — the only per-spectrum metadata serving
    /// needs, so the engine does not retain the peak data of a library it
    /// already programmed.
    ref_peptides: Vec<Option<u32>>,
    /// Precursor bucket key per logical row (drives the serving layout).
    ref_keys: Vec<BucketKey>,
    /// Whether each logical row is a target (vs. decoy) — replaces the
    /// old `ri < n_targets` test, which live mutation invalidates.
    is_target: Vec<bool>,
    /// Drift/refresh lifecycle state per logical row.
    row_state: Vec<RowState>,
    /// Power-law drift model for `cfg.material`.
    drift: DriftModel,
    /// Deterministic logical serving clock (seconds); advanced only by
    /// [`SearchEngine::advance_age`], never by wall time.
    age_seconds: f64,
    /// Global logical-row offset of this engine's row 0 (non-zero on
    /// shards) — keys the per-row refresh RNG so sharded and monolithic
    /// refreshes draw identical streams.
    row_base: usize,
    /// Clean packed reference HVs in logical row order — what refresh
    /// re-programs (the original targets, not the noisy outcome).
    packed_logical: Vec<f32>,
    /// Stored noisy conductances at each row's last programming event, in
    /// logical row order (age 0 relative to `row_state.programmed_at`).
    programmed_logical: Vec<f32>,
    /// The *aged serving panel*: `programmed_logical` drifted to the
    /// current clock and permuted into **bucket-contiguous physical row
    /// order** over the live rows: each precursor bucket's rows form one
    /// contiguous range (`bucket_ranges`), so candidate panels are
    /// borrowed row ranges instead of per-batch gathered copies.
    /// Rebuilt by `rebuild_serving_panel` after every clock or library
    /// change; at age 0 it is byte-identical to the stored conductances.
    noisy_refs: Vec<f32>,
    /// Physical (bank group, row) slot of each *logical* reference row
    /// (slots of removed rows have been released but stay recorded).
    ref_slots: Vec<Slot>,
    /// Precursor bucket -> physical row range into `noisy_refs`.
    bucket_ranges: BTreeMap<BucketKey, std::ops::Range<usize>>,
    /// Physical row in `noisy_refs` -> logical reference row.
    logical_of_phys: Vec<usize>,
    /// Logical reference row -> physical row in `noisy_refs`
    /// (`usize::MAX` for removed rows, which have no physical row).
    phys_of_logical: Vec<usize>,
    program_ops: OpCounts,
    program_report: EnergyReport,
    program_wall: StageTimer,
    /// Packed query HVs keyed by quantized level vector (see the module
    /// docs' "Query-HV cache" section). A `Mutex` (not `RefCell`) keeps
    /// `search_batch(&self)` signature-stable *and* the engine `Sync`, so
    /// shard fan-out can share it across scoped threads.
    query_cache: Mutex<HashMap<Vec<u16>, Vec<f32>>>,
    cache_stats: Mutex<EncodeCacheStats>,
    /// Reusable scoring buffers (segment list, gathered query rows, score
    /// tile), kept across groups *and* batches so steady-state serving
    /// performs no per-batch allocations on the score path. `try_lock`
    /// semantics: a concurrent `search_batch` on the same engine simply
    /// falls back to fresh buffers instead of blocking.
    score_scratch: Mutex<ScoreScratch>,
}

/// Buffers [`SearchEngine::score_packed`] reuses across candidate groups
/// and batches (see the `score_scratch` field).
#[derive(Debug, Default)]
struct ScoreScratch {
    segments: Vec<std::ops::Range<usize>>,
    /// Whole-batch DAC-quantized queries (PR 6 hoisting): each packed
    /// query is quantized once per batch here, instead of once per
    /// candidate-group job inside the blocked kernel. Score-neutral by
    /// DAC idempotence; op accounting is unchanged (DAC ops are charged
    /// per logical conversion, not per kernel call).
    dacq: Vec<f32>,
    q_rows: Vec<f32>,
    scores: Vec<f32>,
}

/// Entry cap for the query-HV cache: past this many distinct spectra the
/// engine stops inserting (existing entries keep hitting). Bounds memory
/// — each entry holds a `cp`-long f32 row plus its `features`-long u16
/// level-vector key (~4-5 KB at paper-scale configs) — without
/// introducing eviction nondeterminism.
const QUERY_CACHE_MAX_ENTRIES: usize = 1 << 16;

impl SearchEngine {
    /// Typed pre-flight: would an `n_rows`-row reference library fit
    /// `cfg`'s banks? [`SearchEngine::program`] returns the crate-wide
    /// string-backed error, so callers that want to react programmatically
    /// (auto-raise `num_banks`, shard the library) should gate on this
    /// first and match the [`CapacityError`] fields directly.
    pub fn check_capacity(cfg: &SpecPcmConfig, n_rows: usize) -> Result<(), CapacityError> {
        let packed = crate::hd::padded_packed_len(cfg.hd_dim, cfg.packing());
        match SegmentAllocator::try_new(cfg.num_banks, packed) {
            Ok(a) if n_rows <= a.capacity() => Ok(()),
            Ok(a) => Err(CapacityError {
                rows_needed: n_rows,
                capacity: a.capacity(),
                num_banks: cfg.num_banks,
                segments: a.segments(),
            }),
            // A single HV wider than all banks together: zero capacity.
            Err(_) => Err(CapacityError {
                rows_needed: n_rows,
                capacity: 0,
                num_banks: cfg.num_banks,
                segments: packed / crate::array::ARRAY_DIM,
            }),
        }
    }

    /// Encode + program the dataset's reference library (targets followed
    /// by decoys) exactly once. Fails with a [`CapacityError`] — before any
    /// encode work is spent — when the library exceeds the banks' capacity
    /// (use [`SearchEngine::check_capacity`] for the typed pre-flight).
    pub fn program(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
    ) -> Result<Self> {
        let rng = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG);
        Self::program_with_rng(cfg, dataset, backend, rng)
    }

    /// [`SearchEngine::program`] with an explicit programming-noise RNG
    /// state (see [`ProgramContext::with_rng`]). The shard layer programs
    /// shard `i+1` from the state [`SearchEngine::noise_rng_state`]
    /// reports after shard `i`, which makes the sharded library's stored
    /// conductances bit-identical to one monolithic engine programming
    /// the same rows in the same order.
    pub fn program_with_rng(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
        rng: Rng,
    ) -> Result<Self> {
        let frontend = HdFrontend::new(&cfg);
        let cp = frontend.packed_width;
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
        let mut ctx = ProgramContext::with_rng(&cfg, cp, rng)?;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();

        let all_refs: Vec<&Spectrum> = dataset
            .library
            .iter()
            .chain(dataset.decoys.iter())
            .collect();
        let n_targets = dataset.library.len();
        ctx.check_fit(all_refs.len())?;

        let packed_refs = wall.time("encode refs", || {
            frontend.encode_pack(&all_refs, backend, &mut ops)
        })?;
        let (noisy_logical, ref_slots, row_faults) = wall.time("program refs", || {
            ctx.program_rows(&packed_refs, all_refs.len(), cp, &mut ops)
        })?;

        // Keep only the serving metadata — peptide ids, bucket keys and
        // target/decoy flags per logical row; the peak data is already
        // encoded into the stored conductances.
        let n_refs = all_refs.len();
        let ref_peptides: Vec<Option<u32>> = all_refs.iter().map(|s| s.peptide_id).collect();
        let ref_keys: Vec<BucketKey> = all_refs
            .iter()
            .map(|s| bucket_key(s.charge, s.precursor_mz, cfg.bucket_width))
            .collect();
        let is_target: Vec<bool> = (0..n_refs).map(|l| l < n_targets).collect();
        let row_state: Vec<RowState> = row_faults
            .iter()
            .map(|&faults| RowState {
                programmed_at: 0.0,
                epoch: 0,
                faults,
                live: true,
            })
            .collect();

        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let program_report = model.report(&ops);
        let drift = DriftModel::for_material(cfg.material);

        let mut engine = SearchEngine {
            cfg,
            frontend,
            ctx,
            adc,
            cp,
            n_targets,
            ref_peptides,
            ref_keys,
            is_target,
            row_state,
            drift,
            age_seconds: 0.0,
            row_base: 0,
            packed_logical: packed_refs,
            programmed_logical: noisy_logical,
            noisy_refs: Vec::new(),
            ref_slots,
            bucket_ranges: BTreeMap::new(),
            logical_of_phys: Vec::new(),
            phys_of_logical: Vec::new(),
            program_ops: ops,
            program_report,
            program_wall: StageTimer::new(),
            query_cache: Mutex::new(HashMap::new()),
            cache_stats: Mutex::new(EncodeCacheStats::default()),
            score_scratch: Mutex::new(ScoreScratch::default()),
        };
        // Permute the host copy of the stored conductances into
        // bucket-contiguous physical order (module docs). This happens
        // strictly *after* programming: every logical row's conductances —
        // and the data-dependent noise stream that produced them — are
        // exactly what a layout-free engine would hold; only the host
        // buffer order changes, in bucket-key order so adjacent candidate
        // buckets coalesce into one contiguous panel. At age 0 the drift
        // pass inside the rebuild is a bit-exact copy.
        wall.time("layout refs", || engine.rebuild_layout());
        engine.program_wall = wall;
        Ok(engine)
    }

    /// Rebuild the bucket-contiguous layout maps over the *live* rows
    /// (ascending logical order within each bucket, buckets in key order —
    /// exactly the `bucket_by_precursor` order initial construction used),
    /// then re-derive the aged serving panel.
    fn rebuild_layout(&mut self) {
        let mut by_bucket: BTreeMap<BucketKey, Vec<usize>> = BTreeMap::new();
        for (l, st) in self.row_state.iter().enumerate() {
            if st.live {
                by_bucket.entry(self.ref_keys[l]).or_default().push(l);
            }
        }
        self.logical_of_phys.clear();
        self.bucket_ranges.clear();
        for (key, rows) in by_bucket {
            let start = self.logical_of_phys.len();
            self.logical_of_phys.extend_from_slice(&rows);
            self.bucket_ranges.insert(key, start..self.logical_of_phys.len());
        }
        self.phys_of_logical = vec![usize::MAX; self.row_state.len()];
        for (p, &l) in self.logical_of_phys.iter().enumerate() {
            self.phys_of_logical[l] = p;
        }
        self.noisy_refs.clear();
        self.noisy_refs
            .resize(self.logical_of_phys.len() * self.cp, 0.0);
        self.rebuild_serving_panel();
    }

    /// Re-derive the serving panel from the stored conductances: each live
    /// row drifted by its own age (clock minus last programming time).
    /// One `powf` per row (`DriftModel::drift_slice_into`), `cp`
    /// multiplies — cheap enough to run after every clock advance.
    fn rebuild_serving_panel(&mut self) {
        let cp = self.cp;
        for (p, &l) in self.logical_of_phys.iter().enumerate() {
            let t = self.age_seconds - self.row_state[l].programmed_at;
            self.drift.drift_slice_into(
                &self.programmed_logical[l * cp..(l + 1) * cp],
                t,
                &mut self.noisy_refs[p * cp..(p + 1) * cp],
            );
        }
    }

    /// Programming-noise RNG state after everything programmed so far —
    /// the hand-off for the next shard (see
    /// [`SearchEngine::program_with_rng`]).
    pub fn noise_rng_state(&self) -> Rng {
        self.ctx.rng_state()
    }

    /// Cumulative query-HV cache hits/misses across every served batch.
    pub fn encode_cache_stats(&self) -> EncodeCacheStats {
        *lock_unpoisoned(&self.cache_stats, "cache stats")
    }

    /// Drop every cached query HV (the cache refills on subsequent
    /// batches; results are identical either way).
    pub fn clear_query_cache(&self) {
        lock_unpoisoned(&self.query_cache, "query cache").clear();
    }

    /// One-time library ops (encode + pack + program + verify), charged at
    /// construction and never again.
    pub fn program_ops(&self) -> &OpCounts {
        &self.program_ops
    }

    /// Energy/latency of the one-time library programming alone.
    pub fn program_report(&self) -> &EnergyReport {
        &self.program_report
    }

    /// Host wall-time breakdown of the one-time library programming.
    pub fn program_wall(&self) -> &StageTimer {
        &self.program_wall
    }

    /// *Live* reference rows currently serving (targets + decoys; removed
    /// rows are excluded).
    pub fn n_refs(&self) -> usize {
        self.logical_of_phys.len()
    }

    /// Live target rows.
    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    /// Current logical serving clock (seconds since construction).
    pub fn age_seconds(&self) -> f64 {
        self.age_seconds
    }

    /// Global logical-row offset of this engine's row 0 (see
    /// [`SearchEngine::set_row_base`]).
    pub fn row_base(&self) -> usize {
        self.row_base
    }

    /// Declare this engine's position in a global row space: local logical
    /// row `l` is global row `row_base + l`. The shard layer sets each
    /// shard's base to its plan offset so per-(global row, epoch) refresh
    /// streams match the monolithic engine's. Placement-only — stored
    /// conductances and scores never depend on it until a refresh draws.
    pub fn set_row_base(&mut self, row_base: usize) {
        self.row_base = row_base;
    }

    /// Advance the deterministic serving clock by `seconds` and re-age the
    /// serving panel. `advance_age(0.0)` is a strict no-op on results (the
    /// rebuild's drift factor is exactly 1.0 for every fresh row).
    pub fn advance_age(&mut self, seconds: f64) {
        assert!(
            seconds.is_finite() && seconds >= 0.0,
            "advance_age: {seconds} is not a finite non-negative duration"
        );
        self.age_seconds += seconds;
        self.rebuild_serving_panel();
    }

    /// Staleness/health summary over the live rows: max age since last
    /// programming, the conductance loss that age implies, total injected
    /// faults, and total re-programming epochs.
    pub fn device_health(&self) -> DeviceHealth {
        let mut h = DeviceHealth::default();
        for st in self.row_state.iter().filter(|st| st.live) {
            h.max_age_seconds = h.max_age_seconds.max(self.age_seconds - st.programmed_at);
            h.injected_faults += st.faults;
            h.refreshes += st.epoch;
        }
        h.est_conductance_loss = 1.0 - self.drift.conductance_factor(h.max_age_seconds);
        h
    }

    /// Per-bucket staleness candidates for refresh selection: every served
    /// bucket with the age of its stalest row. The shard layer pools these
    /// across shards before one global [`RefreshPolicy::select`].
    pub fn refresh_candidates(&self) -> Vec<(BucketKey, f64)> {
        self.bucket_ranges
            .iter()
            .map(|(key, range)| {
                let age = range
                    .clone()
                    .map(|p| self.age_seconds - self.row_state[self.logical_of_phys[p]].programmed_at)
                    .fold(0.0f64, f64::max);
                (*key, age)
            })
            .collect()
    }

    /// Re-program the given bucket segments in place (epoch swap): every
    /// live row of each bucket present on this engine is re-programmed
    /// from its clean packed HV through a fresh per-(global row, epoch)
    /// refresh stream, its programming time reset to the current clock,
    /// and the serving panel rebuilt. The incremental programming work is
    /// charged to the **one-time** ledger — batches stay marginal-only.
    /// Unknown buckets are skipped (a shard refreshes only its portion).
    pub fn refresh_buckets(&mut self, keys: &[BucketKey]) -> RefreshOutcome {
        let cp = self.cp;
        let mut out = RefreshOutcome::default();
        for key in keys {
            let Some(range) = self.bucket_ranges.get(key).cloned() else {
                continue;
            };
            out.buckets += 1;
            // Ascending logical order within the bucket, matching the
            // layout invariant — but order cannot matter: each row's
            // stream is rooted on its own (global row, epoch).
            let mut rows: Vec<usize> =
                range.map(|p| self.logical_of_phys[p]).collect();
            rows.sort_unstable();
            for l in rows {
                let epoch = self.row_state[l].epoch + 1;
                let mut rng = ProgramContext::refresh_rng(
                    &self.cfg,
                    (self.row_base + l) as u64,
                    epoch,
                );
                let (stored, row_faults) = program_refs(
                    &self.packed_logical[l * cp..(l + 1) * cp],
                    1,
                    cp,
                    &self.ctx.programmer,
                    &mut rng,
                    &mut out.ops,
                );
                self.programmed_logical[l * cp..(l + 1) * cp].copy_from_slice(&stored);
                let st = &mut self.row_state[l];
                st.programmed_at = self.age_seconds;
                st.epoch = epoch;
                st.faults = row_faults[0];
                out.rows += 1;
            }
        }
        if out.rows > 0 {
            self.rebuild_serving_panel();
            self.program_ops += &out.ops;
            let model =
                EnergyLatencyModel::new(self.cfg.material, self.cfg.adc_bits, self.cfg.num_banks);
            self.program_report = model.report(&self.program_ops);
        }
        out
    }

    /// One maintenance pass: select stale buckets under `policy` and
    /// refresh them. Intended between serving batches.
    pub fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        let keys = policy.select(self.refresh_candidates());
        self.refresh_buckets(&keys)
    }

    /// Program additional reference spectra into the live library (target
    /// rows when `is_target`, decoy rows otherwise), reusing slots that
    /// `remove_references` released. New rows continue the engine's
    /// chained programming-noise stream and are stamped with the current
    /// clock; encode + programming work is charged to the one-time
    /// ledger. Returns the new logical row indices.
    pub fn add_references(
        &mut self,
        spectra: &[&Spectrum],
        is_target: bool,
        backend: &BackendDispatcher,
    ) -> Result<Vec<usize>> {
        if spectra.is_empty() {
            return Ok(Vec::new());
        }
        self.ctx.check_fit(spectra.len())?;
        let cp = self.cp;
        let mut ops = OpCounts::default();
        let packed = self.frontend.encode_pack(spectra, backend, &mut ops)?;
        let (noisy, slots, row_faults) =
            self.ctx.program_rows(&packed, spectra.len(), cp, &mut ops)?;

        let mut new_rows = Vec::with_capacity(spectra.len());
        for (i, s) in spectra.iter().enumerate() {
            new_rows.push(self.row_state.len());
            self.ref_peptides.push(s.peptide_id);
            self.ref_keys
                .push(bucket_key(s.charge, s.precursor_mz, self.cfg.bucket_width));
            self.is_target.push(is_target);
            if is_target {
                self.n_targets += 1;
            }
            self.ref_slots.push(slots[i]);
            self.row_state.push(RowState {
                programmed_at: self.age_seconds,
                epoch: 0,
                faults: row_faults[i],
                live: true,
            });
        }
        self.packed_logical.extend_from_slice(&packed);
        self.programmed_logical.extend_from_slice(&noisy);
        self.program_ops += &ops;
        let model =
            EnergyLatencyModel::new(self.cfg.material, self.cfg.adc_bits, self.cfg.num_banks);
        self.program_report = model.report(&self.program_ops);
        self.rebuild_layout();
        Ok(new_rows)
    }

    /// Remove live reference rows from service: their allocator slots are
    /// released for reuse, target counts updated, and the serving layout
    /// rebuilt without them. Rows are tombstoned, never reindexed, so
    /// logical row indices stay stable across mutations. Fails without
    /// touching any state when a row is out of range, already removed, or
    /// listed twice.
    pub fn remove_references(&mut self, rows: &[usize]) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for &l in rows {
            crate::ensure!(
                l < self.row_state.len(),
                "remove_references: row {l} out of range"
            );
            crate::ensure!(
                self.row_state[l].live,
                "remove_references: row {l} is not live"
            );
            crate::ensure!(seen.insert(l), "remove_references: row {l} listed twice");
        }
        for &l in rows {
            self.row_state[l].live = false;
            self.ctx.allocator.release(self.ref_slots[l]);
            if self.is_target[l] {
                self.n_targets -= 1;
            }
        }
        if !rows.is_empty() {
            self.rebuild_layout();
        }
        Ok(())
    }

    /// Packed width (`cp`) of every programmed row.
    pub fn packed_width(&self) -> usize {
        self.cp
    }

    /// Physical slot of each reference row, in row order.
    pub fn slots(&self) -> &[Slot] {
        &self.ref_slots
    }

    /// Physical bank indices a reference row's segments occupy.
    pub fn banks_of(&self, slot: Slot) -> Vec<usize> {
        self.ctx.allocator.banks_of(slot)
    }

    /// *Aged* stored conductances of live *logical* reference row `ri`
    /// (`cp` wide) — indexed through the physical layout map, so callers
    /// (ISA mirroring, tests) keep the targets-then-decoys row order no
    /// matter how the host buffer is physically arranged. At age 0 this is
    /// byte-identical to the programmed values. Panics on removed rows
    /// (they have no physical row in the serving panel).
    pub fn noisy_row(&self, ri: usize) -> &[f32] {
        let p = self.phys_of_logical[ri];
        assert!(p != usize::MAX, "noisy_row: logical row {ri} was removed");
        &self.noisy_refs[p * self.cp..(p + 1) * self.cp]
    }

    /// Physical row range the given precursor bucket occupies in the
    /// bucket-contiguous reference layout (`None` when no reference falls
    /// in the bucket). Ranges of adjacent `BucketKey`s are physically
    /// adjacent, which is what lets open-search candidate sets collapse
    /// into a few contiguous panels.
    pub fn bucket_row_range(&self, key: &BucketKey) -> Option<std::ops::Range<usize>> {
        self.bucket_ranges.get(key).cloned()
    }

    /// Physical-to-logical row map of the bucket-contiguous layout:
    /// `logical_of_physical()[p]` is the logical (targets-then-decoys)
    /// reference row stored at physical row `p` of the serving panel.
    pub fn logical_of_physical(&self) -> &[usize] {
        &self.logical_of_phys
    }

    /// Encode one query batch into packed HVs through the query-HV cache:
    /// unique uncached level vectors encode once per batch, everything
    /// else is a copy. Returns the row-major `queries.len() x cp` packed
    /// rows plus this batch's hit/miss stats (also folded into the
    /// cumulative [`SearchEngine::encode_cache_stats`]).
    ///
    /// **No op accounting happens here** — the ASIC encode charge covers
    /// every query regardless of the cache (module docs, "Query-HV
    /// cache"), and belongs to whoever owns the batch: callers charge
    /// [`HdFrontend::count_encode_ops`] exactly once per batch. The shard
    /// layer relies on this split to encode once and share the packed
    /// rows across every shard instead of paying the encode per shard.
    pub fn encode_queries(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<(Vec<f32>, EncodeCacheStats)> {
        let cp = self.cp;
        let mut batch_cache = EncodeCacheStats::default();
        let levels = self.frontend.levels_of(queries);

        // One classification pass under one lock hold: hit rows are copied
        // out *while the entry is provably present*, so a concurrent
        // `clear_query_cache` (the engine is Sync and may be shared across
        // threads) can never invalidate a hit between classification and
        // copy. Misses are deduped and encoded after the lock drops — the
        // expensive kernel never runs under the lock.
        let mut packed = vec![0f32; levels.len() * cp];
        let mut miss_of: HashMap<&Vec<u16>, usize> = HashMap::new();
        let mut miss_levels: Vec<Vec<u16>> = Vec::new();
        // (query index, miss index) rows to fill once the misses encode.
        let mut pending: Vec<(usize, usize)> = Vec::new();
        {
            let cache = lock_unpoisoned(&self.query_cache, "query cache");
            for (qi, lv) in levels.iter().enumerate() {
                if let Some(row) = cache.get(lv) {
                    packed[qi * cp..(qi + 1) * cp].copy_from_slice(row);
                } else if let Some(&mi) = miss_of.get(lv) {
                    pending.push((qi, mi));
                } else {
                    let mi = miss_levels.len();
                    miss_of.insert(lv, mi);
                    miss_levels.push(lv.clone());
                    pending.push((qi, mi));
                }
            }
        }

        let n_misses = miss_levels.len();
        let miss_packed = if miss_levels.is_empty() {
            Vec::new()
        } else {
            self.frontend.encode_pack_levels(&miss_levels, backend)?
        };
        for &(qi, mi) in &pending {
            packed[qi * cp..(qi + 1) * cp].copy_from_slice(&miss_packed[mi * cp..(mi + 1) * cp]);
        }
        {
            // Insert by *moving* the already-owned miss level vectors:
            // exactly one allocation per miss (the cached row copy), not
            // two (the key was cloned here before).
            let mut cache = lock_unpoisoned(&self.query_cache, "query cache");
            for (mi, lv) in miss_levels.into_iter().enumerate() {
                if cache.len() >= QUERY_CACHE_MAX_ENTRIES {
                    break;
                }
                cache.insert(lv, miss_packed[mi * cp..(mi + 1) * cp].to_vec());
            }
        }
        batch_cache.misses = n_misses as u64;
        batch_cache.hits = (levels.len() - n_misses) as u64;

        *lock_unpoisoned(&self.cache_stats, "cache stats") += batch_cache;
        Ok((packed, batch_cache))
    }

    /// Score pre-packed query HVs against this engine's programmed rows:
    /// candidate selection, IMC score tiles and the in-engine top-1 merge,
    /// **without op accounting** — instead the per-group candidate counts
    /// come back as [`GroupCharges`] so the caller charges globally (see
    /// the [`GroupCharges`] docs for why per-shard charging would distort
    /// tile counts). Returns per-query `(best target, best decoy, matched
    /// peptide)` triples in batch order; queries with no local candidates
    /// stay at `(NEG_INFINITY, NEG_INFINITY, None)`, which the shard
    /// merge's strict `>` ignores.
    ///
    /// This is the zero-copy hot loop: candidate sets are contiguous
    /// physical row ranges of the bucket-contiguous layout (adjacent
    /// buckets coalesce into one segment), handed to the backend as
    /// segmented jobs against the borrowed `noisy_refs` panel, with the
    /// segment/query/score buffers reused across groups and batches. The
    /// scores — and, via the explicit lowest-logical-row tie rule, the
    /// per-query bests — are bit-identical to gathering every candidate
    /// row and scoring through `array::imc_mvm_ref`
    /// (`rust/tests/segmented_equivalence.rs`).
    pub fn score_packed(
        &self,
        queries: &[&Spectrum],
        packed_queries: &[f32],
        backend: &BackendDispatcher,
    ) -> Result<ShardScores> {
        let cfg = &self.cfg;
        let cp = self.cp;
        assert_eq!(packed_queries.len(), queries.len() * cp, "packed query shape");
        let mut wall = StageTimer::new();
        let mut charges = GroupCharges::default();
        // Scores and physical ops are charged by the caller from the
        // merged GroupCharges; the dispatcher's own charge goes to a
        // scratch accumulator.
        let mut scratch_ops = OpCounts::default();

        // Reusable buffers, carried across batches. A concurrent
        // `search_batch` on the same engine (the engine is Sync) just
        // takes fresh buffers instead of waiting.
        let mut bufs = self
            .score_scratch
            .try_lock()
            .map(|mut g| std::mem::take(&mut *g))
            .unwrap_or_default();

        // DAC the whole batch once; group jobs below carry `dac_applied`
        // so the kernel skips its per-call re-quantization pass.
        bufs.dacq.clear();
        bufs.dacq.reserve(packed_queries.len());
        bufs.dacq.extend(packed_queries.iter().map(|&x| dac_quantize(x)));

        // Group queries by identical candidate-key sets so one IMC batch
        // shares one reference row block.
        let mut groups: BTreeMap<Vec<BucketKey>, Vec<usize>> = BTreeMap::new();
        for (qi, q) in queries.iter().enumerate() {
            let keys = candidate_keys_open(q.charge, q.precursor_mz, cfg.bucket_width, &PTM_SHIFTS);
            groups.entry(keys).or_default().push(qi);
        }

        // Per-query best (target score, decoy score) + matched peptide,
        // plus the logical row of the current best target for the
        // lowest-logical-row tie rule (physical iteration order is bucket
        // order, so ties must be broken explicitly to reproduce the
        // gathered path's ascending-logical scan).
        let mut best: Vec<(f32, f32, Option<u32>)> =
            vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];
        let mut best_row: Vec<usize> = vec![usize::MAX; queries.len()];

        for (keys, q_idxs) in groups {
            // Candidate panels straight out of the bucket-contiguous
            // layout. `keys` is sorted and `bucket_ranges` assigns
            // physical rows in key order, so ranges arrive in ascending
            // physical order and adjacent buckets merge into one segment.
            bufs.segments.clear();
            let mut n_cand = 0usize;
            for k in &keys {
                if let Some(r) = self.bucket_ranges.get(k) {
                    n_cand += r.len();
                    match bufs.segments.last_mut() {
                        Some(last) if last.end == r.start => last.end = r.end,
                        _ => bufs.segments.push(r.clone()),
                    }
                }
            }
            let nq = q_idxs.len();
            charges.record(keys, nq, n_cand);
            if n_cand == 0 {
                continue;
            }

            // Queries within a group are scattered in the batch; gather
            // just those (already-quantized) rows into the reused stripe
            // (references are never gathered).
            bufs.q_rows.clear();
            bufs.q_rows.reserve(nq * cp);
            for &qi in &q_idxs {
                bufs.q_rows
                    .extend_from_slice(&bufs.dacq[qi * cp..(qi + 1) * cp]);
            }
            bufs.scores.clear();
            bufs.scores.resize(nq * n_cand, 0.0);

            let job = MvmJob::segmented(
                &bufs.q_rows,
                nq,
                &self.noisy_refs,
                &bufs.segments,
                cp,
                self.adc,
            )
            .with_dac_applied();
            debug_assert_eq!(job.nr, n_cand);
            wall.time("similarity (IMC)", || {
                backend.execute_into(&job, &mut bufs.scores, &mut scratch_ops)
            })?;

            wall.time("top-1 + merge (ASIC)", || {
                for (bi, &qi) in q_idxs.iter().enumerate() {
                    let row = &bufs.scores[bi * n_cand..(bi + 1) * n_cand];
                    let mut ci = 0usize;
                    for seg in &bufs.segments {
                        for p in seg.clone() {
                            let s = row[ci];
                            ci += 1;
                            let ri = self.logical_of_phys[p];
                            if self.is_target[ri] {
                                if s > best[qi].0 || (s == best[qi].0 && ri < best_row[qi]) {
                                    best[qi].0 = s;
                                    best[qi].2 = self.ref_peptides[ri];
                                    best_row[qi] = ri;
                                }
                            } else if s > best[qi].1 {
                                best[qi].1 = s;
                            }
                        }
                    }
                }
            });
        }

        if let Ok(mut g) = self.score_scratch.try_lock() {
            *g = bufs;
        }

        Ok(ShardScores {
            best,
            charges,
            wall,
        })
    }

    /// Serve one query batch against the programmed library. Scores are
    /// bit-identical regardless of how queries are split into batches: the
    /// per-(query, candidate) IMC score depends only on the query HV, the
    /// stored conductances and the ADC, never on batch composition.
    pub fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        let cfg = &self.cfg;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();

        self.frontend.count_encode_ops(queries.len(), &mut ops);
        let (packed_queries, batch_cache) =
            wall.time("encode queries", || self.encode_queries(queries, backend))?;

        let scored = self.score_packed(queries, &packed_queries, backend)?;
        for (stage, t, _) in scored.wall.breakdown() {
            wall.add(&stage, t);
        }
        scored.charges.charge(self.cp, &mut ops);

        let pairs: Vec<(f32, f32)> = scored.best.iter().map(|&(t, d, _)| (t, d)).collect();
        let matched: Vec<Option<u32>> = scored.best.iter().map(|&(_, _, m)| m).collect();
        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let report = model.report(&ops);

        Ok(BatchOutcome {
            pairs,
            matched,
            ops,
            report,
            cache: batch_cache,
            health: self.device_health(),
            coverage: Coverage::full(self.n_refs() as u64),
            retries: 0,
            degraded_shards: 0,
            wall,
        })
    }

    /// Split `queries` into contiguous batches and serve each in order —
    /// the shared serving loop behind the CLI's `--serve-batches`, the
    /// streaming example and the Table 3 bench. Returns exactly
    /// `min(n_batches, queries.len())` batches (always at least one, so
    /// per-batch averages never divide by zero), with sizes differing by
    /// at most one.
    pub fn serve_chunked(
        &self,
        queries: &[&Spectrum],
        n_batches: usize,
        backend: &BackendDispatcher,
    ) -> Result<Vec<BatchOutcome>> {
        chunk_ranges(queries.len(), n_batches)
            .into_iter()
            .map(|r| self.search_batch(&queries[r], backend))
            .collect()
    }

    /// Fold served batches into the one-time/marginal/amortized cost split.
    pub fn serving_cost(&self, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost::from_reports(&self.program_report, batches)
    }

    /// Pool accumulated batch outcomes into the one-shot summary shape:
    /// target-decoy FDR over *all* pairs, correctness against ground truth,
    /// and total ops = one-time programming + every marginal batch.
    /// `queries` must be the concatenation of the served batches, in order.
    pub fn finalize(
        &self,
        queries: &[&Spectrum],
        batches: &[BatchOutcome],
    ) -> Result<SearchOutcomeSummary> {
        let model =
            EnergyLatencyModel::new(self.cfg.material, self.cfg.adc_bits, self.cfg.num_banks);
        fold_batches(
            self.cfg.fdr,
            &model,
            &self.program_ops,
            &self.program_wall,
            queries,
            batches,
        )
    }
}

/// The one balanced contiguous-chunking rule in the serving layer, shared
/// by both `serve_chunked` impls and [`super::sharded::ShardPlan`]:
/// exactly `min(n_chunks, n_items).max(1)` ranges tiling `[0, n_items)`
/// in order, sizes differing by at most one (earlier chunks take the
/// remainder; a zero-item input keeps one empty range so per-batch
/// averages downstream never divide by zero).
pub(crate) fn chunk_ranges(n_items: usize, n_chunks: usize) -> Vec<std::ops::Range<usize>> {
    let n = n_chunks.max(1).min(n_items.max(1));
    let base = n_items / n;
    let rem = n_items % n;
    let mut ranges = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// The shared serving fold behind [`SearchEngine::finalize`] and the shard
/// layer's finalize: concatenate batch results in order, run the
/// target-decoy FDR filter over all pairs, score correctness against
/// ground truth, and report total ops = one-time programming + every
/// marginal batch through the given energy model.
pub(crate) fn fold_batches(
    fdr_rate: f64,
    model: &EnergyLatencyModel,
    program_ops: &OpCounts,
    program_wall: &StageTimer,
    queries: &[&Spectrum],
    batches: &[BatchOutcome],
) -> Result<SearchOutcomeSummary> {
    let total: usize = batches.iter().map(|b| b.pairs.len()).sum();
    crate::ensure!(
        total == queries.len(),
        "finalize: {total} batch results for {} queries",
        queries.len()
    );

    let mut pairs = Vec::with_capacity(total);
    let mut matched = Vec::with_capacity(total);
    let mut ops = *program_ops;
    let mut wall = program_wall.clone();
    for b in batches {
        pairs.extend_from_slice(&b.pairs);
        matched.extend_from_slice(&b.matched);
        ops += &b.ops;
        for (stage, t, _) in b.wall.breakdown() {
            wall.add(&stage, t);
        }
    }

    let fdr = wall.time("FDR filter", || fdr_filter(&pairs, fdr_rate));

    let mut correct = 0usize;
    let mut identified_peptides = Vec::new();
    for &qi in &fdr.accepted {
        if let (Some(m), Some(truth)) = (matched[qi], queries[qi].peptide_id) {
            if m == truth {
                correct += 1;
                identified_peptides.push(m);
            }
        }
    }
    identified_peptides.sort_unstable();
    identified_peptides.dedup();

    let report = model.report(&ops);

    Ok(SearchOutcomeSummary {
        identified: fdr.accepted.len(),
        pairs,
        correct,
        total_queries: queries.len(),
        identified_peptides,
        fdr,
        ops,
        report,
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ms::bucket::bucket_by_precursor;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_search()
        }
    }

    #[test]
    fn engine_programs_once_and_serves() {
        let ds = SearchDataset::generate("t", 41, 30, 20, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        assert_eq!(engine.n_refs(), 60);
        assert_eq!(engine.n_targets(), 30);
        assert_eq!(engine.slots().len(), 60);
        assert!(engine.program_ops().program_rounds > 0);
        assert!(engine.program_report().program_j > 0.0);

        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs.len(), queries.len());
        // Marginal batches never pay programming again.
        assert_eq!(batch.ops.program_rounds, 0);
        assert_eq!(batch.ops.verify_rounds, 0);
        assert!(batch.ops.mvm_ops > 0);

        let out = engine.finalize(&queries, &[batch]).unwrap();
        assert_eq!(out.total_queries, queries.len());
        assert_eq!(out.ops.program_rounds, engine.program_ops().program_rounds);
    }

    #[test]
    fn query_cache_hits_are_bit_identical_and_reported() {
        let ds = SearchDataset::generate("t", 45, 30, 12, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        // Cold engine: every distinct query is a miss.
        let cold = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(cold.cache.total(), queries.len() as u64);

        // The same batch again: all hits, and the outcome is bit-identical
        // (pairs, matches, marginal ops and energy all unchanged).
        let warm = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(warm.cache.hits, queries.len() as u64);
        assert_eq!(warm.cache.misses, 0);
        assert_eq!(warm.pairs, cold.pairs);
        assert_eq!(warm.matched, cold.matched);
        assert_eq!(warm.ops, cold.ops);
        assert_eq!(warm.report.total_j(), cold.report.total_j());

        // Duplicates inside one batch hit too: only uniques encode.
        engine.clear_query_cache();
        let doubled: Vec<&Spectrum> = queries.iter().chain(queries.iter()).copied().collect();
        let dup = engine.search_batch(&doubled, &be).unwrap();
        assert_eq!(dup.cache.misses, cold.cache.misses);
        assert_eq!(dup.cache.hits as usize + dup.cache.misses as usize, doubled.len());
        assert_eq!(&dup.pairs[..queries.len()], &cold.pairs[..]);
        assert_eq!(&dup.pairs[queries.len()..], &cold.pairs[..]);
        // Accounting never sees the cache: double the queries, double the
        // encode charge.
        assert_eq!(dup.ops.encode_spectra, 2 * cold.ops.encode_spectra);

        // Cumulative stats fold every batch.
        let total = engine.encode_cache_stats();
        assert_eq!(
            total.total(),
            (queries.len() * 2 + doubled.len()) as u64
        );
        assert!(total.hit_rate() > 0.0);
    }

    #[test]
    fn serve_chunked_exact_batch_count_and_coverage() {
        let ds = SearchDataset::generate("t", 43, 20, 8, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        // 8 queries into 6 batches: exactly 6, sizes differing by <= 1.
        let outcomes = engine.serve_chunked(&queries, 6, &be).unwrap();
        assert_eq!(outcomes.len(), 6);
        let sizes: Vec<usize> = outcomes.iter().map(|b| b.pairs.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().all(|&s| s == 1 || s == 2), "{sizes:?}");

        // More batches than queries degrades to one query per batch.
        let outcomes = engine.serve_chunked(&queries, 100, &be).unwrap();
        assert_eq!(outcomes.len(), 8);

        // Zero queries still yields one (empty) outcome — per-batch
        // averages downstream never divide by zero.
        let outcomes = engine.serve_chunked(&[], 3, &be).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].pairs.is_empty());
    }

    #[test]
    fn check_capacity_typed_preflight() {
        // hd 2048 / n=3 -> 6 segments; 64 banks -> 10 groups x 128 = 1280.
        assert!(SearchEngine::check_capacity(&small_cfg(), 1280).is_ok());
        let e = SearchEngine::check_capacity(&small_cfg(), 1281).unwrap_err();
        assert_eq!(e.capacity, 1280);
        assert_eq!(e.num_banks, 64);
        // A single HV wider than all banks together: zero capacity.
        let cfg = SpecPcmConfig {
            num_banks: 2,
            ..small_cfg()
        };
        let e = SearchEngine::check_capacity(&cfg, 1).unwrap_err();
        assert_eq!(e.capacity, 0);
        assert_eq!(e.segments, 6);
    }

    // The over-capacity `SearchEngine::program` error path is covered at
    // integration level in `rust/tests/engine_equivalence.rs`; the unit
    // tests below pin the typed field values of the pre-flight checks.

    #[test]
    fn check_fit_reports_capacity_fields() {
        let cfg = SpecPcmConfig {
            num_banks: 6,
            ..small_cfg()
        };
        let ctx = ProgramContext::new(&cfg, 768, 0x5e).unwrap();
        let e = ctx.check_fit(200).unwrap_err();
        assert_eq!(e.rows_needed, 200);
        assert_eq!(e.capacity, 128);
        assert_eq!(e.num_banks, 6);
        assert_eq!(e.segments, 6);
        assert!(ctx.check_fit(128).is_ok());
    }

    #[test]
    fn engine_is_sync_shareable() {
        // The shard layer fans `search_batch` out across scoped threads;
        // this fails to compile if interior mutability regresses to
        // `RefCell`.
        fn assert_sync<T: Sync>() {}
        assert_sync::<SearchEngine>();
    }

    #[test]
    fn serving_cost_merge_sums_work_and_maxes_batches() {
        let a = ServingCost {
            one_time_j: 1.0,
            marginal_j: 0.25,
            one_time_s: 2.0,
            marginal_s: 0.5,
            n_batches: 4,
        };
        let b = ServingCost {
            one_time_j: 3.0,
            marginal_j: 0.75,
            one_time_s: 1.0,
            marginal_s: 1.5,
            n_batches: 4,
        };
        let mut m = a;
        m += &b;
        assert_eq!(m.one_time_j, 4.0);
        assert_eq!(m.marginal_j, 1.0);
        assert_eq!(m.one_time_s, 3.0);
        assert_eq!(m.marginal_s, 2.0);
        // Same fan-out run on both shards: not 8 batches.
        assert_eq!(m.n_batches, 4);
        assert_eq!(m.amortized_j_per_batch(), 5.0 / 4.0);

        let s: ServingCost = [a, b].into_iter().sum();
        assert_eq!(s.one_time_j, m.one_time_j);
        assert_eq!(s.n_batches, 4);
    }

    #[test]
    fn group_charges_merge_matches_monolithic_tiling() {
        let key = |i: i64| vec![(2u8, i)];

        // Monolithic: one group of 2 queries x 300 candidates.
        let mut mono = GroupCharges::default();
        mono.record(key(0), 2, 300);
        let mut mono_ops = OpCounts::default();
        mono.charge(256, &mut mono_ops);
        // 2 queries x ceil(300/128)=3 row tiles x 2 col tiles.
        assert_eq!(mono_ops.mvm_ops, 12);
        assert_eq!(mono_ops.merge_elements, 600);

        // The same group split 130 / 170 across two shards: per-shard
        // charging would see ceil(130/128) + ceil(170/128) = 4 row tiles;
        // merging first restores the monolithic 3.
        let mut a = GroupCharges::default();
        a.record(key(0), 2, 130);
        let mut b = GroupCharges::default();
        b.record(key(0), 2, 170);
        // A group empty on shard b merges harmlessly.
        b.record(key(1), 1, 0);
        a.merge(&b);
        let mut sharded_ops = OpCounts::default();
        a.charge(256, &mut sharded_ops);
        assert_eq!(sharded_ops.mvm_ops, mono_ops.mvm_ops);
        assert_eq!(sharded_ops.merge_elements, mono_ops.merge_elements);
    }

    #[test]
    fn encode_then_score_packed_equals_search_batch() {
        let ds = SearchDataset::generate("t", 47, 25, 10, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        let batch = engine.search_batch(&queries, &be).unwrap();

        engine.clear_query_cache();
        let (packed, cache) = engine.encode_queries(&queries, &be).unwrap();
        assert_eq!(cache.total(), queries.len() as u64);
        let scored = engine.score_packed(&queries, &packed, &be).unwrap();
        let pairs: Vec<(f32, f32)> = scored.best.iter().map(|&(t, d, _)| (t, d)).collect();
        assert_eq!(pairs, batch.pairs);

        let mut ops = OpCounts::default();
        engine.frontend.count_encode_ops(queries.len(), &mut ops);
        scored.charges.charge(engine.packed_width(), &mut ops);
        assert_eq!(ops, batch.ops);
    }

    #[test]
    fn bucket_contiguous_layout_invariants() {
        let ds = SearchDataset::generate("t", 49, 40, 10, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let n = engine.n_refs();

        // The physical->logical map is a permutation of every row.
        let mut seen = vec![false; n];
        for &l in engine.logical_of_physical() {
            assert!(!seen[l], "logical row {l} stored twice");
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s), "every logical row is stored");

        // Bucket ranges tile the physical rows contiguously in key order
        // (adjacent buckets are physically adjacent), and each bucket's
        // physical rows hold ascending logical rows — the property the
        // merge tie rule and segment coalescing rely on.
        let mut cursor = 0usize;
        let all_refs: Vec<Spectrum> = ds
            .library
            .iter()
            .chain(ds.decoys.iter())
            .cloned()
            .collect();
        let buckets = bucket_by_precursor(&all_refs, engine.cfg.bucket_width);
        for (key, rows) in &buckets {
            let range = engine.bucket_row_range(key).expect("bucket indexed");
            assert_eq!(range.start, cursor, "ranges contiguous in key order");
            assert_eq!(range.len(), rows.len());
            let stored: Vec<usize> = range
                .clone()
                .map(|p| engine.logical_of_physical()[p])
                .collect();
            assert_eq!(&stored, rows, "bucket rows ascend logically");
            cursor = range.end;
        }
        assert_eq!(cursor, n, "ranges exhaustive");
        assert!(engine.bucket_row_range(&(200, -1)).is_none());

        // noisy_row stays logical: row ri's conductances sit at the
        // mapped physical offset of the serving panel.
        for ri in [0usize, 1, n / 2, n - 1] {
            let row = engine.noisy_row(ri);
            assert_eq!(row.len(), engine.packed_width());
        }
    }

    #[test]
    fn transient_rows_release_and_reuse() {
        let cfg = SpecPcmConfig {
            num_banks: 6,
            ..small_cfg()
        };
        let mut ctx = ProgramContext::new(&cfg, 768, 0xc1).unwrap();
        let packed = vec![1.0f32; 100 * 768];
        let mut ops = OpCounts::default();
        let (noisy, slots, faults) = ctx.program_rows(&packed, 100, 768, &mut ops).unwrap();
        assert_eq!(noisy.len(), packed.len());
        assert_eq!(slots.len(), 100);
        assert!(faults.iter().all(|&f| f == 0), "faults default-disabled");
        assert_eq!(ctx.allocator.free_slots(), 28);
        // A second 100-row bucket does not fit until the first is released.
        assert!(ctx.check_fit(100).is_err());
        ctx.release_rows(slots);
        assert!(ctx.check_fit(100).is_ok());
    }

    #[test]
    fn refresh_policy_select_threshold_order_dedupe_budget() {
        let k = |i: i64| (2u8, i);
        let cands = vec![
            (k(3), 10.0),
            (k(1), 50.0),
            (k(2), 30.0),
            (k(1), 50.0), // shard duplicate of the stalest bucket
            (k(4), 50.0), // same age as k(1): key order breaks the tie
        ];
        let all = RefreshPolicy {
            max_age_seconds: 0.0,
            budget: 0,
        };
        assert_eq!(all.select(cands.clone()), vec![k(1), k(4), k(2), k(3)]);

        let thresholded = RefreshPolicy {
            max_age_seconds: 20.0,
            budget: 0,
        };
        assert_eq!(thresholded.select(cands.clone()), vec![k(1), k(4), k(2)]);

        // Budget counts distinct buckets, not candidate entries.
        let budgeted = RefreshPolicy {
            max_age_seconds: 0.0,
            budget: 2,
        };
        assert_eq!(budgeted.select(cands), vec![k(1), k(4)]);
    }

    #[test]
    fn zero_age_clock_is_a_strict_noop() {
        let ds = SearchDataset::generate("t", 51, 25, 12, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let mut engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let before = engine.search_batch(&queries, &be).unwrap();
        let panel_before = engine.noisy_refs.clone();

        engine.advance_age(0.0);
        assert_eq!(engine.age_seconds(), 0.0);
        let panel_after = engine.noisy_refs.clone();
        assert_eq!(
            panel_before.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            panel_after.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "age-0 rebuild must be byte-identical"
        );
        let after = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(before.pairs, after.pairs);
        assert_eq!(before.matched, after.matched);
        assert_eq!(before.ops, after.ops);

        let h = engine.device_health();
        assert_eq!(h.max_age_seconds, 0.0);
        assert_eq!(h.est_conductance_loss, 0.0);
        assert_eq!(h.injected_faults, 0);
        assert_eq!(h.refreshes, 0);
        assert_eq!(after.health, h);
    }

    #[test]
    fn aging_decays_panel_and_refresh_restores_it() {
        let ds = SearchDataset::generate("t", 53, 25, 8, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let mut engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let fresh_panel = engine.noisy_refs.clone();
        let one_time_before = engine.program_ops().program_rounds;

        let horizon = 1.0e9;
        engine.advance_age(horizon);
        let h = engine.device_health();
        assert_eq!(h.max_age_seconds, horizon);
        assert!(h.est_conductance_loss > 0.0);
        // Every nonzero stored value shrank in magnitude.
        let aged = engine.noisy_refs.clone();
        assert!(aged
            .iter()
            .zip(&fresh_panel)
            .all(|(a, f)| a.abs() <= f.abs()));
        assert!(aged.iter().zip(&fresh_panel).any(|(a, f)| a != f));

        // Full refresh at the aged clock: staleness resets, the panel is
        // re-derived from epoch-1 programming events, and the work lands
        // on the one-time ledger.
        let out = engine.maintain(&RefreshPolicy {
            max_age_seconds: 0.0,
            budget: 0,
        });
        assert_eq!(out.rows, engine.n_refs());
        assert!(out.buckets > 0);
        assert!(out.ops.program_rounds > 0);
        assert!(engine.program_ops().program_rounds > one_time_before);

        let h = engine.device_health();
        assert_eq!(h.max_age_seconds, 0.0);
        assert_eq!(h.refreshes, engine.n_refs() as u64);
        // Refreshed rows carry fresh (epoch-keyed) noise, not the old
        // values — but similar magnitudes (no drift decay remains).
        assert_ne!(engine.noisy_refs, fresh_panel);

        // A second pass under a high threshold finds nothing stale.
        let idle = engine.maintain(&RefreshPolicy {
            max_age_seconds: 1.0,
            budget: 0,
        });
        assert_eq!(idle.rows, 0);
        assert_eq!(idle.buckets, 0);
    }

    #[test]
    fn refresh_outcome_independent_of_schedule_order() {
        let ds = SearchDataset::generate("t", 55, 25, 8, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let run = |keys_rev: bool| {
            let mut e = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
            e.advance_age(1.0e8);
            let mut keys: Vec<BucketKey> =
                e.refresh_candidates().into_iter().map(|(k, _)| k).collect();
            if keys_rev {
                keys.reverse();
            }
            // One bucket at a time in the given order — per-(row, epoch)
            // roots make the result order-independent.
            for k in keys {
                e.refresh_buckets(&[k]);
            }
            e.noisy_refs.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn live_add_remove_updates_library_and_reuses_slots() {
        let ds = SearchDataset::generate("t", 57, 30, 10, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let mut engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        assert_eq!(engine.n_refs(), 60);
        assert_eq!(engine.n_targets(), 30);
        let free_before = engine.ctx.allocator.free_slots();

        // Remove two targets and a decoy.
        engine.remove_references(&[0, 7, 35]).unwrap();
        assert_eq!(engine.n_refs(), 57);
        assert_eq!(engine.n_targets(), 28);
        assert_eq!(engine.ctx.allocator.free_slots(), free_before + 3);
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs.len(), queries.len());

        // Errors leave state untouched: out of range, dead, duplicate.
        assert!(engine.remove_references(&[10_000]).is_err());
        assert!(engine.remove_references(&[0]).is_err());
        assert!(engine.remove_references(&[1, 1]).is_err());
        assert_eq!(engine.n_refs(), 57);

        // Re-add two spectra from another dataset as targets: slots are
        // reused, counts and layout update, and serving still works.
        let extra = SearchDataset::generate("x", 58, 4, 1, 0.8, 0.2, 0, 0);
        let add: Vec<&Spectrum> = extra.library.iter().take(2).collect();
        let ops_before = engine.program_ops().program_rounds;
        let rows = engine.add_references(&add, true, &be).unwrap();
        assert_eq!(rows, vec![60, 61]);
        assert_eq!(engine.n_refs(), 59);
        assert_eq!(engine.n_targets(), 30);
        assert_eq!(engine.ctx.allocator.free_slots(), free_before + 1);
        assert!(engine.program_ops().program_rounds > ops_before);
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs.len(), queries.len());

        // The layout still tiles the live rows exactly once.
        let n = engine.n_refs();
        let mut seen = std::collections::HashSet::new();
        for &l in engine.logical_of_physical() {
            assert!(seen.insert(l));
        }
        assert_eq!(seen.len(), n);
        assert!(!seen.contains(&0) && !seen.contains(&7) && !seen.contains(&35));
    }
}
