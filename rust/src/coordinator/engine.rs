//! Persistent program-once / query-many DB-search engine (paper Table 3,
//! §III: the reference library is programmed into the PCM banks **once**
//! and query batches stream against it).
//!
//! # One-time vs. per-batch energy accounting
//!
//! [`SearchEngine::program`] encodes the target+decoy library, places every
//! reference HV on a physical (bank-group, row) slot through the
//! [`SegmentAllocator`], and programs the packed rows through the
//! write-verify [`ProgramContext`]. All of that work — ASIC encode+pack of
//! the library, programming pulse rounds, verify reads — is charged to the
//! engine's **one-time** [`OpCounts`]/[`EnergyReport`]
//! ([`SearchEngine::program_ops`] / [`SearchEngine::program_report`]) and is
//! *never* charged again, no matter how many batches are served.
//!
//! Each [`SearchEngine::search_batch`] call reuses the programmed noisy
//! conductances and returns a [`BatchOutcome`] whose ops/report cover only
//! the **marginal** per-batch work: query encode+pack, IMC score tiles, and
//! the ASIC top-1 merge. Amortized cost over a serving run is therefore
//! `program_report + sum(batch reports)`, which is exactly what
//! [`SearchEngine::finalize`] folds into the one-shot
//! [`SearchOutcomeSummary`] shape — bit-identical to a monolithic
//! [`super::SearchPipeline::run`] on the same dataset, regardless of how
//! the queries were split into batches.
//!
//! A library that does not fit the configured banks fails construction
//! with a typed [`CapacityError`] instead of silently ignoring `num_banks`
//! — and a library that overflows one engine can be split across several
//! via the shard layer ([`super::sharded::ShardedSearchEngine`]), which
//! builds on the [`SearchEngine::encode_queries`] /
//! [`SearchEngine::score_packed`] / [`GroupCharges`] primitives below.
//!
//! # Bucket-contiguous serving layout
//!
//! Serving is zero-copy on the reference side: after programming, the
//! engine physically reorders its host copy of the stored conductances so
//! that each precursor bucket's rows occupy one contiguous range
//! (`BucketKey -> Range<physical row>`, [`SearchEngine::bucket_row_range`]).
//! A candidate set from `candidate_keys_open` is then a handful of
//! contiguous panels handed to the backend as a segmented
//! [`MvmJob`](crate::backend::MvmJob) — no per-batch gather of reference
//! rows, and the per-group score/query buffers are reused across batches
//! through [`BackendDispatcher::execute_into`].
//!
//! The permutation happens strictly **after** write-verify programming, so
//! the data-dependent per-row noise RNG stream is consumed in the same
//! logical order (targets then decoys) as always — which is what keeps
//! sharded and monolithic engines programming bit-identical conductances.
//! A physical→logical row map ([`SearchEngine::logical_of_physical`])
//! translates scored columns back to logical rows for target/decoy
//! classification, peptide lookup and slot bookkeeping
//! ([`SearchEngine::slots`] / [`SearchEngine::noisy_row`] stay in logical
//! row order). The top-1 merge breaks score ties by **lowest logical
//! row** explicitly, reproducing the gathered path's ascending-logical
//! iteration bit-for-bit — and, downstream, the shard merge's
//! lowest-global-row contract.
//!
//! # Query-HV cache
//!
//! Real serving traffic repeats spectra (re-queries, overlapping batches,
//! replays), and before this cache every occurrence re-ran the HD encode
//! kernel. The engine now memoizes packed query HVs **keyed by the
//! quantized level vector** — the exact input of the encode kernel, so a
//! cache hit is bit-identical to a fresh encode by construction. Hits and
//! misses are surfaced on every [`BatchOutcome`] and cumulatively via
//! [`SearchEngine::encode_cache_stats`]. Op and energy accounting are
//! deliberately **unchanged**: the ASIC still performs the encode for
//! every spectrum, the cache only removes redundant *host* arithmetic
//! (exactly like backend selection, it can never change results or
//! simulated cost — `rust/tests/encode_equivalence.rs` locks this in).
//! The cache lives behind a `Mutex`, never a `RefCell`: `&SearchEngine`
//! is `Sync`, so the shard layer can fan one batch out across scoped
//! threads while hit/miss reporting keeps working per batch.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::array::{dac_quantize, AdcConfig};
use crate::backend::{BackendDispatcher, MvmJob};
use crate::config::SpecPcmConfig;
use crate::device::{MlcConfig, NoiseModel, Programmer};
use crate::energy::{EnergyLatencyModel, EnergyReport, OpCounts};
use crate::ms::bucket::{bucket_by_precursor, candidate_keys_open, BucketKey};
use crate::ms::synth::PTM_SHIFTS;
use crate::ms::{SearchDataset, Spectrum};
use crate::search::fdr_filter;
use crate::telemetry::{EncodeCacheStats, StageTimer};
use crate::util::error::{Error, Result};
use crate::util::sync::lock_unpoisoned;
use crate::util::Rng;

use super::allocator::{SegmentAllocator, Slot};
use super::frontend::HdFrontend;
use super::pipeline::{program_refs, SearchOutcomeSummary};

/// Typed error: a reference set that does not fit the configured banks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CapacityError {
    /// Reference rows the library needs (targets + decoys).
    pub rows_needed: usize,
    /// Row slots the configured banks provide.
    pub capacity: usize,
    pub num_banks: usize,
    /// 128-wide segments per packed HV.
    pub segments: usize,
}

impl std::fmt::Display for CapacityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "library needs {} reference rows, which exceeds the {} row slots \
             {} banks provide for {}-segment HVs; raise num_banks or shrink \
             the library",
            self.rows_needed, self.capacity, self.num_banks, self.segments
        )
    }
}

impl std::error::Error for CapacityError {}

impl From<CapacityError> for Error {
    fn from(e: CapacityError) -> Error {
        Error::msg(e)
    }
}

/// Shared PCM-programming state: the write-verify programmer, the
/// deterministic programming-noise RNG stream, and the bank-capacity
/// allocator. Both pipelines drive all array programming through one
/// context, so noise streams and physical placement are identical whether
/// rows are programmed in one shot (DB-search library) or transiently per
/// bucket (clustering).
pub struct ProgramContext {
    pub programmer: Programmer,
    pub allocator: SegmentAllocator,
    rng: Rng,
}

impl ProgramContext {
    /// Seed tag of the DB-search programming-noise stream (`seed ^ 0x5e`).
    pub const SEARCH_SEED_TAG: u64 = 0x5e;
    /// Seed tag of the clustering programming-noise stream (`seed ^ 0xc1`).
    pub const CLUSTER_SEED_TAG: u64 = 0xc1;

    /// `seed_tag` keeps the clustering and search noise streams distinct
    /// ([`Self::CLUSTER_SEED_TAG`] / [`Self::SEARCH_SEED_TAG`], matching
    /// the pre-engine pipelines).
    pub fn new(cfg: &SpecPcmConfig, packed_width: usize, seed_tag: u64) -> Result<Self> {
        Self::with_rng(cfg, packed_width, Self::noise_rng(cfg, seed_tag))
    }

    /// Root of a fresh programming-noise stream (`cfg.seed ^ seed_tag`).
    /// This is the *only* blessed `Rng::new` site in engine code (contract
    /// lint rule C4-RNG): every downstream consumer — sharded programming
    /// in particular — must chain an existing state through
    /// [`ProgramContext::rng_state`] / `SearchEngine::noise_rng_state`
    /// instead of re-seeding, because per-row RNG consumption is
    /// data-dependent (write-verify converges early) and re-seeding would
    /// desynchronize shards from the monolithic reference.
    pub fn noise_rng(cfg: &SpecPcmConfig, seed_tag: u64) -> Rng {
        Rng::new(cfg.seed ^ seed_tag)
    }

    /// Construct with an explicit programming-noise RNG state. The shard
    /// layer chains contexts through this: shard `i+1` starts from the
    /// exact state shard `i` finished with, so the concatenated per-row
    /// noise stream is bit-identical to one monolithic context programming
    /// every row in sequence (RNG consumption per row is data-dependent —
    /// write-verify converges early — so only state hand-off, not seed
    /// arithmetic, can reproduce the stream).
    pub fn with_rng(cfg: &SpecPcmConfig, packed_width: usize, rng: Rng) -> Result<Self> {
        let programmer = Programmer::new(
            NoiseModel::new(cfg.material, MlcConfig::new(cfg.mlc_bits)),
            cfg.write_verify,
        );
        let allocator = SegmentAllocator::try_new(cfg.num_banks, packed_width)?;
        Ok(ProgramContext {
            programmer,
            allocator,
            rng,
        })
    }

    /// Snapshot of the programming-noise RNG after everything programmed
    /// so far (the hand-off state for the next shard's context).
    pub fn rng_state(&self) -> Rng {
        self.rng.clone()
    }

    /// Typed pre-flight check: do `n_rows` more HVs fit the free slots?
    pub fn check_fit(&self, n_rows: usize) -> Result<(), CapacityError> {
        if n_rows > self.allocator.free_slots() {
            return Err(CapacityError {
                rows_needed: n_rows,
                capacity: self.allocator.capacity(),
                num_banks: self.allocator.num_banks(),
                segments: self.allocator.segments(),
            });
        }
        Ok(())
    }

    /// Allocate slots for and program `n_rows` packed rows (row-major
    /// `n_rows x cp`). Returns the noisy stored conductances plus the
    /// physical slots, or a [`CapacityError`] when the rows don't fit.
    pub fn program_rows(
        &mut self,
        packed: &[f32],
        n_rows: usize,
        cp: usize,
        ops: &mut OpCounts,
    ) -> Result<(Vec<f32>, Vec<Slot>)> {
        self.check_fit(n_rows)?;
        let mut slots = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            slots.push(self.allocator.alloc().expect("free slots were checked"));
        }
        let noisy = program_refs(packed, n_rows, cp, &self.programmer, &mut self.rng, ops);
        Ok((noisy, slots))
    }

    /// Release transient rows (clustering reprograms the banks per bucket).
    pub fn release_rows(&mut self, slots: Vec<Slot>) {
        for s in slots {
            self.allocator.release(s);
        }
    }
}

/// Marginal result of serving one query batch against the programmed
/// library. Ops/report cover *only* this batch's work (query encode, IMC
/// scoring, top-1 merge) — the one-time library programming lives on the
/// engine.
#[derive(Clone, Debug, Default)]
pub struct BatchOutcome {
    /// Per-query best (target score, decoy score) pairs, in batch order.
    pub pairs: Vec<(f32, f32)>,
    /// Best-matching target peptide id per query, in batch order.
    pub matched: Vec<Option<u32>>,
    /// Marginal op counts for this batch only.
    pub ops: OpCounts,
    /// Energy/latency of the marginal ops alone.
    pub report: EnergyReport,
    /// Query-HV cache hits/misses for this batch (host-time telemetry;
    /// ops/report above are independent of the cache by design).
    pub cache: EncodeCacheStats,
    pub wall: StageTimer,
}

/// One-time vs. marginal vs. amortized energy/latency split over a serving
/// run — the single place the accounting formulas live; the CLI, the
/// streaming example and the Table 3 bench only format it.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingCost {
    /// Library encode+program energy, paid once at engine construction.
    pub one_time_j: f64,
    /// Sum of the served batches' marginal energies.
    pub marginal_j: f64,
    /// One-time programming latency (sequential).
    pub one_time_s: f64,
    /// Sum of the served batches' overlapped latencies.
    pub marginal_s: f64,
    pub n_batches: usize,
}

impl ServingCost {
    /// Build the one-time/marginal split from a programming report plus
    /// the served batches' marginal reports — the single constructor
    /// behind both the engine's and the shard layer's `serving_cost`.
    pub fn from_reports(one_time: &EnergyReport, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost {
            one_time_j: one_time.total_j(),
            marginal_j: batches.iter().map(|b| b.report.total_j()).sum(),
            one_time_s: one_time.total_latency_s(),
            marginal_s: batches.iter().map(|b| b.report.overlapped_latency_s()).sum(),
            n_batches: batches.len(),
        }
    }

    pub fn amortized_j_per_batch(&self) -> f64 {
        (self.one_time_j + self.marginal_j) / self.n_batches.max(1) as f64
    }

    pub fn amortized_s_per_batch(&self) -> f64 {
        (self.one_time_s + self.marginal_s) / self.n_batches.max(1) as f64
    }

    /// Fold another engine's cost for the *same* serving run into this one
    /// (shard aggregation): energies and latencies sum — each shard's
    /// banks did its share of the physical work — while `n_batches` takes
    /// the max, because every shard saw the same fan-out batch sequence,
    /// not extra batches.
    pub fn merge(&mut self, other: &ServingCost) {
        self.one_time_j += other.one_time_j;
        self.marginal_j += other.marginal_j;
        self.one_time_s += other.one_time_s;
        self.marginal_s += other.marginal_s;
        self.n_batches = self.n_batches.max(other.n_batches);
    }
}

impl std::ops::AddAssign<&ServingCost> for ServingCost {
    fn add_assign(&mut self, other: &ServingCost) {
        self.merge(other);
    }
}

impl std::ops::AddAssign for ServingCost {
    fn add_assign(&mut self, other: ServingCost) {
        self.merge(&other);
    }
}

impl std::iter::Sum for ServingCost {
    fn sum<I: Iterator<Item = ServingCost>>(iter: I) -> ServingCost {
        iter.fold(ServingCost::default(), |mut acc, c| {
            acc.merge(&c);
            acc
        })
    }
}

/// Per-candidate-group scoring charges: for every distinct candidate-key
/// set served in a batch, the number of queries in the group and the
/// candidate reference rows scored against them. This is the input of the
/// tile-granular ASIC op accounting ([`GroupCharges::charge`]), kept
/// separate from score execution so the shard layer can *merge* the
/// per-shard candidate counts back into global groups before charging —
/// bank MVM ops round candidate rows up to whole 128-row tiles
/// (`MvmJob::bank_ops`), so charging per shard would over-count partial
/// tiles at shard boundaries relative to the monolithic equivalent.
/// Sharding must change placement and host concurrency only, never the
/// simulated ASIC work (`rust/tests/engine_equivalence.rs` locks this in).
#[derive(Clone, Debug, Default)]
pub struct GroupCharges {
    /// Candidate-key set -> (queries in group, candidate rows scored).
    by_group: BTreeMap<Vec<BucketKey>, (usize, usize)>,
}

impl GroupCharges {
    /// Record one group's scoring work (`n_cand` may be 0 for groups whose
    /// candidate set is empty on this shard — they still merge).
    pub fn record(&mut self, keys: Vec<BucketKey>, n_queries: usize, n_cand: usize) {
        let entry = self.by_group.entry(keys).or_insert((n_queries, 0));
        debug_assert_eq!(entry.0, n_queries, "group query count disagrees");
        entry.1 += n_cand;
    }

    /// Fold another shard's charges for the same query batch into this
    /// one: candidate counts sum per group (shards partition the library,
    /// so per-shard candidate sets are disjoint). Keys already present
    /// merge in place; a key vector is cloned only the first time a group
    /// appears, so each group key is allocated once per batch.
    pub fn merge(&mut self, other: &GroupCharges) {
        for (keys, &(nq, nc)) in &other.by_group {
            if let Some(entry) = self.by_group.get_mut(keys) {
                debug_assert_eq!(entry.0, nq, "group query count disagrees");
                entry.1 += nc;
            } else {
                self.by_group.insert(keys.clone(), (nq, nc));
            }
        }
    }

    /// Charge the batch's IMC scoring + ASIC top-1 merge ops: per group
    /// with a non-empty *global* candidate set, every query drives
    /// `ceil(n_cand / 128)` row tiles x `cp / 128` column tiles of bank
    /// MVMs (the [`crate::backend::MvmJob::bank_ops`] formula) and one
    /// merge-element comparison per candidate.
    pub fn charge(&self, cp: usize, ops: &mut OpCounts) {
        let col_tiles = (cp / crate::array::ARRAY_DIM) as u64;
        for &(nq, nc) in self.by_group.values() {
            if nc == 0 {
                continue;
            }
            let row_tiles = nc.div_ceil(crate::array::ARRAY_DIM) as u64;
            ops.mvm_ops += nq as u64 * row_tiles * col_tiles;
            ops.merge_elements += (nq * nc) as u64;
        }
    }
}

/// One engine's (or one shard's) scoring result for a query batch,
/// before op/energy folding: per-query bests, the per-group charge info,
/// and the host wall-time of the scoring stages.
#[derive(Clone, Debug)]
pub struct ShardScores {
    /// Per-query `(best target score, best decoy score, matched peptide)`
    /// in batch order; `(NEG_INFINITY, NEG_INFINITY, None)` when the
    /// query had no candidates on this engine.
    pub best: Vec<(f32, f32, Option<u32>)>,
    /// Per-candidate-group query/candidate counts for central charging.
    pub charges: GroupCharges,
    pub wall: StageTimer,
}

/// Program-once / query-many DB-search engine. See the module docs for the
/// one-time vs. per-batch energy-accounting split.
pub struct SearchEngine {
    pub cfg: SpecPcmConfig,
    pub frontend: HdFrontend,
    ctx: ProgramContext,
    adc: AdcConfig,
    cp: usize,
    n_targets: usize,
    /// Peptide id per *logical* reference row (targets then decoys) — the
    /// only per-spectrum metadata serving needs, so the engine does not
    /// retain the peak data of a library it already programmed.
    ref_peptides: Vec<Option<u32>>,
    /// Programmed noisy conductances, row-major `n_refs x cp`, in
    /// **bucket-contiguous physical row order**: each precursor bucket's
    /// rows form one contiguous range (`bucket_ranges`), so candidate
    /// panels are borrowed row ranges instead of per-batch gathered
    /// copies. Permuted from logical order *after* programming — the
    /// noise stream is consumed in logical row order, untouched.
    noisy_refs: Vec<f32>,
    /// Physical (bank group, row) slot of each *logical* reference row.
    ref_slots: Vec<Slot>,
    /// Precursor bucket -> physical row range into `noisy_refs`.
    bucket_ranges: BTreeMap<BucketKey, std::ops::Range<usize>>,
    /// Physical row in `noisy_refs` -> logical reference row.
    logical_of_phys: Vec<usize>,
    /// Logical reference row -> physical row in `noisy_refs`.
    phys_of_logical: Vec<usize>,
    program_ops: OpCounts,
    program_report: EnergyReport,
    program_wall: StageTimer,
    /// Packed query HVs keyed by quantized level vector (see the module
    /// docs' "Query-HV cache" section). A `Mutex` (not `RefCell`) keeps
    /// `search_batch(&self)` signature-stable *and* the engine `Sync`, so
    /// shard fan-out can share it across scoped threads.
    query_cache: Mutex<HashMap<Vec<u16>, Vec<f32>>>,
    cache_stats: Mutex<EncodeCacheStats>,
    /// Reusable scoring buffers (segment list, gathered query rows, score
    /// tile), kept across groups *and* batches so steady-state serving
    /// performs no per-batch allocations on the score path. `try_lock`
    /// semantics: a concurrent `search_batch` on the same engine simply
    /// falls back to fresh buffers instead of blocking.
    score_scratch: Mutex<ScoreScratch>,
}

/// Buffers [`SearchEngine::score_packed`] reuses across candidate groups
/// and batches (see the `score_scratch` field).
#[derive(Debug, Default)]
struct ScoreScratch {
    segments: Vec<std::ops::Range<usize>>,
    /// Whole-batch DAC-quantized queries (PR 6 hoisting): each packed
    /// query is quantized once per batch here, instead of once per
    /// candidate-group job inside the blocked kernel. Score-neutral by
    /// DAC idempotence; op accounting is unchanged (DAC ops are charged
    /// per logical conversion, not per kernel call).
    dacq: Vec<f32>,
    q_rows: Vec<f32>,
    scores: Vec<f32>,
}

/// Entry cap for the query-HV cache: past this many distinct spectra the
/// engine stops inserting (existing entries keep hitting). Bounds memory
/// — each entry holds a `cp`-long f32 row plus its `features`-long u16
/// level-vector key (~4-5 KB at paper-scale configs) — without
/// introducing eviction nondeterminism.
const QUERY_CACHE_MAX_ENTRIES: usize = 1 << 16;

impl SearchEngine {
    /// Typed pre-flight: would an `n_rows`-row reference library fit
    /// `cfg`'s banks? [`SearchEngine::program`] returns the crate-wide
    /// string-backed error, so callers that want to react programmatically
    /// (auto-raise `num_banks`, shard the library) should gate on this
    /// first and match the [`CapacityError`] fields directly.
    pub fn check_capacity(cfg: &SpecPcmConfig, n_rows: usize) -> Result<(), CapacityError> {
        let packed = crate::hd::padded_packed_len(cfg.hd_dim, cfg.packing());
        match SegmentAllocator::try_new(cfg.num_banks, packed) {
            Ok(a) if n_rows <= a.capacity() => Ok(()),
            Ok(a) => Err(CapacityError {
                rows_needed: n_rows,
                capacity: a.capacity(),
                num_banks: cfg.num_banks,
                segments: a.segments(),
            }),
            // A single HV wider than all banks together: zero capacity.
            Err(_) => Err(CapacityError {
                rows_needed: n_rows,
                capacity: 0,
                num_banks: cfg.num_banks,
                segments: packed / crate::array::ARRAY_DIM,
            }),
        }
    }

    /// Encode + program the dataset's reference library (targets followed
    /// by decoys) exactly once. Fails with a [`CapacityError`] — before any
    /// encode work is spent — when the library exceeds the banks' capacity
    /// (use [`SearchEngine::check_capacity`] for the typed pre-flight).
    pub fn program(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
    ) -> Result<Self> {
        let rng = ProgramContext::noise_rng(&cfg, ProgramContext::SEARCH_SEED_TAG);
        Self::program_with_rng(cfg, dataset, backend, rng)
    }

    /// [`SearchEngine::program`] with an explicit programming-noise RNG
    /// state (see [`ProgramContext::with_rng`]). The shard layer programs
    /// shard `i+1` from the state [`SearchEngine::noise_rng_state`]
    /// reports after shard `i`, which makes the sharded library's stored
    /// conductances bit-identical to one monolithic engine programming
    /// the same rows in the same order.
    pub fn program_with_rng(
        cfg: SpecPcmConfig,
        dataset: &SearchDataset,
        backend: &BackendDispatcher,
        rng: Rng,
    ) -> Result<Self> {
        let frontend = HdFrontend::new(&cfg);
        let cp = frontend.packed_width;
        let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
        let mut ctx = ProgramContext::with_rng(&cfg, cp, rng)?;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();

        let all_refs: Vec<&Spectrum> = dataset
            .library
            .iter()
            .chain(dataset.decoys.iter())
            .collect();
        let n_targets = dataset.library.len();
        ctx.check_fit(all_refs.len())?;

        let packed_refs = wall.time("encode refs", || {
            frontend.encode_pack(&all_refs, backend, &mut ops)
        })?;
        let (noisy_logical, ref_slots) = wall.time("program refs", || {
            ctx.program_rows(&packed_refs, all_refs.len(), cp, &mut ops)
        })?;

        // Bucket the references for candidate selection, then keep only the
        // peptide ids — the peak data is already encoded into the noisy
        // conductances.
        let ref_spectra: Vec<Spectrum> = all_refs.iter().map(|s| (*s).clone()).collect();
        let ref_buckets = bucket_by_precursor(&ref_spectra, cfg.bucket_width);
        let ref_peptides: Vec<Option<u32>> = ref_spectra.iter().map(|s| s.peptide_id).collect();

        // Permute the host copy of the stored conductances into
        // bucket-contiguous physical order (module docs). This happens
        // strictly *after* programming: every logical row's conductances —
        // and the data-dependent noise stream that produced them — are
        // exactly what a layout-free engine would hold; only the host
        // buffer order changes, in bucket-key order so adjacent candidate
        // buckets coalesce into one contiguous panel.
        let n_refs = all_refs.len();
        let mut logical_of_phys = Vec::with_capacity(n_refs);
        let mut bucket_ranges = BTreeMap::new();
        for (key, rows) in &ref_buckets {
            let start = logical_of_phys.len();
            logical_of_phys.extend_from_slice(rows);
            bucket_ranges.insert(*key, start..logical_of_phys.len());
        }
        debug_assert_eq!(logical_of_phys.len(), n_refs, "buckets partition the rows");
        let mut phys_of_logical = vec![0usize; n_refs];
        let mut noisy_refs = vec![0f32; noisy_logical.len()];
        wall.time("layout refs", || {
            for (p, &l) in logical_of_phys.iter().enumerate() {
                phys_of_logical[l] = p;
                noisy_refs[p * cp..(p + 1) * cp]
                    .copy_from_slice(&noisy_logical[l * cp..(l + 1) * cp]);
            }
        });

        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let program_report = model.report(&ops);

        Ok(SearchEngine {
            cfg,
            frontend,
            ctx,
            adc,
            cp,
            n_targets,
            ref_peptides,
            noisy_refs,
            ref_slots,
            bucket_ranges,
            logical_of_phys,
            phys_of_logical,
            program_ops: ops,
            program_report,
            program_wall: wall,
            query_cache: Mutex::new(HashMap::new()),
            cache_stats: Mutex::new(EncodeCacheStats::default()),
            score_scratch: Mutex::new(ScoreScratch::default()),
        })
    }

    /// Programming-noise RNG state after everything programmed so far —
    /// the hand-off for the next shard (see
    /// [`SearchEngine::program_with_rng`]).
    pub fn noise_rng_state(&self) -> Rng {
        self.ctx.rng_state()
    }

    /// Cumulative query-HV cache hits/misses across every served batch.
    pub fn encode_cache_stats(&self) -> EncodeCacheStats {
        *lock_unpoisoned(&self.cache_stats, "cache stats")
    }

    /// Drop every cached query HV (the cache refills on subsequent
    /// batches; results are identical either way).
    pub fn clear_query_cache(&self) {
        lock_unpoisoned(&self.query_cache, "query cache").clear();
    }

    /// One-time library ops (encode + pack + program + verify), charged at
    /// construction and never again.
    pub fn program_ops(&self) -> &OpCounts {
        &self.program_ops
    }

    /// Energy/latency of the one-time library programming alone.
    pub fn program_report(&self) -> &EnergyReport {
        &self.program_report
    }

    /// Host wall-time breakdown of the one-time library programming.
    pub fn program_wall(&self) -> &StageTimer {
        &self.program_wall
    }

    /// Reference rows programmed (targets + decoys).
    pub fn n_refs(&self) -> usize {
        self.ref_peptides.len()
    }

    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    /// Packed width (`cp`) of every programmed row.
    pub fn packed_width(&self) -> usize {
        self.cp
    }

    /// Physical slot of each reference row, in row order.
    pub fn slots(&self) -> &[Slot] {
        &self.ref_slots
    }

    /// Physical bank indices a reference row's segments occupy.
    pub fn banks_of(&self, slot: Slot) -> Vec<usize> {
        self.ctx.allocator.banks_of(slot)
    }

    /// Stored noisy conductances of *logical* reference row `ri` (`cp`
    /// wide) — indexed through the physical layout map, so callers (ISA
    /// mirroring, tests) keep the targets-then-decoys row order no matter
    /// how the host buffer is physically arranged.
    pub fn noisy_row(&self, ri: usize) -> &[f32] {
        let p = self.phys_of_logical[ri];
        &self.noisy_refs[p * self.cp..(p + 1) * self.cp]
    }

    /// Physical row range the given precursor bucket occupies in the
    /// bucket-contiguous reference layout (`None` when no reference falls
    /// in the bucket). Ranges of adjacent `BucketKey`s are physically
    /// adjacent, which is what lets open-search candidate sets collapse
    /// into a few contiguous panels.
    pub fn bucket_row_range(&self, key: &BucketKey) -> Option<std::ops::Range<usize>> {
        self.bucket_ranges.get(key).cloned()
    }

    /// Physical-to-logical row map of the bucket-contiguous layout:
    /// `logical_of_physical()[p]` is the logical (targets-then-decoys)
    /// reference row stored at physical row `p` of the serving panel.
    pub fn logical_of_physical(&self) -> &[usize] {
        &self.logical_of_phys
    }

    /// Encode one query batch into packed HVs through the query-HV cache:
    /// unique uncached level vectors encode once per batch, everything
    /// else is a copy. Returns the row-major `queries.len() x cp` packed
    /// rows plus this batch's hit/miss stats (also folded into the
    /// cumulative [`SearchEngine::encode_cache_stats`]).
    ///
    /// **No op accounting happens here** — the ASIC encode charge covers
    /// every query regardless of the cache (module docs, "Query-HV
    /// cache"), and belongs to whoever owns the batch: callers charge
    /// [`HdFrontend::count_encode_ops`] exactly once per batch. The shard
    /// layer relies on this split to encode once and share the packed
    /// rows across every shard instead of paying the encode per shard.
    pub fn encode_queries(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<(Vec<f32>, EncodeCacheStats)> {
        let cp = self.cp;
        let mut batch_cache = EncodeCacheStats::default();
        let levels = self.frontend.levels_of(queries);

        // One classification pass under one lock hold: hit rows are copied
        // out *while the entry is provably present*, so a concurrent
        // `clear_query_cache` (the engine is Sync and may be shared across
        // threads) can never invalidate a hit between classification and
        // copy. Misses are deduped and encoded after the lock drops — the
        // expensive kernel never runs under the lock.
        let mut packed = vec![0f32; levels.len() * cp];
        let mut miss_of: HashMap<&Vec<u16>, usize> = HashMap::new();
        let mut miss_levels: Vec<Vec<u16>> = Vec::new();
        // (query index, miss index) rows to fill once the misses encode.
        let mut pending: Vec<(usize, usize)> = Vec::new();
        {
            let cache = lock_unpoisoned(&self.query_cache, "query cache");
            for (qi, lv) in levels.iter().enumerate() {
                if let Some(row) = cache.get(lv) {
                    packed[qi * cp..(qi + 1) * cp].copy_from_slice(row);
                } else if let Some(&mi) = miss_of.get(lv) {
                    pending.push((qi, mi));
                } else {
                    let mi = miss_levels.len();
                    miss_of.insert(lv, mi);
                    miss_levels.push(lv.clone());
                    pending.push((qi, mi));
                }
            }
        }

        let n_misses = miss_levels.len();
        let miss_packed = if miss_levels.is_empty() {
            Vec::new()
        } else {
            self.frontend.encode_pack_levels(&miss_levels, backend)?
        };
        for &(qi, mi) in &pending {
            packed[qi * cp..(qi + 1) * cp].copy_from_slice(&miss_packed[mi * cp..(mi + 1) * cp]);
        }
        {
            // Insert by *moving* the already-owned miss level vectors:
            // exactly one allocation per miss (the cached row copy), not
            // two (the key was cloned here before).
            let mut cache = lock_unpoisoned(&self.query_cache, "query cache");
            for (mi, lv) in miss_levels.into_iter().enumerate() {
                if cache.len() >= QUERY_CACHE_MAX_ENTRIES {
                    break;
                }
                cache.insert(lv, miss_packed[mi * cp..(mi + 1) * cp].to_vec());
            }
        }
        batch_cache.misses = n_misses as u64;
        batch_cache.hits = (levels.len() - n_misses) as u64;

        *lock_unpoisoned(&self.cache_stats, "cache stats") += batch_cache;
        Ok((packed, batch_cache))
    }

    /// Score pre-packed query HVs against this engine's programmed rows:
    /// candidate selection, IMC score tiles and the in-engine top-1 merge,
    /// **without op accounting** — instead the per-group candidate counts
    /// come back as [`GroupCharges`] so the caller charges globally (see
    /// the [`GroupCharges`] docs for why per-shard charging would distort
    /// tile counts). Returns per-query `(best target, best decoy, matched
    /// peptide)` triples in batch order; queries with no local candidates
    /// stay at `(NEG_INFINITY, NEG_INFINITY, None)`, which the shard
    /// merge's strict `>` ignores.
    ///
    /// This is the zero-copy hot loop: candidate sets are contiguous
    /// physical row ranges of the bucket-contiguous layout (adjacent
    /// buckets coalesce into one segment), handed to the backend as
    /// segmented jobs against the borrowed `noisy_refs` panel, with the
    /// segment/query/score buffers reused across groups and batches. The
    /// scores — and, via the explicit lowest-logical-row tie rule, the
    /// per-query bests — are bit-identical to gathering every candidate
    /// row and scoring through `array::imc_mvm_ref`
    /// (`rust/tests/segmented_equivalence.rs`).
    pub fn score_packed(
        &self,
        queries: &[&Spectrum],
        packed_queries: &[f32],
        backend: &BackendDispatcher,
    ) -> Result<ShardScores> {
        let cfg = &self.cfg;
        let cp = self.cp;
        assert_eq!(packed_queries.len(), queries.len() * cp, "packed query shape");
        let mut wall = StageTimer::new();
        let mut charges = GroupCharges::default();
        // Scores and physical ops are charged by the caller from the
        // merged GroupCharges; the dispatcher's own charge goes to a
        // scratch accumulator.
        let mut scratch_ops = OpCounts::default();

        // Reusable buffers, carried across batches. A concurrent
        // `search_batch` on the same engine (the engine is Sync) just
        // takes fresh buffers instead of waiting.
        let mut bufs = self
            .score_scratch
            .try_lock()
            .map(|mut g| std::mem::take(&mut *g))
            .unwrap_or_default();

        // DAC the whole batch once; group jobs below carry `dac_applied`
        // so the kernel skips its per-call re-quantization pass.
        bufs.dacq.clear();
        bufs.dacq.reserve(packed_queries.len());
        bufs.dacq.extend(packed_queries.iter().map(|&x| dac_quantize(x)));

        // Group queries by identical candidate-key sets so one IMC batch
        // shares one reference row block.
        let mut groups: BTreeMap<Vec<BucketKey>, Vec<usize>> = BTreeMap::new();
        for (qi, q) in queries.iter().enumerate() {
            let keys = candidate_keys_open(q.charge, q.precursor_mz, cfg.bucket_width, &PTM_SHIFTS);
            groups.entry(keys).or_default().push(qi);
        }

        // Per-query best (target score, decoy score) + matched peptide,
        // plus the logical row of the current best target for the
        // lowest-logical-row tie rule (physical iteration order is bucket
        // order, so ties must be broken explicitly to reproduce the
        // gathered path's ascending-logical scan).
        let mut best: Vec<(f32, f32, Option<u32>)> =
            vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];
        let mut best_row: Vec<usize> = vec![usize::MAX; queries.len()];

        for (keys, q_idxs) in groups {
            // Candidate panels straight out of the bucket-contiguous
            // layout. `keys` is sorted and `bucket_ranges` assigns
            // physical rows in key order, so ranges arrive in ascending
            // physical order and adjacent buckets merge into one segment.
            bufs.segments.clear();
            let mut n_cand = 0usize;
            for k in &keys {
                if let Some(r) = self.bucket_ranges.get(k) {
                    n_cand += r.len();
                    match bufs.segments.last_mut() {
                        Some(last) if last.end == r.start => last.end = r.end,
                        _ => bufs.segments.push(r.clone()),
                    }
                }
            }
            let nq = q_idxs.len();
            charges.record(keys, nq, n_cand);
            if n_cand == 0 {
                continue;
            }

            // Queries within a group are scattered in the batch; gather
            // just those (already-quantized) rows into the reused stripe
            // (references are never gathered).
            bufs.q_rows.clear();
            bufs.q_rows.reserve(nq * cp);
            for &qi in &q_idxs {
                bufs.q_rows
                    .extend_from_slice(&bufs.dacq[qi * cp..(qi + 1) * cp]);
            }
            bufs.scores.clear();
            bufs.scores.resize(nq * n_cand, 0.0);

            let job = MvmJob::segmented(
                &bufs.q_rows,
                nq,
                &self.noisy_refs,
                &bufs.segments,
                cp,
                self.adc,
            )
            .with_dac_applied();
            debug_assert_eq!(job.nr, n_cand);
            wall.time("similarity (IMC)", || {
                backend.execute_into(&job, &mut bufs.scores, &mut scratch_ops)
            })?;

            wall.time("top-1 + merge (ASIC)", || {
                for (bi, &qi) in q_idxs.iter().enumerate() {
                    let row = &bufs.scores[bi * n_cand..(bi + 1) * n_cand];
                    let mut ci = 0usize;
                    for seg in &bufs.segments {
                        for p in seg.clone() {
                            let s = row[ci];
                            ci += 1;
                            let ri = self.logical_of_phys[p];
                            if ri < self.n_targets {
                                if s > best[qi].0 || (s == best[qi].0 && ri < best_row[qi]) {
                                    best[qi].0 = s;
                                    best[qi].2 = self.ref_peptides[ri];
                                    best_row[qi] = ri;
                                }
                            } else if s > best[qi].1 {
                                best[qi].1 = s;
                            }
                        }
                    }
                }
            });
        }

        if let Ok(mut g) = self.score_scratch.try_lock() {
            *g = bufs;
        }

        Ok(ShardScores {
            best,
            charges,
            wall,
        })
    }

    /// Serve one query batch against the programmed library. Scores are
    /// bit-identical regardless of how queries are split into batches: the
    /// per-(query, candidate) IMC score depends only on the query HV, the
    /// stored conductances and the ADC, never on batch composition.
    pub fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        let cfg = &self.cfg;
        let mut ops = OpCounts::default();
        let mut wall = StageTimer::new();

        self.frontend.count_encode_ops(queries.len(), &mut ops);
        let (packed_queries, batch_cache) =
            wall.time("encode queries", || self.encode_queries(queries, backend))?;

        let scored = self.score_packed(queries, &packed_queries, backend)?;
        for (stage, t, _) in scored.wall.breakdown() {
            wall.add(&stage, t);
        }
        scored.charges.charge(self.cp, &mut ops);

        let pairs: Vec<(f32, f32)> = scored.best.iter().map(|&(t, d, _)| (t, d)).collect();
        let matched: Vec<Option<u32>> = scored.best.iter().map(|&(_, _, m)| m).collect();
        let model = EnergyLatencyModel::new(cfg.material, cfg.adc_bits, cfg.num_banks);
        let report = model.report(&ops);

        Ok(BatchOutcome {
            pairs,
            matched,
            ops,
            report,
            cache: batch_cache,
            wall,
        })
    }

    /// Split `queries` into contiguous batches and serve each in order —
    /// the shared serving loop behind the CLI's `--serve-batches`, the
    /// streaming example and the Table 3 bench. Returns exactly
    /// `min(n_batches, queries.len())` batches (always at least one, so
    /// per-batch averages never divide by zero), with sizes differing by
    /// at most one.
    pub fn serve_chunked(
        &self,
        queries: &[&Spectrum],
        n_batches: usize,
        backend: &BackendDispatcher,
    ) -> Result<Vec<BatchOutcome>> {
        chunk_ranges(queries.len(), n_batches)
            .into_iter()
            .map(|r| self.search_batch(&queries[r], backend))
            .collect()
    }

    /// Fold served batches into the one-time/marginal/amortized cost split.
    pub fn serving_cost(&self, batches: &[BatchOutcome]) -> ServingCost {
        ServingCost::from_reports(&self.program_report, batches)
    }

    /// Pool accumulated batch outcomes into the one-shot summary shape:
    /// target-decoy FDR over *all* pairs, correctness against ground truth,
    /// and total ops = one-time programming + every marginal batch.
    /// `queries` must be the concatenation of the served batches, in order.
    pub fn finalize(
        &self,
        queries: &[&Spectrum],
        batches: &[BatchOutcome],
    ) -> Result<SearchOutcomeSummary> {
        let model =
            EnergyLatencyModel::new(self.cfg.material, self.cfg.adc_bits, self.cfg.num_banks);
        fold_batches(
            self.cfg.fdr,
            &model,
            &self.program_ops,
            &self.program_wall,
            queries,
            batches,
        )
    }
}

/// The one balanced contiguous-chunking rule in the serving layer, shared
/// by both `serve_chunked` impls and [`super::sharded::ShardPlan`]:
/// exactly `min(n_chunks, n_items).max(1)` ranges tiling `[0, n_items)`
/// in order, sizes differing by at most one (earlier chunks take the
/// remainder; a zero-item input keeps one empty range so per-batch
/// averages downstream never divide by zero).
pub(crate) fn chunk_ranges(n_items: usize, n_chunks: usize) -> Vec<std::ops::Range<usize>> {
    let n = n_chunks.max(1).min(n_items.max(1));
    let base = n_items / n;
    let rem = n_items % n;
    let mut ranges = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// The shared serving fold behind [`SearchEngine::finalize`] and the shard
/// layer's finalize: concatenate batch results in order, run the
/// target-decoy FDR filter over all pairs, score correctness against
/// ground truth, and report total ops = one-time programming + every
/// marginal batch through the given energy model.
pub(crate) fn fold_batches(
    fdr_rate: f64,
    model: &EnergyLatencyModel,
    program_ops: &OpCounts,
    program_wall: &StageTimer,
    queries: &[&Spectrum],
    batches: &[BatchOutcome],
) -> Result<SearchOutcomeSummary> {
    let total: usize = batches.iter().map(|b| b.pairs.len()).sum();
    crate::ensure!(
        total == queries.len(),
        "finalize: {total} batch results for {} queries",
        queries.len()
    );

    let mut pairs = Vec::with_capacity(total);
    let mut matched = Vec::with_capacity(total);
    let mut ops = *program_ops;
    let mut wall = program_wall.clone();
    for b in batches {
        pairs.extend_from_slice(&b.pairs);
        matched.extend_from_slice(&b.matched);
        ops += &b.ops;
        for (stage, t, _) in b.wall.breakdown() {
            wall.add(&stage, t);
        }
    }

    let fdr = wall.time("FDR filter", || fdr_filter(&pairs, fdr_rate));

    let mut correct = 0usize;
    let mut identified_peptides = Vec::new();
    for &qi in &fdr.accepted {
        if let (Some(m), Some(truth)) = (matched[qi], queries[qi].peptide_id) {
            if m == truth {
                correct += 1;
                identified_peptides.push(m);
            }
        }
    }
    identified_peptides.sort_unstable();
    identified_peptides.dedup();

    let report = model.report(&ops);

    Ok(SearchOutcomeSummary {
        identified: fdr.accepted.len(),
        pairs,
        correct,
        total_queries: queries.len(),
        identified_peptides,
        fdr,
        ops,
        report,
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SpecPcmConfig {
        SpecPcmConfig {
            hd_dim: 2048,
            bucket_width: 5.0,
            num_banks: 64,
            ..SpecPcmConfig::paper_search()
        }
    }

    #[test]
    fn engine_programs_once_and_serves() {
        let ds = SearchDataset::generate("t", 41, 30, 20, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        assert_eq!(engine.n_refs(), 60);
        assert_eq!(engine.n_targets(), 30);
        assert_eq!(engine.slots().len(), 60);
        assert!(engine.program_ops().program_rounds > 0);
        assert!(engine.program_report().program_j > 0.0);

        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs.len(), queries.len());
        // Marginal batches never pay programming again.
        assert_eq!(batch.ops.program_rounds, 0);
        assert_eq!(batch.ops.verify_rounds, 0);
        assert!(batch.ops.mvm_ops > 0);

        let out = engine.finalize(&queries, &[batch]).unwrap();
        assert_eq!(out.total_queries, queries.len());
        assert_eq!(out.ops.program_rounds, engine.program_ops().program_rounds);
    }

    #[test]
    fn query_cache_hits_are_bit_identical_and_reported() {
        let ds = SearchDataset::generate("t", 45, 30, 12, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        // Cold engine: every distinct query is a miss.
        let cold = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(cold.cache.total(), queries.len() as u64);

        // The same batch again: all hits, and the outcome is bit-identical
        // (pairs, matches, marginal ops and energy all unchanged).
        let warm = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(warm.cache.hits, queries.len() as u64);
        assert_eq!(warm.cache.misses, 0);
        assert_eq!(warm.pairs, cold.pairs);
        assert_eq!(warm.matched, cold.matched);
        assert_eq!(warm.ops, cold.ops);
        assert_eq!(warm.report.total_j(), cold.report.total_j());

        // Duplicates inside one batch hit too: only uniques encode.
        engine.clear_query_cache();
        let doubled: Vec<&Spectrum> = queries.iter().chain(queries.iter()).copied().collect();
        let dup = engine.search_batch(&doubled, &be).unwrap();
        assert_eq!(dup.cache.misses, cold.cache.misses);
        assert_eq!(dup.cache.hits as usize + dup.cache.misses as usize, doubled.len());
        assert_eq!(&dup.pairs[..queries.len()], &cold.pairs[..]);
        assert_eq!(&dup.pairs[queries.len()..], &cold.pairs[..]);
        // Accounting never sees the cache: double the queries, double the
        // encode charge.
        assert_eq!(dup.ops.encode_spectra, 2 * cold.ops.encode_spectra);

        // Cumulative stats fold every batch.
        let total = engine.encode_cache_stats();
        assert_eq!(
            total.total(),
            (queries.len() * 2 + doubled.len()) as u64
        );
        assert!(total.hit_rate() > 0.0);
    }

    #[test]
    fn serve_chunked_exact_batch_count_and_coverage() {
        let ds = SearchDataset::generate("t", 43, 20, 8, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        // 8 queries into 6 batches: exactly 6, sizes differing by <= 1.
        let outcomes = engine.serve_chunked(&queries, 6, &be).unwrap();
        assert_eq!(outcomes.len(), 6);
        let sizes: Vec<usize> = outcomes.iter().map(|b| b.pairs.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().all(|&s| s == 1 || s == 2), "{sizes:?}");

        // More batches than queries degrades to one query per batch.
        let outcomes = engine.serve_chunked(&queries, 100, &be).unwrap();
        assert_eq!(outcomes.len(), 8);

        // Zero queries still yields one (empty) outcome — per-batch
        // averages downstream never divide by zero.
        let outcomes = engine.serve_chunked(&[], 3, &be).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].pairs.is_empty());
    }

    #[test]
    fn check_capacity_typed_preflight() {
        // hd 2048 / n=3 -> 6 segments; 64 banks -> 10 groups x 128 = 1280.
        assert!(SearchEngine::check_capacity(&small_cfg(), 1280).is_ok());
        let e = SearchEngine::check_capacity(&small_cfg(), 1281).unwrap_err();
        assert_eq!(e.capacity, 1280);
        assert_eq!(e.num_banks, 64);
        // A single HV wider than all banks together: zero capacity.
        let cfg = SpecPcmConfig {
            num_banks: 2,
            ..small_cfg()
        };
        let e = SearchEngine::check_capacity(&cfg, 1).unwrap_err();
        assert_eq!(e.capacity, 0);
        assert_eq!(e.segments, 6);
    }

    // The over-capacity `SearchEngine::program` error path is covered at
    // integration level in `rust/tests/engine_equivalence.rs`; the unit
    // tests below pin the typed field values of the pre-flight checks.

    #[test]
    fn check_fit_reports_capacity_fields() {
        let cfg = SpecPcmConfig {
            num_banks: 6,
            ..small_cfg()
        };
        let ctx = ProgramContext::new(&cfg, 768, 0x5e).unwrap();
        let e = ctx.check_fit(200).unwrap_err();
        assert_eq!(e.rows_needed, 200);
        assert_eq!(e.capacity, 128);
        assert_eq!(e.num_banks, 6);
        assert_eq!(e.segments, 6);
        assert!(ctx.check_fit(128).is_ok());
    }

    #[test]
    fn engine_is_sync_shareable() {
        // The shard layer fans `search_batch` out across scoped threads;
        // this fails to compile if interior mutability regresses to
        // `RefCell`.
        fn assert_sync<T: Sync>() {}
        assert_sync::<SearchEngine>();
    }

    #[test]
    fn serving_cost_merge_sums_work_and_maxes_batches() {
        let a = ServingCost {
            one_time_j: 1.0,
            marginal_j: 0.25,
            one_time_s: 2.0,
            marginal_s: 0.5,
            n_batches: 4,
        };
        let b = ServingCost {
            one_time_j: 3.0,
            marginal_j: 0.75,
            one_time_s: 1.0,
            marginal_s: 1.5,
            n_batches: 4,
        };
        let mut m = a;
        m += &b;
        assert_eq!(m.one_time_j, 4.0);
        assert_eq!(m.marginal_j, 1.0);
        assert_eq!(m.one_time_s, 3.0);
        assert_eq!(m.marginal_s, 2.0);
        // Same fan-out run on both shards: not 8 batches.
        assert_eq!(m.n_batches, 4);
        assert_eq!(m.amortized_j_per_batch(), 5.0 / 4.0);

        let s: ServingCost = [a, b].into_iter().sum();
        assert_eq!(s.one_time_j, m.one_time_j);
        assert_eq!(s.n_batches, 4);
    }

    #[test]
    fn group_charges_merge_matches_monolithic_tiling() {
        let key = |i: i64| vec![(2u8, i)];

        // Monolithic: one group of 2 queries x 300 candidates.
        let mut mono = GroupCharges::default();
        mono.record(key(0), 2, 300);
        let mut mono_ops = OpCounts::default();
        mono.charge(256, &mut mono_ops);
        // 2 queries x ceil(300/128)=3 row tiles x 2 col tiles.
        assert_eq!(mono_ops.mvm_ops, 12);
        assert_eq!(mono_ops.merge_elements, 600);

        // The same group split 130 / 170 across two shards: per-shard
        // charging would see ceil(130/128) + ceil(170/128) = 4 row tiles;
        // merging first restores the monolithic 3.
        let mut a = GroupCharges::default();
        a.record(key(0), 2, 130);
        let mut b = GroupCharges::default();
        b.record(key(0), 2, 170);
        // A group empty on shard b merges harmlessly.
        b.record(key(1), 1, 0);
        a.merge(&b);
        let mut sharded_ops = OpCounts::default();
        a.charge(256, &mut sharded_ops);
        assert_eq!(sharded_ops.mvm_ops, mono_ops.mvm_ops);
        assert_eq!(sharded_ops.merge_elements, mono_ops.merge_elements);
    }

    #[test]
    fn encode_then_score_packed_equals_search_batch() {
        let ds = SearchDataset::generate("t", 47, 25, 10, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();

        let batch = engine.search_batch(&queries, &be).unwrap();

        engine.clear_query_cache();
        let (packed, cache) = engine.encode_queries(&queries, &be).unwrap();
        assert_eq!(cache.total(), queries.len() as u64);
        let scored = engine.score_packed(&queries, &packed, &be).unwrap();
        let pairs: Vec<(f32, f32)> = scored.best.iter().map(|&(t, d, _)| (t, d)).collect();
        assert_eq!(pairs, batch.pairs);

        let mut ops = OpCounts::default();
        engine.frontend.count_encode_ops(queries.len(), &mut ops);
        scored.charges.charge(engine.packed_width(), &mut ops);
        assert_eq!(ops, batch.ops);
    }

    #[test]
    fn bucket_contiguous_layout_invariants() {
        let ds = SearchDataset::generate("t", 49, 40, 10, 0.8, 0.2, 0, 0);
        let be = BackendDispatcher::reference();
        let engine = SearchEngine::program(small_cfg(), &ds, &be).unwrap();
        let n = engine.n_refs();

        // The physical->logical map is a permutation of every row.
        let mut seen = vec![false; n];
        for &l in engine.logical_of_physical() {
            assert!(!seen[l], "logical row {l} stored twice");
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s), "every logical row is stored");

        // Bucket ranges tile the physical rows contiguously in key order
        // (adjacent buckets are physically adjacent), and each bucket's
        // physical rows hold ascending logical rows — the property the
        // merge tie rule and segment coalescing rely on.
        let mut cursor = 0usize;
        let all_refs: Vec<Spectrum> = ds
            .library
            .iter()
            .chain(ds.decoys.iter())
            .cloned()
            .collect();
        let buckets = bucket_by_precursor(&all_refs, engine.cfg.bucket_width);
        for (key, rows) in &buckets {
            let range = engine.bucket_row_range(key).expect("bucket indexed");
            assert_eq!(range.start, cursor, "ranges contiguous in key order");
            assert_eq!(range.len(), rows.len());
            let stored: Vec<usize> = range
                .clone()
                .map(|p| engine.logical_of_physical()[p])
                .collect();
            assert_eq!(&stored, rows, "bucket rows ascend logically");
            cursor = range.end;
        }
        assert_eq!(cursor, n, "ranges exhaustive");
        assert!(engine.bucket_row_range(&(200, -1)).is_none());

        // noisy_row stays logical: row ri's conductances sit at the
        // mapped physical offset of the serving panel.
        for ri in [0usize, 1, n / 2, n - 1] {
            let row = engine.noisy_row(ri);
            assert_eq!(row.len(), engine.packed_width());
        }
    }

    #[test]
    fn transient_rows_release_and_reuse() {
        let cfg = SpecPcmConfig {
            num_banks: 6,
            ..small_cfg()
        };
        let mut ctx = ProgramContext::new(&cfg, 768, 0xc1).unwrap();
        let packed = vec![1.0f32; 100 * 768];
        let mut ops = OpCounts::default();
        let (noisy, slots) = ctx.program_rows(&packed, 100, 768, &mut ops).unwrap();
        assert_eq!(noisy.len(), packed.len());
        assert_eq!(slots.len(), 100);
        assert_eq!(ctx.allocator.free_slots(), 28);
        // A second 100-row bucket does not fit until the first is released.
        assert!(ctx.check_fit(100).is_err());
        ctx.release_rows(slots);
        assert!(ctx.check_fit(100).is_ok());
    }
}
