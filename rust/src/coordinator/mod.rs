//! L3 coordinator (DESIGN.md §2): the glue that turns spectra into ISA-level
//! array work and backend MVM executions.
//!
//! * [`allocator`] — places HV segments onto (bank, row) slots; an HV wider
//!   than 128 packed dims spans multiple banks at the same row (paper
//!   §III-C).
//! * [`batcher`] — groups work into fixed-geometry tiles (e.g. the B=64 /
//!   R=1024 PJRT artifact), padding with zeros and slicing results back.
//! * [`frontend`] — HD encode+pack routed through the dispatcher's
//!   pluggable `encode::EncodeBackend` (scalar / word-packed bitpacked /
//!   spectra-sharded parallel), or the PJRT artifacts when available —
//!   all bit-identical.
//! * [`engine`] — the persistent program-once/query-many [`SearchEngine`]
//!   (library encoded + programmed exactly once, query batches served
//!   against the stored conductances, repeated query spectra served from
//!   a level-vector-keyed query-HV cache) and the shared
//!   [`ProgramContext`] (programmer + noise stream + capacity allocator)
//!   both pipelines program through. Serving is zero-copy: the stored
//!   conductances are laid out bucket-contiguously after programming, so
//!   candidate sets are borrowed row segments (segmented
//!   `backend::MvmJob`s through `execute_into` with reused buffers), not
//!   per-batch gathered copies — bit-identical to the gathered path
//!   because the blocked kernel preserves each output's accumulation
//!   order and the merge tie-breaks on logical rows (engine module docs).
//! * [`sharded`] — the shard layer: [`ShardPlan`] partitions a library
//!   that overflows one engine's banks into contiguous per-engine row
//!   ranges, and [`ShardedSearchEngine`] programs one engine per range
//!   and fans query batches across them on scoped threads.
//! * [`remote`] — the same shard layer across worker **processes**: a
//!   supervising [`remote::RemoteEngine`] speaks a length-prefixed
//!   binary wire protocol to per-shard workers (see below).
//! * [`scheduler`] — the serving front door (see below).
//! * [`pipeline`] — the end-to-end clustering and DB-search drivers that
//!   the CLI, examples and benches call; both execute score tiles through
//!   the `backend::BackendDispatcher` they are handed. `SearchPipeline` is
//!   a thin one-shot wrapper over the engine.
//!
//! # The three swappable seams
//!
//! The stack deliberately exposes exactly three places where *how* work
//! executes is decoupled from *what* is computed, each bit-identical
//! across its implementations:
//!
//! 1. **MVM backend** (`crate::backend`): where an `nq x nr` score tile's
//!    arithmetic runs — scalar reference, bank-sharded threads, or the
//!    PJRT artifact. Selected by `[backend] kind` / `--backend`.
//! 2. **Encode backend** (`crate::encode`): where HD encode+pack runs —
//!    scalar, u64 word-packed, or spectra-sharded threads. Selected by
//!    `[backend] encode_kind` / `--encode-backend`.
//! 3. **Shard layer** ([`sharded`]): where the reference library's rows
//!    *live* — one engine's bank pool or several engines' pools with
//!    concurrent per-shard fan-out. Selected by `[backend] shards` /
//!    `--shards N|auto`.
//!
//! # Serving front door
//!
//! [`scheduler::FrontDoor`] is what a stream of single-spectrum requests
//! hits before any engine does: requests enter a **bounded FIFO queue**,
//! a [`scheduler::CoalescePolicy`] **coalesces** them into dynamic
//! batches (size-triggered at the tile-fill target derived from
//! `BackendDispatcher::min_utilization`, and/or deadline-triggered on
//! the logical clock), each **flush** drains the queue through
//! [`batcher::Batcher`]-chunked `search_batch` calls and fans results
//! back in arrival order, and idle gaps between flushes run
//! **refresh-in-gaps** `RefreshPolicy::maintain` increments without ever
//! delaying a deadline-due batch (deadlines fire before the clock
//! advances — structural, not tuned). Everything is on the same
//! deterministic **logical clock** as `SearchEngine::advance_age`; wall
//! time never enters, so traces replay tick-for-tick. Coalescing is
//! invisible to results and accounting: for any trace, policy, backend
//! and shard count, the fan-back and cumulative marginal `OpCounts` are
//! bit-identical to one arrival-order `search_batch`
//! (`rust/tests/scheduler_equivalence.rs`).
//!
//! Accounting composes across the seams: backends never touch op counts
//! (the dispatcher charges the physical job regardless of route), the
//! encode cache only removes host arithmetic, and the shard layer charges
//! encode once per batch plus IMC/merge ops from *merged* per-group
//! candidate counts ([`engine::GroupCharges`]) — so total simulated ASIC
//! work is one fixed function of the workload, no matter which seam
//! choices execute it.
//!
//! # Remote shard workers
//!
//! [`remote::RemoteEngine`] serves the shard plan through supervised
//! worker processes (`specpcm worker`, stdin/stdout pipes, the
//! [`remote::wire`] codec). The supervisor owns the whole failure story
//! on the deterministic logical clock — per-request deadlines, bounded
//! retries with exponential backoff, per-worker circuit breakers — and
//! any failed attempt tears the worker down and **respawns it
//! bit-identically**: each slot stores its shard's initial chained
//! noise-RNG state plus a replay log of age/refresh mutations, so a
//! reborn worker's conductances and refresh epochs match a shard that
//! never died. The failure-handling state machine per worker:
//!
//! ```text
//!            spawn+Program+replay ok
//!   [DOWN] ---------------------------> [UP] --score ok--> [UP]
//!     ^  \-- respawn fails --> [DOWN]    |
//!     |                                  | attempt fails (kill/hang/
//!     |   consecutive_failures >=        |  corrupt/app error)
//!     |   breaker_threshold              v
//!     +--------- [BREAKER OPEN] <--- [RETRYING] --budget spent--> skip
//!                     |                  | backoff += base << attempt,
//!                     | one half-open    | respawn, retry
//!                     v probe per batch  v
//!                  [UP on success]    [UP on success]
//! ```
//!
//! A shard that exhausts its retry budget degrades the batch instead of
//! failing it: the merge returns the survivors' results tagged with a
//! partial [`engine::Coverage`] (`rows_searched / rows_total`). With no
//! faults, results and cumulative marginal ops are bit-identical to
//! [`ShardedSearchEngine`] (`rust/tests/worker_fault_tolerance.rs`).

pub mod allocator;
pub mod batcher;
pub mod engine;
pub mod frontend;
pub mod pipeline;
pub mod remote;
pub mod scheduler;
pub mod sharded;

pub use allocator::{AllocError, SegmentAllocator, Slot};
pub use batcher::{pad_matrix, Batcher};
pub use engine::{
    BatchOutcome, CapacityError, Coverage, GroupCharges, ProgramContext, RefreshOutcome,
    RefreshPolicy, SearchEngine, ServingCost, ShardScores,
};
pub use frontend::HdFrontend;
pub use pipeline::{
    ClusteringOutcome, ClusteringPipeline, SearchOutcomeSummary, SearchPipeline,
};
pub use remote::{ChaosEvent, ChaosKind, ChaosPlan, RemoteEngine, WorkerStats};
pub use scheduler::{
    tile_fill_target, ArrivalTrace, CoalescePolicy, FrontDoor, ServeEngine, ServeTraceOutcome,
};
pub use sharded::{ShardPlan, ShardedSearchEngine};
