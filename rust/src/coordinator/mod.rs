//! L3 coordinator (DESIGN.md §2): the glue that turns spectra into ISA-level
//! array work and backend MVM executions.
//!
//! * [`allocator`] — places HV segments onto (bank, row) slots; an HV wider
//!   than 128 packed dims spans multiple banks at the same row (paper
//!   §III-C).
//! * [`batcher`] — groups work into fixed-geometry tiles (e.g. the B=64 /
//!   R=1024 PJRT artifact), padding with zeros and slicing results back.
//! * [`frontend`] — HD encode+pack routed through the dispatcher's
//!   pluggable `encode::EncodeBackend` (scalar / word-packed bitpacked /
//!   spectra-sharded parallel), or the PJRT artifacts when available —
//!   all bit-identical.
//! * [`engine`] — the persistent program-once/query-many [`SearchEngine`]
//!   (library encoded + programmed exactly once, query batches served
//!   against the stored conductances, repeated query spectra served from
//!   a level-vector-keyed query-HV cache) and the shared
//!   [`ProgramContext`] (programmer + noise stream + capacity allocator)
//!   both pipelines program through.
//! * [`pipeline`] — the end-to-end clustering and DB-search drivers that
//!   the CLI, examples and benches call; both execute score tiles through
//!   the `backend::BackendDispatcher` they are handed. `SearchPipeline` is
//!   a thin one-shot wrapper over the engine.

pub mod allocator;
pub mod batcher;
pub mod engine;
pub mod frontend;
pub mod pipeline;

pub use allocator::{SegmentAllocator, Slot};
pub use batcher::{pad_matrix, Batcher};
pub use engine::{BatchOutcome, CapacityError, ProgramContext, SearchEngine, ServingCost};
pub use frontend::HdFrontend;
pub use pipeline::{
    ClusteringOutcome, ClusteringPipeline, SearchOutcomeSummary, SearchPipeline,
};
