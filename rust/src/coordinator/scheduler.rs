//! Serving front door: dynamic batching of single-spectrum requests.
//!
//! SpecPCM's tile economics only pay off when the 128x128 arrays run
//! full — per-spectrum dispatch leaves most of each crossbar's DAC/ADC
//! setup unamortized. The [`FrontDoor`] sits between request producers
//! (the CLI's trace generator today, a network listener tomorrow) and
//! `search_batch`: single-spectrum requests enter a bounded FIFO queue
//! and are coalesced into dynamic batches.
//!
//! # Lifecycle: queue → coalesce → flush → refresh-in-gaps
//!
//! A batch flushes when one of four triggers fires, in priority order:
//!
//! 1. **Deadline** — the oldest queued request has waited
//!    `deadline_ticks` on the logical clock. Deadline flushes are fired
//!    *before* the clock advances past their due tick, so a due batch is
//!    never delayed by later arrivals or by in-gap maintenance.
//! 2. **Backpressure** — the bounded queue is full; it flushes before
//!    accepting the next request so memory stays bounded.
//! 3. **Size** — the queue reaches the tile-fill target (see below).
//! 4. **Drain** — the trace ended; whatever is queued flushes.
//!
//! Every flush drains the whole queue FIFO, split into `search_batch`
//! calls of at most the fill target via [`super::batcher::Batcher`] —
//! the same chunk math as the AOT tile batcher, not a re-derivation.
//! Because the queue is FIFO and flushes preserve it, concatenating the
//! per-batch results *is* the arrival-order fan-back: request `i`'s
//! `(pairs, matched)` sit at global position `i`.
//!
//! After a flush empties the queue, the gap until the next arrival is
//! idle on the logical clock; the front door spends it on one
//! [`RefreshPolicy`] `maintain` increment (the PR 8 drift-recovery
//! path), re-programming the stalest bucket segments while nothing is
//! waiting. Refresh work lands on the engine's one-time ledger, never
//! on batch ops, and the trigger ordering above makes "never delays a
//! deadline-due batch" structural rather than a tuning property.
//!
//! # Logical clock discipline
//!
//! The front door never reads wall time. Arrival times, deadlines and
//! queue-latency telemetry all live on the same deterministic logical
//! clock as [`SearchEngine::advance_age`] — given the same trace,
//! policy and engine state, a serve replays tick-for-tick on any host.
//! [`ArrivalTrace`] generates Poisson-like interarrivals from a
//! caller-provided [`Rng`] (callers seed it from the config, per the
//! C4-RNG contract — this module never constructs its own RNG).
//!
//! # The bit-identity invariant, extended
//!
//! For any arrival trace, any coalescing policy, any backend and any
//! shard count, per-query results and cumulative marginal [`OpCounts`]
//! are bit-identical to one `search_batch` over the same spectra in
//! arrival order: scores depend only on (query HV, stored conductances,
//! ADC), every summed `OpCounts` field is linear per-query within
//! candidate groups, and in-gap refresh charges the one-time ledger.
//! `rust/tests/scheduler_equivalence.rs` proves it end-to-end.

use crate::array::ARRAY_DIM;
use crate::backend::BackendDispatcher;
use crate::energy::OpCounts;
use crate::ms::Spectrum;
use crate::telemetry::{percentile_u64, DeviceHealth, FrontDoorStats};
use crate::util::error::Result;
use crate::util::Rng;

use super::batcher::Batcher;
use super::engine::{BatchOutcome, RefreshOutcome, RefreshPolicy, SearchEngine};
use super::sharded::ShardedSearchEngine;

/// When the front door flushes queued requests into a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoalescePolicy {
    /// Batch-size-1 naive serving: every request flushes immediately.
    /// The baseline the `serving_frontdoor` bench measures against.
    Off,
    /// Flush when the queue reaches `max_batch` queued requests (or on
    /// backpressure/drain). Latency is unbounded under a trickle.
    Size { max_batch: usize },
    /// Size trigger plus a latency bound: flush no later than
    /// `deadline_ticks` logical ticks after the oldest queued arrival.
    SizeDeadline { max_batch: usize, deadline_ticks: u64 },
}

impl CoalescePolicy {
    /// Short name used in telemetry records and CLI flags.
    pub fn name(&self) -> &'static str {
        match self {
            CoalescePolicy::Off => "off",
            CoalescePolicy::Size { .. } => "size",
            CoalescePolicy::SizeDeadline { .. } => "deadline",
        }
    }

    /// The tile-fill target: most requests per flushed batch.
    pub fn max_batch(&self) -> usize {
        match *self {
            CoalescePolicy::Off => 1,
            CoalescePolicy::Size { max_batch }
            | CoalescePolicy::SizeDeadline { max_batch, .. } => max_batch.max(1),
        }
    }

    /// Logical-tick latency bound, when the policy has one.
    pub fn deadline_ticks(&self) -> Option<u64> {
        match *self {
            CoalescePolicy::SizeDeadline { deadline_ticks, .. } => Some(deadline_ticks),
            _ => None,
        }
    }
}

/// The tile-fill target for a given dispatcher routing floor: the batch
/// size at which a full-width query tile clears
/// [`BackendDispatcher::min_utilization`]'s padded-utilization bar.
/// `ceil(ARRAY_DIM * min_utilization)` clamped to `[1, ARRAY_DIM]`; a
/// disabled heuristic (`min_utilization <= 0`, the `reference()` /
/// `parallel()` constructors) targets a full 128-query tile, since
/// nothing short of full amortizes the DAC/ADC setup better.
pub fn tile_fill_target(min_utilization: f64) -> usize {
    if min_utilization <= 0.0 {
        return ARRAY_DIM;
    }
    let frac = min_utilization.min(1.0);
    ((ARRAY_DIM as f64 * frac).ceil() as usize).clamp(1, ARRAY_DIM)
}

/// A deterministic request-arrival schedule: one logical-clock tick per
/// request, nondecreasing. Request `i` of the served query slice
/// arrives at `ticks[i]`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ArrivalTrace {
    pub ticks: Vec<u64>,
}

impl ArrivalTrace {
    /// Evenly spaced arrivals: request `i` at tick `i * every`.
    /// `every = 0` is an all-at-once burst.
    pub fn uniform(n: usize, every: u64) -> Self {
        ArrivalTrace {
            ticks: (0..n as u64).map(|i| i * every).collect(),
        }
    }

    /// Poisson-like arrivals: exponential interarrival gaps with the
    /// given mean (in logical ticks), floored to whole ticks. The RNG is
    /// caller-provided and config-seeded (C4-RNG contract), so a trace
    /// is a pure function of `(seed, n, mean)` and replays exactly.
    pub fn poisson_from_rng(rng: &mut Rng, n: usize, mean_interarrival_ticks: f64) -> Self {
        let mean = mean_interarrival_ticks.max(0.0);
        let mut ticks = Vec::with_capacity(n);
        let mut t = 0u64;
        for _ in 0..n {
            // uniform() is in [0, 1), so 1 - u is in (0, 1] and ln() is
            // finite; inverse-CDF sample of Exp(1/mean).
            let u = rng.uniform();
            t += (-(1.0 - u).ln() * mean).floor() as u64;
            ticks.push(t);
        }
        ArrivalTrace { ticks }
    }

    pub fn len(&self) -> usize {
        self.ticks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ticks.is_empty()
    }
}

/// The engine surface the front door needs — implemented by both
/// [`SearchEngine`] and [`ShardedSearchEngine`], so one scheduler serves
/// monolithic and sharded libraries identically.
pub trait ServeEngine {
    fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome>;
    fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome;
    fn device_health(&self) -> DeviceHealth;
}

impl ServeEngine for SearchEngine {
    fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        SearchEngine::search_batch(self, queries, backend)
    }

    fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        SearchEngine::maintain(self, policy)
    }

    fn device_health(&self) -> DeviceHealth {
        SearchEngine::device_health(self)
    }
}

impl ServeEngine for ShardedSearchEngine {
    fn search_batch(
        &self,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
    ) -> Result<BatchOutcome> {
        ShardedSearchEngine::search_batch(self, queries, backend)
    }

    fn maintain(&mut self, policy: &RefreshPolicy) -> RefreshOutcome {
        ShardedSearchEngine::maintain(self, policy)
    }

    fn device_health(&self) -> DeviceHealth {
        ShardedSearchEngine::device_health(self)
    }
}

/// Everything one served trace produced: the per-batch outcomes (in
/// flush order), the arrival-order fan-back, the cumulative marginal
/// ops, and the queue/fill/latency telemetry.
#[derive(Clone, Debug, Default)]
pub struct ServeTraceOutcome {
    /// Per-flush [`BatchOutcome`]s, in flush order. Their concatenation
    /// is the arrival-order result stream (FIFO queue, FIFO flushes).
    pub outcomes: Vec<BatchOutcome>,
    /// Request `i`'s best (target, decoy) scores — `pairs[i]` answers
    /// the request that arrived at `trace.ticks[i]`.
    pub pairs: Vec<(f32, f32)>,
    /// Request `i`'s best-matching target peptide id.
    pub matched: Vec<Option<u32>>,
    /// Fold of every batch's marginal ops (bit-identical to one
    /// `search_batch` over the whole trace, by the equivalence suite).
    pub ops: OpCounts,
    /// Queue depth, fill fraction, wait percentiles, flush triggers.
    pub stats: FrontDoorStats,
}

/// Why a flush fired (recorded into [`FrontDoorStats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FlushTrigger {
    Size,
    Deadline,
    Backpressure,
    Drain,
}

/// One queued request: index into the served query slice + arrival tick.
#[derive(Clone, Copy, Debug)]
struct Request {
    qi: usize,
    arrived: u64,
}

/// Mutable scratch threaded through one `serve_trace` run.
struct ServeState {
    queue: Vec<Request>,
    outcomes: Vec<BatchOutcome>,
    waits: Vec<u64>,
    fill_sum: f64,
    stats: FrontDoorStats,
}

/// The serving front door: a bounded request queue plus a coalescing
/// policy and an optional in-gap refresh policy. See the module docs
/// for the full lifecycle.
#[derive(Clone, Debug)]
pub struct FrontDoor {
    policy: CoalescePolicy,
    capacity: usize,
    refresh: Option<RefreshPolicy>,
}

impl FrontDoor {
    /// Front door with the given coalescing policy, a default queue
    /// bound of four fill targets, and no in-gap refresh.
    pub fn new(policy: CoalescePolicy) -> Self {
        let capacity = policy.max_batch().saturating_mul(4).max(1);
        FrontDoor {
            policy,
            capacity,
            refresh: None,
        }
    }

    /// Override the bounded-queue capacity (requests). A capacity below
    /// the fill target is honored: the memory bound wins, so bursts
    /// flush partial tiles through the backpressure trigger instead of
    /// queueing up to the ideal fill.
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }

    /// Run one `RefreshPolicy::maintain` increment in each idle gap.
    pub fn with_refresh(mut self, policy: RefreshPolicy) -> Self {
        self.refresh = Some(policy);
        self
    }

    pub fn policy(&self) -> &CoalescePolicy {
        &self.policy
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Serve `queries` according to `trace` (request `i` = `queries[i]`
    /// arriving at `trace.ticks[i]`, which must be nondecreasing).
    /// Returns the arrival-order fan-back plus telemetry. The engine is
    /// `&mut` only for in-gap `maintain`; scoring goes through the
    /// shared-reference `search_batch` contract unchanged.
    pub fn serve_trace<E: ServeEngine>(
        &self,
        engine: &mut E,
        queries: &[&Spectrum],
        trace: &ArrivalTrace,
        backend: &BackendDispatcher,
    ) -> Result<ServeTraceOutcome> {
        crate::ensure!(
            queries.len() == trace.ticks.len(),
            "arrival trace covers {} requests but {} queries were supplied",
            trace.ticks.len(),
            queries.len()
        );
        crate::ensure!(
            trace.ticks.windows(2).all(|w| w[0] <= w[1]),
            "arrival trace ticks must be nondecreasing"
        );

        let max_batch = self.policy.max_batch();
        let deadline = self.policy.deadline_ticks();
        let mut st = ServeState {
            queue: Vec::with_capacity(self.capacity),
            outcomes: Vec::new(),
            waits: Vec::with_capacity(queries.len()),
            fill_sum: 0.0,
            stats: FrontDoorStats {
                requests: queries.len() as u64,
                fill_target: max_batch as u64,
                ..FrontDoorStats::default()
            },
        };
        let mut clock = 0u64;

        for (qi, &arrived) in trace.ticks.iter().enumerate() {
            // 1. Fire every deadline that comes due before this arrival,
            //    at its due tick — a due batch is never delayed by later
            //    arrivals or by in-gap maintenance.
            if let Some(d) = deadline {
                while let Some(oldest) = st.queue.first() {
                    let due = oldest.arrived.saturating_add(d);
                    if due > arrived {
                        break;
                    }
                    clock = clock.max(due);
                    self.flush(engine, queries, backend, clock, FlushTrigger::Deadline, &mut st)?;
                }
            }

            // 2. Spend the idle gap (queue empty, clock behind the next
            //    arrival) on one maintain increment.
            if st.queue.is_empty() && clock < arrived {
                self.idle_maintain(engine, &mut st);
            }
            clock = clock.max(arrived);

            // 3. Backpressure: a full queue flushes before accepting.
            if st.queue.len() == self.capacity {
                self.flush(engine, queries, backend, clock, FlushTrigger::Backpressure, &mut st)?;
            }

            // 4. Enqueue, then fire the size trigger at the fill target.
            st.queue.push(Request { qi, arrived });
            st.stats.max_queue_depth = st.stats.max_queue_depth.max(st.queue.len() as u64);
            if st.queue.len() >= max_batch {
                self.flush(engine, queries, backend, clock, FlushTrigger::Size, &mut st)?;
            }
        }

        // 5. Drain what's left. Under a deadline policy the leftovers
        //    flush at the oldest request's due tick (they would have
        //    flushed then had the trace continued); otherwise at the
        //    final arrival tick.
        if let Some(oldest) = st.queue.first() {
            if let Some(d) = deadline {
                clock = clock.max(oldest.arrived.saturating_add(d));
            }
            self.flush(engine, queries, backend, clock, FlushTrigger::Drain, &mut st)?;
        }

        let mut waits = std::mem::take(&mut st.waits);
        waits.sort_unstable();
        st.stats.p50_wait_ticks = percentile_u64(&waits, 0.50);
        st.stats.p99_wait_ticks = percentile_u64(&waits, 0.99);
        st.stats.max_wait_ticks = waits.last().copied().unwrap_or(0);
        st.stats.mean_fill_fraction = if st.stats.batches == 0 {
            0.0
        } else {
            st.fill_sum / st.stats.batches as f64
        };

        let mut pairs = Vec::with_capacity(queries.len());
        let mut matched = Vec::with_capacity(queries.len());
        let mut ops = OpCounts::default();
        for out in &st.outcomes {
            pairs.extend_from_slice(&out.pairs);
            matched.extend_from_slice(&out.matched);
            ops += &out.ops;
        }

        Ok(ServeTraceOutcome {
            outcomes: st.outcomes,
            pairs,
            matched,
            ops,
            stats: st.stats,
        })
    }

    /// Drain the whole queue FIFO into `search_batch` calls of at most
    /// the fill target, chunked by [`Batcher`]. Only the first chunk is
    /// attributed to `trigger`; follow-on chunks of an oversized drain
    /// (backpressure bursts, end-of-trace) count as size flushes, since
    /// the fill target is what sized them.
    fn flush<E: ServeEngine>(
        &self,
        engine: &mut E,
        queries: &[&Spectrum],
        backend: &BackendDispatcher,
        clock: u64,
        trigger: FlushTrigger,
        st: &mut ServeState,
    ) -> Result<()> {
        let pending = std::mem::take(&mut st.queue);
        if pending.is_empty() {
            return Ok(());
        }
        let max_batch = self.policy.max_batch();
        for (i, b) in Batcher::new(pending.len(), max_batch).batches().into_iter().enumerate() {
            let chunk = &pending[b.start..b.end];
            let batch: Vec<&Spectrum> = chunk.iter().map(|r| queries[r.qi]).collect();
            let outcome = engine.search_batch(&batch, backend)?;
            debug_assert_eq!(outcome.pairs.len(), chunk.len());
            st.stats.batches += 1;
            match (i, trigger) {
                (0, FlushTrigger::Size) => st.stats.size_flushes += 1,
                (0, FlushTrigger::Deadline) => st.stats.deadline_flushes += 1,
                (0, FlushTrigger::Backpressure) => st.stats.backpressure_flushes += 1,
                (0, FlushTrigger::Drain) => st.stats.drain_flushes += 1,
                (_, _) => st.stats.size_flushes += 1,
            }
            st.fill_sum += chunk.len() as f64 / max_batch as f64;
            for r in chunk {
                st.waits.push(clock.saturating_sub(r.arrived));
            }
            st.outcomes.push(outcome);
        }
        Ok(())
    }

    /// One in-gap maintain increment, when a refresh policy is set.
    fn idle_maintain<E: ServeEngine>(&self, engine: &mut E, st: &mut ServeState) {
        if let Some(policy) = &self.refresh {
            let r = engine.maintain(policy);
            st.stats.maintain_calls += 1;
            st.stats.refreshed_rows += r.rows as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_target_tracks_utilization_floor() {
        // config default 0.3 → ceil(128 * 0.3) = 39 queries per tile.
        assert_eq!(tile_fill_target(0.3), 39);
        assert_eq!(tile_fill_target(1.0), ARRAY_DIM);
        assert_eq!(tile_fill_target(2.0), ARRAY_DIM);
        // Disabled heuristic targets a full tile, and tiny floors still
        // coalesce at least one query.
        assert_eq!(tile_fill_target(0.0), ARRAY_DIM);
        assert_eq!(tile_fill_target(-1.0), ARRAY_DIM);
        assert_eq!(tile_fill_target(1e-9), 1);
    }

    #[test]
    fn policy_names_and_bounds() {
        assert_eq!(CoalescePolicy::Off.name(), "off");
        assert_eq!(CoalescePolicy::Off.max_batch(), 1);
        assert_eq!(CoalescePolicy::Off.deadline_ticks(), None);
        let s = CoalescePolicy::Size { max_batch: 39 };
        assert_eq!(s.name(), "size");
        assert_eq!(s.max_batch(), 39);
        let d = CoalescePolicy::SizeDeadline {
            max_batch: 0,
            deadline_ticks: 7,
        };
        assert_eq!(d.name(), "deadline");
        // A zero max_batch still forms singleton batches.
        assert_eq!(d.max_batch(), 1);
        assert_eq!(d.deadline_ticks(), Some(7));
    }

    #[test]
    fn uniform_trace_is_evenly_spaced() {
        let t = ArrivalTrace::uniform(4, 3);
        assert_eq!(t.ticks, vec![0, 3, 6, 9]);
        assert_eq!(ArrivalTrace::uniform(3, 0).ticks, vec![0, 0, 0]);
        assert!(ArrivalTrace::uniform(0, 5).is_empty());
    }

    #[test]
    fn poisson_trace_is_seed_deterministic_and_nondecreasing() {
        let mut a = Rng::new(0xfeed);
        let mut b = Rng::new(0xfeed);
        let ta = ArrivalTrace::poisson_from_rng(&mut a, 64, 3.0);
        let tb = ArrivalTrace::poisson_from_rng(&mut b, 64, 3.0);
        assert_eq!(ta, tb);
        assert_eq!(ta.len(), 64);
        assert!(ta.ticks.windows(2).all(|w| w[0] <= w[1]));
        // A different seed gives a different schedule.
        let mut c = Rng::new(0xbeef);
        assert_ne!(ta, ArrivalTrace::poisson_from_rng(&mut c, 64, 3.0));
        // Mean roughly honored: 64 gaps of mean 3 land well inside
        // [64, 640] with overwhelming margin for a fixed seed.
        let span = *ta.ticks.last().unwrap();
        assert!(span > 32 && span < 1280, "span {span} implausible");
    }

    #[test]
    fn zero_mean_trace_is_a_burst() {
        let mut rng = Rng::new(1);
        let t = ArrivalTrace::poisson_from_rng(&mut rng, 8, 0.0);
        assert!(t.ticks.iter().all(|&x| x == 0));
    }

    #[test]
    fn front_door_capacity_defaults_and_overrides() {
        // Default bound: four fill targets.
        assert_eq!(FrontDoor::new(CoalescePolicy::Off).capacity(), 4);
        assert_eq!(
            FrontDoor::new(CoalescePolicy::Size { max_batch: 39 }).capacity(),
            156
        );
        // An explicit bound below the fill target is honored (memory
        // wins; bursts backpressure-flush partial tiles).
        let fd = FrontDoor::new(CoalescePolicy::Size { max_batch: 39 }).with_capacity(3);
        assert_eq!(fd.capacity(), 3);
        assert_eq!(fd.with_capacity(0).capacity(), 1);
    }
}
