//! Work batching into the fixed AOT artifact geometry.
//!
//! The artifacts are compiled for B=64 queries x R=1024 reference rows; the
//! batcher chops arbitrary workloads into padded tiles and maps results
//! back, preserving input order (proptested invariant).

/// Pad a `rows x width` row-major matrix up to `target_rows` with zeros.
pub fn pad_matrix(data: &[f32], rows: usize, width: usize, target_rows: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * width);
    assert!(target_rows >= rows);
    let mut out = Vec::with_capacity(target_rows * width);
    out.extend_from_slice(data);
    out.resize(target_rows * width, 0.0);
    out
}

/// Iterator over contiguous index chunks of at most `chunk` items.
#[derive(Clone, Debug)]
pub struct Batcher {
    total: usize,
    chunk: usize,
}

/// One batch: the half-open range of original indices it covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Batch {
    pub start: usize,
    pub end: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

impl Batcher {
    pub fn new(total: usize, chunk: usize) -> Self {
        assert!(chunk > 0);
        Batcher { total, chunk }
    }

    pub fn batches(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut start = 0;
        while start < self.total {
            let end = (start + self.chunk).min(self.total);
            out.push(Batch { start, end });
            start = end;
        }
        out
    }

    pub fn num_batches(&self) -> usize {
        self.total.div_ceil(self.chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_in_order_without_overlap() {
        let b = Batcher::new(150, 64);
        let batches = b.batches();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0], Batch { start: 0, end: 64 });
        assert_eq!(batches[1], Batch { start: 64, end: 128 });
        assert_eq!(batches[2], Batch { start: 128, end: 150 });
        let covered: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(covered, 150);
    }

    #[test]
    fn exact_multiple() {
        let b = Batcher::new(128, 64);
        assert_eq!(b.num_batches(), 2);
        assert!(b.batches().iter().all(|x| x.len() == 64));
    }

    #[test]
    fn empty_total() {
        assert!(Batcher::new(0, 64).batches().is_empty());
    }

    #[test]
    fn pad_matrix_zero_fills() {
        let m = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let p = pad_matrix(&m, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &m[..]);
        assert!(p[4..].iter().all(|&x| x == 0.0));
    }
}
