//! Work batching into the fixed AOT artifact geometry.
//!
//! The artifacts are compiled for B=64 queries x R=1024 reference rows; the
//! batcher chops arbitrary workloads into padded tiles and maps results
//! back, preserving input order (proptested invariant).

/// Pad a `rows x width` row-major matrix up to `target_rows` with zeros.
pub fn pad_matrix(data: &[f32], rows: usize, width: usize, target_rows: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * width);
    assert!(target_rows >= rows);
    let mut out = Vec::with_capacity(target_rows * width);
    out.extend_from_slice(data);
    out.resize(target_rows * width, 0.0);
    out
}

/// Iterator over contiguous index chunks of at most `chunk` items.
#[derive(Clone, Debug)]
pub struct Batcher {
    total: usize,
    chunk: usize,
}

/// One batch: the half-open range of original indices it covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Batch {
    pub start: usize,
    pub end: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

impl Batcher {
    pub fn new(total: usize, chunk: usize) -> Self {
        assert!(chunk > 0);
        Batcher { total, chunk }
    }

    pub fn batches(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut start = 0;
        while start < self.total {
            let end = (start + self.chunk).min(self.total);
            out.push(Batch { start, end });
            start = end;
        }
        out
    }

    pub fn num_batches(&self) -> usize {
        self.total.div_ceil(self.chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_in_order_without_overlap() {
        let b = Batcher::new(150, 64);
        let batches = b.batches();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0], Batch { start: 0, end: 64 });
        assert_eq!(batches[1], Batch { start: 64, end: 128 });
        assert_eq!(batches[2], Batch { start: 128, end: 150 });
        let covered: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(covered, 150);
    }

    #[test]
    fn exact_multiple() {
        let b = Batcher::new(128, 64);
        assert_eq!(b.num_batches(), 2);
        assert!(b.batches().iter().all(|x| x.len() == 64));
    }

    #[test]
    fn empty_total() {
        assert!(Batcher::new(0, 64).batches().is_empty());
    }

    #[test]
    fn pad_matrix_zero_fills() {
        let m = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let p = pad_matrix(&m, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &m[..]);
        assert!(p[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_matrix_noop_at_target_and_empty() {
        let m = vec![5.0, 6.0, 7.0];
        assert_eq!(pad_matrix(&m, 1, 3, 1), m);
        // Zero rows pad to pure zeros; zero target stays empty.
        assert_eq!(pad_matrix(&[], 0, 4, 2), vec![0.0; 8]);
        assert!(pad_matrix(&[], 0, 4, 0).is_empty());
    }

    #[test]
    #[should_panic]
    fn pad_matrix_rejects_shrinking() {
        pad_matrix(&[0.0; 8], 2, 4, 1);
    }

    #[test]
    #[should_panic]
    fn pad_matrix_rejects_mismatched_shape() {
        pad_matrix(&[0.0; 7], 2, 4, 4);
    }

    #[test]
    #[should_panic]
    fn batcher_rejects_zero_chunk() {
        Batcher::new(10, 0);
    }

    #[test]
    fn chunk_of_one_preserves_every_index() {
        let batches = Batcher::new(5, 1).batches();
        assert_eq!(batches.len(), 5);
        for (i, b) in batches.iter().enumerate() {
            assert_eq!((b.start, b.end), (i, i + 1));
        }
    }

    /// Property sweep over a seeded grid of (total, chunk): batches tile
    /// [0, total) exactly, in order, every one nonempty, only the last
    /// ragged — the invariant the front door's flush path leans on when
    /// it splits a drained queue with `Batcher`.
    #[test]
    fn batches_tile_in_order_property() {
        let mut rng = crate::util::Rng::new(0xba7c4);
        let mut cases: Vec<(usize, usize)> =
            vec![(0, 1), (1, 1), (1, 64), (63, 64), (64, 64), (65, 64)];
        for _ in 0..200 {
            cases.push((rng.below(300), 1 + rng.below(80)));
        }
        for (total, chunk) in cases {
            let b = Batcher::new(total, chunk);
            let batches = b.batches();
            assert_eq!(batches.len(), b.num_batches(), "({total}, {chunk})");
            let mut cursor = 0;
            for (i, batch) in batches.iter().enumerate() {
                assert_eq!(batch.start, cursor, "gap/overlap at ({total}, {chunk})");
                assert!(!batch.is_empty(), "empty batch at ({total}, {chunk})");
                let full = batch.len() == chunk;
                let last = i + 1 == batches.len();
                assert!(full || last, "ragged non-tail at ({total}, {chunk})");
                cursor = batch.end;
            }
            assert_eq!(cursor, total, "coverage at ({total}, {chunk})");
        }
    }

    /// Padding then slicing the original row range back is the identity,
    /// for a seeded grid of shapes.
    #[test]
    fn pad_matrix_roundtrip_property() {
        let mut rng = crate::util::Rng::new(0x9ad5);
        for _ in 0..100 {
            let rows = rng.below(12);
            let width = 1 + rng.below(9);
            let target = rows + rng.below(8);
            let data: Vec<f32> = (0..rows * width)
                .map(|_| rng.range_f64(-1.0, 1.0) as f32)
                .collect();
            let padded = pad_matrix(&data, rows, width, target);
            assert_eq!(padded.len(), target * width);
            assert_eq!(&padded[..rows * width], &data[..]);
            assert!(padded[rows * width..].iter().all(|&x| x == 0.0));
        }
    }
}
