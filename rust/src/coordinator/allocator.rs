//! Segment placement: maps reference HVs onto physical (bank, row) slots.
//!
//! An HV packed to `segments` 128-wide pieces occupies `segments`
//! consecutive banks at the same row index (paper §III-C); a *bank group*
//! of `segments` banks therefore holds up to 128 HVs. The allocator hands
//! out (group, row) slots, tracks freedom, and never double-books — the
//! invariant proptested in `rust/tests/property_tests.rs`. The engine
//! (`coordinator::engine`) allocates through it for every programmed row,
//! so placement respects bank capacity and over-full libraries fail with a
//! typed `CapacityError`.

use crate::array::ARRAY_DIM;
use crate::util::error::Error;

/// One allocated slot: bank group index and row within the group.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Slot {
    pub group: usize,
    pub row: usize,
}

/// Typed construction failure for [`SegmentAllocator::try_new`] (crate
/// standard: no stringly-typed `Result<_, String>` in public APIs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// `packed_width` is zero or not a multiple of [`ARRAY_DIM`].
    UnalignedWidth { packed_width: usize },
    /// A single HV needs more segments than there are banks.
    TooWide {
        num_banks: usize,
        packed_width: usize,
        segments: usize,
    },
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::UnalignedWidth { packed_width } => {
                write!(f, "packed width {packed_width} is not a multiple of {ARRAY_DIM}")
            }
            AllocError::TooWide {
                num_banks,
                packed_width,
                segments,
            } => write!(
                f,
                "{num_banks} banks cannot hold a {packed_width}-wide HV ({segments} segments)"
            ),
        }
    }
}

impl std::error::Error for AllocError {}

impl From<AllocError> for Error {
    fn from(e: AllocError) -> Self {
        Error::msg(e)
    }
}

#[derive(Clone, Debug)]
pub struct SegmentAllocator {
    /// Banks per group (= segments per HV).
    segments: usize,
    /// Total bank groups available.
    groups: usize,
    /// Physical banks the allocator was built for.
    num_banks: usize,
    /// Free rows per group (LIFO).
    free: Vec<Vec<usize>>,
    /// Per-group occupancy bitset (bit `row` set = allocated). Keeps the
    /// double-release check O(1) in every build — the former
    /// `free.contains(&row)` scan was O(rows) per release, which made
    /// bulk release/reuse (clustering reprograms every bucket) quadratic.
    used: Vec<u128>,
}

// One `u128` word per group covers every row.
const _: () = assert!(ARRAY_DIM <= 128);

impl SegmentAllocator {
    /// `num_banks` physical banks serving HVs of `packed_width` (must be a
    /// multiple of 128). Panicking form of [`SegmentAllocator::try_new`].
    pub fn new(num_banks: usize, packed_width: usize) -> Self {
        Self::try_new(num_banks, packed_width).unwrap()
    }

    /// Fallible constructor: errors when the packed width is not
    /// segment-aligned or a single HV is wider than all banks together.
    pub fn try_new(num_banks: usize, packed_width: usize) -> Result<Self, AllocError> {
        if packed_width == 0 || packed_width % ARRAY_DIM != 0 {
            return Err(AllocError::UnalignedWidth { packed_width });
        }
        let segments = packed_width / ARRAY_DIM;
        let groups = num_banks / segments;
        if groups == 0 {
            return Err(AllocError::TooWide {
                num_banks,
                packed_width,
                segments,
            });
        }
        Ok(SegmentAllocator {
            segments,
            groups,
            num_banks,
            free: (0..groups)
                .map(|_| (0..ARRAY_DIM).rev().collect())
                .collect(),
            used: vec![0u128; groups],
        })
    }

    pub fn segments(&self) -> usize {
        self.segments
    }

    pub fn num_banks(&self) -> usize {
        self.num_banks
    }

    pub fn capacity(&self) -> usize {
        self.groups * ARRAY_DIM
    }

    pub fn free_slots(&self) -> usize {
        self.free.iter().map(|f| f.len()).sum()
    }

    /// Allocate one slot (fills group 0 first — keeps row blocks dense for
    /// whole-array MVM activation).
    pub fn alloc(&mut self) -> Option<Slot> {
        for (g, rows) in self.free.iter_mut().enumerate() {
            if let Some(row) = rows.pop() {
                self.used[g] |= 1u128 << row;
                return Some(Slot { group: g, row });
            }
        }
        None
    }

    /// Release a slot back to the pool. Double releases are caught in
    /// every build via the O(1) occupancy bitset (not an O(rows) scan of
    /// the free list, and not debug-only — a double-booked row would
    /// silently corrupt placement).
    pub fn release(&mut self, slot: Slot) {
        assert!(slot.group < self.groups && slot.row < ARRAY_DIM);
        let bit = 1u128 << slot.row;
        assert!(
            self.used[slot.group] & bit != 0,
            "double release of {slot:?}"
        );
        self.used[slot.group] &= !bit;
        self.free[slot.group].push(slot.row);
    }

    /// Physical bank indices a slot's segments live on.
    pub fn banks_of(&self, slot: Slot) -> Vec<usize> {
        (0..self.segments)
            .map(|s| slot.group * self.segments + s)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_math() {
        // 128 banks, 768-wide HVs (6 segments) -> 21 groups * 128 rows.
        let a = SegmentAllocator::new(128, 768);
        assert_eq!(a.segments(), 6);
        assert_eq!(a.capacity(), 21 * 128);
        assert_eq!(a.free_slots(), a.capacity());
    }

    #[test]
    fn alloc_until_exhausted() {
        let mut a = SegmentAllocator::new(4, 256); // 2 groups * 128 rows
        let mut seen = std::collections::HashSet::new();
        for _ in 0..256 {
            let s = a.alloc().unwrap();
            assert!(seen.insert(s), "double-booked {s:?}");
        }
        assert!(a.alloc().is_none());
    }

    #[test]
    fn release_reuses() {
        let mut a = SegmentAllocator::new(2, 256); // 1 group
        let slots: Vec<Slot> = (0..128).map(|_| a.alloc().unwrap()).collect();
        assert!(a.alloc().is_none());
        a.release(slots[17]);
        let s = a.alloc().unwrap();
        assert_eq!(s, slots[17]);
    }

    #[test]
    fn banks_of_contiguous() {
        let a = SegmentAllocator::new(12, 384); // 3 segments, 4 groups
        let banks = a.banks_of(Slot { group: 2, row: 5 });
        assert_eq!(banks, vec![6, 7, 8]);
    }

    #[test]
    #[should_panic]
    fn too_wide_for_banks() {
        SegmentAllocator::new(2, 768); // needs 6 banks
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_is_caught_in_release_builds() {
        let mut a = SegmentAllocator::new(2, 256);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s); // O(1) bitset check, armed in every build profile
    }

    #[test]
    fn try_new_errors_are_typed_with_fields() {
        match SegmentAllocator::try_new(2, 768) {
            Err(AllocError::TooWide {
                num_banks,
                packed_width,
                segments,
            }) => {
                assert_eq!((num_banks, packed_width, segments), (2, 768, 6));
            }
            other => panic!("expected TooWide, got {other:?}"),
        }
        match SegmentAllocator::try_new(8, 100) {
            Err(AllocError::UnalignedWidth { packed_width }) => assert_eq!(packed_width, 100),
            other => panic!("expected UnalignedWidth, got {other:?}"),
        }
        // Message text preserved across the String -> enum migration (the
        // CLI and CapacityError paths surface it to users).
        let msg = SegmentAllocator::try_new(2, 768).unwrap_err().to_string();
        assert_eq!(msg, "2 banks cannot hold a 768-wide HV (6 segments)");
        let msg = SegmentAllocator::try_new(8, 100).unwrap_err().to_string();
        assert_eq!(msg, "packed width 100 is not a multiple of 128");
    }

    #[test]
    fn scattered_release_reuses_lifo_with_bank_mapping_preserved() {
        // The live add/remove shape: a programmed engine releases a
        // scattered subset of rows, then programs new references into the
        // freed slots. Reuse must hand back exactly the released slots
        // (LIFO per group, group 0 first) with their original bank spans.
        let mut a = SegmentAllocator::new(6, 384); // 3 segments, 2 groups
        let slots: Vec<Slot> = (0..256).map(|_| a.alloc().unwrap()).collect();
        assert!(a.alloc().is_none());
        let removed = [3usize, 200, 77, 128, 5];
        let banks_before: Vec<Vec<usize>> =
            removed.iter().map(|&i| a.banks_of(slots[i])).collect();
        for &i in &removed {
            a.release(slots[i]);
        }
        assert_eq!(a.free_slots(), removed.len());
        // Group 0 drains first, each group LIFO within itself: releases in
        // group 0 were rows of slots[3], slots[77], slots[5] (in release
        // order), so reuse pops 5, 77, 3; then group 1 pops 128, 200.
        for &want in &[5usize, 77, 3, 128, 200] {
            let got = a.alloc().unwrap();
            assert_eq!(got, slots[want], "reuse order");
            let bi = removed.iter().position(|&r| r == want).unwrap();
            assert_eq!(a.banks_of(got), banks_before[bi], "bank span must survive reuse");
        }
        assert!(a.alloc().is_none());
    }

    #[test]
    fn interleaved_add_remove_never_double_books() {
        // Alternate removes and adds against a nearly-full pool; the
        // occupancy bitset must keep live slots unique throughout.
        let mut a = SegmentAllocator::new(4, 256); // 2 groups x 128 rows
        let mut live: Vec<Slot> = (0..200).map(|_| a.alloc().unwrap()).collect();
        for round in 0..40usize {
            let victim = live.remove((round * 13) % live.len());
            a.release(victim);
            let s = a.alloc().unwrap();
            assert!(!live.contains(&s), "reused slot {s:?} double-booked");
            live.push(s);
        }
        assert_eq!(live.len(), 200);
        let unique: std::collections::HashSet<Slot> = live.iter().copied().collect();
        assert_eq!(unique.len(), 200);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_trips_even_after_interleaved_reuse() {
        let mut a = SegmentAllocator::new(2, 256);
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        a.release(s1);
        let s3 = a.alloc().unwrap(); // LIFO: reoccupies s1's row
        assert_eq!(s1, s3);
        a.release(s2);
        a.release(s2); // second release of a freed row must still trip
    }

    #[test]
    fn bulk_release_and_reuse_round_trips() {
        let mut a = SegmentAllocator::new(4, 256); // 2 groups x 128 rows
        let slots: Vec<Slot> = (0..256).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.free_slots(), 0);
        for &s in &slots {
            a.release(s);
        }
        assert_eq!(a.free_slots(), a.capacity());
        // Every slot is allocatable again, still without double-booking.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..256 {
            assert!(seen.insert(a.alloc().unwrap()));
        }
        assert!(a.alloc().is_none());
    }
}
