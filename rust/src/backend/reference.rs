//! Scalar reference backend — the bit-exact oracle every other backend is
//! checked against, and the fallback target of the dispatcher's routing
//! heuristic.
//!
//! Jobs execute through the cache-blocked kernel
//! (`array::imc_mvm_blocked_into`), which is bit-identical to the
//! unblocked `array::imc_mvm_ref` by construction — blocking reorders
//! which output is computed next, never the accumulation order inside one
//! output — so "reference" still means "the transfer function", just with
//! the 128-col reference tiles kept hot across a query block. Dense jobs
//! run as a single full-panel segment; segmented jobs score their ranges
//! in place with no gather.

use crate::array::{imc_mvm_blocked_dacq_into, imc_mvm_blocked_into};
use crate::util::error::Result;

use super::{MvmBackend, MvmJob};

/// Executes jobs with the single-threaded blocked transfer function
/// (bit-identical to `array::imc_mvm_ref` — the rust mirror of the L1
/// Pallas kernel).
#[derive(Clone, Copy, Debug, Default)]
pub struct RefBackend;

impl MvmBackend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()> {
        let mut storage = [0..0];
        let segments = job.effective_segments(&mut storage);
        if job.dac_applied {
            // Caller already DAC-quantized the batch (ScoreScratch
            // hoisting); skip the per-job re-quantization pass.
            let (q, nq, cp) = (job.queries, job.nq, job.cp);
            imc_mvm_blocked_dacq_into(q, job.refs, segments, nq, cp, job.adc, out);
        } else {
            imc_mvm_blocked_into(job.queries, job.refs, segments, job.nq, job.cp, job.adc, out);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{imc_mvm_ref, AdcConfig};
    use crate::util::Rng;

    #[test]
    fn matches_transfer_function() {
        let mut rng = Rng::new(7);
        let (nq, nr, cp) = (4, 9, 256);
        let q: Vec<f32> = (0..nq * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let g: Vec<f32> = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::new(&q, nq, &g, nr, cp, adc);
        let got = RefBackend.mvm_scores(&job).unwrap();
        let want = imc_mvm_ref(&q, &g, nq, nr, cp, adc);
        assert_eq!(got, want);
        assert_eq!(RefBackend.utilization(&job), 1.0);
    }

    #[test]
    fn segmented_matches_gathered_transfer_function() {
        let mut rng = Rng::new(8);
        let (nq, panel_rows, cp) = (3, 200, 128);
        let q: Vec<f32> = (0..nq * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let panel: Vec<f32> =
            (0..panel_rows * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let segs = vec![0..10, 50..50, 120..200];
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::segmented(&q, nq, &panel, &segs, cp, adc);

        let mut gathered = Vec::new();
        for s in &segs {
            gathered.extend_from_slice(&panel[s.start * cp..s.end * cp]);
        }
        let want = imc_mvm_ref(&q, &gathered, nq, job.nr, cp, adc);

        let mut got = vec![f32::NAN; nq * job.nr];
        RefBackend.mvm_scores_into(&job, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn dac_applied_jobs_bit_identical() {
        // Fractional query values so the DAC really quantizes; the hoisted
        // (pre-quantized) job must score identically to the plain one.
        let mut rng = Rng::new(9);
        let (nq, nr, cp) = (5, 40, 128);
        let q: Vec<f32> = (0..nq * cp).map(|_| rng.range_i64(-40, 40) as f32 / 8.0).collect();
        let g: Vec<f32> = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let adc = AdcConfig::new(6, 512.0);
        let want = RefBackend.mvm_scores(&MvmJob::new(&q, nq, &g, nr, cp, adc)).unwrap();

        let dacq: Vec<f32> = q.iter().map(|&x| crate::array::dac_quantize(x)).collect();
        let hoisted = MvmJob::new(&dacq, nq, &g, nr, cp, adc).with_dac_applied();
        assert!(hoisted.dac_applied);
        assert_eq!(RefBackend.mvm_scores(&hoisted).unwrap(), want);
    }
}
