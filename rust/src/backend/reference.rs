//! Scalar reference backend — the bit-exact oracle every other backend is
//! checked against, and the fallback target of the dispatcher's routing
//! heuristic.

use crate::array::imc_mvm_ref;
use crate::util::error::Result;

use super::{MvmBackend, MvmJob};

/// Executes jobs with the single-threaded reference transfer function
/// (`array::imc_mvm_ref` — the rust mirror of the L1 Pallas kernel).
#[derive(Clone, Copy, Debug, Default)]
pub struct RefBackend;

impl MvmBackend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn mvm_scores(&self, job: &MvmJob) -> Result<Vec<f32>> {
        Ok(imc_mvm_ref(
            job.queries,
            job.refs,
            job.nq,
            job.nr,
            job.cp,
            job.adc,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::AdcConfig;
    use crate::util::Rng;

    #[test]
    fn matches_transfer_function() {
        let mut rng = Rng::new(7);
        let (nq, nr, cp) = (4, 9, 256);
        let q: Vec<f32> = (0..nq * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let g: Vec<f32> = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::new(&q, nq, &g, nr, cp, adc);
        let got = RefBackend.mvm_scores(&job).unwrap();
        let want = imc_mvm_ref(&q, &g, nq, nr, cp, adc);
        assert_eq!(got, want);
        assert_eq!(RefBackend.utilization(&job), 1.0);
    }
}
