//! Bank-sharded parallel backend: the host-side analogue of the
//! accelerator's bank parallelism (paper §III-C tiles one logical MVM
//! across independent 128x128 banks; we tile the same score matrix across
//! OS threads).
//!
//! Sharding is by **query rows of the output tile**: each worker computes
//! a contiguous `qn x nr` stripe with the identical blocked kernel the
//! reference backend runs, writing directly into its disjoint slice of
//! the caller's output buffer (no per-worker score allocation, no final
//! copy). Per-element arithmetic and ordering are unchanged, so results
//! are bit-identical to [`RefBackend`] for every thread count — the
//! invariant `rust/tests/backend_equivalence.rs` locks in. Segmented jobs
//! shard the same way: every worker scores the same borrowed panel
//! ranges for its query stripe, so the zero-copy property survives the
//! fan-out. Each worker also accumulates its shard's physical
//! [`OpCounts`], merged after the scope joins (the counts are
//! deterministic, so the merge must agree with [`MvmJob::bank_ops`] —
//! debug-asserted).
//!
//! `std::thread::scope` keeps the implementation dependency-free; workers
//! borrow the job buffers directly, no cloning.

use crate::energy::OpCounts;
use crate::util::error::Result;

use super::reference::RefBackend;
use super::{MvmBackend, MvmJob};

/// Minimum scalar multiply-accumulate count (`nq * nr * cp`) before
/// spawning threads pays for itself; smaller jobs run on the caller's
/// thread. Small candidate buckets dominate both pipelines, so this guard
/// matters for end-to-end wall time.
const MIN_PARALLEL_MACS: usize = 100_000;

/// Shards `MvmJob`s across `threads` scoped workers.
#[derive(Clone, Copy, Debug)]
pub struct ParallelBackend {
    threads: usize,
}

impl ParallelBackend {
    /// `threads = 0` auto-detects (`std::thread::available_parallelism`).
    pub fn new(threads: usize) -> Self {
        ParallelBackend { threads }
    }

    /// The worker count jobs actually run with.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

impl Default for ParallelBackend {
    fn default() -> Self {
        ParallelBackend::new(0)
    }
}

impl MvmBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()> {
        let (nq, nr, cp) = (job.nq, job.nr, job.cp);
        assert_eq!(out.len(), nq * nr, "out shape");
        let threads = self.effective_threads().min(nq.max(1));
        if threads <= 1 || nq * nr * cp < MIN_PARALLEL_MACS {
            return RefBackend.mvm_scores_into(job, out);
        }

        // Contiguous query-row chunks; the last chunk absorbs the ragged
        // remainder. `chunks_mut` hands each worker a disjoint &mut stripe
        // of the caller's buffer.
        let chunk_rows = nq.div_ceil(threads);
        let mut merged = OpCounts::default();
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            for (ci, out_chunk) in out.chunks_mut(chunk_rows * nr).enumerate() {
                let q0 = ci * chunk_rows;
                let qn = out_chunk.len() / nr;
                let q_rows = &job.queries[q0 * cp..(q0 + qn) * cp];
                let refs = job.refs;
                let segments = job.segments;
                let adc = job.adc;
                handles.push(s.spawn(move || {
                    let shard_job = if segments.is_empty() {
                        MvmJob::new(q_rows, qn, refs, nr, cp, adc)
                    } else {
                        MvmJob::segmented(q_rows, qn, refs, segments, cp, adc)
                    };
                    RefBackend
                        .mvm_scores_into(&shard_job, out_chunk)
                        .expect("reference kernel is infallible");
                    // Shard-local physical op count, merged after join.
                    let mut shard_ops = OpCounts::default();
                    shard_job.count_ops(&mut shard_ops);
                    shard_ops
                }));
            }
            for h in handles {
                merged += h.join().expect("MVM shard worker panicked");
            }
        });
        debug_assert_eq!(
            merged.mvm_ops,
            job.bank_ops(),
            "merged shard op counts must equal the whole-job count"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::AdcConfig;
    use crate::util::Rng;

    fn job_buffers(seed: u64, nq: usize, nr: usize, cp: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let q = (0..nq * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let g = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        (q, g)
    }

    #[test]
    fn bit_identical_to_reference_across_thread_counts() {
        // Above the MIN_PARALLEL_MACS cutoff so threads actually spawn.
        let (nq, nr, cp) = (37, 211, 256);
        let (q, g) = job_buffers(11, nq, nr, cp);
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::new(&q, nq, &g, nr, cp, adc);
        let want = RefBackend.mvm_scores(&job).unwrap();
        for threads in [1usize, 2, 3, 8, 64] {
            let got = ParallelBackend::new(threads).mvm_scores(&job).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn segmented_bit_identical_across_thread_counts() {
        let (nq, panel_rows, cp) = (23, 600, 256);
        let (q, panel) = job_buffers(14, nq, panel_rows, cp);
        let segs = vec![0..100, 130..131, 200..200, 250..600];
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::segmented(&q, nq, &panel, &segs, cp, adc);
        let want = RefBackend.mvm_scores(&job).unwrap();
        for threads in [2usize, 3, 8] {
            let mut got = vec![f32::NAN; nq * job.nr];
            ParallelBackend::new(threads).mvm_scores_into(&job, &mut got).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn tiny_job_takes_scalar_path() {
        let (nq, nr, cp) = (2, 3, 128);
        let (q, g) = job_buffers(12, nq, nr, cp);
        let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::ideal());
        let got = ParallelBackend::new(8).mvm_scores(&job).unwrap();
        assert_eq!(got, RefBackend.mvm_scores(&job).unwrap());
    }

    #[test]
    fn more_threads_than_rows() {
        let (nq, nr, cp) = (3, 400, 128);
        let (q, g) = job_buffers(13, nq, nr, cp);
        let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(4, 128.0));
        let got = ParallelBackend::new(16).mvm_scores(&job).unwrap();
        assert_eq!(got, RefBackend.mvm_scores(&job).unwrap());
    }

    #[test]
    fn auto_threads_resolve() {
        assert!(ParallelBackend::new(0).effective_threads() >= 1);
        assert_eq!(ParallelBackend::new(5).effective_threads(), 5);
    }
}
