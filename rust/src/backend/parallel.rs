//! Bank-sharded parallel backend: the host-side analogue of the
//! accelerator's bank parallelism (paper §III-C tiles one logical MVM
//! across independent 128x128 banks; we tile the same score matrix across
//! OS threads).
//!
//! Sharding is 2-D, picked per job from the output-tile shape:
//!
//! * **Query-row sharding** (`nq >= threads`): each worker computes a
//!   contiguous `qn x nr` stripe with the identical blocked kernel the
//!   reference backend runs, writing directly into its disjoint slice of
//!   the caller's output buffer (no per-worker score allocation, no final
//!   copy).
//! * **Reference-row striping** (`nq < threads`, PR 6): the candidate span
//!   is split into tile-aligned sub-ranges of output *columns*, one
//!   `(query, stripe)` piece per worker unit, so the dominant `nq = 1`
//!   front-door serving shape fans out instead of running single-threaded.
//!   Stripe boundaries are multiples of [`ARRAY_DIM`] in candidate-row
//!   space — each piece's bank-op charge then sums exactly to the whole
//!   job's [`MvmJob::bank_ops`] (the `ceil(nr/128)` row-tile count is not
//!   linear across arbitrary splits, but is across tile-aligned ones).
//!   Stripe height comes from detected topology
//!   (`available_parallelism`-bounded worker count) or the
//!   `[backend] stripe_rows` config override.
//!
//! Per-element arithmetic and ordering are unchanged either way — a score
//! depends only on its own `(query, reference)` pair under the lane-ordered
//! accumulation contract (`crate::array::transfer`), never on which worker
//! computes its neighbors — so results are bit-identical to [`RefBackend`]
//! for every thread count and stripe shape, the invariant
//! `rust/tests/backend_equivalence.rs` locks in. Segmented jobs shard the
//! same way: stripes slice the segment list in output-column space, so the
//! zero-copy property survives the fan-out. Each worker also accumulates
//! its shard's physical [`OpCounts`], merged after the scope joins (the
//! counts are deterministic, so the merge must agree with
//! [`MvmJob::bank_ops`] — debug-asserted).
//!
//! `std::thread::scope` keeps the implementation dependency-free; workers
//! borrow the job buffers directly, no cloning.

use std::ops::Range;

use crate::array::ARRAY_DIM;
use crate::energy::OpCounts;
use crate::util::error::Result;

use super::reference::RefBackend;
use super::{MvmBackend, MvmJob};

/// Minimum scalar multiply-accumulate count (`nq * nr * cp`) before
/// spawning threads pays for itself; smaller jobs run on the caller's
/// thread. Small candidate buckets dominate both pipelines, so this guard
/// matters for end-to-end wall time. The same budget keeps 2-D striping
/// honest: auto stripe sizing never cuts a job into stripes carrying less
/// than this much work each.
const MIN_PARALLEL_MACS: usize = 100_000;

/// Shards `MvmJob`s across `threads` scoped workers.
#[derive(Clone, Copy, Debug)]
pub struct ParallelBackend {
    threads: usize,
    stripe_rows: usize,
}

impl ParallelBackend {
    /// `threads = 0` auto-detects (`std::thread::available_parallelism`).
    pub fn new(threads: usize) -> Self {
        ParallelBackend { threads, stripe_rows: 0 }
    }

    /// Override the reference-row stripe height for the `nq < threads`
    /// path (`[backend] stripe_rows` / `--stripe-rows`). `0` sizes stripes
    /// automatically from the worker count and the MAC budget; nonzero
    /// values are rounded up to a multiple of [`ARRAY_DIM`] so bank-op
    /// accounting stays exact. Score-neutral either way.
    pub fn with_stripe_rows(mut self, rows: usize) -> Self {
        self.stripe_rows = rows;
        self
    }

    /// The worker count jobs actually run with.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }

    /// Stripe height (in candidate rows) the `nq < threads` path uses for
    /// a `nq x nr x cp` job — tile-aligned, from the override or the
    /// topology/work heuristic. Exposed for tests and benches.
    pub fn stripe_height(&self, nq: usize, nr: usize, cp: usize) -> usize {
        let row_tiles = nr.div_ceil(ARRAY_DIM).max(1);
        let tiles_per_stripe = if self.stripe_rows > 0 {
            self.stripe_rows.div_ceil(ARRAY_DIM)
        } else {
            // Aim for ~threads pieces across the batch, but never stripes
            // thinner than the scalar-path MAC budget.
            let by_topology = self.effective_threads().div_ceil(nq.max(1));
            let by_work = (nq * nr * cp) / MIN_PARALLEL_MACS;
            let stripes = by_topology.min(by_work.max(1)).min(row_tiles);
            row_tiles.div_ceil(stripes.max(1))
        };
        tiles_per_stripe * ARRAY_DIM
    }
}

impl Default for ParallelBackend {
    fn default() -> Self {
        ParallelBackend::new(0)
    }
}

/// Map the output-column range `c0..c1` (candidate-row space, across the
/// concatenated segments) back onto panel-row sub-ranges. Overlapping
/// input segments are legal — the mapping treats each independently.
fn slice_segments(segments: &[Range<usize>], c0: usize, c1: usize) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    let mut base = 0usize;
    for s in segments {
        let len = s.len();
        let lo = c0.max(base);
        let hi = c1.min(base + len);
        if lo < hi {
            out.push(s.start + (lo - base)..s.start + (hi - base));
        }
        base += len;
    }
    out
}

impl MvmBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()> {
        let (nq, nr, cp) = (job.nq, job.nr, job.cp);
        assert_eq!(out.len(), nq * nr, "out shape");
        // Degenerate tiles have nothing to compute — and `nr == 0` would
        // make the row path's `chunks_mut(chunk_rows * nr)` chunk by zero.
        if nq == 0 || nr == 0 {
            return Ok(());
        }
        let threads = self.effective_threads();
        if threads <= 1 || nq * nr * cp < MIN_PARALLEL_MACS {
            return RefBackend.mvm_scores_into(job, out);
        }
        if nq >= threads {
            self.row_sharded(job, out, threads)
        } else {
            self.column_striped(job, out, threads)
        }
    }
}

impl ParallelBackend {
    /// Query-row sharding: contiguous query chunks, the last absorbs the
    /// ragged remainder. `chunks_mut` hands each worker a disjoint &mut
    /// stripe of the caller's buffer.
    fn row_sharded(&self, job: &MvmJob, out: &mut [f32], threads: usize) -> Result<()> {
        let (nq, nr, cp) = (job.nq, job.nr, job.cp);
        let chunk_rows = nq.div_ceil(threads);
        let mut merged = OpCounts::default();
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            for (ci, out_chunk) in out.chunks_mut(chunk_rows * nr).enumerate() {
                let q0 = ci * chunk_rows;
                let qn = out_chunk.len() / nr;
                let q_rows = &job.queries[q0 * cp..(q0 + qn) * cp];
                let refs = job.refs;
                let segments = job.segments;
                let adc = job.adc;
                let dac_applied = job.dac_applied;
                handles.push(s.spawn(move || {
                    let mut shard_job = if segments.is_empty() {
                        MvmJob::new(q_rows, qn, refs, nr, cp, adc)
                    } else {
                        MvmJob::segmented(q_rows, qn, refs, segments, cp, adc)
                    };
                    if dac_applied {
                        shard_job = shard_job.with_dac_applied();
                    }
                    RefBackend
                        .mvm_scores_into(&shard_job, out_chunk)
                        .expect("reference kernel is infallible");
                    // Shard-local physical op count, merged after join.
                    let mut shard_ops = OpCounts::default();
                    shard_job.count_ops(&mut shard_ops);
                    shard_ops
                }));
            }
            for h in handles {
                merged += h.join().expect("MVM shard worker panicked");
            }
        });
        debug_assert_eq!(
            merged.mvm_ops,
            job.bank_ops(),
            "merged shard op counts must equal the whole-job count"
        );
        Ok(())
    }

    /// Reference-row striping for `nq < threads`: tile-aligned output
    /// column stripes, one `(query, stripe)` piece per worker unit, each
    /// writing a disjoint contiguous slice of `out`.
    fn column_striped(&self, job: &MvmJob, out: &mut [f32], threads: usize) -> Result<()> {
        let (nq, nr, cp) = (job.nq, job.nr, job.cp);
        let sr = self.stripe_height(nq, nr, cp);
        let n_stripes = nr.div_ceil(sr);
        if nq * n_stripes <= 1 {
            // One piece == the whole job; skip the spawn overhead.
            return RefBackend.mvm_scores_into(job, out);
        }

        let mut storage = [0..0];
        let segments = job.effective_segments(&mut storage);

        // Piece list in output order: qi-outer, stripe-inner walks `out`
        // contiguously (stripe `nr..nr` of query qi abuts stripe `0..` of
        // qi+1), so sequential `split_at_mut` yields the disjoint slices.
        let mut pieces = Vec::with_capacity(nq * n_stripes);
        let mut rest = &mut out[..];
        for qi in 0..nq {
            let q_row = &job.queries[qi * cp..(qi + 1) * cp];
            for si in 0..n_stripes {
                let c0 = si * sr;
                let c1 = nr.min(c0 + sr);
                // `take` moves the tail out so the split-off head can
                // outlive this iteration (a plain reborrow could not).
                let (piece_out, tail) = std::mem::take(&mut rest).split_at_mut(c1 - c0);
                rest = tail;
                pieces.push((q_row, slice_segments(segments, c0, c1), piece_out));
            }
        }
        debug_assert!(rest.is_empty());

        let per_worker = pieces.len().div_ceil(threads);
        let mut merged = OpCounts::default();
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            let mut iter = pieces.into_iter();
            loop {
                let group: Vec<_> = iter.by_ref().take(per_worker).collect();
                if group.is_empty() {
                    break;
                }
                let refs = job.refs;
                let adc = job.adc;
                let dac_applied = job.dac_applied;
                handles.push(s.spawn(move || {
                    let mut shard_ops = OpCounts::default();
                    for (q_row, segs, piece_out) in group {
                        let mut piece = MvmJob::segmented(q_row, 1, refs, &segs, cp, adc);
                        if dac_applied {
                            piece = piece.with_dac_applied();
                        }
                        RefBackend
                            .mvm_scores_into(&piece, piece_out)
                            .expect("reference kernel is infallible");
                        piece.count_ops(&mut shard_ops);
                    }
                    shard_ops
                }));
            }
            for h in handles {
                merged += h.join().expect("MVM stripe worker panicked");
            }
        });
        debug_assert_eq!(
            merged.mvm_ops,
            job.bank_ops(),
            "tile-aligned stripe op counts must sum to the whole-job count"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::{dac_quantize, AdcConfig};
    use crate::util::Rng;

    fn job_buffers(seed: u64, nq: usize, nr: usize, cp: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let q = (0..nq * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let g = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        (q, g)
    }

    #[test]
    fn bit_identical_to_reference_across_thread_counts() {
        // Above the MIN_PARALLEL_MACS cutoff so threads actually spawn.
        let (nq, nr, cp) = (37, 211, 256);
        let (q, g) = job_buffers(11, nq, nr, cp);
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::new(&q, nq, &g, nr, cp, adc);
        let want = RefBackend.mvm_scores(&job).unwrap();
        for threads in [1usize, 2, 3, 8, 64] {
            let got = ParallelBackend::new(threads).mvm_scores(&job).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn segmented_bit_identical_across_thread_counts() {
        let (nq, panel_rows, cp) = (23, 600, 256);
        let (q, panel) = job_buffers(14, nq, panel_rows, cp);
        let segs = vec![0..100, 130..131, 200..200, 250..600];
        let adc = AdcConfig::new(6, 512.0);
        let job = MvmJob::segmented(&q, nq, &panel, &segs, cp, adc);
        let want = RefBackend.mvm_scores(&job).unwrap();
        for threads in [2usize, 3, 8] {
            let mut got = vec![f32::NAN; nq * job.nr];
            ParallelBackend::new(threads).mvm_scores_into(&job, &mut got).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn single_query_stripes_bit_identical_across_shapes() {
        // The nq < threads column-striped path, dense and segmented, across
        // thread counts and explicit stripe overrides (including heights
        // that round up to a tile and one taller than the whole span).
        let (nq, panel_rows, cp) = (1, 1500, 256);
        let (q, panel) = job_buffers(15, nq, panel_rows, cp);
        let adc = AdcConfig::new(6, 512.0);
        let segs = vec![0..700, 800..801, 900..900, 1000..1500];
        for job in [
            MvmJob::new(&q, nq, &panel, panel_rows, cp, adc),
            MvmJob::segmented(&q, nq, &panel, &segs, cp, adc),
        ] {
            let want = RefBackend.mvm_scores(&job).unwrap();
            for threads in [2usize, 3, 8, 64] {
                for stripe_rows in [0usize, 1, 128, 300, 1_000_000] {
                    let be = ParallelBackend::new(threads).with_stripe_rows(stripe_rows);
                    let mut got = vec![f32::NAN; nq * job.nr];
                    be.mvm_scores_into(&job, &mut got).unwrap();
                    assert_eq!(got, want, "threads={threads} stripe_rows={stripe_rows}");
                }
            }
        }
    }

    #[test]
    fn few_queries_many_threads_stripes_bit_identical() {
        // 2 < nq < threads: pieces mix query and stripe splits.
        let (nq, nr, cp) = (3, 900, 256);
        let (q, g) = job_buffers(16, nq, nr, cp);
        let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(3, 128.0));
        let want = RefBackend.mvm_scores(&job).unwrap();
        for threads in [4usize, 8, 16] {
            let got = ParallelBackend::new(threads).mvm_scores(&job).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn stripe_height_is_tile_aligned_and_work_honest() {
        let be = ParallelBackend::new(8);
        // Any auto stripe is a positive multiple of ARRAY_DIM.
        for (nq, nr, cp) in [(1usize, 1500usize, 256usize), (3, 900, 128), (1, 1, 128)] {
            let sr = be.stripe_height(nq, nr, cp);
            assert!(sr > 0 && sr % ARRAY_DIM == 0, "({nq},{nr},{cp}) -> {sr}");
        }
        // Barely above the MAC cutoff: the work budget caps striping to a
        // single stripe rather than slicing a thin job eight ways.
        let sr = be.stripe_height(1, 800, 128);
        assert_eq!(sr, 800usize.div_ceil(ARRAY_DIM) * ARRAY_DIM);
        // Overrides round up to a tile.
        assert_eq!(ParallelBackend::new(8).with_stripe_rows(1).stripe_height(1, 1500, 256), 128);
        assert_eq!(ParallelBackend::new(8).with_stripe_rows(300).stripe_height(1, 1500, 256), 384);
    }

    #[test]
    fn empty_jobs_early_return() {
        // nq == 0 and nr == 0 must return without touching chunk math.
        let be = ParallelBackend::new(8);
        let g = vec![1.0f32; 4 * 128];
        let no_q = MvmJob::new(&[], 0, &g, 4, 128, AdcConfig::ideal());
        assert_eq!(be.mvm_scores(&no_q).unwrap().len(), 0);
        let q = vec![1.0f32; 2 * 128];
        let no_r = MvmJob::new(&q, 2, &[], 0, 128, AdcConfig::ideal());
        assert_eq!(be.mvm_scores(&no_r).unwrap().len(), 0);
        // Segmented with only-empty segments is the same degenerate shape.
        let seg_job = MvmJob::segmented(&q, 2, &g, &[2..2], 128, AdcConfig::ideal());
        assert_eq!(be.mvm_scores(&seg_job).unwrap().len(), 0);
    }

    #[test]
    fn dac_applied_passthrough_bit_identical() {
        // Fractional queries, both sharding shapes: the hoisted flag must
        // ride through to every shard/piece without changing scores.
        let mut rng = Rng::new(17);
        for (nq, nr, threads) in [(1usize, 1200usize, 8usize), (24, 300, 4)] {
            let cp = 256;
            let q: Vec<f32> = (0..nq * cp).map(|_| rng.range_i64(-40, 40) as f32 / 8.0).collect();
            let g: Vec<f32> = (0..nr * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
            let adc = AdcConfig::new(6, 512.0);
            let want = ParallelBackend::new(threads)
                .mvm_scores(&MvmJob::new(&q, nq, &g, nr, cp, adc))
                .unwrap();
            let dacq: Vec<f32> = q.iter().map(|&x| dac_quantize(x)).collect();
            let hoisted = MvmJob::new(&dacq, nq, &g, nr, cp, adc).with_dac_applied();
            let got = ParallelBackend::new(threads).mvm_scores(&hoisted).unwrap();
            assert_eq!(got, want, "nq={nq}");
        }
    }

    #[test]
    fn slice_segments_maps_output_columns_to_panel_rows() {
        let segs = vec![10..13, 20..20, 5..9];
        // Candidate rows: [10,11,12, 5,6,7,8].
        assert_eq!(slice_segments(&segs, 0, 7), vec![10..13, 5..9]);
        assert_eq!(slice_segments(&segs, 1, 3), vec![11..13]);
        assert_eq!(slice_segments(&segs, 2, 5), vec![12..13, 5..7]);
        assert_eq!(slice_segments(&segs, 3, 7), vec![5..9]);
        assert!(slice_segments(&segs, 7, 7).is_empty());
    }

    #[test]
    fn tiny_job_takes_scalar_path() {
        let (nq, nr, cp) = (2, 3, 128);
        let (q, g) = job_buffers(12, nq, nr, cp);
        let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::ideal());
        let got = ParallelBackend::new(8).mvm_scores(&job).unwrap();
        assert_eq!(got, RefBackend.mvm_scores(&job).unwrap());
    }

    #[test]
    fn more_threads_than_rows() {
        let (nq, nr, cp) = (3, 400, 128);
        let (q, g) = job_buffers(13, nq, nr, cp);
        let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(4, 128.0));
        let got = ParallelBackend::new(16).mvm_scores(&job).unwrap();
        assert_eq!(got, RefBackend.mvm_scores(&job).unwrap());
    }

    #[test]
    fn auto_threads_resolve() {
        assert!(ParallelBackend::new(0).effective_threads() >= 1);
        assert_eq!(ParallelBackend::new(5).effective_threads(), 5);
    }
}
