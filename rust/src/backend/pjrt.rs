//! PJRT artifact backend (feature `pjrt`): executes the AOT-compiled
//! `mvm_c{width}` HLO artifact through the PJRT CPU client.
//!
//! The artifact runs a fixed `B x R` geometry; this backend batches
//! arbitrary jobs into padded tiles (reusing the reference-block literal
//! across query batches — the marshalling optimisation from EXPERIMENTS.md
//! §Perf L3) and reports padded-tile utilization so the dispatcher can
//! route low-occupancy jobs to the scalar path instead.
//!
//! The runtime sits behind `Arc<Mutex<_>>` because executable compilation
//! caches mutate it and the `MvmBackend` contract is `Send + Sync` (the
//! shard layer executes jobs from scoped threads); the dispatcher shares
//! the same handle with the HD frontend for the encoder artifact.

use std::sync::{Arc, Mutex};

use crate::coordinator::batcher::{pad_matrix, Batcher};
use crate::runtime::{Manifest, Runtime};
use crate::util::error::Result;
use crate::util::sync::lock_unpoisoned;

use super::{MvmBackend, MvmJob};

/// Executes jobs on the PJRT runtime's compiled MVM artifacts.
pub struct PjrtBackend {
    rt: Arc<Mutex<Runtime>>,
}

impl PjrtBackend {
    /// Wrap an already-loaded runtime.
    pub fn new(rt: Runtime) -> Self {
        PjrtBackend {
            rt: Arc::new(Mutex::new(rt)),
        }
    }

    /// Load the manifest + PJRT client from an artifacts directory.
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        Ok(PjrtBackend::new(Runtime::load(artifacts_dir)?))
    }

    /// Shared handle to the underlying runtime (encoder artifact path,
    /// telemetry).
    pub fn shared_runtime(&self) -> Arc<Mutex<Runtime>> {
        self.rt.clone()
    }
}

impl MvmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// A compiled `mvm_c{cp}` artifact must exist for the job's packed
    /// width (and the tile must be non-empty); otherwise the dispatcher's
    /// fallback computes the job on the bit-identical rust path.
    fn supports(&self, job: &MvmJob) -> bool {
        job.nq > 0
            && job.nr > 0
            && lock_unpoisoned(&self.rt, "pjrt runtime")
                .manifest
                .get(&Manifest::mvm_name(job.cp))
                .is_some()
    }

    /// Padded-tile occupancy: `(nq * nr) / (padded_nq * padded_nr)`, or
    /// 0.0 when the job is unsupported (routes to the fallback).
    fn utilization(&self, job: &MvmJob) -> f64 {
        if !self.supports(job) {
            return 0.0;
        }
        let rt = lock_unpoisoned(&self.rt, "pjrt runtime");
        let padded = job.nq.div_ceil(rt.manifest.batch)
            * rt.manifest.batch
            * job.nr.div_ceil(rt.manifest.rows)
            * rt.manifest.rows;
        (job.nq * job.nr) as f64 / padded as f64
    }

    fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()> {
        // The artifact runs fixed dense `B x R` tiles, so segmented jobs
        // gather their candidate panel into a contiguous block first —
        // the host-side gather is the price of the fixed geometry and
        // stays behind the same bit-identical contract (the dispatcher's
        // utilization routing is unchanged either way).
        if !job.segments.is_empty() {
            let cp = job.cp;
            let mut gathered = Vec::with_capacity(job.nr * cp);
            for seg in job.segments {
                gathered.extend_from_slice(&job.refs[seg.start * cp..seg.end * cp]);
            }
            let dense = MvmJob::new(job.queries, job.nq, &gathered, job.nr, cp, job.adc);
            return self.mvm_scores_into(&dense, out);
        }

        let mut rt = lock_unpoisoned(&self.rt, "pjrt runtime");
        let b = rt.manifest.batch;
        let r_block = rt.manifest.rows;
        let (nq, nr, cp) = (job.nq, job.nr, job.cp);
        assert_eq!(out.len(), nq * nr, "out shape");

        for rb in Batcher::new(nr, r_block).batches() {
            let refs_block = pad_matrix(
                &job.refs[rb.start * cp..rb.end * cp],
                rb.len(),
                cp,
                r_block,
            );
            // Marshal the (large) reference block into a PJRT literal once
            // per row block; every query batch against it reuses the
            // literal.
            let refs_lit = rt.mvm_refs_literal(cp, &refs_block)?;
            for qb in Batcher::new(nq, b).batches() {
                let q_block = pad_matrix(
                    &job.queries[qb.start * cp..qb.end * cp],
                    qb.len(),
                    cp,
                    b,
                );
                let scores =
                    rt.mvm_with_refs(cp, &q_block, &refs_lit, job.adc.lsb(), job.adc.qmax())?;
                for qi in 0..qb.len() {
                    let src = &scores[qi * r_block..qi * r_block + rb.len()];
                    let dst_row = qb.start + qi;
                    out[dst_row * nr + rb.start..dst_row * nr + rb.end].copy_from_slice(src);
                }
            }
        }
        Ok(())
    }
}
