//! Pluggable MVM execution backends (the coordinator's hot path).
//!
//! SpecPCM's speedups come from tiling MVM work across many independent
//! 128x128 PCM banks; on the simulator host the same tiling is a
//! parallelization seam. This module turns the execution strategy into a
//! first-class, swappable layer:
//!
//! * [`MvmJob`] — one `nq x nr` score-tile computation over `cp`-wide
//!   packed HVs, plus its physical bank-op accounting.
//! * [`MvmBackend`] — the execution contract: `mvm_scores(&MvmJob)`.
//!   Every implementation must be **bit-identical** to the reference
//!   transfer function (`array::imc_mvm_ref`) — backends change *where*
//!   the arithmetic runs, never *what* it computes (integration-tested in
//!   `rust/tests/backend_equivalence.rs`).
//! * [`RefBackend`] — the scalar reference path.
//! * [`ParallelBackend`] — shards the score tile's query rows across
//!   `std::thread::scope` workers (host-side analogue of bank
//!   parallelism; no external dependencies).
//! * [`PjrtBackend`] (feature `pjrt`) — executes the AOT HLO artifact
//!   through the PJRT runtime.
//! * [`BackendDispatcher`] — owns the utilization-based routing heuristic
//!   that used to live inline in `coordinator::pipeline::mvm_scores`, and
//!   is what the pipelines, the ISA executor and the benches consume.
//!
//! # The two-backend-seam architecture
//!
//! The coordinator has exactly two host hot paths, and each is a
//! first-class swappable seam behind the same dispatcher object:
//!
//! 1. **MVM seam** (this module): `nq x nr` score tiles, contract
//!    [`MvmBackend`], kinds `ref | parallel | pjrt`.
//! 2. **Encode seam** (`crate::encode`): HD encode+pack batches, contract
//!    `encode::EncodeBackend`, kinds `scalar | bitpacked | parallel` —
//!    the word-packed kernels live in `crate::hd::bitpacked`.
//!
//! Both seams share the invariant that every backend is **bit-identical**
//! to its scalar oracle — selection changes host wall time, never results
//! (`rust/tests/backend_equivalence.rs`, `rust/tests/encode_equivalence.rs`)
//! — and both are selected through the `[backend]` config section
//! (`kind`, `encode_kind`, `threads`, `min_utilization`) or the
//! `--backend` / `--encode-backend` / `--threads` CLI flags.

pub mod dispatch;
pub mod parallel;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use dispatch::BackendDispatcher;
pub use parallel::ParallelBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use reference::RefBackend;

use crate::array::{AdcConfig, ARRAY_DIM};
use crate::energy::OpCounts;
use crate::util::error::Result;

/// Which backend the dispatcher routes the hot path to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Scalar rust reference path (always available, bit-exact oracle).
    Reference,
    /// Bank-sharded host-parallel path (default).
    Parallel,
    /// PJRT artifact path (requires the `pjrt` feature + built artifacts).
    Pjrt,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Reference => "ref",
            BackendKind::Parallel => "parallel",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "ref" | "reference" => Ok(BackendKind::Reference),
            "parallel" => Ok(BackendKind::Parallel),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!(
                "unknown backend '{other}' (want ref|parallel|pjrt)"
            )),
        }
    }
}

/// One IMC MVM score-tile job: `nq x nr` scores over `cp`-wide packed HVs.
///
/// `queries` is row-major `nq x cp` (packed query HVs after DAC driving),
/// `refs` is row-major `nr x cp` (stored noisy conductance differences).
/// `cp` must be a multiple of [`ARRAY_DIM`] — the coordinator always pads
/// packed HVs to whole array segments.
#[derive(Clone, Copy, Debug)]
pub struct MvmJob<'a> {
    pub queries: &'a [f32],
    pub nq: usize,
    pub refs: &'a [f32],
    pub nr: usize,
    pub cp: usize,
    pub adc: AdcConfig,
}

impl<'a> MvmJob<'a> {
    pub fn new(
        queries: &'a [f32],
        nq: usize,
        refs: &'a [f32],
        nr: usize,
        cp: usize,
        adc: AdcConfig,
    ) -> Self {
        assert_eq!(queries.len(), nq * cp, "queries shape");
        assert_eq!(refs.len(), nr * cp, "refs shape");
        assert!(cp > 0 && cp % ARRAY_DIM == 0, "cp must be a multiple of {ARRAY_DIM}");
        MvmJob {
            queries,
            nq,
            refs,
            nr,
            cp,
            adc,
        }
    }

    /// Physical array operations this job represents: every real query
    /// vector drives every 128-row x 128-col bank holding candidate rows
    /// (independent of which host backend executes the math).
    pub fn bank_ops(&self) -> u64 {
        let row_tiles = self.nr.div_ceil(ARRAY_DIM) as u64;
        let col_tiles = (self.cp / ARRAY_DIM) as u64;
        self.nq as u64 * row_tiles * col_tiles
    }

    /// Charge this job's physical op count to an accumulator.
    pub fn count_ops(&self, ops: &mut OpCounts) {
        ops.mvm_ops += self.bank_ops();
    }
}

/// The execution contract every backend implements.
///
/// Implementations must produce scores **bit-identical** to
/// [`crate::array::imc_mvm_ref`] on the same job (the PJRT artifact is
/// bit-exact by the pow-2 ADC full-scale argument; the parallel backend by
/// running the identical scalar kernel per shard).
///
/// `Send + Sync` are part of the contract: the coordinator's shard layer
/// fans one query batch out across scoped threads that all execute jobs
/// through one shared [`BackendDispatcher`], so a backend with
/// single-thread interior mutability must synchronize it internally
/// (`Mutex`, not `RefCell`).
pub trait MvmBackend: Send + Sync {
    /// Short stable identifier (telemetry / CLI echo).
    fn name(&self) -> &'static str;

    /// Execute one score-tile job, returning `nq * nr` row-major scores.
    fn mvm_scores(&self, job: &MvmJob) -> Result<Vec<f32>>;

    /// Whether this backend can execute the job at all (e.g. the PJRT
    /// backend needs a compiled artifact for the job's packed width). The
    /// dispatcher routes unsupported jobs to the scalar fallback
    /// regardless of the utilization threshold.
    fn supports(&self, _job: &MvmJob) -> bool {
        true
    }

    /// Fraction of the backend's padded compute tile holding real scores
    /// for this job, in [0, 1]. The dispatcher falls back to the reference
    /// path below its `min_utilization` threshold. Backends without
    /// padding report 1.0.
    fn utilization(&self, _job: &MvmJob) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [BackendKind::Reference, BackendKind::Parallel, BackendKind::Pjrt] {
            assert_eq!(BackendKind::from_name(k.name()).unwrap(), k);
        }
        assert_eq!(BackendKind::from_name("reference").unwrap(), BackendKind::Reference);
        assert!(BackendKind::from_name("gpu").is_err());
    }

    #[test]
    fn job_bank_ops_formula() {
        let q = vec![0f32; 3 * 256];
        let g = vec![0f32; 300 * 256];
        let job = MvmJob::new(&q, 3, &g, 300, 256, AdcConfig::ideal());
        // 3 queries x ceil(300/128)=3 row tiles x 256/128=2 col tiles.
        assert_eq!(job.bank_ops(), 3 * 3 * 2);
        let mut ops = OpCounts::default();
        job.count_ops(&mut ops);
        assert_eq!(ops.mvm_ops, 18);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn job_rejects_untiled_cp() {
        let q = vec![0f32; 100];
        let g = vec![0f32; 100];
        MvmJob::new(&q, 1, &g, 1, 100, AdcConfig::ideal());
    }
}
