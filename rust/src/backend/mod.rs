//! Pluggable MVM execution backends (the coordinator's hot path).
//!
//! SpecPCM's speedups come from tiling MVM work across many independent
//! 128x128 PCM banks; on the simulator host the same tiling is a
//! parallelization seam. This module turns the execution strategy into a
//! first-class, swappable layer:
//!
//! * [`MvmJob`] — one `nq x nr` score-tile computation over `cp`-wide
//!   packed HVs, plus its physical bank-op accounting. A job is either
//!   **dense** (`refs` is exactly `nr` gathered rows) or **segmented**
//!   ([`MvmJob::segmented`]): `refs` borrows one large bucket-contiguous
//!   panel and `segments` names the candidate row ranges, so serving
//!   never copies reference rows out of the programmed library.
//! * [`MvmBackend`] — the execution contract:
//!   `mvm_scores_into(&MvmJob, &mut [f32])` (the allocating
//!   `mvm_scores` wrapper is provided). Callers own the output buffer and
//!   reuse it across batches — the hot serving loop performs zero
//!   per-batch reference copies and zero per-batch score allocations.
//!   Every implementation must be **bit-identical** to the reference
//!   transfer function (`array::imc_mvm_ref`) on the gathered equivalent
//!   of the job — backends change *where* the arithmetic runs, never
//!   *what* it computes (integration-tested in
//!   `rust/tests/backend_equivalence.rs` and
//!   `rust/tests/segmented_equivalence.rs`).
//! * [`RefBackend`] — the scalar reference path.
//! * [`ParallelBackend`] — shards the score tile across
//!   `std::thread::scope` workers in 2-D (host-side analogue of bank
//!   parallelism; no external dependencies): query rows when the batch is
//!   wide, tile-aligned reference-row stripes when `nq < threads` so a
//!   single front-door query still fans out across the candidate span.
//! * [`PjrtBackend`] (feature `pjrt`) — executes the AOT HLO artifact
//!   through the PJRT runtime.
//! * [`BackendDispatcher`] — owns the utilization-based routing heuristic
//!   that used to live inline in `coordinator::pipeline::mvm_scores`, and
//!   is what the pipelines, the ISA executor and the benches consume.
//!
//! # The two-backend-seam architecture
//!
//! The coordinator has exactly two host hot paths, and each is a
//! first-class swappable seam behind the same dispatcher object:
//!
//! 1. **MVM seam** (this module): `nq x nr` score tiles, contract
//!    [`MvmBackend`], kinds `ref | parallel | pjrt`.
//! 2. **Encode seam** (`crate::encode`): HD encode+pack batches, contract
//!    `encode::EncodeBackend`, kinds `scalar | bitpacked | parallel` —
//!    the word-packed kernels live in `crate::hd::bitpacked`.
//!
//! Both seams share the invariant that every backend is **bit-identical**
//! to its scalar oracle — selection changes host wall time, never results
//! (`rust/tests/backend_equivalence.rs`, `rust/tests/encode_equivalence.rs`)
//! — and both are selected through the `[backend]` config section
//! (`kind`, `encode_kind`, `threads`, `min_utilization`, `stripe_rows`)
//! or the `--backend` / `--encode-backend` / `--threads` /
//! `--stripe-rows` CLI flags.
//!
//! Since PR 6 "the reference transfer function" means the **lane-ordered**
//! oracle (`crate::array::transfer` module docs): eight `k % 8` partial
//! sum lanes per 128-column tile, reduced by a fixed binary tree. Backends
//! inherit the contract for free by running the blocked kernel, which
//! shares `lane_tile_dot` with the oracle's independently-coded scalar
//! loops.

pub mod dispatch;
pub mod parallel;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use dispatch::BackendDispatcher;
pub use parallel::ParallelBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use reference::RefBackend;

use crate::array::{AdcConfig, ARRAY_DIM};
use crate::energy::OpCounts;
use crate::util::error::Result;

/// Which backend the dispatcher routes the hot path to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Scalar rust reference path (always available, bit-exact oracle).
    Reference,
    /// Bank-sharded host-parallel path (default).
    Parallel,
    /// PJRT artifact path (requires the `pjrt` feature + built artifacts).
    Pjrt,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Reference => "ref",
            BackendKind::Parallel => "parallel",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "ref" | "reference" => Ok(BackendKind::Reference),
            "parallel" => Ok(BackendKind::Parallel),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!(
                "unknown backend '{other}' (want ref|parallel|pjrt)"
            )),
        }
    }
}

/// One IMC MVM score-tile job: `nq x nr` scores over `cp`-wide packed HVs.
///
/// `queries` is row-major `nq x cp` (packed query HVs after DAC driving).
/// For a **dense** job ([`MvmJob::new`], `segments` empty) `refs` is
/// row-major `nr x cp` (stored noisy conductance differences). For a
/// **segmented** job ([`MvmJob::segmented`]) `refs` borrows a whole
/// bucket-contiguous panel and `segments` names the candidate row ranges
/// into it, concatenated left-to-right into the `nr` output columns — the
/// zero-copy serving shape. `cp` must be a multiple of [`ARRAY_DIM`] —
/// the coordinator always pads packed HVs to whole array segments.
#[derive(Clone, Copy, Debug)]
pub struct MvmJob<'a> {
    pub queries: &'a [f32],
    pub nq: usize,
    pub refs: &'a [f32],
    /// Candidate reference rows scored (sum of segment lengths for
    /// segmented jobs) — the score matrix is `nq x nr` either way.
    pub nr: usize,
    pub cp: usize,
    pub adc: AdcConfig,
    /// Physical row ranges of `refs` making up the candidate set, in
    /// output-column order. Empty means a dense job over rows `0..nr`.
    pub segments: &'a [std::ops::Range<usize>],
    /// Caller attests `queries` already passed through the DAC
    /// ([`crate::array::dac_quantize`]). The DAC is idempotent on its own
    /// output, so this flag never changes scores — it only lets backends
    /// skip the redundant re-quantization pass (and its allocation) when a
    /// batch loop hoisted it, as the engine's `ScoreScratch` does.
    pub dac_applied: bool,
}

impl<'a> MvmJob<'a> {
    pub fn new(
        queries: &'a [f32],
        nq: usize,
        refs: &'a [f32],
        nr: usize,
        cp: usize,
        adc: AdcConfig,
    ) -> Self {
        assert_eq!(queries.len(), nq * cp, "queries shape");
        assert_eq!(refs.len(), nr * cp, "refs shape");
        assert!(cp > 0 && cp % ARRAY_DIM == 0, "cp must be a multiple of {ARRAY_DIM}");
        MvmJob {
            queries,
            nq,
            refs,
            nr,
            cp,
            adc,
            segments: &[],
            dac_applied: false,
        }
    }

    /// A zero-copy job over `segments` of a borrowed row-major `panel`
    /// (`panel.len() / cp` rows). The candidate count `nr` — and with it
    /// the [`MvmJob::bank_ops`] charge — is the summed segment length, so
    /// accounting is identical to gathering the same rows into a dense
    /// job. Empty segments are legal (an empty bucket contributes no
    /// output columns).
    pub fn segmented(
        queries: &'a [f32],
        nq: usize,
        panel: &'a [f32],
        segments: &'a [std::ops::Range<usize>],
        cp: usize,
        adc: AdcConfig,
    ) -> Self {
        assert_eq!(queries.len(), nq * cp, "queries shape");
        assert!(cp > 0 && cp % ARRAY_DIM == 0, "cp must be a multiple of {ARRAY_DIM}");
        assert_eq!(panel.len() % cp, 0, "panel shape");
        let panel_rows = panel.len() / cp;
        let mut nr = 0usize;
        for s in segments {
            assert!(s.start <= s.end && s.end <= panel_rows, "segment {s:?} out of panel");
            nr += s.len();
        }
        MvmJob {
            queries,
            nq,
            refs: panel,
            nr,
            cp,
            adc,
            segments,
            dac_applied: false,
        }
    }

    /// Mark `queries` as already DAC-quantized (see
    /// [`MvmJob::dac_applied`]). Only pass buffers that really went
    /// through [`crate::array::dac_quantize`]; the attestation is
    /// score-neutral for such buffers by DAC idempotence.
    pub fn with_dac_applied(mut self) -> Self {
        self.dac_applied = true;
        self
    }

    /// The candidate row ranges this job scores: its `segments`, or the
    /// whole dense range for gathered jobs. `storage` is written only in
    /// the dense case (borrow it from the caller's stack).
    pub fn effective_segments<'s>(
        &self,
        storage: &'s mut [std::ops::Range<usize>; 1],
    ) -> &'s [std::ops::Range<usize>]
    where
        'a: 's,
    {
        if self.segments.is_empty() {
            storage[0] = 0..self.nr;
            &storage[..]
        } else {
            self.segments
        }
    }

    /// Physical array operations this job represents: every real query
    /// vector drives every 128-row x 128-col bank holding candidate rows
    /// (independent of which host backend executes the math).
    pub fn bank_ops(&self) -> u64 {
        let row_tiles = self.nr.div_ceil(ARRAY_DIM) as u64;
        let col_tiles = (self.cp / ARRAY_DIM) as u64;
        self.nq as u64 * row_tiles * col_tiles
    }

    /// Charge this job's physical op count to an accumulator.
    pub fn count_ops(&self, ops: &mut OpCounts) {
        ops.mvm_ops += self.bank_ops();
    }
}

/// The execution contract every backend implements.
///
/// Implementations must produce scores **bit-identical** to
/// [`crate::array::imc_mvm_ref`] on the gathered equivalent of the job
/// (the PJRT artifact is bit-exact by the pow-2 ADC full-scale argument;
/// the parallel backend by running the identical blocked kernel per
/// shard; the blocked kernel by preserving each output's accumulation
/// order — see [`crate::array::imc_mvm_blocked_into`]).
///
/// `Send + Sync` are part of the contract: the coordinator's shard layer
/// fans one query batch out across scoped threads that all execute jobs
/// through one shared [`BackendDispatcher`], so a backend with
/// single-thread interior mutability must synchronize it internally
/// (`Mutex`, not `RefCell`).
pub trait MvmBackend: Send + Sync {
    /// Short stable identifier (telemetry / CLI echo).
    fn name(&self) -> &'static str;

    /// Execute one score-tile job, writing the `nq * nr` row-major scores
    /// into the caller-owned `out` (must be exactly `nq * nr` long). This
    /// is the primitive serving loops call so one output buffer is reused
    /// across batches instead of allocated per job.
    fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()>;

    /// Execute one score-tile job, returning `nq * nr` row-major scores
    /// in a fresh allocation (convenience wrapper over
    /// [`MvmBackend::mvm_scores_into`]).
    fn mvm_scores(&self, job: &MvmJob) -> Result<Vec<f32>> {
        let mut out = vec![0f32; job.nq * job.nr];
        self.mvm_scores_into(job, &mut out)?;
        Ok(out)
    }

    /// Whether this backend can execute the job at all (e.g. the PJRT
    /// backend needs a compiled artifact for the job's packed width). The
    /// dispatcher routes unsupported jobs to the scalar fallback
    /// regardless of the utilization threshold.
    fn supports(&self, _job: &MvmJob) -> bool {
        true
    }

    /// Fraction of the backend's padded compute tile holding real scores
    /// for this job, in [0, 1]. The dispatcher falls back to the reference
    /// path below its `min_utilization` threshold. Backends without
    /// padding report 1.0.
    fn utilization(&self, _job: &MvmJob) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [BackendKind::Reference, BackendKind::Parallel, BackendKind::Pjrt] {
            assert_eq!(BackendKind::from_name(k.name()).unwrap(), k);
        }
        assert_eq!(BackendKind::from_name("reference").unwrap(), BackendKind::Reference);
        assert!(BackendKind::from_name("gpu").is_err());
    }

    #[test]
    fn job_bank_ops_formula() {
        let q = vec![0f32; 3 * 256];
        let g = vec![0f32; 300 * 256];
        let job = MvmJob::new(&q, 3, &g, 300, 256, AdcConfig::ideal());
        // 3 queries x ceil(300/128)=3 row tiles x 256/128=2 col tiles.
        assert_eq!(job.bank_ops(), 3 * 3 * 2);
        let mut ops = OpCounts::default();
        job.count_ops(&mut ops);
        assert_eq!(ops.mvm_ops, 18);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn job_rejects_untiled_cp() {
        let q = vec![0f32; 100];
        let g = vec![0f32; 100];
        MvmJob::new(&q, 1, &g, 1, 100, AdcConfig::ideal());
    }

    #[test]
    fn segmented_job_counts_summed_rows() {
        let q = vec![0f32; 3 * 256];
        let panel = vec![0f32; 400 * 256];
        let segs = vec![0..100, 150..150, 200..400];
        let job = MvmJob::segmented(&q, 3, &panel, &segs, 256, AdcConfig::ideal());
        assert_eq!(job.nr, 300);
        // Identical bank-op charge to the gathered 300-row job: the tiling
        // formula sees only the candidate count, never the layout.
        assert_eq!(job.bank_ops(), 3 * 3 * 2);
        let mut storage = [0..0];
        assert_eq!(job.effective_segments(&mut storage), &segs[..]);

        let dense = MvmJob::new(&q, 3, &panel[..300 * 256], 300, 256, AdcConfig::ideal());
        let mut storage = [0..0];
        assert_eq!(dense.effective_segments(&mut storage), &[0..300]);
    }

    #[test]
    #[should_panic(expected = "out of panel")]
    fn segmented_job_rejects_out_of_panel_range() {
        let q = vec![0f32; 128];
        let panel = vec![0f32; 4 * 128];
        MvmJob::segmented(&q, 1, &panel, &[2..5], 128, AdcConfig::ideal());
    }
}
