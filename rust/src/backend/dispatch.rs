//! Backend selection + the utilization routing heuristic.
//!
//! The heuristic formerly inlined in `coordinator::pipeline::mvm_scores`:
//! a fixed-geometry backend (the PJRT artifact's `B x R` tile) mostly
//! multiplies padding zeros on small jobs, so below a padded-utilization
//! threshold the bit-identical scalar path wins (measured crossover ~30%,
//! EXPERIMENTS.md §Perf L3). The dispatcher owns that decision for *any*
//! primary backend via [`MvmBackend::utilization`], and is the single
//! object the pipelines, ISA executor and benches execute MVM jobs
//! through.
//!
//! The dispatcher also routes the **encode seam**: it carries the
//! configured [`EncodeBackend`] (`encode/`) and is what the HD frontend
//! executes [`EncodeJob`]s through — one object, both hot paths.

#[cfg(feature = "pjrt")]
use std::sync::{Arc, Mutex};

use crate::config::SpecPcmConfig;
use crate::encode::{backend_of_kind, EncodeBackend, EncodeJob, EncodeKind};
use crate::energy::OpCounts;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::util::error::Result;

use super::parallel::ParallelBackend;
use super::reference::RefBackend;
use super::{BackendKind, MvmBackend, MvmJob};

#[cfg(feature = "pjrt")]
use super::pjrt::PjrtBackend;

/// Routes each [`MvmJob`] to the primary backend or the scalar fallback,
/// charging the job's physical op count either way, and each [`EncodeJob`]
/// to the configured encode backend.
pub struct BackendDispatcher {
    primary: Box<dyn MvmBackend>,
    fallback: RefBackend,
    min_utilization: f64,
    encode: Box<dyn EncodeBackend>,
    /// Shared PJRT runtime handle when the primary is the artifact
    /// backend — the HD frontend uses it for the encoder artifact.
    #[cfg(feature = "pjrt")]
    runtime: Option<Arc<Mutex<Runtime>>>,
}

impl BackendDispatcher {
    /// MVM backend + scalar encode; use [`Self::with_encode_kind`] or
    /// [`Self::from_config`] to pick a faster encode path.
    pub fn new(primary: Box<dyn MvmBackend>, min_utilization: f64) -> Self {
        BackendDispatcher {
            primary,
            fallback: RefBackend,
            min_utilization,
            encode: backend_of_kind(EncodeKind::Scalar, 0),
            #[cfg(feature = "pjrt")]
            runtime: None,
        }
    }

    /// Pure scalar-reference dispatcher (tests, deterministic defaults):
    /// scalar MVM *and* scalar encode — the all-oracle configuration.
    pub fn reference() -> Self {
        BackendDispatcher::new(Box::new(RefBackend), 0.0)
    }

    /// Bank-sharded parallel MVM + spectra-sharded parallel encode
    /// (`threads = 0` auto-detects).
    pub fn parallel(threads: usize) -> Self {
        BackendDispatcher::new(Box::new(ParallelBackend::new(threads)), 0.0)
            .with_encode_kind(EncodeKind::Parallel, threads)
    }

    /// Swap the encode backend (builder style); results are bit-identical
    /// for every kind, only host wall time changes.
    pub fn with_encode_kind(mut self, kind: EncodeKind, threads: usize) -> Self {
        self.encode = backend_of_kind(kind, threads);
        self
    }

    /// PJRT dispatcher sharing the runtime handle with the frontend.
    #[cfg(feature = "pjrt")]
    pub fn with_pjrt(backend: PjrtBackend, min_utilization: f64) -> Self {
        let runtime = backend.shared_runtime();
        let mut d = BackendDispatcher::new(Box::new(backend), min_utilization);
        d.runtime = Some(runtime);
        d
    }

    /// Build the dispatcher a config asks for. `kind = "pjrt"` degrades to
    /// the reference backend (with a note on stderr) when the `pjrt`
    /// feature is off, artifacts are absent, or `use_artifacts = false` —
    /// results are bit-identical either way, only host speed differs.
    pub fn from_config(cfg: &SpecPcmConfig) -> Self {
        let min_u = cfg.backend.min_utilization;
        let d = match cfg.backend.kind {
            BackendKind::Reference => BackendDispatcher::new(Box::new(RefBackend), min_u),
            BackendKind::Parallel => BackendDispatcher::new(
                Box::new(
                    ParallelBackend::new(cfg.backend.threads)
                        .with_stripe_rows(cfg.backend.stripe_rows),
                ),
                min_u,
            ),
            BackendKind::Pjrt => Self::pjrt_or_fallback(cfg, min_u),
        };
        d.with_encode_kind(cfg.backend.encode_kind, cfg.backend.threads)
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_or_fallback(cfg: &SpecPcmConfig, min_u: f64) -> Self {
        if cfg.use_artifacts {
            match PjrtBackend::load(&cfg.artifacts_dir) {
                Ok(b) => return Self::with_pjrt(b, min_u),
                Err(e) => eprintln!("backend: pjrt unavailable ({e}); using reference path"),
            }
        } else {
            eprintln!("backend: use_artifacts = false; using reference path");
        }
        BackendDispatcher::new(Box::new(RefBackend), min_u)
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_or_fallback(_cfg: &SpecPcmConfig, min_u: f64) -> Self {
        eprintln!("backend: built without the `pjrt` feature; using reference path");
        BackendDispatcher::new(Box::new(RefBackend), min_u)
    }

    /// Name of the configured primary backend.
    pub fn primary_name(&self) -> &'static str {
        self.primary.name()
    }

    /// The padded-utilization floor below which jobs fall through to the
    /// scalar backend. The serving front door derives its tile-fill
    /// target from this same heuristic (`coordinator::scheduler`), so
    /// coalesced batches clear the routing bar they are sized for.
    pub fn min_utilization(&self) -> f64 {
        self.min_utilization
    }

    /// Name of the configured encode backend.
    pub fn encode_name(&self) -> &'static str {
        self.encode.name()
    }

    /// Execute one encode+pack batch through the configured encode
    /// backend, writing row-major packed f32 rows into `out`. No routing
    /// heuristic: encode jobs have no padded-tile geometry, so the
    /// configured backend always runs (all kinds are bit-identical).
    pub fn encode_pack(&self, job: &EncodeJob, out: &mut [f32]) -> Result<()> {
        self.encode.encode_pack(job, out)
    }

    /// Shared PJRT runtime handle, when the primary backend carries one.
    #[cfg(feature = "pjrt")]
    pub fn runtime(&self) -> Option<&Arc<Mutex<Runtime>>> {
        self.runtime.as_ref()
    }

    /// Execute one job: charge its physical op count, then run it on the
    /// primary backend when it supports the job and the job fills enough
    /// of the backend's compute tile, else on the bit-identical scalar
    /// fallback. The `supports` check is structural (e.g. no compiled
    /// artifact for this packed width) and applies even at
    /// `min_utilization = 0`.
    pub fn execute(&self, job: &MvmJob, ops: &mut OpCounts) -> Result<Vec<f32>> {
        let mut out = vec![0f32; job.nq * job.nr];
        self.execute_into(job, &mut out, ops)?;
        Ok(out)
    }

    /// [`BackendDispatcher::execute`] writing into a caller-owned buffer
    /// (exactly `nq * nr` long) — the zero-allocation primitive the
    /// serving hot loop drives segmented jobs through, reusing one score
    /// buffer across groups and batches. Routing and op charging are
    /// identical to `execute`.
    pub fn execute_into(&self, job: &MvmJob, out: &mut [f32], ops: &mut OpCounts) -> Result<()> {
        job.count_ops(ops);
        if self.primary.supports(job) && self.primary.utilization(job) >= self.min_utilization {
            self.primary.mvm_scores_into(job, out)
        } else {
            self.fallback.mvm_scores_into(job, out)
        }
    }
}

impl Default for BackendDispatcher {
    fn default() -> Self {
        BackendDispatcher::reference()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::array::AdcConfig;
    use crate::util::Rng;

    /// A fake padded backend: reports fixed support/utilization, returns
    /// a sentinel so tests can see which path ran.
    struct Padded {
        supported: bool,
        util: f64,
    }

    impl MvmBackend for Padded {
        fn name(&self) -> &'static str {
            "padded"
        }

        fn supports(&self, _job: &MvmJob) -> bool {
            self.supported
        }

        fn utilization(&self, _job: &MvmJob) -> f64 {
            self.util
        }

        fn mvm_scores_into(&self, job: &MvmJob, out: &mut [f32]) -> Result<()> {
            assert_eq!(out.len(), job.nq * job.nr);
            out.fill(42.0);
            Ok(())
        }
    }

    fn small_job(buf: &mut (Vec<f32>, Vec<f32>)) -> MvmJob<'_> {
        let mut rng = Rng::new(3);
        buf.0 = (0..2 * 128).map(|_| rng.range_i64(-3, 3) as f32).collect();
        buf.1 = (0..5 * 128).map(|_| rng.range_i64(-3, 3) as f32).collect();
        MvmJob::new(&buf.0, 2, &buf.1, 5, 128, AdcConfig::ideal())
    }

    #[test]
    fn routes_by_utilization_threshold() {
        let mut buf = (vec![], vec![]);
        let job = small_job(&mut buf);
        let mut ops = OpCounts::default();

        let padded = |supported, util| {
            Box::new(Padded { supported, util }) as Box<dyn MvmBackend>
        };

        let high = BackendDispatcher::new(padded(true, 0.9), 0.3);
        assert_eq!(high.execute(&job, &mut ops).unwrap()[0], 42.0);

        let low = BackendDispatcher::new(padded(true, 0.1), 0.3);
        let scores = low.execute(&job, &mut ops).unwrap();
        // Fallback ran: real scores, not the sentinel fill.
        assert_eq!(scores, RefBackend.mvm_scores(&job).unwrap());

        // Unsupported jobs route to the fallback even at threshold 0 —
        // a zeroed min_utilization must not defeat the structural check.
        let unsupported = BackendDispatcher::new(padded(false, 1.0), 0.0);
        let scores = unsupported.execute(&job, &mut ops).unwrap();
        assert_eq!(scores, RefBackend.mvm_scores(&job).unwrap());
    }

    #[test]
    fn execute_into_reuses_buffer_and_matches_execute() {
        let mut rng = Rng::new(9);
        let cp = 256;
        let panel: Vec<f32> = (0..40 * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let q: Vec<f32> = (0..2 * cp).map(|_| rng.range_i64(-3, 3) as f32).collect();
        let segs = vec![0..10, 25..40];
        let job = MvmJob::segmented(&q, 2, &panel, &segs, cp, AdcConfig::new(6, 512.0));

        let mut ops = OpCounts::default();
        let want = BackendDispatcher::reference().execute(&job, &mut ops).unwrap();

        // One poisoned buffer reused across repeated batches: every call
        // overwrites it fully and charges the job again.
        let mut out = vec![f32::NAN; job.nq * job.nr];
        let mut ops_into = OpCounts::default();
        for rep in 1..=3u64 {
            BackendDispatcher::parallel(2)
                .execute_into(&job, &mut out, &mut ops_into)
                .unwrap();
            assert_eq!(out, want, "rep {rep}");
            assert_eq!(ops_into.mvm_ops, rep * job.bank_ops());
        }
    }

    #[test]
    fn execute_counts_ops_regardless_of_route() {
        let mut buf = (vec![], vec![]);
        let job = small_job(&mut buf);
        let mut ops = OpCounts::default();
        BackendDispatcher::reference().execute(&job, &mut ops).unwrap();
        assert_eq!(ops.mvm_ops, job.bank_ops());
        BackendDispatcher::parallel(4).execute(&job, &mut ops).unwrap();
        assert_eq!(ops.mvm_ops, 2 * job.bank_ops());
    }

    #[test]
    fn encode_routing_honours_kind_and_stays_bit_identical() {
        use crate::hd::{BitItemMemory, ItemMemory};

        assert_eq!(BackendDispatcher::reference().encode_name(), "scalar");
        assert_eq!(BackendDispatcher::parallel(2).encode_name(), "parallel");
        let d = BackendDispatcher::reference().with_encode_kind(EncodeKind::Bitpacked, 0);
        assert_eq!(d.encode_name(), "bitpacked");

        let im = ItemMemory::generate(77, 32, 8, 512);
        let bits = BitItemMemory::from_item_memory(&im);
        let levels: Vec<Vec<u16>> = (0..3)
            .map(|i| (0..32).map(|j| ((i * j) % 8) as u16).collect())
            .collect();
        let job = EncodeJob::new(&levels, &im, &bits, 3);
        let mut want = vec![0f32; job.out_len()];
        BackendDispatcher::reference().encode_pack(&job, &mut want).unwrap();
        for disp in [
            BackendDispatcher::parallel(2),
            BackendDispatcher::reference().with_encode_kind(EncodeKind::Bitpacked, 0),
        ] {
            let mut got = vec![f32::NAN; job.out_len()];
            disp.encode_pack(&job, &mut got).unwrap();
            assert_eq!(got, want, "encode backend {}", disp.encode_name());
        }
    }

    #[test]
    fn from_config_honours_kind() {
        let mut cfg = SpecPcmConfig::paper_clustering();
        cfg.backend.kind = BackendKind::Reference;
        assert_eq!(BackendDispatcher::from_config(&cfg).primary_name(), "ref");
        cfg.backend.kind = BackendKind::Parallel;
        assert_eq!(
            BackendDispatcher::from_config(&cfg).primary_name(),
            "parallel"
        );
        // pjrt degrades to ref when the feature is off / artifacts absent.
        cfg.backend.kind = BackendKind::Pjrt;
        cfg.artifacts_dir = "/nonexistent-artifacts-dir".into();
        assert_eq!(BackendDispatcher::from_config(&cfg).primary_name(), "ref");

        // The encode seam follows its own config key.
        assert_eq!(BackendDispatcher::from_config(&cfg).encode_name(), "parallel");
        cfg.backend.encode_kind = EncodeKind::Bitpacked;
        assert_eq!(BackendDispatcher::from_config(&cfg).encode_name(), "bitpacked");
    }
}
