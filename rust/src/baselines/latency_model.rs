//! Paper-anchored baseline latencies (Tables 2 and 3).
//!
//! These are the latencies the paper *measured* on its own testbeds (Intel
//! i7-11700K, RTX 4090, the SpecHD FPGA, and the RRAM/3D-NAND IMC designs);
//! we cannot re-measure them here, so the speedup benches anchor the
//! baseline columns to these published numbers and compare them against our
//! *simulated* SpecPCM latency, extrapolated to the paper's dataset sizes
//! (DESIGN.md §5). Latency scaling across dataset sizes is modeled linear
//! in the number of pairwise comparisons.

/// One baseline tool's published latency on one dataset.
#[derive(Clone, Copy, Debug)]
pub struct BaselineEntry {
    pub tool: &'static str,
    pub hardware: &'static str,
    pub dataset: &'static str,
    pub latency_s: f64,
}

/// Table 2 — clustering baselines.
pub const CLUSTERING_BASELINES: [BaselineEntry; 10] = [
    BaselineEntry { tool: "Falcon", hardware: "CPU", dataset: "PXD001468", latency_s: 573.0 },
    BaselineEntry { tool: "msCRUSH", hardware: "CPU", dataset: "PXD001468", latency_s: 358.0 },
    BaselineEntry { tool: "HyperSpec", hardware: "GPU", dataset: "PXD001468", latency_s: 38.0 },
    BaselineEntry { tool: "SpecHD", hardware: "FPGA", dataset: "PXD001468", latency_s: 13.17 },
    BaselineEntry { tool: "SpecPCM(paper)", hardware: "TSMC 40nm", dataset: "PXD001468", latency_s: 5.46 },
    BaselineEntry { tool: "Falcon", hardware: "CPU", dataset: "PXD000561", latency_s: 134.0 * 60.0 },
    BaselineEntry { tool: "msCRUSH", hardware: "CPU", dataset: "PXD000561", latency_s: 42.0 * 60.0 },
    BaselineEntry { tool: "HyperSpec", hardware: "GPU", dataset: "PXD000561", latency_s: 17.0 * 60.0 },
    BaselineEntry { tool: "SpecHD", hardware: "FPGA", dataset: "PXD000561", latency_s: 179.0 },
    BaselineEntry { tool: "SpecPCM(paper)", hardware: "TSMC 40nm", dataset: "PXD000561", latency_s: 98.4 },
];

/// Table 3 — DB-search baselines.
pub const SEARCH_BASELINES: [BaselineEntry; 9] = [
    BaselineEntry { tool: "ANN-SoLo", hardware: "CPU-GPU", dataset: "iPRG2012", latency_s: 6.45 },
    BaselineEntry { tool: "HyperOMS", hardware: "GPU", dataset: "iPRG2012", latency_s: 2.08 },
    BaselineEntry { tool: "RRAM", hardware: "130nm IMC", dataset: "iPRG2012", latency_s: 1.22 },
    BaselineEntry { tool: "3D NAND", hardware: "ASAP 7nm", dataset: "iPRG2012", latency_s: 0.145 },
    BaselineEntry { tool: "SpecPCM(paper)", hardware: "TSMC 40nm", dataset: "iPRG2012", latency_s: 0.049 },
    BaselineEntry { tool: "ANN-SoLo", hardware: "CPU-GPU", dataset: "HEK293", latency_s: 45.14 },
    BaselineEntry { tool: "HyperOMS", hardware: "GPU", dataset: "HEK293", latency_s: 10.4 },
    BaselineEntry { tool: "ANN-SoLo(ref)", hardware: "CPU-GPU", dataset: "HEK293", latency_s: 45.14 },
    BaselineEntry { tool: "SpecPCM(paper)", hardware: "TSMC 40nm", dataset: "HEK293", latency_s: 0.316 },
];

/// Baselines for a dataset, slowest first (the speedup denominator is the
/// first entry, matching the paper's "1x" convention).
pub fn clustering_for(dataset: &str) -> Vec<BaselineEntry> {
    let mut v: Vec<BaselineEntry> = CLUSTERING_BASELINES
        .iter()
        .filter(|b| b.dataset == dataset)
        .copied()
        .collect();
    v.sort_by(|a, b| b.latency_s.total_cmp(&a.latency_s));
    v
}

pub fn search_for(dataset: &str) -> Vec<BaselineEntry> {
    let mut v: Vec<BaselineEntry> = SEARCH_BASELINES
        .iter()
        .filter(|b| b.dataset == dataset && b.tool != "ANN-SoLo(ref)")
        .copied()
        .collect();
    v.sort_by(|a, b| b.latency_s.total_cmp(&a.latency_s));
    v
}

/// Paper speedups for cross-checking our reproduction of the table math.
pub fn paper_speedup(dataset: &str, tool: &str) -> Option<f64> {
    match (dataset, tool) {
        ("PXD001468", "SpecPCM(paper)") => Some(104.94),
        ("PXD000561", "SpecPCM(paper)") => Some(81.7),
        ("iPRG2012", "SpecPCM(paper)") => Some(131.63),
        ("HEK293", "SpecPCM(paper)") => Some(142.84),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_speedups_reproduce() {
        // Paper Table 2: speedup = slowest baseline / tool latency.
        for ds in ["PXD001468", "PXD000561"] {
            let rows = clustering_for(ds);
            let base = rows[0].latency_s;
            let spec = rows.iter().find(|r| r.tool == "SpecPCM(paper)").unwrap();
            let speedup = base / spec.latency_s;
            let expected = paper_speedup(ds, "SpecPCM(paper)").unwrap();
            assert!(
                (speedup - expected).abs() / expected < 0.01,
                "{ds}: {speedup} vs {expected}"
            );
        }
    }

    #[test]
    fn table3_speedups_reproduce() {
        for ds in ["iPRG2012", "HEK293"] {
            let rows = search_for(ds);
            let base = rows[0].latency_s;
            let spec = rows.iter().find(|r| r.tool == "SpecPCM(paper)").unwrap();
            let speedup = base / spec.latency_s;
            let expected = paper_speedup(ds, "SpecPCM(paper)").unwrap();
            assert!(
                (speedup - expected).abs() / expected < 0.01,
                "{ds}: {speedup} vs {expected}"
            );
        }
    }

    #[test]
    fn slowest_first_ordering() {
        let rows = clustering_for("PXD001468");
        assert_eq!(rows[0].tool, "Falcon");
        assert_eq!(rows.last().unwrap().tool, "SpecPCM(paper)");
    }

    #[test]
    fn nand_faster_than_rram() {
        // Table 3 ordering among prior IMC designs.
        let rows = search_for("iPRG2012");
        let rram = rows.iter().find(|r| r.tool == "RRAM").unwrap();
        let nand = rows.iter().find(|r| r.tool == "3D NAND").unwrap();
        assert!(nand.latency_s < rram.latency_s);
    }
}
