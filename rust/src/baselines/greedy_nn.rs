//! Falcon-style baseline: greedy nearest-neighbor clustering on float
//! vectors [18]. Spectra stream in; each joins the first existing cluster
//! whose representative is within the cosine threshold, else founds a new
//! cluster. Fast and simple, but order-dependent and purity-limited — the
//! behaviour Fig. 9 shows for falcon relative to HyperSpec/SpecPCM.

use super::cosine;

/// Cluster binned spectra greedily. Returns one label per input vector.
/// `threshold` is the minimum cosine similarity to join a cluster.
pub fn cluster(vectors: &[Vec<f32>], threshold: f32) -> Vec<usize> {
    let mut reps: Vec<Vec<f32>> = Vec::new();
    let mut counts: Vec<usize> = Vec::new();
    let mut labels = Vec::with_capacity(vectors.len());

    for v in vectors {
        let mut best = (usize::MAX, threshold);
        for (c, rep) in reps.iter().enumerate() {
            let s = cosine(v, rep);
            if s >= best.1 {
                best = (c, s);
            }
        }
        match best.0 {
            usize::MAX => {
                reps.push(v.clone());
                counts.push(1);
                labels.push(reps.len() - 1);
            }
            c => {
                // Running-mean representative update.
                let k = counts[c] as f32;
                for (r, &x) in reps[c].iter_mut().zip(v) {
                    *r = (*r * k + x) / (k + 1.0);
                }
                counts[c] += 1;
                labels.push(c);
            }
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn noisy_copy(base: &[f32], rng: &mut Rng, noise: f32) -> Vec<f32> {
        base.iter()
            .map(|&x| (x + noise * rng.gaussian() as f32).max(0.0))
            .collect()
    }

    #[test]
    fn groups_recovered() {
        let mut rng = Rng::new(1);
        let base_a: Vec<f32> = (0..64).map(|_| rng.range_f64(0.0, 10.0) as f32).collect();
        let base_b: Vec<f32> = (0..64).map(|_| rng.range_f64(0.0, 10.0) as f32).collect();
        let mut vectors = Vec::new();
        for _ in 0..5 {
            vectors.push(noisy_copy(&base_a, &mut rng, 0.5));
        }
        for _ in 0..5 {
            vectors.push(noisy_copy(&base_b, &mut rng, 0.5));
        }
        let labels = cluster(&vectors, 0.8);
        for i in 1..5 {
            assert_eq!(labels[0], labels[i]);
        }
        for i in 6..10 {
            assert_eq!(labels[5], labels[i]);
        }
        assert_ne!(labels[0], labels[5]);
    }

    #[test]
    fn high_threshold_all_singletons() {
        let mut rng = Rng::new(2);
        let vectors: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..32).map(|_| rng.range_f64(0.0, 1.0) as f32).collect())
            .collect();
        let labels = cluster(&vectors, 0.9999);
        let uniq: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(uniq.len(), 6);
    }

    #[test]
    fn empty_input() {
        assert!(cluster(&[], 0.5).is_empty());
    }
}
