//! ANN-SoLo-style baseline [5]: exact float cosine similarity search over
//! binned spectra — the quality ceiling in Fig. 10 (highest identifications,
//! highest compute cost).
//!
//! For open-modification search ANN-SoLo scores with the *shifted dot
//! product*: a modified peptide's fragment peaks split into an unshifted
//! set and a set displaced by the modification mass, so the score combines
//! the direct match with the best mass-shift-aligned match
//! ([`search_scores_shifted`]).

use super::cosine;

/// Score one query against all references (targets followed by decoys),
/// returning the cosine score row.
pub fn search_scores(query: &[f32], refs: &[Vec<f32>]) -> Vec<f32> {
    refs.iter().map(|r| cosine(query, r)).collect()
}

/// Batch search: row-major score matrix (queries x refs).
pub fn search_matrix(queries: &[Vec<f32>], refs: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::with_capacity(queries.len() * refs.len());
    for q in queries {
        out.extend(search_scores(q, refs));
    }
    out
}

/// Shift a binned vector left by `bins` positions (peaks displaced by a
/// negative mass delta; out-of-range mass drops off the ends).
pub fn shift_bins(v: &[f32], bins: i64) -> Vec<f32> {
    let n = v.len() as i64;
    (0..n)
        .map(|i| {
            let src = i + bins;
            if (0..n).contains(&src) {
                v[src as usize]
            } else {
                0.0
            }
        })
        .collect()
}

/// ANN-SoLo-style open-modification scores: the *shifted dot product*. A
/// peptide carrying a modification of mass `delta` fragments into an
/// unshifted peak set (fragments missing the modified residue) and a set
/// displaced by `delta`; the open score therefore sums the direct match
/// and the best mass-shift-aligned match — the two sets are disjoint in
/// the reference, so the contributions add:
/// `score = max_delta( cos(q, r) + cos(shift(q, -delta), r) )`,
/// with delta = 0 recovering the plain cosine.
pub fn search_scores_shifted(
    query: &[f32],
    refs: &[Vec<f32>],
    shift_candidates: &[i64],
) -> Vec<f32> {
    let shifted: Vec<Vec<f32>> = shift_candidates
        .iter()
        .map(|&k| shift_bins(query, k))
        .collect();
    refs.iter()
        .map(|r| {
            let direct = cosine(query, r);
            let mut best = direct;
            for s in &shifted {
                let combo = direct + cosine(s, r);
                if combo > best {
                    best = combo;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_scores_one() {
        let q = vec![1.0, 2.0, 3.0];
        let refs = vec![vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]];
        let s = search_scores(&q, &refs);
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!(s[1] < s[0]);
    }

    #[test]
    fn shift_bins_moves_mass() {
        let v = vec![0.0, 1.0, 2.0, 0.0];
        assert_eq!(shift_bins(&v, 1), vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(shift_bins(&v, -1), vec![0.0, 0.0, 1.0, 2.0]);
        assert_eq!(shift_bins(&v, 0), v);
        assert_eq!(shift_bins(&v, 10), vec![0.0; 4]);
    }

    #[test]
    fn shifted_score_recovers_displaced_query() {
        // Reference has peaks at bins 2 and 6; the "modified" query sees
        // the second peak displaced by +2 bins.
        let mut r = vec![0f32; 16];
        r[2] = 1.0;
        r[6] = 1.0;
        let mut q = vec![0f32; 16];
        q[2] = 1.0;
        q[8] = 1.0; // 6 + 2
        let direct = search_scores(&q, &[r.clone()])[0];
        let open = search_scores_shifted(&q, &[r], &[2])[0];
        assert!(open > direct, "shifted alignment helps: {open} vs {direct}");
    }

    #[test]
    fn unmodified_query_unaffected_by_orthogonal_shifts() {
        // When the shifted copy shares no bins with the reference the open
        // score reduces to the direct cosine.
        let q = vec![1.0, 0.0, 0.0, 2.0];
        let direct = search_scores(&q, &[q.clone()])[0];
        let open = search_scores_shifted(&q, &[q.clone()], &[1])[0];
        assert!((open - direct).abs() < 1e-6, "{open} vs {direct}");
    }

    #[test]
    fn matrix_layout() {
        let queries = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let refs = vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]];
        let m = search_matrix(&queries, &refs);
        assert_eq!(m.len(), 6);
        assert!((m[0] - 1.0).abs() < 1e-6); // q0 vs r0
        assert!((m[4] - 1.0).abs() < 1e-6); // q1 vs r1
    }
}
