//! Baseline comparators (paper §IV-A).
//!
//! Two kinds:
//!
//! 1. **Algorithmic baselines** actually run here for *quality* curves:
//!    - [`greedy_nn`] — falcon-style greedy nearest-neighbor clustering on
//!      float-binned spectra,
//!    - [`lsh`] — msCRUSH-style locality-sensitive-hashing clustering,
//!    - [`hd_soft`] — HyperSpec/HyperOMS-style exact binary HD (no device
//!      non-idealities) for clustering and search,
//!    - [`exact`] — ANN-SoLo-style exact cosine DB search (quality ceiling).
//! 2. **Latency anchors** ([`latency_model`]): the paper's *measured*
//!    baseline latencies (Tables 2/3) on their CPU/GPU/FPGA/IMC testbeds,
//!    used to compute the speedup columns — we cannot re-measure an RTX
//!    4090 here (DESIGN.md §5).

pub mod exact;
pub mod greedy_nn;
pub mod hd_soft;
pub mod latency_model;
pub mod lsh;

/// Binned float vector (sqrt-scaled levels) shared by the float baselines.
pub fn levels_to_f32(levels: &[u16]) -> Vec<f32> {
    levels.iter().map(|&v| v as f32).collect()
}

/// Cosine similarity of two float vectors (0 when either is all-zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += (x * y) as f64;
        na += (x * x) as f64;
        nb += (y * y) as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        (dot / (na.sqrt() * nb.sqrt())) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        let a = vec![1.0, 0.0, 1.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        assert_eq!(cosine(&a, &[0.0, 1.0, 0.0]), 0.0);
        assert_eq!(cosine(&a, &[0.0, 0.0, 0.0]), 0.0);
    }
}
