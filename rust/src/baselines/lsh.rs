//! msCRUSH-style baseline: locality-sensitive-hashing clustering [19].
//!
//! Random-hyperplane signatures split spectra into LSH buckets (bands of
//! hash bits); spectra colliding in any band are union-found into one
//! cluster, then each cluster is refined greedily by cosine. Coarser than
//! exact pairwise methods — matching its Fig. 9 position below falcon/
//! HyperSpec.

use crate::util::Rng;

use super::cosine;

struct Dsu(Vec<usize>);

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu((0..n).collect())
    }
    fn find(&mut self, x: usize) -> usize {
        if self.0[x] != x {
            let r = self.find(self.0[x]);
            self.0[x] = r;
        }
        self.0[x]
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.0[ra] = rb;
        }
    }
}

/// LSH clustering parameters: `bands` signature bands of `bits` hyperplane
/// bits each; candidates colliding in a band must still pass `threshold`
/// cosine against the bucket seed to merge.
pub fn cluster(
    vectors: &[Vec<f32>],
    bands: usize,
    bits: usize,
    threshold: f32,
    seed: u64,
) -> Vec<usize> {
    let n = vectors.len();
    if n == 0 {
        return vec![];
    }
    let dim = vectors[0].len();
    let mut rng = Rng::new(seed);

    // Random hyperplanes per band.
    let planes: Vec<Vec<Vec<f32>>> = (0..bands)
        .map(|_| {
            (0..bits)
                .map(|_| (0..dim).map(|_| rng.gaussian() as f32).collect())
                .collect()
        })
        .collect();

    let mut dsu = Dsu::new(n);
    for band in &planes {
        let mut buckets: std::collections::HashMap<u64, Vec<usize>> =
            std::collections::HashMap::new();
        for (i, v) in vectors.iter().enumerate() {
            let mut sig = 0u64;
            for (b, plane) in band.iter().enumerate() {
                let dot: f32 = v.iter().zip(plane).map(|(x, p)| x * p).sum();
                if dot >= 0.0 {
                    sig |= 1 << b;
                }
            }
            buckets.entry(sig).or_default().push(i);
        }
        for members in buckets.values() {
            // Union members that pass the cosine check against the first.
            let seed_idx = members[0];
            for &m in &members[1..] {
                if cosine(&vectors[seed_idx], &vectors[m]) >= threshold {
                    dsu.union(seed_idx, m);
                }
            }
        }
    }

    // Densify labels.
    let mut labels = vec![0usize; n];
    let mut next = 0;
    let mut map = std::collections::HashMap::new();
    for i in 0..n {
        let r = dsu.find(i);
        let l = *map.entry(r).or_insert_with(|| {
            let v = next;
            next += 1;
            v
        });
        labels[i] = l;
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn near_duplicates_collide() {
        let mut rng = Rng::new(3);
        let base: Vec<f32> = (0..128).map(|_| rng.gaussian() as f32).collect();
        let mut vectors = Vec::new();
        for _ in 0..4 {
            vectors.push(
                base.iter()
                    .map(|&x| x + 0.05 * rng.gaussian() as f32)
                    .collect(),
            );
        }
        // A far-away vector.
        vectors.push((0..128).map(|_| rng.gaussian() as f32).collect());
        let labels = cluster(&vectors, 8, 10, 0.7, 42);
        for i in 1..4 {
            assert_eq!(labels[0], labels[i], "replicas collide");
        }
        assert_ne!(labels[0], labels[4], "outlier separate");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(4);
        let vectors: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..64).map(|_| rng.gaussian() as f32).collect())
            .collect();
        assert_eq!(
            cluster(&vectors, 4, 8, 0.5, 7),
            cluster(&vectors, 4, 8, 0.5, 7)
        );
    }

    #[test]
    fn empty() {
        assert!(cluster(&[], 4, 8, 0.5, 1).is_empty());
    }
}
