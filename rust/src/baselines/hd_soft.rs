//! HyperSpec/HyperOMS-style software HD baseline [6][7]: exact binary HD
//! encoding + exact integer dot products — the paper's GPU tools, minus the
//! GPU. No dimension packing, no DAC/ADC quantization, no PCM noise; this
//! is the quality reference SpecPCM's SLC/MLC curves are compared against
//! in Figs. 9/10.

use crate::cluster::linkage::{complete_linkage, Dendrogram};
use crate::hd::{dot, Hv};

/// Exact HD pairwise-distance matrix (normalized to [0, 2]).
pub fn distance_matrix(hvs: &[Hv]) -> Vec<f32> {
    let n = hvs.len();
    let d = if n > 0 { hvs[0].len() as f32 } else { 1.0 };
    let mut m = vec![0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dist = 1.0 - dot(&hvs[i], &hvs[j]) as f32 / d;
            m[i * n + j] = dist;
            m[j * n + i] = dist;
        }
    }
    m
}

/// HyperSpec-style clustering: exact HD distances + complete linkage.
pub fn cluster(hvs: &[Hv], max_distance: f32) -> Dendrogram {
    let m = distance_matrix(hvs);
    complete_linkage(&m, hvs.len(), max_distance)
}

/// HyperOMS-style search scores: exact dot products of one query against
/// references; returns the score row.
pub fn search_scores(query: &Hv, refs: &[Hv]) -> Vec<f32> {
    refs.iter().map(|r| dot(query, r) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    fn flip_some(hv: &Hv, k: usize, rng: &mut Rng) -> Hv {
        let mut out = hv.clone();
        for i in rng.sample_indices(hv.len(), k) {
            out[i] = -out[i];
        }
        out
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let mut rng = Rng::new(1);
        let hvs: Vec<Hv> = (0..5).map(|_| rand_hv(&mut rng, 512)).collect();
        let m = distance_matrix(&hvs);
        for i in 0..5 {
            assert_eq!(m[i * 5 + i], 0.0);
            for j in 0..5 {
                assert_eq!(m[i * 5 + j], m[j * 5 + i]);
            }
        }
    }

    #[test]
    fn clustering_recovers_structure() {
        let mut rng = Rng::new(2);
        let a = rand_hv(&mut rng, 2048);
        let b = rand_hv(&mut rng, 2048);
        let hvs = vec![
            a.clone(),
            flip_some(&a, 100, &mut rng),
            flip_some(&a, 120, &mut rng),
            b.clone(),
            flip_some(&b, 100, &mut rng),
        ];
        let dend = cluster(&hvs, 0.5);
        let labels = dend.cut(0.5);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn search_ranks_true_match_first() {
        let mut rng = Rng::new(3);
        let q = rand_hv(&mut rng, 2048);
        let refs = vec![
            rand_hv(&mut rng, 2048),
            flip_some(&q, 150, &mut rng), // near-duplicate
            rand_hv(&mut rng, 2048),
        ];
        let scores = search_scores(&q, &refs);
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(best, 1);
    }
}
