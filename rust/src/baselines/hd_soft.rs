//! HyperSpec/HyperOMS-style software HD baseline [6][7]: exact binary HD
//! encoding + exact integer dot products — the paper's GPU tools, minus the
//! GPU. No dimension packing, no DAC/ADC quantization, no PCM noise; this
//! is the quality reference SpecPCM's SLC/MLC curves are compared against
//! in Figs. 9/10.

use crate::cluster::linkage::{complete_linkage, Dendrogram};
use crate::hd::{BitHv, Hv};

/// Exact HD pairwise-distance matrix (normalized to [0, 2]). The O(n^2)
/// dot products run on word-packed [`BitHv`]s (XOR + popcount) — exactly
/// equal to the scalar `hd::dot` since `dot = D - 2 * hamming` is an
/// integer identity, an order of magnitude faster on the host.
pub fn distance_matrix(hvs: &[Hv]) -> Vec<f32> {
    let n = hvs.len();
    let d = if n > 0 { hvs[0].len() as f32 } else { 1.0 };
    let bits: Vec<BitHv> = hvs.iter().map(|hv| BitHv::from_hv(hv)).collect();
    let mut m = vec![0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dist = 1.0 - bits[i].dot(&bits[j]) as f32 / d;
            m[i * n + j] = dist;
            m[j * n + i] = dist;
        }
    }
    m
}

/// HyperSpec-style clustering: exact HD distances + complete linkage.
pub fn cluster(hvs: &[Hv], max_distance: f32) -> Dendrogram {
    let m = distance_matrix(hvs);
    complete_linkage(&m, hvs.len(), max_distance)
}

/// Pack reference HVs once for repeated [`search_scores`] calls (the
/// per-query loops in the search benches would otherwise re-pack the
/// whole library on every call).
pub fn pack_refs(refs: &[Hv]) -> Vec<BitHv> {
    refs.iter().map(|hv| BitHv::from_hv(hv)).collect()
}

/// HyperOMS-style search scores: exact dot products of one query against
/// pre-packed references (popcount path; see [`pack_refs`]); returns the
/// score row.
pub fn search_scores(query: &Hv, refs: &[BitHv]) -> Vec<f32> {
    let q = BitHv::from_hv(query);
    refs.iter().map(|r| q.dot(r) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_hv(rng: &mut Rng, d: usize) -> Hv {
        (0..d).map(|_| rng.pm1()).collect()
    }

    fn flip_some(hv: &Hv, k: usize, rng: &mut Rng) -> Hv {
        let mut out = hv.clone();
        for i in rng.sample_indices(hv.len(), k) {
            out[i] = -out[i];
        }
        out
    }

    #[test]
    fn popcount_path_matches_scalar_dot() {
        let mut rng = Rng::new(7);
        let hvs: Vec<Hv> = (0..4).map(|_| rand_hv(&mut rng, 1000)).collect();
        let m = distance_matrix(&hvs);
        for i in 0..4 {
            for j in 0..4 {
                if i == j {
                    continue;
                }
                let want = 1.0 - crate::hd::dot(&hvs[i], &hvs[j]) as f32 / 1000.0;
                assert_eq!(m[i * 4 + j], want, "({i},{j})");
            }
        }
        let scores = search_scores(&hvs[0], &pack_refs(&hvs[1..]));
        for (k, s) in scores.iter().enumerate() {
            assert_eq!(*s, crate::hd::dot(&hvs[0], &hvs[k + 1]) as f32);
        }
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let mut rng = Rng::new(1);
        let hvs: Vec<Hv> = (0..5).map(|_| rand_hv(&mut rng, 512)).collect();
        let m = distance_matrix(&hvs);
        for i in 0..5 {
            assert_eq!(m[i * 5 + i], 0.0);
            for j in 0..5 {
                assert_eq!(m[i * 5 + j], m[j * 5 + i]);
            }
        }
    }

    #[test]
    fn clustering_recovers_structure() {
        let mut rng = Rng::new(2);
        let a = rand_hv(&mut rng, 2048);
        let b = rand_hv(&mut rng, 2048);
        let hvs = vec![
            a.clone(),
            flip_some(&a, 100, &mut rng),
            flip_some(&a, 120, &mut rng),
            b.clone(),
            flip_some(&b, 100, &mut rng),
        ];
        let dend = cluster(&hvs, 0.5);
        let labels = dend.cut(0.5);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn search_ranks_true_match_first() {
        let mut rng = Rng::new(3);
        let q = rand_hv(&mut rng, 2048);
        let refs = vec![
            rand_hv(&mut rng, 2048),
            flip_some(&q, 150, &mut rng), // near-duplicate
            rand_hv(&mut rng, 2048),
        ];
        let scores = search_scores(&q, &pack_refs(&refs));
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(best, 1);
    }
}
