//! Spectrum and peak types.



/// One fragment-ion peak: mass-to-charge ratio and relative intensity.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Peak {
    pub mz: f64,
    pub intensity: f32,
}

/// A (tandem) mass spectrum with simulation ground truth attached.
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// Unique scan identifier within a dataset.
    pub scan_id: u64,
    /// Precursor mass-to-charge ratio.
    pub precursor_mz: f64,
    /// Precursor charge state.
    pub charge: u8,
    /// Fragment peaks, sorted by m/z.
    pub peaks: Vec<Peak>,
    /// Ground-truth peptide id (None for noise/unidentifiable spectra).
    pub peptide_id: Option<u32>,
    /// True for decoy-library entries (target-decoy FDR, ref [17]).
    pub is_decoy: bool,
    /// Open-modification ground truth: mass shift applied (0.0 = unmodified).
    pub mod_shift: f64,
}

impl Spectrum {
    pub fn new(scan_id: u64, precursor_mz: f64, charge: u8, mut peaks: Vec<Peak>) -> Self {
        peaks.sort_by(|a, b| a.mz.total_cmp(&b.mz));
        Spectrum {
            scan_id,
            precursor_mz,
            charge,
            peaks,
            peptide_id: None,
            is_decoy: false,
            mod_shift: 0.0,
        }
    }

    pub fn with_peptide(mut self, id: u32) -> Self {
        self.peptide_id = Some(id);
        self
    }

    pub fn as_decoy(mut self) -> Self {
        self.is_decoy = true;
        self
    }

    pub fn with_mod_shift(mut self, shift: f64) -> Self {
        self.mod_shift = shift;
        self
    }

    /// Total ion current (sum of intensities).
    pub fn tic(&self) -> f64 {
        self.peaks.iter().map(|p| p.intensity as f64).sum()
    }

    pub fn base_peak_intensity(&self) -> f32 {
        self.peaks
            .iter()
            .map(|p| p.intensity)
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peaks_sorted_on_construction() {
        let s = Spectrum::new(
            1,
            500.0,
            2,
            vec![
                Peak { mz: 300.0, intensity: 1.0 },
                Peak { mz: 100.0, intensity: 2.0 },
                Peak { mz: 200.0, intensity: 3.0 },
            ],
        );
        let mzs: Vec<f64> = s.peaks.iter().map(|p| p.mz).collect();
        assert_eq!(mzs, vec![100.0, 200.0, 300.0]);
    }

    #[test]
    fn tic_and_base_peak() {
        let s = Spectrum::new(
            1,
            500.0,
            2,
            vec![
                Peak { mz: 100.0, intensity: 2.0 },
                Peak { mz: 200.0, intensity: 5.0 },
            ],
        );
        assert_eq!(s.tic(), 7.0);
        assert_eq!(s.base_peak_intensity(), 5.0);
    }

    #[test]
    fn builder_flags() {
        let s = Spectrum::new(1, 500.0, 2, vec![])
            .with_peptide(42)
            .as_decoy()
            .with_mod_shift(79.97);
        assert_eq!(s.peptide_id, Some(42));
        assert!(s.is_decoy);
        assert_eq!(s.mod_shift, 79.97);
    }
}
