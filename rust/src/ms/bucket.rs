//! Precursor-mass bucketing (Fig. 1 first stage).
//!
//! Spectra are partitioned by (charge, precursor-m/z window) before any
//! pairwise work: only spectra that could plausibly be the same analyte are
//! compared, which bounds the per-bucket distance-matrix size. DB search
//! uses the same windows to select candidate references (plus widened
//! windows for open-modification search).

use std::collections::BTreeMap;

use super::spectrum::Spectrum;

/// Bucket key: (charge, floor(precursor_mz / width)).
pub type BucketKey = (u8, i64);

pub fn bucket_key(charge: u8, precursor_mz: f64, width: f64) -> BucketKey {
    (charge, (precursor_mz / width).floor() as i64)
}

/// Partition spectrum indices into precursor buckets.
pub fn bucket_by_precursor(spectra: &[Spectrum], width: f64) -> BTreeMap<BucketKey, Vec<usize>> {
    let mut buckets: BTreeMap<BucketKey, Vec<usize>> = BTreeMap::new();
    for (i, s) in spectra.iter().enumerate() {
        buckets
            .entry(bucket_key(s.charge, s.precursor_mz, width))
            .or_default()
            .push(i);
    }
    buckets
}

/// Candidate buckets for a query in *standard* search: its own bucket plus
/// both neighbors (tolerance straddles a boundary).
pub fn candidate_keys_standard(charge: u8, precursor_mz: f64, width: f64) -> Vec<BucketKey> {
    let (c, b) = bucket_key(charge, precursor_mz, width);
    vec![(c, b - 1), (c, b), (c, b + 1)]
}

/// Candidate buckets for *open-modification* search: the standard window
/// plus windows shifted by each PTM delta (the query precursor carries the
/// modification mass; candidate references sit `delta/charge` below).
pub fn candidate_keys_open(
    charge: u8,
    precursor_mz: f64,
    width: f64,
    ptm_shifts: &[f64],
) -> Vec<BucketKey> {
    let mut keys = candidate_keys_standard(charge, precursor_mz, width);
    for &delta in ptm_shifts {
        let shifted = precursor_mz - delta / charge as f64;
        keys.extend(candidate_keys_standard(charge, shifted, width));
    }
    keys.sort_unstable();
    keys.dedup();
    keys
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ms::spectrum::Spectrum;

    fn spec(charge: u8, mz: f64) -> Spectrum {
        Spectrum::new(0, mz, charge, vec![])
    }

    #[test]
    fn same_precursor_same_bucket() {
        let spectra = vec![spec(2, 500.3), spec(2, 500.4), spec(2, 700.0), spec(3, 500.3)];
        let buckets = bucket_by_precursor(&spectra, 1.0);
        assert_eq!(buckets.len(), 3);
        let k = bucket_key(2, 500.3, 1.0);
        assert_eq!(buckets[&k], vec![0, 1]);
    }

    #[test]
    fn charge_separates_buckets() {
        let a = bucket_key(2, 500.0, 1.0);
        let b = bucket_key(3, 500.0, 1.0);
        assert_ne!(a, b);
    }

    #[test]
    fn standard_candidates_cover_neighbors() {
        let keys = candidate_keys_standard(2, 500.0, 1.0);
        assert_eq!(keys.len(), 3);
        assert!(keys.contains(&(2, 499)));
        assert!(keys.contains(&(2, 500)));
        assert!(keys.contains(&(2, 501)));
    }

    #[test]
    fn open_candidates_include_ptm_windows() {
        let keys = candidate_keys_open(2, 540.0, 1.0, &[79.96633]);
        // 540 window + (540 - 79.97/2) ~= 500 window
        assert!(keys.contains(&(2, 540)));
        assert!(keys.contains(&(2, 500)));
        // dedup: no repeated keys
        let mut sorted = keys.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), keys.len());
    }
}
