//! Mass-spectrometry domain substrate (paper §II-B, Figs. 1–2).
//!
//! The paper evaluates on MassIVE datasets (PXD001468, PXD000561, iPRG2012,
//! HEK293) that are not available here; per DESIGN.md §5 this module
//! provides a *synthetic proteomics workload generator* that preserves the
//! statistical structure the pipelines are sensitive to: groups of replicate
//! spectra of the same peptide (clustering), libraries of reference spectra
//! with true/false/modified query matches and shuffled decoys (DB search).

pub mod bucket;
pub mod dataset;
pub mod preprocess;
pub mod spectrum;
pub mod synth;

pub use bucket::bucket_by_precursor;
pub use dataset::{ClusteringDataset, SearchDataset};
pub use preprocess::{PreprocessConfig, preprocess};
pub use spectrum::{Peak, Spectrum};
