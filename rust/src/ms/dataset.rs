//! Dataset presets mirroring the paper's four evaluation datasets at
//! configurable scale (DESIGN.md §5): synthetic stand-ins preserve the
//! group-replicate structure (clustering) and target/decoy/modified query
//! mix (DB search); `paper_spectra` records the real dataset size used for
//! latency extrapolation in the Table 2/3 benches.

use crate::util::Rng;

use super::spectrum::Spectrum;
use super::synth::{
    library_spectrum, observe, observe_modified, ObservationNoise, Peptide, PTM_SHIFTS,
};

/// A clustering workload: spectra with ground-truth peptide groups.
#[derive(Clone, Debug)]
pub struct ClusteringDataset {
    pub name: &'static str,
    pub spectra: Vec<Spectrum>,
    /// Number of distinct ground-truth peptides (incl. singletons).
    pub n_peptides: usize,
    /// Size of the real dataset this preset stands in for.
    pub paper_spectra: u64,
}

impl ClusteringDataset {
    /// Core generator: `groups` multi-spectrum peptides with replicate
    /// counts in [min_rep, max_rep], plus `singletons` one-off peptides.
    pub fn generate(
        name: &'static str,
        seed: u64,
        groups: usize,
        min_rep: usize,
        max_rep: usize,
        singletons: usize,
        paper_spectra: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let noise = ObservationNoise::default();
        let mut spectra = Vec::new();
        let mut scan = 0u64;
        let mut pid = 0u32;

        for _ in 0..groups {
            let pep = Peptide::random(pid, &mut rng);
            pid += 1;
            let reps = rng.range_i64(min_rep as i64, max_rep as i64) as usize;
            let charge = 2 + (rng.next_u64() % 2) as u8;
            for _ in 0..reps {
                spectra.push(observe(&pep, scan, charge, &noise, &mut rng));
                scan += 1;
            }
        }
        for _ in 0..singletons {
            let pep = Peptide::random(pid, &mut rng);
            pid += 1;
            let charge = 2 + (rng.next_u64() % 2) as u8;
            spectra.push(observe(&pep, scan, charge, &noise, &mut rng));
            scan += 1;
        }
        rng.shuffle(&mut spectra);

        ClusteringDataset {
            name,
            spectra,
            n_peptides: pid as usize,
            paper_spectra,
        }
    }

    /// PXD001468-like (paper's small clustering set: 1.1 M kidney-cell
    /// spectra). `scale` multiplies the synthetic size.
    pub fn pxd001468_like(seed: u64, scale: f64) -> Self {
        let s = |x: f64| (x * scale).max(1.0) as usize;
        Self::generate("PXD001468-like", seed, s(120.0), 3, 12, s(300.0), 1_100_000)
    }

    /// PXD000561-like (paper's large set: 21.1 M draft-human-proteome
    /// spectra) — higher replicate multiplicity than the small set.
    pub fn pxd000561_like(seed: u64, scale: f64) -> Self {
        let s = |x: f64| (x * scale).max(1.0) as usize;
        Self::generate("PXD000561-like", seed, s(250.0), 4, 20, s(400.0), 21_100_000)
    }

    pub fn len(&self) -> usize {
        self.spectra.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spectra.is_empty()
    }
}

/// A DB-search workload: reference library (targets + shuffled decoys) and
/// queries with ground truth.
#[derive(Clone, Debug)]
pub struct SearchDataset {
    pub name: &'static str,
    /// Target reference spectra (one library spectrum per peptide).
    pub library: Vec<Spectrum>,
    /// Decoy reference spectra (shuffled sequences, same mass).
    pub decoys: Vec<Spectrum>,
    pub queries: Vec<Spectrum>,
    /// Fraction of queries whose peptide exists in the library.
    pub identifiable_fraction: f64,
    pub paper_queries: u64,
    pub paper_library: u64,
}

impl SearchDataset {
    /// `lib_size` target peptides; `n_queries` queries of which
    /// `identifiable_fraction` are true library peptides (and of those,
    /// `modified_fraction` carry an open modification).
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        name: &'static str,
        seed: u64,
        lib_size: usize,
        n_queries: usize,
        identifiable_fraction: f64,
        modified_fraction: f64,
        paper_queries: u64,
        paper_library: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let noise = ObservationNoise::default();

        let peptides: Vec<Peptide> = (0..lib_size as u32)
            .map(|i| Peptide::random(i, &mut rng))
            .collect();

        let mut library = Vec::with_capacity(lib_size);
        let mut decoys = Vec::with_capacity(lib_size);
        let mut scan = 0u64;
        for pep in &peptides {
            library.push(library_spectrum(pep, scan, 2, &mut rng));
            scan += 1;
            let d = pep.decoy(&mut rng);
            decoys.push(library_spectrum(&d, scan, 2, &mut rng).as_decoy());
            scan += 1;
        }

        let mut queries = Vec::with_capacity(n_queries);
        // Fresh peptides disjoint from the library for unidentifiable queries.
        let mut fresh_id = lib_size as u32 + 1_000_000;
        for _ in 0..n_queries {
            if rng.uniform() < identifiable_fraction {
                let pep = &peptides[rng.below(lib_size)];
                let q = if rng.uniform() < modified_fraction {
                    let delta = PTM_SHIFTS[rng.below(PTM_SHIFTS.len())];
                    observe_modified(pep, scan, 2, delta, &noise, &mut rng)
                } else {
                    observe(pep, scan, 2, &noise, &mut rng)
                };
                queries.push(q);
            } else {
                let pep = Peptide::random(fresh_id, &mut rng);
                fresh_id += 1;
                let mut q = observe(&pep, scan, 2, &noise, &mut rng);
                q.peptide_id = None; // not in library: unidentifiable
                queries.push(q);
            }
            scan += 1;
        }

        SearchDataset {
            name,
            library,
            decoys,
            queries,
            identifiable_fraction,
            paper_queries,
            paper_library,
        }
    }

    /// iPRG2012-like (small): 15,867 queries vs 1.16 M-spectrum yeast library.
    pub fn iprg2012_like(seed: u64, scale: f64) -> Self {
        let s = |x: f64| (x * scale).max(4.0) as usize;
        Self::generate(
            "iPRG2012-like",
            seed,
            s(800.0),
            s(400.0),
            0.75,
            0.3,
            15_867,
            1_162_392,
        )
    }

    /// HEK293-like (large): 46,665 queries/subset vs 3 M-spectrum human library.
    pub fn hek293_like(seed: u64, scale: f64) -> Self {
        let s = |x: f64| (x * scale).max(4.0) as usize;
        Self::generate(
            "HEK293-like",
            seed,
            s(1600.0),
            s(800.0),
            0.7,
            0.4,
            46_665,
            2_992_672,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn clustering_dataset_group_structure() {
        let ds = ClusteringDataset::generate("t", 1, 50, 3, 8, 100, 0);
        let mut by_pep: HashMap<u32, usize> = HashMap::new();
        for s in &ds.spectra {
            *by_pep.entry(s.peptide_id.unwrap()).or_default() += 1;
        }
        let multi = by_pep.values().filter(|&&c| c >= 3).count();
        let single = by_pep.values().filter(|&&c| c == 1).count();
        assert!(multi >= 45, "multi-spectrum groups present: {multi}");
        assert!(single >= 90, "singletons present: {single}");
        assert_eq!(ds.n_peptides, 150);
    }

    #[test]
    fn clustering_presets_deterministic() {
        let a = ClusteringDataset::pxd001468_like(9, 0.1);
        let b = ClusteringDataset::pxd001468_like(9, 0.1);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.spectra[0].scan_id, b.spectra[0].scan_id);
        assert_eq!(a.paper_spectra, 1_100_000);
    }

    #[test]
    fn search_dataset_composition() {
        let ds = SearchDataset::generate("t", 2, 100, 200, 0.8, 0.25, 0, 0);
        assert_eq!(ds.library.len(), 100);
        assert_eq!(ds.decoys.len(), 100);
        assert_eq!(ds.queries.len(), 200);
        assert!(ds.decoys.iter().all(|d| d.is_decoy));
        let identifiable = ds.queries.iter().filter(|q| q.peptide_id.is_some()).count();
        assert!((130..=190).contains(&identifiable), "{identifiable}");
        let modified = ds.queries.iter().filter(|q| q.mod_shift != 0.0).count();
        assert!(modified > 10, "{modified}");
    }

    #[test]
    fn library_ids_match_targets() {
        let ds = SearchDataset::generate("t", 3, 50, 50, 1.0, 0.0, 0, 0);
        for q in &ds.queries {
            let pid = q.peptide_id.unwrap();
            assert!(ds.library.iter().any(|l| l.peptide_id == Some(pid)));
        }
    }
}
