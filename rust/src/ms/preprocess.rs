//! Spectrum preprocessing: the [6]/[7] methodology — peak filtering, square
//! -root intensity scaling, m/z binning into a fixed-length vector, and
//! intensity quantization into the `m` levels consumed by ID-level encoding.



use super::spectrum::Spectrum;

#[derive(Clone, Copy, Debug)]
pub struct PreprocessConfig {
    /// Fragment m/z window retained.
    pub mz_min: f64,
    pub mz_max: f64,
    /// Number of m/z bins == HD feature positions F.
    pub bins: usize,
    /// Intensity quantization levels m.
    pub levels: usize,
    /// Keep only the top-N most intense peaks (0 = keep all).
    pub top_peaks: usize,
    /// Drop peaks below this fraction of the base peak.
    pub min_intensity_ratio: f32,
}

impl Default for PreprocessConfig {
    fn default() -> Self {
        PreprocessConfig {
            mz_min: 100.0,
            mz_max: 1900.0,
            bins: 512,
            levels: 64,
            top_peaks: 150,
            min_intensity_ratio: 0.01,
        }
    }
}

impl PreprocessConfig {
    pub fn bin_width(&self) -> f64 {
        (self.mz_max - self.mz_min) / self.bins as f64
    }
}

/// Preprocess a spectrum into quantized intensity levels per m/z bin —
/// the `levels` input of the encoder artifact (and of `hd::encode`).
pub fn preprocess(s: &Spectrum, cfg: &PreprocessConfig) -> Vec<u16> {
    // 1. Intensity filtering.
    let base = s.base_peak_intensity();
    let floor = base * cfg.min_intensity_ratio;
    let mut kept: Vec<(f64, f32)> = s
        .peaks
        .iter()
        .filter(|p| p.intensity >= floor && p.mz >= cfg.mz_min && p.mz < cfg.mz_max)
        .map(|p| (p.mz, p.intensity))
        .collect();

    // 2. Top-N by intensity.
    if cfg.top_peaks > 0 && kept.len() > cfg.top_peaks {
        kept.sort_by(|a, b| b.1.total_cmp(&a.1));
        kept.truncate(cfg.top_peaks);
    }

    // 3. Bin with sqrt scaling (max-pool within a bin).
    let mut binned = vec![0f32; cfg.bins];
    let w = cfg.bin_width();
    for (mz, inten) in kept {
        let b = ((mz - cfg.mz_min) / w) as usize;
        let b = b.min(cfg.bins - 1);
        binned[b] = binned[b].max(inten.sqrt());
    }

    // 4. Normalize to the max bin and quantize into levels 0..m-1.
    let maxv = binned.iter().fold(0f32, |a, &b| a.max(b));
    let scale = if maxv > 0.0 {
        (cfg.levels - 1) as f32 / maxv
    } else {
        0.0
    };
    binned
        .iter()
        .map(|&v| ((v * scale).round() as u16).min((cfg.levels - 1) as u16))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ms::spectrum::Peak;
    use crate::ms::synth::{observe, ObservationNoise, Peptide};
    use crate::util::Rng;

    #[test]
    fn output_shape_and_range() {
        let mut rng = Rng::new(1);
        let p = Peptide::random(0, &mut rng);
        let s = observe(&p, 1, 2, &ObservationNoise::default(), &mut rng);
        let cfg = PreprocessConfig::default();
        let v = preprocess(&s, &cfg);
        assert_eq!(v.len(), 512);
        assert!(v.iter().all(|&x| x < 64));
        assert!(v.iter().any(|&x| x > 0), "some bins populated");
        assert_eq!(*v.iter().max().unwrap(), 63, "max bin hits top level");
    }

    #[test]
    fn empty_spectrum_all_zero() {
        let s = Spectrum::new(1, 500.0, 2, vec![]);
        let v = preprocess(&s, &PreprocessConfig::default());
        assert!(v.iter().all(|&x| x == 0));
    }

    #[test]
    fn replicates_similar_random_different() {
        let mut rng = Rng::new(2);
        let cfg = PreprocessConfig::default();
        let noise = ObservationNoise::default();
        let pa = Peptide::random(1, &mut rng);
        let pb = Peptide::random(2, &mut rng);
        let a1 = preprocess(&observe(&pa, 1, 2, &noise, &mut rng), &cfg);
        let a2 = preprocess(&observe(&pa, 2, 2, &noise, &mut rng), &cfg);
        let b1 = preprocess(&observe(&pb, 3, 2, &noise, &mut rng), &cfg);
        let overlap = |x: &[u16], y: &[u16]| -> usize {
            x.iter()
                .zip(y)
                .filter(|(a, b)| **a > 0 && **b > 0)
                .count()
        };
        assert!(
            overlap(&a1, &a2) > 2 * overlap(&a1, &b1),
            "replicates share bins: {} vs {}",
            overlap(&a1, &a2),
            overlap(&a1, &b1)
        );
    }

    #[test]
    fn out_of_window_peaks_dropped() {
        let s = Spectrum::new(
            1,
            500.0,
            2,
            vec![
                Peak { mz: 50.0, intensity: 10.0 },
                Peak { mz: 5000.0, intensity: 10.0 },
                Peak { mz: 500.0, intensity: 1.0 },
            ],
        );
        let v = preprocess(&s, &PreprocessConfig::default());
        assert_eq!(v.iter().filter(|&&x| x > 0).count(), 1);
    }
}
