//! Fault-tolerant remote shard serving (the "Remote shard workers"
//! contract in `coordinator::remote`): three properties, all under the
//! seeded deterministic [`ChaosPlan`] — zero wall-clock dependence
//! anywhere (contract C6-TIME):
//!
//! 1. **No-fault bit-identity** — with no injected faults, serving
//!    through per-shard worker *processes* is bit-identical — per-query
//!    scores, matched peptides, cumulative marginal `OpCounts`, health,
//!    coverage, final summary — to the in-process
//!    `ShardedSearchEngine`, for every backend, shard count, batch
//!    split, and front-door coalescing policy.
//! 2. **Kill-and-respawn convergence** — killed, hung, and
//!    frame-corrupted workers are respawned from the stored initial
//!    chained RNG state plus the age/refresh replay log, and serving
//!    converges back to bit-identity (even when the fault lands *after*
//!    drift and a refresh pass). The logical clock's exact final value
//!    pins the attempt/backoff/deadline tick math.
//! 3. **Graceful degradation** — a shard that exhausts its retry budget
//!    degrades the batch to the surviving shards: results equal an
//!    oracle merged over the surviving shards only, and the partial
//!    [`Coverage`] is reported, never silently dropped.
//!
//! Worker processes are the serving binary itself re-exec'd under the
//! hidden `worker` subcommand (`CARGO_BIN_EXE_specpcm`).

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{
    ArrivalTrace, BatchOutcome, ChaosEvent, ChaosKind, ChaosPlan, CoalescePolicy, Coverage,
    FrontDoor, GroupCharges, HdFrontend, RefreshPolicy, RemoteEngine, ShardedSearchEngine,
};
use specpcm::energy::OpCounts;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::util::Rng;

/// The serving binary; its hidden `worker` subcommand is what the
/// supervisor spawns per shard.
const EXE: &str = env!("CARGO_BIN_EXE_specpcm");

/// 12 banks per engine so the 90+90-row dataset genuinely needs
/// multiple shards (same geometry as the sharded-serving suite).
fn cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 12,
        ..SpecPcmConfig::paper_search()
    }
}

fn dataset() -> SearchDataset {
    SearchDataset::generate("wft", 53, 90, 40, 0.8, 0.2, 0, 0)
}

fn bits(pairs: &[(f32, f32)]) -> Vec<(u32, u32)> {
    pairs.iter().map(|&(t, d)| (t.to_bits(), d.to_bits())).collect()
}

/// Remote batches must equal in-process batches bit-for-bit in every
/// result-bearing field. Telemetry that legitimately differs across the
/// process boundary (cache hit/miss split, wall timers, retry counts) is
/// deliberately not compared here.
fn assert_batches_match(remote: &[BatchOutcome], sharded: &[BatchOutcome], tag: &str) {
    assert_eq!(remote.len(), sharded.len(), "{tag}: batch counts");
    for (bi, (r, s)) in remote.iter().zip(sharded).enumerate() {
        assert_eq!(bits(&r.pairs), bits(&s.pairs), "{tag}[{bi}]: pairs");
        assert_eq!(r.matched, s.matched, "{tag}[{bi}]: matched peptides");
        assert_eq!(r.ops, s.ops, "{tag}[{bi}]: marginal ops");
        assert_eq!(r.health, s.health, "{tag}[{bi}]: device health");
        assert_eq!(r.coverage, s.coverage, "{tag}[{bi}]: coverage");
        assert!(r.coverage.is_full(), "{tag}[{bi}]: expected full coverage");
        assert_eq!(r.degraded_shards, 0, "{tag}[{bi}]: degraded shards");
    }
}

/// Property 1: for every backend x shard count x batch split, no-fault
/// remote serving is bit-identical to the in-process sharded engine —
/// programming ops, per-batch results, and the folded summary — and the
/// logical clock advances exactly one tick per (batch, shard) attempt.
#[test]
fn no_fault_remote_serving_is_bit_identical_to_sharded() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    for n_shards in [2usize, 3] {
        for be in [BackendDispatcher::reference(), BackendDispatcher::parallel(4)] {
            let tag = format!("{}x{n_shards}", be.primary_name());
            let sharded = ShardedSearchEngine::program(cfg(), &ds, &be, n_shards).unwrap();
            let remote =
                RemoteEngine::program(cfg(), &ds, n_shards, EXE, ChaosPlan::none()).unwrap();

            assert_eq!(remote.n_shards(), n_shards, "{tag}");
            assert_eq!(remote.n_refs(), sharded.n_refs(), "{tag}: programmed rows");
            assert_eq!(
                remote.program_ops(),
                sharded.program_ops(),
                "{tag}: one-time programming ops"
            );

            let mut served = 0u64;
            for n_batches in [1usize, 3] {
                let r = remote.serve_chunked(&queries, n_batches, &be).unwrap();
                let s = sharded.serve_chunked(&queries, n_batches, &be).unwrap();
                assert_batches_match(&r, &s, &format!("{tag}/b{n_batches}"));
                for b in &r {
                    assert_eq!(b.retries, 0, "{tag}: no-fault retries");
                }
                served += r.len() as u64;
            }
            // One score attempt per (batch, shard), nothing else ticks.
            assert_eq!(remote.clock(), served * n_shards as u64, "{tag}: clock");

            let stats = remote.worker_stats();
            assert_eq!(stats.workers, n_shards, "{tag}");
            assert_eq!(stats.workers_up, n_shards, "{tag}");
            assert_eq!(stats.respawns, 0, "{tag}");
            assert_eq!(stats.retries, 0, "{tag}");
            assert_eq!(stats.degraded_batches, 0, "{tag}");
            assert_eq!(stats.breakers_open, 0, "{tag}");

            // The folded summary — FDR, ops, energy — is the same fold.
            let rb = remote.serve_chunked(&queries, 2, &be).unwrap();
            let sb = sharded.serve_chunked(&queries, 2, &be).unwrap();
            let rs = remote.finalize(&queries, &rb).unwrap();
            let ss = sharded.finalize(&queries, &sb).unwrap();
            assert_eq!(rs.identified, ss.identified, "{tag}: identified");
            assert_eq!(rs.correct, ss.correct, "{tag}: correct");
            assert_eq!(bits(&rs.pairs), bits(&ss.pairs), "{tag}: summary pairs");
            assert_eq!(rs.ops, ss.ops, "{tag}: summary ops");
            assert_eq!(
                rs.report.total_j().to_bits(),
                ss.report.total_j().to_bits(),
                "{tag}: summary energy"
            );
        }
    }
}

/// Property 1, front-door leg: the remote engine behind `ServeEngine` is
/// indistinguishable from in-process serving for every coalescing
/// policy — fan-back and cumulative marginal ops match the one-batch
/// arrival-order oracle.
#[test]
fn front_door_drives_remote_workers_identically_to_in_process() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    let sharded = ShardedSearchEngine::program(cfg(), &ds, &be, 2).unwrap();
    let oracle = sharded.search_batch(&queries, &be).unwrap();
    let mut remote = RemoteEngine::program(cfg(), &ds, 2, EXE, ChaosPlan::none()).unwrap();

    let mut rng = Rng::new(0xfau64);
    let traces = [
        ("poisson", ArrivalTrace::poisson_from_rng(&mut rng, queries.len(), 3.0)),
        ("burst", ArrivalTrace::uniform(queries.len(), 0)),
    ];
    let policies = [
        CoalescePolicy::Off,
        CoalescePolicy::Size { max_batch: 7 },
        CoalescePolicy::SizeDeadline {
            max_batch: 16,
            deadline_ticks: 5,
        },
    ];
    for (tname, trace) in &traces {
        for policy in policies {
            let tag = format!("{tname}/{}", policy.name());
            let fd = FrontDoor::new(policy);
            let served = fd.serve_trace(&mut remote, &queries, trace, &be).unwrap();
            assert_eq!(bits(&served.pairs), bits(&oracle.pairs), "{tag}: fan-back");
            assert_eq!(served.matched, oracle.matched, "{tag}: matched");
            assert_eq!(served.ops, oracle.ops, "{tag}: cumulative marginal ops");
        }
    }
    assert_eq!(remote.worker_stats().retries, 0);
}

/// Property 2: kill and corrupt-frame faults are retried through
/// respawn-from-log and serving stays bit-identical — including a kill
/// that lands *after* `advance_age` + a refresh pass, which forces the
/// respawn to replay both mutations to reconverge. The exact final clock
/// pins the attempt (+1) and backoff (+base << attempt) tick model.
#[test]
fn killed_and_corrupted_workers_respawn_and_converge_to_bit_identity() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    // Tick trace (2 shards, 3+2 batches, backoff base 8, retries 3):
    //   batch 1: s0 attempt@1 killed -> backoff to 9, respawn, ok@10;
    //            s1 attempt@11 corrupted -> backoff to 19, respawn, ok@20
    //   batches 2-3: 21, 22, 23, 24  (age + maintain do not tick)
    //   batch 4: s0 attempt@25 killed -> backoff to 33, respawn replays
    //            the age+refresh log, ok@34; s1 ok@35
    //   batch 5: 36, 37
    let chaos = ChaosPlan::new(vec![
        ChaosEvent { tick: 1, shard: 0, kind: ChaosKind::Kill },
        ChaosEvent { tick: 2, shard: 1, kind: ChaosKind::CorruptFrame },
        ChaosEvent { tick: 25, shard: 0, kind: ChaosKind::Kill },
    ]);
    let mut sharded = ShardedSearchEngine::program(cfg(), &ds, &be, 2).unwrap();
    let mut remote = RemoteEngine::program(cfg(), &ds, 2, EXE, chaos).unwrap();

    let r1 = remote.serve_chunked(&queries, 3, &be).unwrap();
    let s1 = sharded.serve_chunked(&queries, 3, &be).unwrap();
    assert_batches_match(&r1, &s1, "pre-maintain");
    assert_eq!(r1[0].retries, 2, "both faults land in batch 1");
    assert_eq!(r1[1].retries + r1[2].retries, 0);
    assert_eq!(remote.clock(), 24);

    // Drift + one refresh pass on both engines: identical selection and
    // identical one-time ledger growth.
    sharded.advance_age(500.0);
    remote.advance_age(500.0);
    let policy = RefreshPolicy {
        max_age_seconds: 0.0,
        budget: 6,
    };
    let so = sharded.maintain(&policy);
    let ro = remote.maintain(&policy);
    assert_eq!((ro.buckets, ro.rows), (so.buckets, so.rows), "refresh outcome");
    assert_eq!(ro.ops, so.ops, "refresh ops");
    assert_eq!(remote.program_ops(), sharded.program_ops(), "one-time ledger");

    // The post-maintain kill: the respawn must replay age + refresh to
    // stay bit-identical to the shard that never died.
    let r2 = remote.serve_chunked(&queries, 2, &be).unwrap();
    let s2 = sharded.serve_chunked(&queries, 2, &be).unwrap();
    assert_batches_match(&r2, &s2, "post-maintain");
    assert_eq!(r2[0].retries, 1, "post-maintain kill lands in batch 4");
    assert_eq!(remote.clock(), 37);
    assert_eq!(remote.device_health(), sharded.device_health());

    let stats = remote.worker_stats();
    assert_eq!(stats.respawns, 3);
    assert_eq!(stats.retries, 3);
    assert_eq!(stats.degraded_batches, 0);
    assert_eq!(stats.workers_up, 2);
    assert_eq!(stats.breakers_open, 0);

    let all_r: Vec<BatchOutcome> = r1.into_iter().chain(r2).collect();
    let all_s: Vec<BatchOutcome> = s1.into_iter().chain(s2).collect();
    let rs = remote.finalize(&queries, &all_r).unwrap();
    let ss = sharded.finalize(&queries, &all_s).unwrap();
    assert_eq!(rs.identified, ss.identified);
    assert_eq!(rs.ops, ss.ops, "chaos never leaks into the op ledger");
}

/// Property 2, hang leg: a hang charges the full deadline on the logical
/// clock before the worker is declared dead, then retry converges.
/// Trace: attempt@1 hangs (+1024 deadline -> 1025), backoff +8 -> 1033,
/// respawn ok@1034, s1 ok@1035.
#[test]
fn hung_worker_is_charged_the_deadline_and_recovers() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    let chaos = ChaosPlan::new(vec![ChaosEvent {
        tick: 1,
        shard: 0,
        kind: ChaosKind::Hang,
    }]);
    let sharded = ShardedSearchEngine::program(cfg(), &ds, &be, 2).unwrap();
    let remote = RemoteEngine::program(cfg(), &ds, 2, EXE, chaos).unwrap();

    let r = remote.search_batch(&queries, &be).unwrap();
    let s = sharded.search_batch(&queries, &be).unwrap();
    assert_batches_match(&[r], &[s], "hang");
    assert_eq!(remote.clock(), 1035, "deadline + backoff tick math");
    let stats = remote.worker_stats();
    assert_eq!(stats.respawns, 1);
    assert_eq!(stats.retries, 1);
    assert_eq!(stats.workers_up, 2);
}

/// Property 3: a shard that exhausts its retry budget degrades the batch
/// instead of failing it. The degraded results equal an oracle merged
/// over the surviving shards only (same strict-`>` shard-order merge,
/// same central charging), the partial coverage is reported exactly, the
/// breaker opens — and the next batch's half-open probe heals the shard
/// back to full bit-identical coverage.
#[test]
fn exhausted_budget_degrades_to_surviving_shards_with_reported_coverage() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    let mut c = cfg();
    c.remote.retries = 0; // fail-fast: no second attempt
    c.remote.breaker_threshold = 1;
    // Batch 1 ticks: s0 ok@1, s1 killed@2 (budget spent -> degraded,
    // breaker opens), s2 ok@3.
    let chaos = ChaosPlan::new(vec![ChaosEvent {
        tick: 2,
        shard: 1,
        kind: ChaosKind::Kill,
    }]);
    let sharded = ShardedSearchEngine::program(c.clone(), &ds, &be, 3).unwrap();
    let remote = RemoteEngine::program(c.clone(), &ds, 3, EXE, chaos).unwrap();

    let batch = remote.search_batch(&queries, &be).unwrap();
    let surviving =
        (remote.plan().range(0).len() + remote.plan().range(2).len()) as u64;
    assert_eq!(batch.degraded_shards, 1);
    assert_eq!(batch.retries, 0, "retries = 0 means fail-fast");
    assert_eq!(
        batch.coverage,
        Coverage {
            rows_searched: surviving,
            rows_total: remote.n_refs() as u64,
        },
        "partial coverage is reported exactly"
    );
    assert!(!batch.coverage.is_full());
    assert!(batch.coverage.fraction() < 1.0);

    // Oracle: the full-plan in-process shards (identical noise chaining),
    // merged over shards 0 and 2 only, charged centrally.
    let (packed, _) = sharded.shard(0).encode_queries(&queries, &be).unwrap();
    let mut oracle_ops = OpCounts::default();
    HdFrontend::new(&c).count_encode_ops(queries.len(), &mut oracle_ops);
    let mut best: Vec<(f32, f32, Option<u32>)> =
        vec![(f32::NEG_INFINITY, f32::NEG_INFINITY, None); queries.len()];
    let mut charges = GroupCharges::default();
    for si in [0usize, 2] {
        let scored = sharded.shard(si).score_packed(&queries, &packed, &be).unwrap();
        for (qi, &(t, d, m)) in scored.best.iter().enumerate() {
            if t > best[qi].0 {
                best[qi].0 = t;
                best[qi].2 = m;
            }
            if d > best[qi].1 {
                best[qi].1 = d;
            }
        }
        charges.merge(&scored.charges);
    }
    charges.charge(sharded.shard(0).packed_width(), &mut oracle_ops);
    let oracle_pairs: Vec<(f32, f32)> = best.iter().map(|&(t, d, _)| (t, d)).collect();
    let oracle_matched: Vec<Option<u32>> = best.iter().map(|&(_, _, m)| m).collect();
    assert_eq!(bits(&batch.pairs), bits(&oracle_pairs), "degraded pairs");
    assert_eq!(batch.matched, oracle_matched, "degraded matches");
    assert_eq!(batch.ops, oracle_ops, "degraded ops cover survivors only");

    let stats = remote.worker_stats();
    assert_eq!(stats.degraded_batches, 1);
    assert_eq!(stats.workers_up, 2);
    assert_eq!(stats.breakers_open, 1);
    assert_eq!(stats.respawns, 0);

    // The open breaker's single half-open probe respawns the shard; the
    // next batch is back to full coverage and bit-identity.
    let b2 = remote.search_batch(&queries, &be).unwrap();
    let s2 = sharded.search_batch(&queries, &be).unwrap();
    assert_batches_match(&[b2], &[s2], "healed");
    let stats = remote.worker_stats();
    assert_eq!(stats.respawns, 1);
    assert_eq!(stats.workers_up, 3);
    assert_eq!(stats.breakers_open, 0);
    assert_eq!(stats.degraded_batches, 1, "only the first batch degraded");
}

/// Degradation has a floor: a batch with zero surviving shards is a
/// typed error, not an empty result set.
#[test]
fn zero_surviving_shards_is_a_typed_error() {
    let ds = dataset();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    let mut c = cfg();
    c.num_banks = 36; // whole library fits one worker
    c.remote.retries = 0;
    let chaos = ChaosPlan::new(vec![ChaosEvent {
        tick: 1,
        shard: 0,
        kind: ChaosKind::Kill,
    }]);
    let remote = RemoteEngine::program(c, &ds, 1, EXE, chaos).unwrap();
    let err = remote.search_batch(&queries, &be).unwrap_err();
    assert!(
        err.to_string().contains("all 1 shards down"),
        "got: {err}"
    );
}

/// The CLI seam (satellite checks at the binary level): misuse of the
/// remote flags and the hidden worker subcommand exits 2 with a typed
/// one-line error, and a worker fed a clean EOF exits 0.
#[test]
fn cli_worker_misuse_exits_2_and_clean_worker_eof_exits_0() {
    let run = |args: &[&str]| {
        std::process::Command::new(EXE)
            .args(args)
            .stdin(std::process::Stdio::null())
            .output()
            .unwrap()
    };

    let out = run(&["search", "--workers", "2", "--shards", "auto"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let out = run(&["search", "--workers", "banana"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--workers"));

    let out = run(&["worker", "--workers", "2"]);
    assert_eq!(out.status.code(), Some(2));

    // A worker that reads EOF before any request exits its loop cleanly.
    let out = run(&["worker"]);
    assert_eq!(out.status.code(), Some(0));
    assert!(out.stdout.is_empty(), "no unsolicited response frames");
}
