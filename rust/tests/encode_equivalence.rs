//! Encode-backend equivalence (the second half of the pluggable-backend
//! contract): every encode backend — scalar reference, word-packed
//! bitpacked, spectra-sharded parallel at any thread count — must produce
//! **bit-identical** packed HV rows to `hd::encode` + `hd::pack` (same
//! `sign(0) = +1` tie rule, same zero padding), at kernel level, at
//! frontend level, and at pipeline level (clustering and search summaries
//! unchanged for every backend choice). Also locks in the engine's
//! query-HV cache contract: cached batches are bit-identical and hits are
//! surfaced. Runs on the default feature set (no artifacts, no external
//! crates).

use specpcm::backend::BackendDispatcher;
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchEngine, SearchPipeline};
use specpcm::encode::{
    backend_of_kind, EncodeBackend, EncodeJob, EncodeKind, ParallelEncodeBackend,
    ScalarEncodeBackend,
};
use specpcm::hd::{self, BitItemMemory, ItemMemory};
use specpcm::ms::{ClusteringDataset, SearchDataset, Spectrum};
use specpcm::util::Rng;

fn sparse_levels(rng: &mut Rng, f: usize, m: usize, peaks: usize) -> Vec<u16> {
    let mut v = vec![0u16; f];
    for _ in 0..peaks {
        v[rng.below(f)] = 1 + rng.below(m - 1) as u16;
    }
    v
}

/// Property test: across random seeds, sparse/empty spectra, all-tie
/// inputs and dims that are *not* multiples of 64 (tail-word masking),
/// the bitpacked encode+pack matches the scalar reference bit for bit.
#[test]
fn bitpacked_matches_scalar_reference_property() {
    // 100/130/2000 exercise the tail-word mask; 64/2048 the aligned path.
    for (seed, d) in [(1u64, 64usize), (2, 100), (3, 130), (4, 512), (5, 2000), (6, 2048)] {
        let mut rng = Rng::new(0xec0de ^ seed);
        let im = ItemMemory::generate(seed, 96, 16, d);
        let bim = BitItemMemory::from_item_memory(&im);
        for n in 1usize..=4 {
            let mut batch: Vec<Vec<u16>> = Vec::new();
            batch.push(vec![0u16; 96]); // empty spectrum: all-tie output
            batch.push(vec![1u16; 96]); // every bin occupied
            for peaks in [1usize, 7, 30, 96] {
                batch.push(sparse_levels(&mut rng, 96, 16, peaks));
            }
            let job = EncodeJob::new(&batch, &im, &bim, n);
            let mut want = vec![0f32; job.out_len()];
            ScalarEncodeBackend.encode_pack(&job, &mut want).unwrap();
            // Row 0 (empty spectrum) must be the packed all-(+1) vector:
            // sign(0) = +1 everywhere, so every full group packs to n.
            assert!(
                want[..hd::packed_len(d, n)]
                    .iter()
                    .take(d / n)
                    .all(|&v| v == n as f32),
                "tie rule broke: seed={seed} d={d} n={n}"
            );
            for kind in [EncodeKind::Bitpacked, EncodeKind::Parallel] {
                let mut got = vec![f32::NAN; job.out_len()];
                backend_of_kind(kind, 2).encode_pack(&job, &mut got).unwrap();
                assert_eq!(got, want, "seed={seed} d={d} n={n} kind={}", kind.name());
            }
        }
    }
}

/// Exactly cancelling contributions: acc == 0 on every element, so the
/// `sign(0) = +1` tie rule decides the entire output — on every backend.
#[test]
fn all_tie_inputs_agree_across_backends() {
    let mut im = ItemMemory::generate(44, 2, 3, 192);
    im.id_hvs = vec![vec![1; 192], vec![1; 192]];
    im.level_hvs = vec![vec![1; 192], vec![1; 192], vec![-1; 192]];
    let bim = BitItemMemory::from_item_memory(&im);
    let batch = vec![vec![1u16, 2u16]];
    let job = EncodeJob::new(&batch, &im, &bim, 3);
    let want = hd::pack(&vec![1i8; 192], 3);
    for kind in [EncodeKind::Scalar, EncodeKind::Bitpacked, EncodeKind::Parallel] {
        let mut got = vec![f32::NAN; job.out_len()];
        backend_of_kind(kind, 2).encode_pack(&job, &mut got).unwrap();
        assert_eq!(got, want, "kind={}", kind.name());
    }
}

#[test]
fn parallel_encode_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0xabc);
    let im = ItemMemory::generate(9, 128, 32, 2048);
    let bim = BitItemMemory::from_item_memory(&im);
    let batch: Vec<Vec<u16>> = (0..41).map(|_| sparse_levels(&mut rng, 128, 32, 40)).collect();
    let job = EncodeJob::new(&batch, &im, &bim, 3);
    let mut want = vec![0f32; job.out_len()];
    ScalarEncodeBackend.encode_pack(&job, &mut want).unwrap();
    for threads in [1usize, 2, 8] {
        let mut got = vec![f32::NAN; job.out_len()];
        ParallelEncodeBackend::new(threads)
            .encode_pack(&job, &mut got)
            .unwrap();
        assert_eq!(got, want, "threads={threads}");
    }
}

fn encode_dispatchers() -> Vec<(String, BackendDispatcher)> {
    let mut out = vec![(
        "scalar".to_string(),
        BackendDispatcher::reference(),
    )];
    out.push((
        "bitpacked".to_string(),
        BackendDispatcher::reference().with_encode_kind(EncodeKind::Bitpacked, 0),
    ));
    for threads in [1usize, 2, 8] {
        out.push((
            format!("parallel x{threads}"),
            BackendDispatcher::reference().with_encode_kind(EncodeKind::Parallel, threads),
        ));
    }
    out
}

#[test]
fn clustering_pipeline_identical_across_encode_backends() {
    let cfg = SpecPcmConfig {
        hd_dim: 1024,
        bucket_width: 50.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_clustering()
    };
    // Same dataset as backend_equivalence.rs's clustering test, so the
    // closing quality assert is a known-green workload.
    let ds = ClusteringDataset::generate("t", 31, 10, 4, 6, 8, 0);
    let via_scalar = ClusteringPipeline::new(cfg.clone())
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();
    for (name, be) in encode_dispatchers() {
        let via = ClusteringPipeline::new(cfg.clone()).run(&ds, &be).unwrap();
        assert_eq!(via.ops.mvm_ops, via_scalar.ops.mvm_ops, "{name}");
        assert_eq!(via.ops.encode_spectra, via_scalar.ops.encode_spectra, "{name}");
        assert_eq!(via.n_buckets, via_scalar.n_buckets, "{name}");
        for (a, b) in via.curve.iter().zip(&via_scalar.curve) {
            assert_eq!(a.clustered_ratio, b.clustered_ratio, "{name} t={}", a.threshold);
            assert_eq!(a.incorrect_ratio, b.incorrect_ratio, "{name} t={}", a.threshold);
        }
    }
    assert!(clustered_at_incorrect(&via_scalar.curve, 0.02) > 0.3);
}

#[test]
fn search_pipeline_identical_across_encode_backends() {
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    // Same dataset as backend_equivalence.rs's search test (known-green
    // identification count).
    let ds = SearchDataset::generate("t", 32, 60, 80, 0.8, 0.2, 0, 0);
    let via_scalar = SearchPipeline::new(cfg.clone())
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();
    for (name, be) in encode_dispatchers() {
        let via = SearchPipeline::new(cfg.clone()).run(&ds, &be).unwrap();
        assert_eq!(via.pairs, via_scalar.pairs, "{name}");
        assert_eq!(via.identified, via_scalar.identified, "{name}");
        assert_eq!(via.correct, via_scalar.correct, "{name}");
        assert_eq!(via.identified_peptides, via_scalar.identified_peptides, "{name}");
        assert_eq!(via.ops.encode_spectra, via_scalar.ops.encode_spectra, "{name}");
    }
    assert!(via_scalar.identified > 20, "identified {}", via_scalar.identified);
}

/// The engine's query-HV cache serves repeated spectra without
/// re-encoding, returns bit-identical [`BatchOutcome`]s, reports its
/// hits, and never perturbs op/energy accounting.
#[test]
fn engine_query_cache_bit_identical_and_reports_hits() {
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate("t", 63, 30, 20, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::parallel(2);
    let engine = SearchEngine::program(cfg, &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let cold = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(cold.cache.hits + cold.cache.misses, queries.len() as u64);
    assert!(cold.cache.misses > 0);

    // Serving the same spectra again: all hits, outcome bit-identical.
    let warm = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(warm.cache.hits, queries.len() as u64);
    assert_eq!(warm.cache.misses, 0);
    assert_eq!(warm.pairs, cold.pairs);
    assert_eq!(warm.matched, cold.matched);
    assert_eq!(warm.ops, cold.ops);
    assert_eq!(warm.report.total_j(), cold.report.total_j());

    // finalize over cached batches still folds to the one-shot summary.
    let doubled: Vec<&Spectrum> = queries.iter().chain(queries.iter()).copied().collect();
    let out = engine.finalize(&doubled, &[cold.clone(), warm]).unwrap();
    assert_eq!(out.total_queries, doubled.len());
    assert_eq!(&out.pairs[..queries.len()], &cold.pairs[..]);
    assert_eq!(&out.pairs[queries.len()..], &cold.pairs[..]);

    let stats = engine.encode_cache_stats();
    assert_eq!(stats.total(), 2 * queries.len() as u64);
    assert!(stats.hit_rate() >= 0.5);
}
