//! Backend equivalence and determinism (the pluggable-backend contract,
//! DESIGN.md §8): every MVM backend must produce **bit-identical** score
//! matrices on the same job — the dispatcher may change *where* the
//! arithmetic runs, never *what* it computes. Runs on the default feature
//! set (no artifacts, no external crates).

use specpcm::array::AdcConfig;
use specpcm::backend::{BackendDispatcher, MvmBackend, MvmJob, ParallelBackend, RefBackend};
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchPipeline};
use specpcm::energy::OpCounts;
use specpcm::ms::{ClusteringDataset, SearchDataset};
use specpcm::util::Rng;

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

/// Seeded synthetic workloads, deliberately including ragged tiles (`nq`,
/// `nr` not multiples of 128), a tile big enough to engage threading, and
/// `nq < threads` large-span shapes that route the parallel backend down
/// the PR 6 column-striped path.
const SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 128),     // minimal
    (3, 5, 128),     // tiny bucket
    (37, 211, 256),  // ragged both ways
    (64, 128, 384),  // aligned rows, odd width
    (128, 100, 256), // ragged refs only
    (50, 1024, 768), // wide tile (well above the threading cutoff)
    (1, 2048, 256),  // single query, large span (column-striped)
    (3, 1500, 384),  // few queries, large ragged span (mixed 2-D split)
];

#[test]
fn ref_and_parallel_bit_identical_across_thread_counts() {
    for (si, &(nq, nr, cp)) in SHAPES.iter().enumerate() {
        let mut rng = Rng::new(0xe9_u64 ^ si as u64);
        let q = rand_packed(&mut rng, nq * cp, 3);
        let g = rand_packed(&mut rng, nr * cp, 3);
        for adc in [AdcConfig::new(6, 512.0), AdcConfig::new(3, 128.0)] {
            let job = MvmJob::new(&q, nq, &g, nr, cp, adc);
            let want = RefBackend.mvm_scores(&job).unwrap();
            assert_eq!(want.len(), nq * nr);
            for threads in [1usize, 2, 8] {
                let got = ParallelBackend::new(threads).mvm_scores(&job).unwrap();
                assert_eq!(
                    got, want,
                    "shape ({nq},{nr},{cp}) adc {adc:?} threads {threads}"
                );
            }
        }
    }
}

#[test]
fn stripe_shapes_bit_identical_on_single_query_spans() {
    // Every stripe-height override (auto, one-tile, ragged round-up,
    // bigger-than-span) must be score-neutral on the column-striped path.
    let (nq, nr, cp) = (1usize, 2048usize, 256usize);
    let mut rng = Rng::new(0x57a1);
    let q = rand_packed(&mut rng, nq * cp, 3);
    let g = rand_packed(&mut rng, nr * cp, 3);
    let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(6, 512.0));
    let want = RefBackend.mvm_scores(&job).unwrap();
    for threads in [2usize, 4, 16] {
        for stripe_rows in [0usize, 1, 128, 500, 1 << 20] {
            let be = ParallelBackend::new(threads).with_stripe_rows(stripe_rows);
            let got = be.mvm_scores(&job).unwrap();
            assert_eq!(got, want, "threads={threads} stripe_rows={stripe_rows}");
        }
    }
}

#[test]
fn backends_are_deterministic_across_repeated_runs() {
    let (nq, nr, cp) = (37, 211, 256);
    let mut rng = Rng::new(0xdead);
    let q = rand_packed(&mut rng, nq * cp, 3);
    let g = rand_packed(&mut rng, nr * cp, 3);
    let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(6, 512.0));
    let be = ParallelBackend::new(8);
    let first = be.mvm_scores(&job).unwrap();
    for _ in 0..3 {
        assert_eq!(be.mvm_scores(&job).unwrap(), first);
    }
}

#[test]
fn dispatcher_matches_backends_and_counts_ops() {
    let (nq, nr, cp) = (64, 300, 256);
    let mut rng = Rng::new(0xd15);
    let q = rand_packed(&mut rng, nq * cp, 3);
    let g = rand_packed(&mut rng, nr * cp, 3);
    let job = MvmJob::new(&q, nq, &g, nr, cp, AdcConfig::new(6, 512.0));
    let want = RefBackend.mvm_scores(&job).unwrap();

    for disp in [
        BackendDispatcher::reference(),
        BackendDispatcher::parallel(2),
        BackendDispatcher::parallel(8),
        BackendDispatcher::from_config(&SpecPcmConfig::paper_clustering()),
    ] {
        let mut ops = OpCounts::default();
        let got = disp.execute(&job, &mut ops).unwrap();
        assert_eq!(got, want, "dispatcher {}", disp.primary_name());
        // 64 queries x ceil(300/128)=3 row tiles x 2 col tiles.
        assert_eq!(ops.mvm_ops, 64 * 3 * 2);
    }
}

#[test]
fn clustering_pipeline_identical_across_backends() {
    let cfg = SpecPcmConfig {
        hd_dim: 1024,
        bucket_width: 50.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_clustering()
    };
    let ds = ClusteringDataset::generate("t", 31, 10, 4, 6, 8, 0);

    let via_ref = ClusteringPipeline::new(cfg.clone())
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();
    for threads in [1usize, 2, 8] {
        let via_par = ClusteringPipeline::new(cfg.clone())
            .run(&ds, &BackendDispatcher::parallel(threads))
            .unwrap();
        assert_eq!(via_par.ops.mvm_ops, via_ref.ops.mvm_ops);
        assert_eq!(via_par.n_buckets, via_ref.n_buckets);
        for (a, b) in via_par.curve.iter().zip(&via_ref.curve) {
            assert_eq!(a.clustered_ratio, b.clustered_ratio, "t={}", a.threshold);
            assert_eq!(a.incorrect_ratio, b.incorrect_ratio, "t={}", a.threshold);
        }
    }
    // And the outcome is actually useful, not just consistent.
    assert!(clustered_at_incorrect(&via_ref.curve, 0.02) > 0.3);
}

#[test]
fn search_pipeline_identical_across_backends() {
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate("t", 32, 60, 80, 0.8, 0.2, 0, 0);

    let via_ref = SearchPipeline::new(cfg.clone())
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();
    for threads in [2usize, 8] {
        let via_par = SearchPipeline::new(cfg.clone())
            .run(&ds, &BackendDispatcher::parallel(threads))
            .unwrap();
        assert_eq!(via_par.identified, via_ref.identified);
        assert_eq!(via_par.correct, via_ref.correct);
        assert_eq!(via_par.identified_peptides, via_ref.identified_peptides);
        assert_eq!(via_par.ops.mvm_ops, via_ref.ops.mvm_ops);
        // Raw score pairs, not just the FDR aggregate, must match exactly.
        assert_eq!(via_par.pairs, via_ref.pairs);
    }
    assert!(via_ref.identified > 20, "identified {}", via_ref.identified);
}

#[test]
fn segmented_jobs_and_buffer_reuse_bit_identical() {
    // A segmented job over a borrowed panel must equal the gathered dense
    // job on every backend, with `mvm_scores_into` fully overwriting a
    // reused output buffer (no stale values survive between batches).
    let (nq, panel_rows, cp) = (37, 400, 256);
    let mut rng = Rng::new(0x5e9);
    let q = rand_packed(&mut rng, nq * cp, 3);
    let panel = rand_packed(&mut rng, panel_rows * cp, 3);
    let segs = vec![0..50, 120..121, 200..200, 250..400];
    let adc = AdcConfig::new(6, 512.0);
    let seg_job = MvmJob::segmented(&q, nq, &panel, &segs, cp, adc);

    let mut gathered = Vec::new();
    for s in &segs {
        gathered.extend_from_slice(&panel[s.start * cp..s.end * cp]);
    }
    let want = RefBackend
        .mvm_scores(&MvmJob::new(&q, nq, &gathered, seg_job.nr, cp, adc))
        .unwrap();

    let mut out = vec![f32::NAN; nq * seg_job.nr];
    for threads in [1usize, 2, 8] {
        out.fill(f32::NAN);
        ParallelBackend::new(threads)
            .mvm_scores_into(&seg_job, &mut out)
            .unwrap();
        assert_eq!(out, want, "threads={threads}");
    }
    let mut ops = OpCounts::default();
    out.fill(f32::NAN);
    BackendDispatcher::reference()
        .execute_into(&seg_job, &mut out, &mut ops)
        .unwrap();
    assert_eq!(out, want);
    assert_eq!(ops.mvm_ops, seg_job.bank_ops());
}

#[test]
fn empty_and_degenerate_jobs() {
    let adc = AdcConfig::ideal();
    // No queries.
    let g = vec![1.0f32; 4 * 128];
    let job = MvmJob::new(&[], 0, &g, 4, 128, adc);
    assert_eq!(RefBackend.mvm_scores(&job).unwrap().len(), 0);
    assert_eq!(ParallelBackend::new(8).mvm_scores(&job).unwrap().len(), 0);
    // No refs.
    let q = vec![1.0f32; 2 * 128];
    let job = MvmJob::new(&q, 2, &[], 0, 128, adc);
    assert_eq!(RefBackend.mvm_scores(&job).unwrap().len(), 0);
    assert_eq!(ParallelBackend::new(8).mvm_scores(&job).unwrap().len(), 0);
    assert_eq!(job.bank_ops(), 0);
}
