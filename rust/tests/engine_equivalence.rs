//! SearchEngine-vs-SearchPipeline equivalence (the program-once/query-many
//! serving contract): serving the query set in 1, 2, or 7 uneven batches
//! through a persistent [`SearchEngine`] is bit-identical to the one-shot
//! [`SearchPipeline::run`] — same per-query score pairs, same accepted
//! queries, same total op counts — while the library's encode+program work
//! is charged exactly once, on the engine, regardless of batch count.
//!
//! The second half covers the shard layer's contract: a
//! [`ShardedSearchEngine`] over `k` shards of `B` banks each — programming
//! noise chained across shards, queries encoded once, per-query bests
//! merged in shard order, ops charged from merged group candidate counts —
//! is bit-identical to one monolithic engine with `k * B` banks, for every
//! shard count and batch split, including shard ranges that straddle the
//! target/decoy boundary.

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{BatchOutcome, SearchEngine, SearchPipeline, ShardedSearchEngine};
use specpcm::ms::{SearchDataset, Spectrum};

fn cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    }
}

fn serve(
    engine: &SearchEngine,
    queries: &[&Spectrum],
    sizes: &[usize],
    backend: &BackendDispatcher,
) -> Vec<BatchOutcome> {
    assert_eq!(sizes.iter().sum::<usize>(), queries.len());
    let mut outcomes = Vec::with_capacity(sizes.len());
    let mut start = 0;
    for &s in sizes {
        outcomes.push(engine.search_batch(&queries[start..start + s], backend).unwrap());
        start += s;
    }
    outcomes
}

#[test]
fn batched_serving_matches_one_shot_bit_identically() {
    let ds = SearchDataset::generate("t", 11, 60, 80, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();

    let one_shot = SearchPipeline::new(cfg()).run(&ds, &be).unwrap();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let splits: [&[usize]; 3] = [&[80], &[40, 40], &[11, 7, 23, 5, 19, 9, 6]];
    for sizes in splits {
        let outcomes = serve(&engine, &queries, sizes, &be);
        let out = engine.finalize(&queries, &outcomes).unwrap();

        // Bit-identical serving results.
        assert_eq!(out.pairs, one_shot.pairs, "split {sizes:?}");
        assert_eq!(out.fdr.accepted, one_shot.fdr.accepted, "split {sizes:?}");
        assert_eq!(out.fdr.threshold, one_shot.fdr.threshold);
        assert_eq!(out.identified, one_shot.identified);
        assert_eq!(out.correct, one_shot.correct);
        assert_eq!(out.identified_peptides, one_shot.identified_peptides);

        // Identical totals: bank MVM ops are linear in batched queries and
        // programming is one-time, so any split sums to the one-shot count.
        assert_eq!(out.ops.mvm_ops, one_shot.ops.mvm_ops, "split {sizes:?}");
        assert_eq!(out.ops.program_rounds, one_shot.ops.program_rounds);
        assert_eq!(out.ops.verify_rounds, one_shot.ops.verify_rounds);
        assert_eq!(out.ops.encode_spectra, one_shot.ops.encode_spectra);
        assert_eq!(out.ops.pack_elements, one_shot.ops.pack_elements);
        assert_eq!(out.ops.merge_elements, one_shot.ops.merge_elements);
        assert_eq!(out.report.total_j(), one_shot.report.total_j());

        // The library's programming is charged exactly once, on the
        // engine's one-time counters — never on a marginal batch.
        for b in &outcomes {
            assert_eq!(b.ops.program_rounds, 0);
            assert_eq!(b.ops.verify_rounds, 0);
        }
        assert_eq!(
            engine.program_ops().program_rounds,
            one_shot.ops.program_rounds
        );
        assert_eq!(
            engine.program_ops().encode_spectra,
            (ds.library.len() + ds.decoys.len()) as u64
        );
    }

    // Sanity: the workload actually identifies something.
    assert!(one_shot.identified > 20, "identified {}", one_shot.identified);
}

#[test]
fn marginal_batch_reports_exclude_programming_energy() {
    let ds = SearchDataset::generate("t", 12, 40, 30, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let batch = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(batch.report.program_j, 0.0);
    assert_eq!(batch.report.verify_j, 0.0);
    assert!(batch.report.mvm_j > 0.0);
    assert!(engine.program_report().program_j > 0.0);

    // One-time + marginal folds to the one-shot total.
    let out = engine.finalize(&queries, &[batch.clone()]).unwrap();
    let folded = engine.program_report().total_j() + batch.report.total_j();
    assert!(
        (out.report.total_j() - folded).abs() < 1e-15,
        "{} vs {}",
        out.report.total_j(),
        folded
    );
}

#[test]
fn over_capacity_library_is_a_typed_error() {
    // 6 banks hold exactly one 6-segment (D=2048, n=3) bank group: 128 row
    // slots. A 100-target library needs 200 rows (targets + decoys).
    let cfg = SpecPcmConfig {
        num_banks: 6,
        ..cfg()
    };
    let ds = SearchDataset::generate("t", 13, 100, 4, 0.8, 0.2, 0, 0);
    let err = match SearchEngine::program(cfg, &ds, &BackendDispatcher::reference()) {
        Ok(_) => panic!("200-row library on 128 slots must not program"),
        Err(e) => e,
    };
    let msg = err.to_string();
    assert!(msg.contains("exceeds"), "unexpected error: {msg}");
    assert!(msg.contains("128"), "capacity in message: {msg}");

    // The same library fits once the banks are doubled.
    let cfg_fits = SpecPcmConfig {
        num_banks: 12,
        ..self::cfg()
    };
    assert!(SearchEngine::program(cfg_fits, &ds, &BackendDispatcher::reference()).is_ok());
}

#[test]
fn finalize_rejects_mismatched_query_count() {
    let ds = SearchDataset::generate("t", 14, 20, 10, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let batch = engine.search_batch(&queries[..5], &be).unwrap();
    assert!(engine.finalize(&queries, &[batch]).is_err());
}

// ---------------------------------------------------------------------------
// Shard layer
// ---------------------------------------------------------------------------

/// 36 banks at D=2048 n=3 (6 segments) = 6 bank groups x 128 = 768 slots.
const UNION_BANKS: usize = 36;

#[test]
fn sharded_matches_monolithic_across_shard_counts_and_batch_splits() {
    // 120 targets + 120 decoys = 240 reference rows, 60 queries.
    let ds = SearchDataset::generate("t", 11, 120, 60, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();

    // Monolithic oracle: one engine owning the whole union bank pool.
    let mono_cfg = SpecPcmConfig {
        num_banks: UNION_BANKS,
        ..cfg()
    };
    let mono = SearchEngine::program(mono_cfg, &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let mono_batch = mono.search_batch(&queries, &be).unwrap();
    let mono_out = mono.finalize(&queries, &[mono_batch.clone()]).unwrap();

    for shards in [1usize, 2, 3] {
        // Split the same pool: k shards of 36/k banks each.
        let shard_cfg = SpecPcmConfig {
            num_banks: UNION_BANKS / shards,
            ..cfg()
        };
        let engine = ShardedSearchEngine::program(shard_cfg, &ds, &be, shards).unwrap();
        assert_eq!(engine.n_shards(), shards);
        assert_eq!(engine.n_refs(), 240);
        assert_eq!(engine.total_banks(), UNION_BANKS);

        // One-time programming: the chained noise RNG reproduces the
        // monolithic pulse trajectory row for row, so op counts (which
        // depend on write-verify convergence draws) match exactly.
        assert_eq!(engine.program_ops(), mono.program_ops(), "{shards} shards");

        // Single fan-out batch: results, ops and energy all bit-identical.
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs, mono_batch.pairs, "{shards} shards");
        assert_eq!(batch.matched, mono_batch.matched, "{shards} shards");
        assert_eq!(batch.ops, mono_batch.ops, "{shards} shards");
        assert_eq!(batch.report.total_j(), mono_batch.report.total_j());
        // Queries encode once at the shard layer, never per shard.
        assert_eq!(batch.ops.encode_spectra, queries.len() as u64);
        assert_eq!(batch.cache.misses + batch.cache.hits, queries.len() as u64);

        // Uneven batch splits fold to the same summary.
        engine.clear_query_cache();
        let splits: [&[usize]; 2] = [&[60], &[13, 7, 23, 17]];
        for sizes in splits {
            let mut outcomes = Vec::new();
            let mut start = 0;
            for &s in sizes {
                outcomes.push(engine.search_batch(&queries[start..start + s], &be).unwrap());
                start += s;
            }
            for b in &outcomes {
                assert_eq!(b.ops.program_rounds, 0);
                assert_eq!(b.ops.verify_rounds, 0);
            }
            let out = engine.finalize(&queries, &outcomes).unwrap();
            assert_eq!(out.pairs, mono_out.pairs, "{shards} shards, split {sizes:?}");
            assert_eq!(out.fdr.accepted, mono_out.fdr.accepted);
            assert_eq!(out.fdr.threshold, mono_out.fdr.threshold);
            assert_eq!(out.identified, mono_out.identified);
            assert_eq!(out.correct, mono_out.correct);
            assert_eq!(out.identified_peptides, mono_out.identified_peptides);
            assert_eq!(out.ops, mono_out.ops, "{shards} shards, split {sizes:?}");
            assert_eq!(out.report.total_j(), mono_out.report.total_j());
        }
    }
}

#[test]
fn shard_boundary_inside_decoy_block_is_partition_safe() {
    // 3 shards over 120 + 120 rows: ranges [0, 80), [80, 160), [160, 240)
    // — shard 1 straddles the target/decoy boundary at row 120.
    let ds = SearchDataset::generate("t", 11, 120, 40, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let shard_cfg = SpecPcmConfig {
        num_banks: UNION_BANKS / 3,
        ..cfg()
    };
    let engine = ShardedSearchEngine::program(shard_cfg, &ds, &be, 3).unwrap();
    let plan = engine.plan();
    assert_eq!(plan.target_range(1), 80..120);
    assert_eq!(plan.decoy_range(1), 0..40);
    assert_eq!(engine.shard(1).n_targets(), 40);
    assert_eq!(engine.shard(1).n_refs(), 80);
    assert_eq!(engine.shard(2).n_targets(), 0, "pure-decoy shard");

    // Decoy classification stays correct across the split: identical
    // per-query (target, decoy) pairs to the monolithic engine.
    let mono_cfg = SpecPcmConfig {
        num_banks: UNION_BANKS,
        ..cfg()
    };
    let mono = SearchEngine::program(mono_cfg, &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let sharded = engine.search_batch(&queries, &be).unwrap();
    let monolithic = mono.search_batch(&queries, &be).unwrap();
    assert_eq!(sharded.pairs, monolithic.pairs);
    assert_eq!(sharded.matched, monolithic.matched);
}

#[test]
fn over_capacity_library_completes_via_auto_sharding() {
    // 240 rows vs 128 slots per engine (6 banks): monolithic fails,
    // auto-sharding resolves to 2 engines and matches a monolithic
    // engine with the union pool (12 banks, 256 slots).
    let ds = SearchDataset::generate("t", 13, 120, 30, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let small = SpecPcmConfig {
        num_banks: 6,
        ..cfg()
    };
    assert!(SearchEngine::program(small.clone(), &ds, &be).is_err());

    let engine = ShardedSearchEngine::program(small, &ds, &be, 0).unwrap();
    assert_eq!(engine.n_shards(), 2);

    let mono_cfg = SpecPcmConfig {
        num_banks: 12,
        ..cfg()
    };
    let mono = SearchEngine::program(mono_cfg, &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let sharded_out = {
        let outcomes = engine.serve_chunked(&queries, 3, &be).unwrap();
        engine.finalize(&queries, &outcomes).unwrap()
    };
    let mono_out = {
        let outcomes = mono.serve_chunked(&queries, 3, &be).unwrap();
        mono.finalize(&queries, &outcomes).unwrap()
    };
    assert_eq!(sharded_out.pairs, mono_out.pairs);
    assert_eq!(sharded_out.fdr.accepted, mono_out.fdr.accepted);
    assert_eq!(sharded_out.ops, mono_out.ops, "total ASIC work unchanged by sharding");
    assert_eq!(sharded_out.report.total_j(), mono_out.report.total_j());

    // Sanity: something is actually identified on this workload.
    assert!(sharded_out.identified > 0);
}
