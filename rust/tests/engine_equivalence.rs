//! SearchEngine-vs-SearchPipeline equivalence (the program-once/query-many
//! serving contract): serving the query set in 1, 2, or 7 uneven batches
//! through a persistent [`SearchEngine`] is bit-identical to the one-shot
//! [`SearchPipeline::run`] — same per-query score pairs, same accepted
//! queries, same total op counts — while the library's encode+program work
//! is charged exactly once, on the engine, regardless of batch count.

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{BatchOutcome, SearchEngine, SearchPipeline};
use specpcm::ms::{SearchDataset, Spectrum};

fn cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    }
}

fn serve(
    engine: &SearchEngine,
    queries: &[&Spectrum],
    sizes: &[usize],
    backend: &BackendDispatcher,
) -> Vec<BatchOutcome> {
    assert_eq!(sizes.iter().sum::<usize>(), queries.len());
    let mut outcomes = Vec::with_capacity(sizes.len());
    let mut start = 0;
    for &s in sizes {
        outcomes.push(engine.search_batch(&queries[start..start + s], backend).unwrap());
        start += s;
    }
    outcomes
}

#[test]
fn batched_serving_matches_one_shot_bit_identically() {
    let ds = SearchDataset::generate("t", 11, 60, 80, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();

    let one_shot = SearchPipeline::new(cfg()).run(&ds, &be).unwrap();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let splits: [&[usize]; 3] = [&[80], &[40, 40], &[11, 7, 23, 5, 19, 9, 6]];
    for sizes in splits {
        let outcomes = serve(&engine, &queries, sizes, &be);
        let out = engine.finalize(&queries, &outcomes).unwrap();

        // Bit-identical serving results.
        assert_eq!(out.pairs, one_shot.pairs, "split {sizes:?}");
        assert_eq!(out.fdr.accepted, one_shot.fdr.accepted, "split {sizes:?}");
        assert_eq!(out.fdr.threshold, one_shot.fdr.threshold);
        assert_eq!(out.identified, one_shot.identified);
        assert_eq!(out.correct, one_shot.correct);
        assert_eq!(out.identified_peptides, one_shot.identified_peptides);

        // Identical totals: bank MVM ops are linear in batched queries and
        // programming is one-time, so any split sums to the one-shot count.
        assert_eq!(out.ops.mvm_ops, one_shot.ops.mvm_ops, "split {sizes:?}");
        assert_eq!(out.ops.program_rounds, one_shot.ops.program_rounds);
        assert_eq!(out.ops.verify_rounds, one_shot.ops.verify_rounds);
        assert_eq!(out.ops.encode_spectra, one_shot.ops.encode_spectra);
        assert_eq!(out.ops.pack_elements, one_shot.ops.pack_elements);
        assert_eq!(out.ops.merge_elements, one_shot.ops.merge_elements);
        assert_eq!(out.report.total_j(), one_shot.report.total_j());

        // The library's programming is charged exactly once, on the
        // engine's one-time counters — never on a marginal batch.
        for b in &outcomes {
            assert_eq!(b.ops.program_rounds, 0);
            assert_eq!(b.ops.verify_rounds, 0);
        }
        assert_eq!(
            engine.program_ops().program_rounds,
            one_shot.ops.program_rounds
        );
        assert_eq!(
            engine.program_ops().encode_spectra,
            (ds.library.len() + ds.decoys.len()) as u64
        );
    }

    // Sanity: the workload actually identifies something.
    assert!(one_shot.identified > 20, "identified {}", one_shot.identified);
}

#[test]
fn marginal_batch_reports_exclude_programming_energy() {
    let ds = SearchDataset::generate("t", 12, 40, 30, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let batch = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(batch.report.program_j, 0.0);
    assert_eq!(batch.report.verify_j, 0.0);
    assert!(batch.report.mvm_j > 0.0);
    assert!(engine.program_report().program_j > 0.0);

    // One-time + marginal folds to the one-shot total.
    let out = engine.finalize(&queries, &[batch.clone()]).unwrap();
    let folded = engine.program_report().total_j() + batch.report.total_j();
    assert!(
        (out.report.total_j() - folded).abs() < 1e-15,
        "{} vs {}",
        out.report.total_j(),
        folded
    );
}

#[test]
fn over_capacity_library_is_a_typed_error() {
    // 6 banks hold exactly one 6-segment (D=2048, n=3) bank group: 128 row
    // slots. A 100-target library needs 200 rows (targets + decoys).
    let cfg = SpecPcmConfig {
        num_banks: 6,
        ..cfg()
    };
    let ds = SearchDataset::generate("t", 13, 100, 4, 0.8, 0.2, 0, 0);
    let err = match SearchEngine::program(cfg, &ds, &BackendDispatcher::reference()) {
        Ok(_) => panic!("200-row library on 128 slots must not program"),
        Err(e) => e,
    };
    let msg = err.to_string();
    assert!(msg.contains("exceeds"), "unexpected error: {msg}");
    assert!(msg.contains("128"), "capacity in message: {msg}");

    // The same library fits once the banks are doubled.
    let cfg_fits = SpecPcmConfig {
        num_banks: 12,
        ..self::cfg()
    };
    assert!(SearchEngine::program(cfg_fits, &ds, &BackendDispatcher::reference()).is_ok());
}

#[test]
fn finalize_rejects_mismatched_query_count() {
    let ds = SearchDataset::generate("t", 14, 20, 10, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let batch = engine.search_batch(&queries[..5], &be).unwrap();
    assert!(engine.finalize(&queries, &[batch]).is_err());
}
